"""Shared benchmark utilities."""

from __future__ import annotations

import time


def timed(fn, *args, repeats: int = 1, warmup: int = 0, **kw):
    """Return (result, best_seconds)."""
    for _ in range(warmup):
        fn(*args, **kw)
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best


def csv_row(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"
