"""§Perf evidence for the mining kernel's structural optimizations.

Measures, on real zone batches (not ShapeDtypeStructs):

1. **live-window block skipping** (kernels/zone_scan): the fraction of
   (candidate-block x edge-block) grid cells whose index/time tests skip
   them — the work reduction the 2-D kernel grid buys over the dense
   O(E^2) sweep of the paper-faithful formulation;
2. **adaptive zoning** (core/tzp e_cap): padded-batch size with and without
   the density-adaptive zone shrinking on a bursty stream — zone padding is
   wasted vector work, so the ratio is a direct work saving;
3. measured **unique-code populations** per device-shard, validating the
   hierarchical-merge out_cap used in the dry-run variants.
"""

from __future__ import annotations

import numpy as np

from repro.core import tzp
from repro.data import synthetic_graphs as sg

from .common import csv_row


def _skip_fraction(batch, delta, l_max, c_blk=256, e_blk=256):
    """Fraction of kernel grid cells skipped by the live-window tests."""
    e = batch.e_cap
    n_c = -(-e // c_blk)
    n_e = -(-e // e_blk)
    zi = np.flatnonzero(batch.valid.any(axis=1))
    t = batch.t[zi]                                     # [Z, E]
    c_hi = np.minimum((np.arange(n_c) + 1) * c_blk, e) - 1
    e_lo = np.minimum(np.arange(n_e) * e_blk, e - 1)
    index_live = (e_lo[None, :] + e_blk - 1) >= (
        np.arange(n_c)[:, None] * c_blk)                # [C, E]
    time_live = (
        t[:, e_lo][:, None, :] <= t[:, c_hi][:, :, None] + l_max * delta
    )                                                    # [Z, C, E]
    live = (index_live[None] & time_live).sum()
    total = len(zi) * n_c * n_e
    return 1.0 - live / max(total, 1)


def run() -> list[str]:
    rows = []
    delta, l_max = 90, 5

    # 1) live-window skipping on two regimes (bursts big enough that a
    #    zone spans many kernel blocks)
    for name, gen in (("bursty", sg.bursty_stream(
                          30_000, 300, burst_size=2_000, burst_span=900,
                          gap_span=20_000, seed=2)),
                      ("poisson", sg.poisson_stream(20_000, 500, rate=0.5,
                                                    seed=2))):
        plan = tzp.plan_zones(gen, delta=delta, l_max=l_max, omega=20)
        batch = tzp.build_zone_batch(gen, plan)
        frac = _skip_fraction(batch, delta, l_max)
        rows.append(csv_row(
            f"perf_mining/skip_fraction/{name}", 0.0,
            f"omega=20;skipped={frac:.1%};work_reduction="
            f"{1/(1-frac) if frac < 1 else 0:.1f}x",
        ))

    # 2) adaptive zoning on a heavy-burst stream
    # bursts longer than 2*L_b so the adaptive planner can split them
    g = sg.bursty_stream(30_000, 200, burst_size=3_000, burst_span=5_000,
                         gap_span=36_000, seed=4)
    plan_fixed = tzp.plan_zones(g, delta=delta, l_max=l_max, omega=20)
    b_fixed = tzp.build_zone_batch(g, plan_fixed)
    plan_adapt = tzp.plan_zones(g, delta=delta, l_max=l_max, omega=20,
                                e_cap=768)
    b_adapt = tzp.build_zone_batch(g, plan_adapt, e_cap=768)
    work_fixed = b_fixed.n_zones * b_fixed.e_cap ** 2
    work_adapt = b_adapt.n_zones * b_adapt.e_cap ** 2
    rows.append(csv_row(
        "perf_mining/adaptive_zoning", 0.0,
        f"fixed=({b_fixed.n_zones}z x cap{b_fixed.e_cap});"
        f"adaptive=({b_adapt.n_zones}z x cap{b_adapt.e_cap});"
        f"padded_sweep_work_reduction={work_fixed/work_adapt:.1f}x;"
        f"overflow={b_adapt.overflow}",
    ))

    # 3) unique codes per shard (out_cap validation)
    from repro.core import discover, from_edges

    g_small = from_edges(g.u[:8000], g.v[:8000], g.t[:8000])
    res = discover(g_small, delta=delta, l_max=l_max, omega=8, e_cap=1024)
    rows.append(csv_row(
        "perf_mining/unique_codes", 0.0,
        f"global_unique={len(res.counts)};"
        f"out_cap_16384_headroom={16384 / max(len(res.counts), 1):.0f}x",
    ))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
