"""§Perf evidence for the mining kernel's structural optimizations.

Measures, on real zone batches (not ShapeDtypeStructs):

1. **live-window block skipping** (kernels/zone_scan): the fraction of
   (candidate-block x edge-block) grid cells whose index/time tests skip
   them — the work reduction the 2-D kernel grid buys over the dense
   O(E^2) sweep of the paper-faithful formulation;
2. **adaptive zoning** (core/tzp e_cap): padded-batch size with and without
   the density-adaptive zone shrinking on a bursty stream — zone padding is
   wasted vector work, so the ratio is a direct work saving;
3. measured **unique-code populations** per device-shard, validating the
   hierarchical-merge out_cap used in the dry-run variants;
4. **hierarchical chunked aggregation** (core/executor agg modes): measured
   throughput of legacy whole-batch vs hierarchical fold vs the pipelined
   runner on one batch, plus the planner's peak-memory model showing the
   zone-count ceiling move — at a fixed budget the legacy O(Z*C) flatten
   caps Z, while the hierarchical fold's peak is Z-independent, and the
   benchmark *runs* the fold at a zone count beyond the legacy cap;
5. **engine compiled-plan reuse** (core/engine): cold vs warm
   ``PTMTEngine.discover`` on the same-shaped workload.  The warm call must
   register a compile-cache hit and be measurably faster — this is the
   acceptance gate for the session-engine API and is re-asserted by CI on
   the smoke JSON;
6. **ragged zone layout** (core/tzp ``ZoneBatchLayout``): dense vs
   size-bucketed padding ratio, per-bucket occupancy, and measured
   edges/sec on a bursty corpus whose zone sizes span several power-of-two
   buckets, plus proof that the engine's per-bucket compile cache still
   registers hits under the bucketed layout.  CI asserts
   ``padding_ratio_bucketed < padding_ratio_dense`` on the smoke JSON;
7. **fused single-launch scan** (kernels/zone_scan ``fused_zone_scan_flat``
   + executor ``run_fused``): per-bucket dispatch loop vs ONE bucket-native
   ``pallas_call`` over the concatenated slot stream with the Phase-2
   signed fold fused on-device — only the bounded ``CodeCounts`` table and
   a spill flag return to host.  CI asserts the fused path reports exactly
   one launch per mine and edges/sec no worse than per-bucket;
8. **observability overhead** (repro.obs): the no-op span micro-bench ×
   spans-per-mine projection must stay under 2% of a disabled-mode fused
   mine (asserted), and a live registry snapshot of the instrumented run
   is recorded under ``observability.metrics_sample``.

``run_json`` additionally returns a structured payload for
``benchmarks/run.py --out-json`` (edges/sec + peak-memory estimates + the
warm/cold engine timings — the ``BENCH_mining.json`` perf trajectory).
"""

from __future__ import annotations

import time

import numpy as np

import repro.obs as obs_mod
from repro.core import (
    MiningConfig,
    MiningExecutor,
    PTMTEngine,
    planner,
    transitions,
    tzp,
)
from repro.data import synthetic_graphs as sg

from .common import csv_row, timed

DELTA, L_MAX = 90, 5


def _skip_fraction(batch, delta, l_max, c_blk=256, e_blk=256):
    """Fraction of kernel grid cells skipped by the live-window tests."""
    e = batch.e_cap
    n_c = -(-e // c_blk)
    n_e = -(-e // e_blk)
    zi = np.flatnonzero(batch.valid.any(axis=1))
    t = batch.t[zi]                                     # [Z, E]
    c_hi = np.minimum((np.arange(n_c) + 1) * c_blk, e) - 1
    e_lo = np.minimum(np.arange(n_e) * e_blk, e - 1)
    index_live = (e_lo[None, :] + e_blk - 1) >= (
        np.arange(n_c)[:, None] * c_blk)                # [C, E]
    time_live = (
        t[:, e_lo][:, None, :] <= t[:, c_hi][:, :, None] + l_max * delta
    )                                                    # [Z, C, E]
    live = (index_live[None] & time_live).sum()
    total = len(zi) * n_c * n_e
    return 1.0 - live / max(total, 1)


def _legacy_z_ceiling(budget_bytes, e_cap, l_max, zone_chunk) -> int:
    """Largest zone count whose legacy whole-batch peak fits the budget."""
    lo, hi = 0, 1 << 30
    while lo < hi:
        mid = (lo + hi + 1) // 2
        peak = planner.legacy_peak_bytes(mid, e_cap, l_max,
                                         zone_chunk=zone_chunk)
        if peak <= budget_bytes:
            lo = mid
        else:
            hi = mid - 1
    return lo


def _hierarchical_section(smoke: bool):
    """Throughput of the three agg modes + the memory-ceiling move."""
    n_edges = 4_000 if smoke else 24_000
    g = sg.poisson_stream(n_edges, 300, rate=0.5, seed=7)
    # small-omega, e_cap-split zones: many modest zones, the regime where
    # the O(Z*C) whole-batch flatten is the binding constraint.  The cap
    # stays above the adaptive floor's edge population (~2*L_b*rate) so no
    # edges are dropped and counts remain exact.
    cap = 512 if smoke else 1024
    plan = tzp.plan_zones(g, delta=DELTA, l_max=L_MAX, omega=2, e_cap=cap)
    zc = 4 if smoke else 8
    batch = tzp.build_zone_batch(g, plan, e_cap=cap, pad_zones_to=zc)

    modes = {}
    counts_seen = {}
    for agg in ("legacy", "hierarchical", "pipelined"):
        ex = MiningExecutor(delta=DELTA, l_max=L_MAX, zone_chunk=zc, agg=agg)
        run = lambda: transitions.device_counts_to_dict(ex.run(batch))
        counts, secs = timed(run, warmup=1, repeats=1 if smoke else 2)
        counts_seen[agg] = counts
        modes[agg] = {
            "seconds": secs,
            "edges_per_s": g.n_edges / secs if secs else 0.0,
        }
    assert counts_seen["hierarchical"] == counts_seen["legacy"] \
        == counts_seen["pipelined"], "agg modes disagree — differential bug"

    merge_cap = planner.default_merge_cap(zc, batch.e_cap)
    hier_peak = planner.hierarchical_peak_bytes(
        zc, batch.e_cap, L_MAX, merge_cap=merge_cap)
    # the budget IS the fold's own peak: at the memory hierarchical
    # aggregation needs, how many zones could the legacy flatten hold?
    budget = hier_peak
    z_legacy_max = _legacy_z_ceiling(budget, batch.e_cap, L_MAX, zc)
    legacy_peak_at_run = planner.legacy_peak_bytes(
        batch.n_zones, batch.e_cap, L_MAX, zone_chunk=zc)
    ceiling = {
        "budget_mb": budget / 2**20,
        "e_cap": batch.e_cap,
        "zone_chunk": zc,
        "merge_cap": merge_cap,
        "hier_peak_mb": hier_peak / 2**20,
        "legacy_peak_mb_at_run": legacy_peak_at_run / 2**20,
        "z_max_legacy_at_budget": z_legacy_max,
        "z_run": batch.n_zones,
        "ceiling_moved": batch.n_zones > z_legacy_max
        and hier_peak <= budget,
        "motif_types": len(counts_seen["hierarchical"]),
    }
    throughput = {
        "edges": g.n_edges,
        "n_zones": batch.n_zones,
        "e_cap": batch.e_cap,
        "zone_chunk": zc,
        "modes": modes,
    }

    rows = [
        csv_row(
            f"perf_mining/agg_{agg}", m["seconds"],
            f"edges_per_s={m['edges_per_s']:.0f};zones={batch.n_zones};"
            f"zone_chunk={zc}",
        )
        for agg, m in modes.items()
    ]
    rows.append(csv_row(
        "perf_mining/memory_ceiling", 0.0,
        f"budget={ceiling['budget_mb']:.1f}MB;"
        f"legacy_z_max={z_legacy_max};hier_z_run={batch.n_zones};"
        f"hier_peak={ceiling['hier_peak_mb']:.1f}MB;"
        f"legacy_peak_at_run={ceiling['legacy_peak_mb_at_run']:.1f}MB;"
        f"ceiling_moved={ceiling['ceiling_moved']}",
    ))
    return rows, {"throughput": throughput, "memory_ceiling": ceiling}


def _zone_layout_section(smoke: bool):
    """Dense vs size-bucketed layout on a bursty (skewed-zone) corpus."""
    from repro.core import MiningExecutor as _Ex

    n_edges = 2_500 if smoke else 20_000
    g = sg.bursty_stream(n_edges, 250, burst_size=120, burst_span=200,
                         gap_span=30_000, seed=13)
    plan = tzp.plan_zones(g, delta=DELTA, l_max=L_MAX, omega=2)
    layouts = {
        kind: tzp.build_zone_layout(g, plan, layout=kind)
        for kind in ("dense", "bucketed")
    }
    assert layouts["bucketed"].n_buckets >= 3, \
        "bursty corpus must span >= 3 buckets"

    modes = {}
    counts_seen = {}
    for kind, lay in layouts.items():
        ex = _Ex(delta=DELTA, l_max=L_MAX)
        run = lambda lay=lay, ex=ex: transitions.device_counts_to_dict(
            ex.run_layout(lay).counts)
        counts, secs = timed(run, warmup=1, repeats=1 if smoke else 2)
        counts_seen[kind] = counts
        modes[kind] = {
            "seconds": secs,
            "edges_per_s": g.n_edges / secs if secs else 0.0,
            "padding_ratio": lay.padding_ratio,
            "padded_slots": lay.padded_slots,
            "sweep_slots": lay.sweep_slots,
        }
    assert counts_seen["bucketed"] == counts_seen["dense"], \
        "layouts disagree — differential bug"

    # the per-bucket compile cache must keep registering hits: a second
    # same-graph discover dispatches every bucket to a cached executable
    # (and skips host-side planning via the zone-plan cache)
    engine = PTMTEngine(MiningConfig(delta=DELTA, l_max=L_MAX, omega=2,
                                     zone_layout="bucketed"))
    engine.discover(g)
    engine.discover(g)
    payload = {
        "edges": g.n_edges,
        "n_zones": plan.n_zones,
        "modes": modes,
        "padding_ratio_dense": modes["dense"]["padding_ratio"],
        "padding_ratio_bucketed": modes["bucketed"]["padding_ratio"],
        "buckets": layouts["bucketed"].summary()["buckets"],
        "compile_cache_hits_bucketed": engine.stats.compile_cache_hits,
        "plan_cache_hits": engine.stats.plan_cache_hits,
        "speedup_bucketed_vs_dense": (
            modes["dense"]["seconds"] / modes["bucketed"]["seconds"]
            if modes["bucketed"]["seconds"] else 0.0),
    }
    rows = [
        csv_row(
            f"perf_mining/zone_layout_{kind}", m["seconds"],
            f"edges_per_s={m['edges_per_s']:.0f};"
            f"padding_ratio={m['padding_ratio']:.3f};"
            f"sweep_slots={m['sweep_slots']}",
        )
        for kind, m in modes.items()
    ]
    rows.append(csv_row(
        "perf_mining/zone_layout", 0.0,
        f"buckets={len(payload['buckets'])};"
        f"pad_dense={payload['padding_ratio_dense']:.3f};"
        f"pad_bucketed={payload['padding_ratio_bucketed']:.3f};"
        f"speedup={payload['speedup_bucketed_vs_dense']:.2f}x;"
        f"bucketed_cache_hits={payload['compile_cache_hits_bucketed']}",
    ))
    return rows, payload


def _fused_section(smoke: bool):
    """Fused single-launch scan vs the per-bucket dispatch loop.

    Same bursty corpus and bucketed layout; the fused path concatenates
    every bucket into one flat slot stream and runs ONE launch with the
    Phase-2 fold on-device, so candidate codes never round-trip to host.
    Three modes: ``per_bucket`` (one launch per bucket), ``fused`` (the
    ``"auto"``-dispatched lowering — the compiled xla formulation on CPU
    hosts, the Pallas kernel where it compiles) and ``fused_interpret``
    (the Pallas lowering pinned via ``fused_backend="pallas"`` — the old
    interpret-mode baseline on CPU).  Counts must be identical across all
    three.  Launch accounting comes from the executor's metrics registry
    (``repro_mining_launches_total{path=...}`` counter deltas per mine
    plus the ``repro_mining_fused_*`` gauges) — the same surface a scrape
    sees — and one ``RunOutcome.stats`` dict is read to assert the two
    surfaces agree.  CI asserts the fused path reports exactly one launch
    per mine, resolves to the compiled ``fused_xla`` path on CPU, and is
    no slower than the interpret baseline.
    """
    n_edges = 2_500 if smoke else 20_000
    g = sg.bursty_stream(n_edges, 250, burst_size=120, burst_span=200,
                         gap_span=30_000, seed=13)
    plan = tzp.plan_zones(g, delta=DELTA, l_max=L_MAX, omega=2)
    lay = tzp.build_zone_layout(g, plan, layout="bucketed")
    obs = obs_mod.enabled()
    ex_auto = MiningExecutor(delta=DELTA, l_max=L_MAX, backend="pallas",
                             obs=obs)
    ex_interp = MiningExecutor(delta=DELTA, l_max=L_MAX, backend="pallas",
                               fused_backend="pallas", obs=obs)

    repeats = 2 if smoke else 3
    modes = {}
    counts_seen = {}
    for name, ex, fused in (("per_bucket", ex_auto, False),
                            ("fused", ex_auto, True),
                            ("fused_interpret", ex_interp, True)):
        # probe run: compiles, and tells us which launch-counter label
        # this executor's dispatch actually lands on
        probe = ex.run_layout(lay, fused=fused).stats
        path = probe["path"]
        launch_counter = obs.metrics.counter("repro_mining_launches_total",
                                             path=path)
        c0 = launch_counter.value
        # the interpreter is orders of magnitude slower — one timed rep
        # keeps the full suite's wall time bounded
        reps = repeats if name != "fused_interpret" else (2 if smoke else 1)
        run = lambda ex=ex, fused=fused: transitions.device_counts_to_dict(
            ex.run_layout(lay, fused=fused).counts)
        counts, secs = timed(run, warmup=1, repeats=reps)
        counts_seen[name] = counts
        modes[name] = {
            "seconds": secs,
            "edges_per_s": g.n_edges / secs if secs else 0.0,
            "launches": (launch_counter.value - c0) // (1 + reps),
            "path": path,
            "backend": probe.get("backend", "pallas"),
        }
    assert counts_seen["fused"] == counts_seen["per_bucket"], \
        "fused != per-bucket — differential bug"
    assert counts_seen["fused"] == counts_seen["fused_interpret"], \
        "compiled fused != pallas fused — differential bug"
    assert modes["fused"]["launches"] == 1
    assert modes["fused_interpret"]["launches"] == 1

    gauge = lambda n: int(obs.metrics.gauge(n).value)
    spills = obs.metrics.find("repro_mining_spill_retries_total",
                              path=modes["fused"]["path"])
    # the registry mirrors the RunOutcome stats, never redefines them —
    # assert the two surfaces agree on the fused geometry
    lrs = ex_auto.run_layout(lay, fused=True).stats
    assert (lrs["path"], lrs["launches"]) == (modes["fused"]["path"], 1)
    assert lrs["merge_cap"] == gauge("repro_mining_fused_merge_cap")
    assert lrs["n_slots"] == gauge("repro_mining_fused_slots")

    payload = {
        "edges": g.n_edges,
        "n_buckets": lay.n_buckets,
        "modes": modes,
        "fused_path": modes["fused"]["path"],
        "fused_backend": modes["fused"]["backend"],
        "fused_bounds": lrs["bounds"],
        "launches_fused": modes["fused"]["launches"],
        "launches_per_bucket": modes["per_bucket"]["launches"],
        "edges_per_s_fused": modes["fused"]["edges_per_s"],
        "edges_per_s_fused_interpret":
            modes["fused_interpret"]["edges_per_s"],
        "edges_per_s_per_bucket": modes["per_bucket"]["edges_per_s"],
        "fold_chunk": gauge("repro_mining_fused_fold_chunk"),
        "merge_cap": gauge("repro_mining_fused_merge_cap"),
        "n_slots": gauge("repro_mining_fused_slots"),
        "sweep_slots": gauge("repro_mining_fused_sweep_slots"),
        # cumulative over the section's runs (counters only go up)
        "spill_retries": int(spills.value) if spills else 0,
        "speedup_fused_vs_per_bucket": (
            modes["per_bucket"]["seconds"] / modes["fused"]["seconds"]
            if modes["fused"]["seconds"] else 0.0),
        "speedup_fused_vs_interpret": (
            modes["fused_interpret"]["seconds"] / modes["fused"]["seconds"]
            if modes["fused"]["seconds"] else 0.0),
    }
    rows = [
        csv_row(
            f"perf_mining/scan_{name}", m["seconds"],
            f"edges_per_s={m['edges_per_s']:.0f};launches={m['launches']};"
            f"path={m['path']}",
        )
        for name, m in modes.items()
    ]
    rows.append(csv_row(
        "perf_mining/fused_launch", 0.0,
        f"launches=1_vs_{payload['launches_per_bucket']};"
        f"path={payload['fused_path']};"
        f"speedup_vs_per_bucket="
        f"{payload['speedup_fused_vs_per_bucket']:.2f}x;"
        f"speedup_vs_interpret="
        f"{payload['speedup_fused_vs_interpret']:.2f}x;"
        f"n_slots={payload['n_slots']};fold_chunk={payload['fold_chunk']}",
    ))
    return rows, payload


def _observability_section(smoke: bool):
    """Observability cost proof + a metrics-snapshot sample for the BENCH
    trajectory.

    Two claims land in ``BENCH_mining.json``:

    * **disabled-mode overhead on the fused path is < 2%** — asserted, not
      eyeballed.  The no-op span (what every instrumented call site pays
      when observability is off) is micro-benchmarked in a tight loop, its
      cost scaled by the number of spans one enabled fused mine actually
      emits, and that projection compared against the measured
      disabled-mode run time.  The projection is the right comparison: the
      raw enabled-vs-disabled wall delta is dominated by registry/tracer
      bookkeeping the disabled path never executes, while the projection
      isolates exactly the residue the NULL_OBS design leaves behind.
    * **metrics_sample** — the registry snapshot of the enabled mine, so
      the BENCH file carries the exact export schema downstream tooling
      parses.
    """
    n_edges = 2_500 if smoke else 20_000
    g = sg.bursty_stream(n_edges, 250, burst_size=120, burst_span=200,
                         gap_span=30_000, seed=13)
    plan = tzp.plan_zones(g, delta=DELTA, l_max=L_MAX, omega=2)
    lay = tzp.build_zone_layout(g, plan, layout="bucketed")

    # disabled-mode fused run: the default NULL_OBS executor
    ex_off = MiningExecutor(delta=DELTA, l_max=L_MAX, backend="pallas")
    run_off = lambda: transitions.device_counts_to_dict(
        ex_off.run_layout(lay, fused=True).counts)
    counts_off, secs_off = timed(run_off, warmup=1, repeats=2)

    # enabled run on the same workload: span census + snapshot sample
    obs = obs_mod.enabled()
    ex_on = MiningExecutor(delta=DELTA, l_max=L_MAX, backend="pallas",
                           obs=obs)
    run_on = lambda: transitions.device_counts_to_dict(
        ex_on.run_layout(lay, fused=True).counts)
    counts_on, secs_on = timed(run_on, warmup=1, repeats=2)
    assert counts_on == counts_off, "observability changed mining results"
    n_runs_on = 3  # warmup + repeats
    spans_per_run = -(-len(obs.tracer.events()) // n_runs_on)

    # no-op span micro-bench (per-span cost with observability off)
    iters = 20_000 if smoke else 50_000
    null_tracer = obs_mod.NULL_OBS.tracer
    t0 = time.perf_counter()
    for _ in range(iters):
        with null_tracer.span("noop"):
            pass
    noop_span_s = (time.perf_counter() - t0) / iters

    projected_s = spans_per_run * noop_span_s
    frac = projected_s / secs_off if secs_off else 0.0
    assert frac < 0.02, (
        f"disabled-mode span overhead projects to {frac:.2%} of a fused "
        f"mine ({spans_per_run} spans x {noop_span_s * 1e6:.2f}us vs "
        f"{secs_off:.3f}s) — observability must stay near-free when off")

    payload = {
        "edges": g.n_edges,
        "disabled_seconds": secs_off,
        "enabled_seconds": secs_on,
        "enabled_over_disabled": secs_on / secs_off if secs_off else 0.0,
        "spans_per_run": spans_per_run,
        "noop_span_us": noop_span_s * 1e6,
        "projected_disabled_overhead_fraction": frac,
        "overhead_bound": 0.02,
        "metrics_sample": obs.metrics.snapshot(),
    }
    row = csv_row(
        "perf_mining/observability", secs_on,
        f"disabled_s={secs_off:.3f};enabled_s={secs_on:.3f};"
        f"spans={spans_per_run};noop_span_us={payload['noop_span_us']:.2f};"
        f"projected_off_overhead={frac:.4%}",
    )
    return [row], payload


def _engine_reuse_section(smoke: bool):
    """Cold vs warm ``PTMTEngine.discover`` on one workload shape.

    Parameters are chosen to not collide with any other section's jit-cache
    key (distinct delta/l_max), so the cold call genuinely pays trace +
    compile even when the whole suite runs in one process.
    """
    g = sg.poisson_stream(1_500 if smoke else 8_000, 200, rate=0.5, seed=9)
    engine = PTMTEngine(MiningConfig(delta=75, l_max=4, omega=6,
                                     zone_chunk=4))

    t0 = time.perf_counter()
    cold_res = engine.discover(g)
    cold_s = time.perf_counter() - t0
    # min-of-N warm timing: the reuse property itself is proven
    # deterministically by the compile-cache counter below; the timing
    # only has to survive scheduler noise on a loaded CI runner
    warm_s = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        warm_res = engine.discover(g)
        warm_s = min(warm_s, time.perf_counter() - t0)

    assert warm_res.counts == cold_res.counts, "warm call changed counts"
    assert engine.stats.compile_cache_hits >= 3, \
        "same-shape discover calls did not register compile-cache hits"
    assert warm_s < cold_s, (
        f"warm engine call ({warm_s:.3f}s) not faster than cold "
        f"({cold_s:.3f}s) — compiled-plan reuse is broken")

    payload = {
        "edges": g.n_edges,
        "cold_seconds": cold_s,
        "warm_seconds": warm_s,
        "warm_runs": 3,
        "speedup": cold_s / warm_s if warm_s else 0.0,
        "compile_cache_hits": engine.stats.compile_cache_hits,
        "compile_cache_misses": engine.stats.compile_cache_misses,
    }
    row = csv_row(
        "perf_mining/engine_reuse", warm_s,
        f"cold_s={cold_s:.3f};warm_s={warm_s:.4f};"
        f"speedup={payload['speedup']:.2f}x;"
        f"hits={payload['compile_cache_hits']}",
    )
    return [row], payload


def run_json(smoke: bool = False):
    """Returns (csv rows, structured payload for BENCH_mining.json)."""
    rows = []
    payload = {"suite": "perf_mining", "smoke": smoke,
               "delta": DELTA, "l_max": L_MAX}
    delta, l_max = DELTA, L_MAX
    scale = 0.1 if smoke else 1.0

    # 1) live-window skipping on two regimes (bursts big enough that a
    #    zone spans many kernel blocks)
    for name, gen in (("bursty", sg.bursty_stream(
                          int(30_000 * scale), 300,
                          burst_size=int(2_000 * scale) or 100,
                          burst_span=900,
                          gap_span=20_000, seed=2)),
                      ("poisson", sg.poisson_stream(int(20_000 * scale), 500,
                                                    rate=0.5, seed=2))):
        plan = tzp.plan_zones(gen, delta=delta, l_max=l_max, omega=20)
        batch = tzp.build_zone_batch(gen, plan)
        frac = _skip_fraction(batch, delta, l_max)
        rows.append(csv_row(
            f"perf_mining/skip_fraction/{name}", 0.0,
            f"omega=20;skipped={frac:.1%};work_reduction="
            f"{1/(1-frac) if frac < 1 else 0:.1f}x",
        ))

    # 2) adaptive zoning on a heavy-burst stream
    # bursts longer than 2*L_b so the adaptive planner can split them
    g = sg.bursty_stream(int(30_000 * scale), 200,
                         burst_size=int(3_000 * scale) or 300,
                         burst_span=5_000, gap_span=36_000, seed=4)
    plan_fixed = tzp.plan_zones(g, delta=delta, l_max=l_max, omega=20)
    b_fixed = tzp.build_zone_batch(g, plan_fixed)
    e_adapt = 768 if not smoke else 96
    plan_adapt = tzp.plan_zones(g, delta=delta, l_max=l_max, omega=20,
                                e_cap=e_adapt)
    b_adapt = tzp.build_zone_batch(g, plan_adapt, e_cap=e_adapt)
    work_fixed = b_fixed.n_zones * b_fixed.e_cap ** 2
    work_adapt = b_adapt.n_zones * b_adapt.e_cap ** 2
    rows.append(csv_row(
        "perf_mining/adaptive_zoning", 0.0,
        f"fixed=({b_fixed.n_zones}z x cap{b_fixed.e_cap});"
        f"adaptive=({b_adapt.n_zones}z x cap{b_adapt.e_cap});"
        f"padded_sweep_work_reduction={work_fixed/work_adapt:.1f}x;"
        f"overflow={b_adapt.overflow}",
    ))

    # 3) unique codes per shard (out_cap validation)
    from repro.core import from_edges

    n3 = int(8000 * scale) or 1000
    g_small = from_edges(g.u[:n3], g.v[:n3], g.t[:n3])
    res = PTMTEngine(MiningConfig(
        delta=delta, l_max=l_max, omega=8, e_cap=1024, allow_overflow=True,
    )).discover(g_small)
    rows.append(csv_row(
        "perf_mining/unique_codes", 0.0,
        f"global_unique={len(res.counts)};"
        f"out_cap_16384_headroom={16384 / max(len(res.counts), 1):.0f}x",
    ))

    # 4) hierarchical aggregation: throughput + the memory-ceiling move
    hier_rows, hier_payload = _hierarchical_section(smoke)
    rows.extend(hier_rows)
    payload.update(hier_payload)

    # 5) engine compiled-plan reuse: warm call must beat cold
    reuse_rows, reuse_payload = _engine_reuse_section(smoke)
    rows.extend(reuse_rows)
    payload["engine_reuse"] = reuse_payload

    # 6) ragged zone layout: bucketed must waste fewer padded slots
    layout_rows, layout_payload = _zone_layout_section(smoke)
    rows.extend(layout_rows)
    payload["zone_layout"] = layout_payload

    # 7) fused single-launch scan: one dispatch, fold on-device
    fused_rows, fused_payload = _fused_section(smoke)
    rows.extend(fused_rows)
    payload["fused"] = fused_payload

    # 8) observability: disabled-mode overhead < 2% + snapshot sample
    obs_rows, obs_payload = _observability_section(smoke)
    rows.extend(obs_rows)
    payload["observability"] = obs_payload
    return rows, payload


def run(smoke: bool = False) -> list[str]:
    rows, _ = run_json(smoke)
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
