"""Paper Fig. 8 — parallel scalability across workers.

The paper scales OpenMP threads; our parallel axis is mesh devices.  On this
1-core container extra virtual devices share one ALU, so wall-clock cannot
improve; what we CAN measure faithfully is (a) work distribution balance
across devices (the paper's load-variance metric) and (b) that device counts
1..8 produce identical results with proportionally fewer zones per device.
Wall-times per device count are reported for completeness.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

from .common import csv_row

_CHILD = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ndev}"
import json, time
import jax
from repro.core import MiningConfig, PTMTEngine
from repro.data import synthetic_graphs as sg

g = sg.bursty_stream(20_000, 400, seed=3)
mesh = jax.make_mesh(({ndev},), ("zones",))
t0 = time.perf_counter()
engine = PTMTEngine(MiningConfig(delta=90, l_max=5, omega=8,
                                zone_chunk=2))
res = engine.sharded(g, mesh, ("zones",))
dt = time.perf_counter() - t0
print(json.dumps({{"n_types": len(res.counts),
                   "total": res.total_processes(),
                   "zones": res.n_zones, "time_s": dt}}))
"""


def run() -> list[str]:
    rows = []
    results = {}
    for ndev in (1, 2, 4, 8):
        env = dict(os.environ, PYTHONPATH="src")
        out = subprocess.run(
            [sys.executable, "-c", _CHILD.format(ndev=ndev)],
            capture_output=True, text=True, timeout=900, env=env,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        )
        if out.returncode != 0:
            rows.append(csv_row(f"fig8_scaling/dev={ndev}", 0.0,
                                "ERROR=" + out.stderr[-120:]))
            continue
        data = json.loads(out.stdout.strip().splitlines()[-1])
        results[ndev] = data
        rows.append(csv_row(
            f"fig8_scaling/dev={ndev}", data["time_s"],
            f"types={data['n_types']};zones={data['zones']}",
        ))
    counts = {d: (r["n_types"], r["total"]) for d, r in results.items()}
    consistent = len(set(counts.values())) == 1
    rows.append(csv_row(
        "fig8_scaling/consistency", 0.0,
        f"identical_results_across_device_counts="
        f"{'yes' if consistent else 'NO'}",
    ))
    assert consistent, counts
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
