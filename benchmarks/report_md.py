"""Generate the EXPERIMENTS.md §Roofline markdown table from dry-run JSONs.

    PYTHONPATH=src python -m benchmarks.report_md [--mesh single]
"""

from __future__ import annotations

import argparse

from .bench_roofline import load_records


def fmt_table(records, mesh=None, tags=("",)):
    rows = [r for r in records if r.get("status") == "ok"
            and (mesh is None or r["mesh"] == mesh)
            and r.get("tag", "") in tags]
    out = [
        "| arch | shape | mesh | compute (ms) | memory (ms) | "
        "collective (ms) | dominant | useful FLOPs | roofline frac | "
        "temp GB/chip |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']}"
            f"{('+' + r['tag']) if r.get('tag') else ''} | "
            f"{r['compute_s']*1e3:.2f} | {r['memory_s']*1e3:.2f} | "
            f"{r['collective_s']*1e3:.2f} | {r['dominant']} | "
            f"{r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} | "
            f"{r['memory']['temp_bytes']/1e9:.2f} |"
        )
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--tags", default="",
                    help="comma list; empty string = baselines only")
    args = ap.parse_args()
    tags = tuple(args.tags.split(",")) if args.tags else ("",)
    print(fmt_table(load_records(), mesh=args.mesh, tags=tags))


if __name__ == "__main__":
    main()
