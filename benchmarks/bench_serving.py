"""Motif serving — sustained multi-tenant ingest and query tail latency.

Replays a synthetic stream into several tenant sessions of
:class:`repro.serving.motif.MotifService` under the driver's mixed query
workload and reports:

  * sustained ingest edges/sec across all tenants (batched admission);
  * query p50/p99 latency and the snapshot-cache hit rate (epoch-keyed, so
    every query between two frontier advances after the first is a hit);
  * a correctness audit: each tenant's served counts must equal batch
    ``discover`` on its closed prefix;
  * a **config-lattice co-mine** comparison: N tenant configs (shared
    graph, differing ``delta``/``l_max``) mined through ONE shared Phase-1
    sweep (``engine.discover_many``) vs N independent ``discover`` calls —
    wall-clock, Phase-1 launch counters, and a byte-equivalence flag.

The service runs with a live :class:`repro.obs.Observability` bundle and
the query-latency row is derived from the registry's per-(tenant, op)
``repro_serving_query_latency_ms`` histograms (pooled via
:func:`repro.obs.metrics.merged_percentile`) — the same numbers a scrape
of the Prometheus surface would see — rather than from the driver's
client-side lists, which are kept only as a cross-check.

``run(smoke=True)`` shrinks sizes for the CI suite-registry smoke check.
"""

from __future__ import annotations

import time

import numpy as np

import repro.obs as obs_mod
from repro.core import from_edges
from repro.launch.serve_motifs import (
    build_report,
    run_workload,
    tenant_streams,
    verify_against_batch,
)
from repro.obs.metrics import merged_percentile
from repro.serving.motif import MotifService

from .common import csv_row

DELTA, L_MAX, OMEGA = 40, 4, 3


def _make_stream(n, nodes=40, span_per_edge=8, seed=11):
    rng = np.random.default_rng(seed)
    return from_edges(
        rng.integers(0, nodes, n), rng.integers(0, nodes, n),
        np.sort(rng.integers(0, span_per_edge * n, n)),
    )


def _comine_section(smoke: bool):
    """N-config co-mine vs N independent mines on one shared graph.

    Warm both sides first (compile + plan caches), then time steady-state:
    the co-mined side runs ONE dominating Phase-1 expansion and splits
    member count tables in the fold, so its launch count is a single
    sweep's while the independent side pays one full sweep per config.
    Counts must match byte-for-byte — CI asserts the flag.
    """
    from repro.core.config import MiningConfig
    from repro.core.engine import PTMTEngine

    n_edges = 1_200 if smoke else 8_000
    g = _make_stream(n_edges, seed=17)
    base = MiningConfig(delta=DELTA, l_max=L_MAX, omega=OMEGA, backend="ref")
    configs = [
        base,
        base.with_updates(delta=DELTA // 2, l_max=L_MAX - 1),
        base.with_updates(delta=DELTA - 10, l_max=L_MAX),
        base.with_updates(delta=DELTA, l_max=2),
    ]

    # independent baseline: one warm engine per tenant config
    solo_engines = [PTMTEngine(c) for c in configs]
    for e in solo_engines:
        e.discover(g)                                   # warm caches
    t0 = time.perf_counter()
    solo = [e.discover(g) for e in solo_engines]
    independent_s = time.perf_counter() - t0
    independent_launches = sum(
        r.layout["execution"]["launches"] for r in solo)

    eng = PTMTEngine(base)
    eng.discover_many(g, configs)                       # warm caches
    t0 = time.perf_counter()
    many = eng.discover_many(g, configs)
    comine_s = time.perf_counter() - t0
    comine_launches = many[0].layout["execution"]["launches"]

    equal = all(r.counts == s.counts for r, s in zip(many, solo))
    payload = {
        "edges": g.n_edges,
        "n_configs": len(configs),
        "configs": [
            {"delta": c.delta, "l_max": c.l_max, "omega": c.omega}
            for c in configs
        ],
        "path": many[0].layout["execution"]["path"],
        "independent_seconds": independent_s,
        "comine_seconds": comine_s,
        "independent_launches": independent_launches,
        "comine_launches": comine_launches,
        "speedup_comine_vs_independent": (
            independent_s / comine_s if comine_s else 0.0),
        "counts_equal": equal,
    }
    row = csv_row(
        f"serving/comine_n{len(configs)}", comine_s,
        f"independent_s={independent_s:.3f};"
        f"speedup={payload['speedup_comine_vs_independent']:.2f}x;"
        f"launches={comine_launches}_vs_{independent_launches};"
        f"equal={'yes' if equal else 'NO'}",
    )
    assert equal, "co-mined counts diverged from independent discover"
    return row, payload


def _serving_section(smoke: bool):
    n_edges = 1_500 if smoke else 6_000
    tenants = 2 if smoke else 3
    chunk = 96 if smoke else 256
    ingest_batch = 192 if smoke else 512

    g = _make_stream(n_edges)
    streams = tenant_streams(g, tenants)
    names = [f"tenant{i}" for i in range(tenants)]
    obs = obs_mod.enabled()
    service = MotifService(delta=DELTA, l_max=L_MAX, omega=OMEGA,
                           ingest_batch=ingest_batch, obs=obs)
    for name in names:
        service.create_session(name)

    t0 = time.perf_counter()
    ingest_lat, query_lat, first_call_lat = run_workload(
        service, streams, names, chunk_edges=chunk, queries_per_chunk=4,
    )
    wall = time.perf_counter() - t0

    report = build_report(service, names, g.n_edges, wall,
                          ingest_lat, query_lat, first_call_lat)
    verify_rows = verify_against_batch(
        service, names, streams, delta=DELTA, l_max=L_MAX, omega=OMEGA)
    # match is None when the batch reference itself overflowed (only the
    # stream side is exact there) — mirror the driver and skip those rows
    exact = all(row["match"] for row in verify_rows
                if row["match"] is not None)

    # steady-state query latency as the metrics surface sees it: pool the
    # per-(tenant, op) histograms the service populated
    hists = [h for h in obs.metrics.instruments()
             if h.name == "repro_serving_query_latency_ms"]
    reg_n = sum(h.count for h in hists)
    assert reg_n == report["queries"], (
        f"registry saw {reg_n} steady-state queries, "
        f"driver saw {report['queries']}")
    query_p50_ms = merged_percentile(hists, 50)
    query_p99_ms = merged_percentile(hists, 99)
    first_hists = [h for h in obs.metrics.instruments()
                   if h.name == "repro_serving_query_first_call_ms"]
    n_first = sum(h.count for h in first_hists)

    rows = [
        csv_row(
            f"serving/ingest_t{tenants}",
            report["ingest_p50_ms"] / 1e3,
            f"edges_per_s={report['ingest_edges_per_s']:.0f};"
            f"chunk_p99_ms={report['ingest_p99_ms']:.1f};"
            f"admission_batch={ingest_batch}",
        ),
        csv_row(
            f"serving/query_t{tenants}",
            query_p50_ms / 1e3,
            f"p99_ms={query_p99_ms:.2f};n={reg_n};"
            f"first_calls={n_first};"
            f"hit_rate={report['cache_hit_rate']:.2f};"
            f"snapshots={report['snapshots_mined']};"
            f"source=registry;"
            f"exact={'yes' if exact else 'NO'}",
        ),
    ]
    assert exact, "served counts diverged from batch discover"
    payload = {
        "edges": g.n_edges,
        "tenants": tenants,
        "ingest_edges_per_s": report["ingest_edges_per_s"],
        "query_p50_ms": query_p50_ms,
        "query_p99_ms": query_p99_ms,
        "queries": reg_n,
        "first_calls": n_first,
        "cache_hit_rate": report["cache_hit_rate"],
        "snapshots_mined": report["snapshots_mined"],
        "exact": exact,
    }
    return rows, payload


def _drive(coordinator, streams, names, offsets, *, until, chunk,
           queries_per_chunk, rng, checkpoint_every):
    """Feed each tenant up to index ``until`` with the query mix running.

    Returns ingest/query latency lists and updated offsets; periodic
    checkpoints carry the post-chunk offset (the failover rewind point).
    """
    from repro.serving.motif import QueryRequest

    ingest_lat, query_lat = [], []
    since = {n: 0 for n in names}
    live = True
    while live:
        live = False
        for name, g in zip(names, streams):
            i = offsets[name]
            end = min(until, g.n_edges)
            if i >= end:
                continue
            live = True
            j = min(i + chunk, end)
            t0 = time.perf_counter()
            while True:
                ack = coordinator.ingest(name, g.u[i:j], g.v[i:j], g.t[i:j])
                if not ack.throttled:
                    break
                coordinator.flush(name)
            ingest_lat.append(time.perf_counter() - t0)
            offsets[name] = j
            since[name] += j - i
            if since[name] >= checkpoint_every:
                coordinator.checkpoint(name, {"offset": j})
                since[name] = 0
            for _ in range(queries_per_chunk):
                level = int(rng.integers(1, 4))
                t0 = time.perf_counter()
                resp = coordinator.query(QueryRequest(
                    session=name, op="top_k", level=level, k=8))
                if not resp.first_call:
                    query_lat.append(time.perf_counter() - t0)
    return ingest_lat, query_lat


def _slo(ingest_lat, query_lat, edges, wall):
    from repro.obs.timing import percentile_ms

    return {
        "edges": edges,
        "seconds": wall,
        "ingest_edges_per_s": edges / wall if wall else 0.0,
        "ingest_p50_ms": percentile_ms(ingest_lat, 50),
        "ingest_p99_ms": percentile_ms(ingest_lat, 99),
        "queries": len(query_lat),
        "query_p50_ms": percentile_ms(query_lat, 50),
        "query_p99_ms": percentile_ms(query_lat, 99),
    }


def _failover_section(smoke: bool):
    """Ingest SLO + query tail latency across a worker kill + failover.

    Phase 1 (healthy): feed half the stream through a 3-worker cluster
    with periodic checkpoints.  Then kill a tenant-owning worker —
    failover restores its tenants' checkpoints on the rendezvous
    runner-up and hands back their durable offsets.  Phase 2 (degraded):
    rewind those tenants and finish the stream on the survivors.  Final
    counts must be byte-identical to an uninterrupted single-process
    replay — the availability layer's core guarantee — and CI asserts
    the flag plus the presence of both phases' p50/p99.
    """
    import tempfile

    from repro.core.config import MiningConfig
    from repro.launch.serve_motifs import reference_counts, tenant_counts
    from repro.serving.cluster import ClusterCoordinator

    n_edges = 1_200 if smoke else 6_000
    tenants = 3
    chunk = 96 if smoke else 256
    ingest_batch = 192 if smoke else 512
    ckpt_every = 2 * chunk

    cfg = MiningConfig(delta=DELTA, l_max=L_MAX, omega=OMEGA, backend="ref")
    g = _make_stream(n_edges, seed=23)
    from repro.launch.serve_motifs import tenant_streams

    streams = tenant_streams(g, tenants)
    names = [f"tenant{i}" for i in range(tenants)]
    rng = np.random.default_rng(5)

    with tempfile.TemporaryDirectory() as ckdir:
        co = ClusterCoordinator(3, config=cfg, checkpoint_dir=ckdir,
                                ingest_batch=ingest_batch)
        for name in names:
            co.create_tenant(name)
            co.checkpoint(name, {"offset": 0})
        offsets = {n: 0 for n in names}
        half = max(s.n_edges for s in streams) // 2

        t0 = time.perf_counter()
        h_ingest, h_query = _drive(
            co, streams, names, offsets, until=half, chunk=chunk,
            queries_per_chunk=2, rng=rng, checkpoint_every=ckpt_every)
        healthy_wall = time.perf_counter() - t0
        healthy = _slo(h_ingest, h_query,
                       sum(offsets.values()), healthy_wall)

        # kill a worker that owns at least one tenant; failover restores
        # its tenants elsewhere and returns their durable offsets
        victim = co.owner_of(names[0])
        t0 = time.perf_counter()
        recovered = co.kill_worker(victim)
        failover_s = time.perf_counter() - t0
        for name, meta in recovered.items():
            offsets[name] = int(meta["offset"])
        fed_before = sum(offsets.values())

        t0 = time.perf_counter()
        f_ingest, f_query = _drive(
            co, streams, names, offsets,
            until=max(s.n_edges for s in streams), chunk=chunk,
            queries_per_chunk=2, rng=rng, checkpoint_every=ckpt_every)
        degraded_wall = time.perf_counter() - t0
        degraded = _slo(f_ingest, f_query,
                        sum(offsets.values()) - fed_before, degraded_wall)
        co.flush_all()

        ref = reference_counts(cfg, streams, names,
                               ingest_batch=ingest_batch)
        equal = all(tenant_counts(co, n) == ref[n] for n in names)

    payload = {
        "workers": 3,
        "tenants": tenants,
        "edges": g.n_edges,
        "killed_worker": victim,
        "tenants_failed_over": sorted(recovered),
        "replayed_edges": sum(
            offsets[n] - int(recovered[n]["offset"]) for n in recovered),
        "failover_seconds": failover_s,
        "healthy": healthy,
        "failover": degraded,
        "counts_equal": equal,
    }
    row = csv_row(
        f"serving/failover_w3_t{tenants}", failover_s,
        f"healthy_q_p99_ms={healthy['query_p99_ms']:.2f};"
        f"degraded_q_p99_ms={degraded['query_p99_ms']:.2f};"
        f"failed_over={len(recovered)};"
        f"equal={'yes' if equal else 'NO'}",
    )
    assert equal, "failover counts diverged from uninterrupted replay"
    return row, payload


def run(smoke: bool = False) -> list[str]:
    rows, _ = run_json(smoke=smoke)
    return rows


def run_json(smoke: bool = False):
    """Rows + the structured payload ``--out-json`` lands in BENCH JSON.

    Written standalone to ``BENCH_serving.json`` (via ``benchmarks/run.py
    --only serving --out-json BENCH_serving.json``) — serving SLOs no
    longer ride in ``BENCH_mining.json``.
    """
    rows, workload = _serving_section(smoke)
    comine_row, comine = _comine_section(smoke)
    failover_row, failover = _failover_section(smoke)
    payload = {
        "suite": "serving",
        "smoke": smoke,
        "workload": workload,
        "comine": comine,
        "failover": failover,
    }
    return rows + [comine_row, failover_row], payload


if __name__ == "__main__":
    print("\n".join(run()))
