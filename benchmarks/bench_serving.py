"""Motif serving — sustained multi-tenant ingest and query tail latency.

Replays a synthetic stream into several tenant sessions of
:class:`repro.serving.motif.MotifService` under the driver's mixed query
workload and reports:

  * sustained ingest edges/sec across all tenants (batched admission);
  * query p50/p99 latency and the snapshot-cache hit rate (epoch-keyed, so
    every query between two frontier advances after the first is a hit);
  * a correctness audit: each tenant's served counts must equal batch
    ``discover`` on its closed prefix.

``run(smoke=True)`` shrinks sizes for the CI suite-registry smoke check.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import from_edges
from repro.launch.serve_motifs import (
    build_report,
    run_workload,
    tenant_streams,
    verify_against_batch,
)
from repro.serving.motif import MotifService

from .common import csv_row

DELTA, L_MAX, OMEGA = 40, 4, 3


def _make_stream(n, nodes=40, span_per_edge=8, seed=11):
    rng = np.random.default_rng(seed)
    return from_edges(
        rng.integers(0, nodes, n), rng.integers(0, nodes, n),
        np.sort(rng.integers(0, span_per_edge * n, n)),
    )


def run(smoke: bool = False) -> list[str]:
    n_edges = 1_500 if smoke else 6_000
    tenants = 2 if smoke else 3
    chunk = 96 if smoke else 256
    ingest_batch = 192 if smoke else 512

    g = _make_stream(n_edges)
    streams = tenant_streams(g, tenants)
    names = [f"tenant{i}" for i in range(tenants)]
    service = MotifService(delta=DELTA, l_max=L_MAX, omega=OMEGA,
                           ingest_batch=ingest_batch)
    for name in names:
        service.create_session(name)

    t0 = time.perf_counter()
    ingest_lat, query_lat = run_workload(
        service, streams, names, chunk_edges=chunk, queries_per_chunk=4,
    )
    wall = time.perf_counter() - t0

    report = build_report(service, names, g.n_edges, wall,
                          ingest_lat, query_lat)
    verify_rows = verify_against_batch(
        service, names, streams, delta=DELTA, l_max=L_MAX, omega=OMEGA)
    # match is None when the batch reference itself overflowed (only the
    # stream side is exact there) — mirror the driver and skip those rows
    exact = all(row["match"] for row in verify_rows
                if row["match"] is not None)

    rows = [
        csv_row(
            f"serving/ingest_t{tenants}",
            report["ingest_p50_ms"] / 1e3,
            f"edges_per_s={report['ingest_edges_per_s']:.0f};"
            f"chunk_p99_ms={report['ingest_p99_ms']:.1f};"
            f"admission_batch={ingest_batch}",
        ),
        csv_row(
            f"serving/query_t{tenants}",
            report["query_p50_ms"] / 1e3,
            f"p99_ms={report['query_p99_ms']:.2f};n={report['queries']};"
            f"hit_rate={report['cache_hit_rate']:.2f};"
            f"snapshots={report['snapshots_mined']};"
            f"exact={'yes' if exact else 'NO'}",
        ),
    ]
    assert exact, "served counts diverged from batch discover"
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
