"""Motif serving — sustained multi-tenant ingest and query tail latency.

Replays a synthetic stream into several tenant sessions of
:class:`repro.serving.motif.MotifService` under the driver's mixed query
workload and reports:

  * sustained ingest edges/sec across all tenants (batched admission);
  * query p50/p99 latency and the snapshot-cache hit rate (epoch-keyed, so
    every query between two frontier advances after the first is a hit);
  * a correctness audit: each tenant's served counts must equal batch
    ``discover`` on its closed prefix.

The service runs with a live :class:`repro.obs.Observability` bundle and
the query-latency row is derived from the registry's per-(tenant, op)
``repro_serving_query_latency_ms`` histograms (pooled via
:func:`repro.obs.metrics.merged_percentile`) — the same numbers a scrape
of the Prometheus surface would see — rather than from the driver's
client-side lists, which are kept only as a cross-check.

``run(smoke=True)`` shrinks sizes for the CI suite-registry smoke check.
"""

from __future__ import annotations

import time

import numpy as np

import repro.obs as obs_mod
from repro.core import from_edges
from repro.launch.serve_motifs import (
    build_report,
    run_workload,
    tenant_streams,
    verify_against_batch,
)
from repro.obs.metrics import merged_percentile
from repro.serving.motif import MotifService

from .common import csv_row

DELTA, L_MAX, OMEGA = 40, 4, 3


def _make_stream(n, nodes=40, span_per_edge=8, seed=11):
    rng = np.random.default_rng(seed)
    return from_edges(
        rng.integers(0, nodes, n), rng.integers(0, nodes, n),
        np.sort(rng.integers(0, span_per_edge * n, n)),
    )


def run(smoke: bool = False) -> list[str]:
    n_edges = 1_500 if smoke else 6_000
    tenants = 2 if smoke else 3
    chunk = 96 if smoke else 256
    ingest_batch = 192 if smoke else 512

    g = _make_stream(n_edges)
    streams = tenant_streams(g, tenants)
    names = [f"tenant{i}" for i in range(tenants)]
    obs = obs_mod.enabled()
    service = MotifService(delta=DELTA, l_max=L_MAX, omega=OMEGA,
                           ingest_batch=ingest_batch, obs=obs)
    for name in names:
        service.create_session(name)

    t0 = time.perf_counter()
    ingest_lat, query_lat, first_call_lat = run_workload(
        service, streams, names, chunk_edges=chunk, queries_per_chunk=4,
    )
    wall = time.perf_counter() - t0

    report = build_report(service, names, g.n_edges, wall,
                          ingest_lat, query_lat, first_call_lat)
    verify_rows = verify_against_batch(
        service, names, streams, delta=DELTA, l_max=L_MAX, omega=OMEGA)
    # match is None when the batch reference itself overflowed (only the
    # stream side is exact there) — mirror the driver and skip those rows
    exact = all(row["match"] for row in verify_rows
                if row["match"] is not None)

    # steady-state query latency as the metrics surface sees it: pool the
    # per-(tenant, op) histograms the service populated
    hists = [h for h in obs.metrics.instruments()
             if h.name == "repro_serving_query_latency_ms"]
    reg_n = sum(h.count for h in hists)
    assert reg_n == report["queries"], (
        f"registry saw {reg_n} steady-state queries, "
        f"driver saw {report['queries']}")
    query_p50_ms = merged_percentile(hists, 50)
    query_p99_ms = merged_percentile(hists, 99)
    first_hists = [h for h in obs.metrics.instruments()
                   if h.name == "repro_serving_query_first_call_ms"]
    n_first = sum(h.count for h in first_hists)

    rows = [
        csv_row(
            f"serving/ingest_t{tenants}",
            report["ingest_p50_ms"] / 1e3,
            f"edges_per_s={report['ingest_edges_per_s']:.0f};"
            f"chunk_p99_ms={report['ingest_p99_ms']:.1f};"
            f"admission_batch={ingest_batch}",
        ),
        csv_row(
            f"serving/query_t{tenants}",
            query_p50_ms / 1e3,
            f"p99_ms={query_p99_ms:.2f};n={reg_n};"
            f"first_calls={n_first};"
            f"hit_rate={report['cache_hit_rate']:.2f};"
            f"snapshots={report['snapshots_mined']};"
            f"source=registry;"
            f"exact={'yes' if exact else 'NO'}",
        ),
    ]
    assert exact, "served counts diverged from batch discover"
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
