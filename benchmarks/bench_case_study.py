"""Paper Table 6 / Section 5.6 — WikiTalk case-study analog.

Mines the triadic-closure-heavy synthetic stream and reports the motif
transition tree proportions (evolved vs non-evolved, triangle closure /
chain extension / reciprocal shares of the 0101 family).
"""

from __future__ import annotations

from repro.core import MiningConfig, PTMTEngine
from repro.data import synthetic_graphs as sg

from .common import csv_row, timed


def run() -> list[str]:
    rows = []
    g = sg.make("wikitalk-like")
    engine = PTMTEngine(MiningConfig(delta=600, l_max=3, omega=8))
    res, t = timed(engine.discover, g)
    tree = res.tree()

    total = res.total_processes()
    evolved = sum(
        node.through for node in tree.root.children.values()
        if len(node.code) == 2 and node.evolved
    )
    rows.append(csv_row(
        "table6_case_study/mine", t,
        f"processes={total};motif_types={len(res.counts)}",
    ))
    for code in ("0101", "0102"):
        if code not in tree.root.children:
            continue
        node = tree.root.children[code]
        shares = sorted(node.transition_rows(), key=lambda r: -r[1])[:3]
        share_str = "|".join(f"{c}:{s:.1%}" for c, _, s in shares)
        rows.append(csv_row(
            f"table6_case_study/{code}", 0.0,
            f"evolved={node.evolved};stopped={node.stopped};"
            f"top_transitions={share_str}",
        ))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
