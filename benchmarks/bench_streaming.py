"""Streaming discovery — sustained ingest rate and per-chunk latency.

Replays a synthetic stream through :class:`repro.core.StreamingMiner` at
several chunk sizes (including one that does not divide the edge count) and
reports:

  * sustained edges/sec over the whole replay;
  * mean / max per-chunk ingest latency (the serving-side metric: how long
    one arrival batch blocks the frontier);
  * a correctness audit: the final snapshot must equal batch ``discover``.
"""

from __future__ import annotations

import numpy as np

from repro.core import MiningConfig, PTMTEngine, from_edges
from repro.core.streaming import replay_stream

from .common import csv_row

DELTA, L_MAX, OMEGA = 40, 4, 3


def _make_stream(n=4_000, nodes=40, span=30_000, seed=11):
    rng = np.random.default_rng(seed)
    return from_edges(
        rng.integers(0, nodes, n), rng.integers(0, nodes, n),
        np.sort(rng.integers(0, span, n)),
    )


def run(smoke: bool = False) -> list[str]:
    rows = []
    g = _make_stream(n=1_000 if smoke else 4_000)
    engine = PTMTEngine(MiningConfig(delta=DELTA, l_max=L_MAX, omega=OMEGA))
    batch = engine.discover(g)

    # at least one size does not divide the stream — exercises the ragged tail
    chunks = (128, 192) if smoke else (256, 768, 1024)
    for chunk in chunks:
        miner = engine.stream()
        latencies, total = replay_stream(miner, g, chunk)
        snap = miner.snapshot(final=True)
        exact = snap.counts == batch.counts
        mean_lat = sum(latencies) / len(latencies)
        rows.append(csv_row(
            f"streaming/chunk{chunk}", mean_lat,
            f"edges_per_s={g.n_edges / total:.0f};"
            f"max_chunk_ms={1e3 * max(latencies):.1f};"
            f"zones_finalized={miner.n_zones_finalized};"
            f"retired={miner.n_edges_retired};exact={'yes' if exact else 'NO'}",
        ))
        assert exact, f"streaming chunk={chunk} diverged from batch discover"
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
