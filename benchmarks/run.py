"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig9] [--smoke]
        [--out-json BENCH_mining.json]

Prints ``name,us_per_call,derived`` CSV rows.  ``--smoke`` runs suites that
support it (a ``run(smoke=...)`` signature) at tiny sizes — the CI mode that
catches suite-registry breakage without paying full benchmark cost.

``--out-json FILE`` additionally collects structured payloads from suites
exposing ``run_json`` (mining: edges/sec + peak-memory estimates; roofline:
ragged-sweep bandwidth; serving: multi-tenant latency + config-lattice
co-mine comparison).  Payloads merge into an existing file by suite name,
so ``BENCH_*.json`` accumulates across invocations instead of clobbering;
each invocation also appends a timestamped entry to a bounded ``history``
list (suite names + argv + the per-suite payloads), so a perf regression
can be traced to the run that introduced it instead of being silently
overwritten by the latest numbers.
"""

from __future__ import annotations

import argparse
import inspect
import json
import sys
import time
import traceback

from . import (
    bench_accuracy,
    bench_case_study,
    bench_perf_mining,
    bench_roofline,
    bench_runtime,
    bench_scalability,
    bench_sensitivity,
    bench_serving,
    bench_streaming,
    bench_tzp,
)

SUITES = {
    "fig7_accuracy": bench_accuracy,
    "table2_runtime": bench_runtime,
    "fig8_scaling": bench_scalability,
    "fig9_fig10_sensitivity": bench_sensitivity,
    "table4_tzp": bench_tzp,
    "table6_case_study": bench_case_study,
    "perf_mining": bench_perf_mining,
    "roofline": bench_roofline,
    "streaming": bench_streaming,
    "serving": bench_serving,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on suite name")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes where the suite supports run(smoke=...)")
    ap.add_argument("--out-json", default=None,
                    help="write structured results from suites exposing "
                         "run_json (edges/sec, peak-memory estimates)")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failures = 0
    payloads: dict[str, object] = {}
    for name, mod in SUITES.items():
        if args.only and args.only not in name:
            continue
        kwargs = {}
        if args.smoke and "smoke" in inspect.signature(mod.run).parameters:
            kwargs["smoke"] = True
        try:
            if args.out_json and hasattr(mod, "run_json"):
                rows, payloads[name] = mod.run_json(**kwargs)
            else:
                rows = mod.run(**kwargs)
            for row in rows:
                print(row, flush=True)
        except Exception as exc:  # keep the harness going
            failures += 1
            print(f"{name},0.0,ERROR={type(exc).__name__}:{exc}",
                  flush=True)
            traceback.print_exc(file=sys.stderr)
    if args.out_json:
        # merge into an existing BENCH file so suites written by separate
        # invocations (e.g. perf_mining then serving) accumulate instead
        # of clobbering each other; "suites" always holds the LATEST
        # payload per suite (what CI asserts against) while "history"
        # appends one timestamped entry per invocation so older numbers
        # survive a re-run
        try:
            with open(args.out_json) as f:
                existing = json.load(f)
            suites = dict(existing.get("suites", {}))
            history = list(existing.get("history", []))
        except (FileNotFoundError, json.JSONDecodeError):
            suites, history = {}, []
        suites.update(payloads)
        history.append({
            "at": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
            "argv": sys.argv[1:],
            "suites": payloads,
        })
        history = history[-50:]  # bound file growth
        with open(args.out_json, "w") as f:
            json.dump({"argv": sys.argv[1:], "history": history,
                       "suites": suites},
                      f, indent=1, sort_keys=True)
        print(f"json written to {args.out_json}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
