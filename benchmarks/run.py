"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig9]

Prints ``name,us_per_call,derived`` CSV rows.
"""

from __future__ import annotations

import argparse
import sys
import traceback

from . import (
    bench_accuracy,
    bench_case_study,
    bench_perf_mining,
    bench_roofline,
    bench_runtime,
    bench_scalability,
    bench_sensitivity,
    bench_streaming,
    bench_tzp,
)

SUITES = {
    "fig7_accuracy": bench_accuracy,
    "table2_runtime": bench_runtime,
    "fig8_scaling": bench_scalability,
    "fig9_fig10_sensitivity": bench_sensitivity,
    "table4_tzp": bench_tzp,
    "table6_case_study": bench_case_study,
    "perf_mining": bench_perf_mining,
    "roofline": bench_roofline,
    "streaming": bench_streaming,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on suite name")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failures = 0
    for name, mod in SUITES.items():
        if args.only and args.only not in name:
            continue
        try:
            for row in mod.run():
                print(row, flush=True)
        except Exception as exc:  # keep the harness going
            failures += 1
            print(f"{name},0.0,ERROR={type(exc).__name__}:{exc}",
                  flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
