"""Paper Table 4 / Appendix B — TZP reconciliation audit.

For a stream partitioned into G1/B1/G2, count each zone *independently* and
verify |G1| + |G2| - |B1| equals the full-graph ground truth per motif code
(the inclusion-exclusion identity of Lemma 4.2), reported per code.
"""

from __future__ import annotations

import numpy as np

from repro.core import from_edges, oracle, tzp
from repro.core.config import MiningConfig
from repro.core.engine import PTMTEngine

from .common import csv_row, timed


def run() -> list[str]:
    rows = []
    rng = np.random.default_rng(4)
    n = 600
    g = from_edges(
        rng.integers(0, 12, n), rng.integers(0, 12, n),
        np.sort(rng.integers(0, 4_000, n)),
    )
    delta, l_max = 120, 3

    def zone_counts(lo, cnt):
        sub = from_edges(
            g.u[lo:lo + cnt], g.v[lo:lo + cnt], g.t[lo:lo + cnt])
        return dict(oracle.count_codes(sub.u, sub.v, sub.t, delta, l_max))

    def audit():
        plan = tzp.plan_zones(g, delta=delta, l_max=l_max, omega=2)
        per_zone = [
            zone_counts(int(plan.lo[z]), int(plan.count[z]))
            for z in range(plan.n_zones)
        ]
        truth = dict(oracle.count_codes(g.u, g.v, g.t, delta, l_max))
        combined: dict[str, int] = {}
        for z, counts in enumerate(per_zone):
            sign = int(plan.sign[z])
            for code, c in counts.items():
                combined[code] = combined.get(code, 0) + sign * c
        combined = {k: v for k, v in combined.items() if v}
        return plan, truth, combined

    (plan, truth, combined), t = timed(audit)
    keys = set(truth) | set(combined)
    mismatches = sum(truth.get(k, 0) != combined.get(k, 0) for k in keys)
    dup_before = sum(
        c for z, counts in enumerate(
            [zone_counts(int(plan.lo[z]), int(plan.count[z]))
             for z in np.flatnonzero(plan.sign < 0)])
        for c in counts.values()
    )
    rows.append(csv_row(
        "table4_tzp/reconciliation", t,
        f"zones={plan.n_zones};codes={len(keys)};"
        f"boundary_dups_removed={dup_before};mismatches={mismatches}",
    ))
    assert mismatches == 0
    # also confirm the device pipeline agrees with the oracle audit
    seq = PTMTEngine(MiningConfig(
        delta=delta, l_max=l_max, zone_chunk=0)).sequential(g)
    assert seq.counts == truth
    rows.append(csv_row("table4_tzp/pipeline_vs_oracle", 0.0, "exact=yes"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
