"""Paper Table 2 — runtime: PTMT vs the sequential TMC-analog.

The paper's speedup has two sources: (1) the TZP partition turns the O(n^2)
global candidate sweep into O(n * e_cap), and (2) zones run in parallel.
On this 1-core CPU container source (2) cannot show wall-clock gains, so the
measured speedup here is the *algorithmic* one — the paper's Table 2 numbers
additionally multiply by parallel efficiency (their 32 threads -> 12-50x).
"""

from __future__ import annotations

import numpy as np

from repro.core import MiningConfig, PTMTEngine
from repro.data import synthetic_graphs as sg

from .common import csv_row, timed


def run() -> list[str]:
    rows = []
    sizes = [4_000, 8_000, 16_000]
    speedups = []
    for n in sizes:
        g = sg.bursty_stream(n, max(n // 40, 10), seed=1)
        delta, l_max, omega = 90, 5, 8
        engine = PTMTEngine(MiningConfig(
            delta=delta, l_max=l_max, omega=omega))
        par, t_par = timed(engine.discover, g, repeats=2, warmup=1)
        seq_engine = PTMTEngine(MiningConfig(
            delta=delta, l_max=l_max, zone_chunk=0))
        seq, t_seq = timed(seq_engine.sequential, g, repeats=1, warmup=1)
        assert par.counts == seq.counts
        speedups.append(t_seq / t_par)
        rows.append(csv_row(
            f"table2_runtime/n={n}", t_par,
            f"seq_s={t_seq:.3f};par_s={t_par:.3f};"
            f"speedup={t_seq / t_par:.1f}x;zones={par.n_zones}",
        ))
    # paper finds speedup grows with scale (r=0.91); check monotone trend
    trend = "growing" if speedups[-1] > speedups[0] else "flat"
    rows.append(csv_row(
        "table2_runtime/trend", 0.0,
        f"speedups={[f'{s:.1f}' for s in speedups]};trend={trend}",
    ))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
