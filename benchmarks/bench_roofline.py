"""Roofline summary — reads the dry-run artifacts (launch/dryrun.py) and
emits the per-(arch x shape x mesh) three-term roofline table (§Roofline of
EXPERIMENTS.md is generated from this)."""

from __future__ import annotations

import json
import os

from .common import csv_row

RESULTS = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "results", "dryrun")


def load_records() -> list[dict]:
    if not os.path.isdir(RESULTS):
        return []
    out = []
    for fn in sorted(os.listdir(RESULTS)):
        if fn.endswith(".json"):
            with open(os.path.join(RESULTS, fn)) as f:
                out.append(json.load(f))
    return out


def run() -> list[str]:
    rows = []
    records = load_records()
    ok = [r for r in records if r.get("status") == "ok"]
    bad = [r for r in records if r.get("status") != "ok"]
    rows.append(csv_row(
        "roofline/coverage", 0.0,
        f"cells_ok={len(ok)};cells_failed={len(bad)}",
    ))
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"],
                                       r.get("tag", ""))):
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        tag = ("+" + r["tag"]) if r.get("tag") else ""
        rows.append(csv_row(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}{tag}", bound,
            f"comp_ms={r['compute_s']*1e3:.2f};"
            f"mem_ms={r['memory_s']*1e3:.2f};"
            f"coll_ms={r['collective_s']*1e3:.2f};"
            f"dom={r['dominant']};frac={r['roofline_fraction']:.3f}",
        ))
    for r in bad:
        rows.append(csv_row(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}", 0.0,
            "status=ERROR"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
