"""Roofline summary — dry-run model table + measured ragged-sweep bandwidth.

Two sections:

1. **model table** — reads the dry-run artifacts (launch/dryrun.py) and
   emits the per-(arch x shape x mesh) three-term roofline table
   (§Roofline of EXPERIMENTS.md is generated from this);
2. **measured ragged sweep** — times the fused single-launch zone scan
   (``MiningExecutor.run_layout(fused=True)``) on bursty corpora of
   increasing size under BOTH fused lowerings side by side: the compiled
   ``xla`` formulation (an achieved-vs-peak measurement — real XLA machine
   code against a jitted triad ``c = a + b`` streaming peak proxy) and the
   pinned Pallas path (which interprets on CPU — those points carry an
   ``interpret_caveat`` and are trajectory smoke only).  Every point
   records ``path``/``backend``/``compiled`` so a reader (or CI) can tell
   which regime produced it, and a ``sweep_compaction`` section reports
   how much modeled sweep traffic the host-planned live ``[lo, hi)``
   bounds shave off the full plan.

``run_json`` returns a structured payload for
``benchmarks/run.py --out-json`` — the ``BENCH_roofline.json`` history.
CI smoke-checks that the fused path reports exactly one launch per mine
and that at least one point ran compiled (no caveat).
"""

from __future__ import annotations

import json
import os
import time

from repro.core import MiningExecutor, planner, transitions, tzp
from repro.data import synthetic_graphs as sg

from .common import csv_row

RESULTS = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "results", "dryrun")

DELTA, L_MAX = 90, 5


# ---------------------------------------------------------------------------
# section 1: dry-run model table
# ---------------------------------------------------------------------------


def load_records() -> list[dict]:
    if not os.path.isdir(RESULTS):
        return []
    out = []
    for fn in sorted(os.listdir(RESULTS)):
        if fn.endswith(".json"):
            with open(os.path.join(RESULTS, fn)) as f:
                out.append(json.load(f))
    return out


def _model_rows() -> list[str]:
    rows = []
    records = load_records()
    ok = [r for r in records if r.get("status") == "ok"]
    bad = [r for r in records if r.get("status") != "ok"]
    rows.append(csv_row(
        "roofline/coverage", 0.0,
        f"cells_ok={len(ok)};cells_failed={len(bad)}",
    ))
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"],
                                       r.get("tag", ""))):
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        tag = ("+" + r["tag"]) if r.get("tag") else ""
        rows.append(csv_row(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}{tag}", bound,
            f"comp_ms={r['compute_s']*1e3:.2f};"
            f"mem_ms={r['memory_s']*1e3:.2f};"
            f"coll_ms={r['collective_s']*1e3:.2f};"
            f"dom={r['dominant']};frac={r['roofline_fraction']:.3f}",
        ))
    for r in bad:
        rows.append(csv_row(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}", 0.0,
            "status=ERROR"))
    return rows


# ---------------------------------------------------------------------------
# section 2: measured ragged-sweep bandwidth (fused single-launch scan)
# ---------------------------------------------------------------------------


def _peak_bandwidth_proxy(mb: int = 32) -> float:
    """Streaming-bandwidth ceiling proxy: jitted ``c = a + b`` triad
    (2 reads + 1 write), min of 5.  Whatever memory system runs the
    kernel, this is the same memory system at its friendliest."""
    import jax
    import jax.numpy as jnp

    n = mb * 2**20 // 4
    a = jnp.arange(n, dtype=jnp.float32)
    b = jnp.ones((n,), jnp.float32)
    add = jax.jit(lambda a, b: a + b)
    add(a, b).block_until_ready()
    best = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        add(a, b).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return 3 * n * 4 / best


def _ragged_sweep_section(smoke: bool):
    from repro.kernels.common import resolve_interpret

    peak = _peak_bandwidth_proxy(8 if smoke else 32)
    sizes = ((1_500, 2_500) if smoke else (5_000, 20_000, 40_000))
    pallas_interprets = resolve_interpret(None, quiet=True)
    # one executor per lowering: the compiled xla formulation vs the
    # Pallas kernel (which interprets on CPU hosts)
    executors = {
        "xla": MiningExecutor(delta=DELTA, l_max=L_MAX, backend="pallas",
                              fused_backend="xla"),
        "pallas": MiningExecutor(delta=DELTA, l_max=L_MAX, backend="pallas",
                                 fused_backend="pallas"),
    }
    rows, points, compaction = [], [], []
    by_size: dict[int, dict[str, float]] = {}
    for n_edges in sizes:
        g = sg.bursty_stream(n_edges, 250, burst_size=120, burst_span=200,
                             gap_span=30_000, seed=13)
        plan = tzp.plan_zones(g, delta=DELTA, l_max=L_MAX, omega=2)
        lay = tzp.build_zone_layout(g, plan, layout="bucketed")
        for fb, ex in executors.items():
            compiled = not (fb == "pallas" and pallas_interprets)
            outcome = ex.run_layout(lay, fused=True)  # warmup / compile
            # the interpreter is ~3 orders slower; one timed rep at the
            # big sizes keeps the suite's wall time bounded
            reps = 2 if smoke else (1 if not compiled and n_edges >= 20_000
                                    else 3)
            best = float("inf")
            for _ in range(reps):
                t0 = time.perf_counter()
                outcome = ex.run_layout(lay, fused=True)
                best = min(best, time.perf_counter() - t0)
            stats = dict(outcome.stats)
            assert stats["launches"] == 1, stats
            fl = tzp.concat_layout(lay, blk=ex.fused_blk,
                                   pad_slots_to=stats["fold_chunk"],
                                   delta=DELTA, l_max=L_MAX,
                                   bounds=stats["bounds"])
            assert fl.sweep_slots == stats["sweep_slots"], (fl.sweep_slots,
                                                            stats)
            traffic = planner.fused_traffic_bytes(fl, L_MAX)
            achieved = traffic / best if best else 0.0
            point = {
                "edges": g.n_edges,
                "path": stats["path"],
                "backend": stats["backend"],
                "bounds": stats["bounds"],
                "compiled": compiled,
                "n_buckets": lay.n_buckets,
                "n_slots": fl.n_slots,
                "sweep_slots": fl.sweep_slots,
                "seconds": best,
                "edges_per_s": g.n_edges / best if best else 0.0,
                "traffic_bytes": traffic,
                "achieved_bytes_per_s": achieved,
                "fraction_of_peak": achieved / peak if peak else 0.0,
                "launches": stats["launches"],
                "motif_types": len(
                    transitions.device_counts_to_dict(outcome.counts)),
            }
            if not compiled:
                point["interpret_caveat"] = (
                    "this point executed the Pallas kernel in interpret "
                    "mode; its fraction is trajectory smoke only")
            points.append(point)
            by_size.setdefault(n_edges, {})[fb] = point["edges_per_s"]
            rows.append(csv_row(
                f"roofline/ragged_sweep/{fb}/e{n_edges}", best,
                f"path={stats['path']};compiled={int(compiled)};"
                f"achieved_gb_s={achieved/1e9:.3f};"
                f"frac_of_peak={point['fraction_of_peak']:.4f};"
                f"launches=1;slots={fl.n_slots}",
            ))
        # host-planned sweep compaction: modeled traffic, full vs live
        full = tzp.concat_layout(lay, blk=executors["xla"].fused_blk)
        live = tzp.concat_layout(lay, blk=executors["xla"].fused_blk,
                                 delta=DELTA, l_max=L_MAX, bounds="live")
        compaction.append({
            "edges": g.n_edges,
            "full_sweep_slots": full.sweep_slots,
            "live_sweep_slots": live.sweep_slots,
            "full_traffic_bytes": planner.fused_traffic_bytes(full, L_MAX),
            "live_traffic_bytes": planner.fused_traffic_bytes(live, L_MAX),
            "sweep_slots_saved_frac":
                1.0 - live.sweep_slots / full.sweep_slots
                if full.sweep_slots else 0.0,
        })
        rows.append(csv_row(
            f"roofline/sweep_compaction/e{n_edges}", 0.0,
            f"full_slots={full.sweep_slots};live_slots={live.sweep_slots};"
            f"saved_frac={compaction[-1]['sweep_slots_saved_frac']:.4f}",
        ))
    rows.append(csv_row(
        "roofline/peak_proxy", 0.0,
        f"triad_gb_s={peak/1e9:.2f}",
    ))
    side_by_side = [
        {
            "edges": n_edges,
            "compiled_edges_per_s": per_fb.get("xla", 0.0),
            "interpret_edges_per_s": per_fb.get("pallas", 0.0),
            "speedup": (per_fb["xla"] / per_fb["pallas"]
                        if per_fb.get("pallas") else 0.0),
        }
        for n_edges, per_fb in sorted(by_size.items())
        if pallas_interprets
    ]
    payload = {
        "peak_proxy_bytes_per_s": peak,
        "points": points,
        "compiled_vs_interpret": side_by_side,
        "sweep_compaction": compaction,
    }
    return rows, payload


def run_json(smoke: bool = False):
    """Returns (csv rows, structured payload for BENCH_roofline.json)."""
    rows = _model_rows()
    sweep_rows, sweep_payload = _ragged_sweep_section(smoke)
    rows.extend(sweep_rows)
    payload = {"suite": "roofline", "smoke": smoke,
               "delta": DELTA, "l_max": L_MAX,
               "ragged_sweep": sweep_payload}
    return rows, payload


def run(smoke: bool = False) -> list[str]:
    rows, _ = run_json(smoke)
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
