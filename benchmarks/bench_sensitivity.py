"""Paper Figs. 9 & 10 — parameter sensitivity: delta sweep and l_max sweep.

The paper reports PTMT's runtime growing as ~O(delta^1.1) vs TMC's
O(delta^1.8), and O(l_max^1.4) vs O(l_max^2.7): the TZP bound on zone size
decouples runtime from the global window blow-up.  We fit the same power
laws on CPU-scale streams.
"""

from __future__ import annotations

import numpy as np

from repro.core import MiningConfig, PTMTEngine
from repro.data import synthetic_graphs as sg

from .common import csv_row, timed


def _fit_exponent(xs, ts):
    return float(np.polyfit(np.log(xs), np.log(ts), 1)[0])


def run() -> list[str]:
    rows = []
    g = sg.poisson_stream(8_000, 200, rate=0.5, seed=5)

    # Fig 9: delta sweep
    deltas = [15, 30, 60, 120]
    t_par, t_seq = [], []
    for delta in deltas:
        _, tp = timed(PTMTEngine(MiningConfig(
            delta=delta, l_max=4, omega=6)).discover, g,
            repeats=1, warmup=1)
        _, ts = timed(PTMTEngine(MiningConfig(
            delta=delta, l_max=4, zone_chunk=0)).sequential, g,
            repeats=1, warmup=1)
        t_par.append(tp)
        t_seq.append(ts)
        rows.append(csv_row(
            f"fig9_delta/delta={delta}", tp,
            f"seq_s={ts:.3f};speedup={ts / tp:.1f}x"))
    rows.append(csv_row(
        "fig9_delta/exponents", 0.0,
        f"ptmt_delta_exp={_fit_exponent(deltas, t_par):.2f};"
        f"seq_delta_exp={_fit_exponent(deltas, t_seq):.2f}"))

    # Fig 10: l_max sweep
    lmaxes = [2, 4, 6, 8]
    t_par2 = []
    for l_max in lmaxes:
        _, tp = timed(PTMTEngine(MiningConfig(
            delta=60, l_max=l_max, omega=5)).discover, g,
            repeats=1, warmup=1)
        t_par2.append(tp)
        rows.append(csv_row(f"fig10_lmax/l_max={l_max}", tp, ""))
    rows.append(csv_row(
        "fig10_lmax/exponent", 0.0,
        f"ptmt_lmax_exp={_fit_exponent(lmaxes, t_par2):.2f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
