"""Paper Fig. 7 — complete consistency validation.

PTMT (zone-partitioned, parallel) must reproduce the sequential TMC-analog's
counts *exactly*, code-for-code, on dataset analogs of both density regimes.
Prints per-dataset match statistics.
"""

from __future__ import annotations

from repro.core import MiningConfig, PTMTEngine
from repro.data import synthetic_graphs as sg

from .common import csv_row, timed


def run() -> list[str]:
    rows = []
    cases = [
        ("email-eu-like", 600, 4, 8),      # dense power-law
        ("wikitalk-like", 600, 4, 8),      # triadic, medium
        ("collegemsg-like", 3600, 3, 4),   # sparse poisson
    ]
    cap = 8_000   # the O(n^2) sequential baseline bounds feasible size here
    for name, delta, l_max, omega in cases:
        g = sg.make(name)
        if g.n_edges > cap:
            from repro.core import from_edges

            g = from_edges(g.u[:cap], g.v[:cap], g.t[:cap])
        engine = PTMTEngine(MiningConfig(
            delta=delta, l_max=l_max, omega=omega))
        res, t_par = timed(engine.discover, g)
        seq_engine = PTMTEngine(MiningConfig(
            delta=delta, l_max=l_max, zone_chunk=0))
        seq, _ = timed(seq_engine.sequential, g)
        keys = set(res.counts) | set(seq.counts)
        mism = sum(
            res.counts.get(k, 0) != seq.counts.get(k, 0) for k in keys)
        rows.append(csv_row(
            f"fig7_accuracy/{name}", t_par,
            f"types={len(keys)};mismatches={mism};"
            f"exact={'yes' if mism == 0 else 'NO'}",
        ))
        assert mism == 0, f"{name}: {mism} mismatching codes"
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
