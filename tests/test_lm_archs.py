"""Per-arch smoke tests: reduced configs, one forward/train/serve step on CPU.

Full-size configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation) — see launch/dryrun.py and tests/test_dryrun_smoke.py.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_arch, lm_arch_names
from repro.configs.common import lm_active_params
from repro.models import transformer
from repro.training import optimizer


@pytest.fixture(params=lm_arch_names())
def arch(request):
    return get_arch(request.param)


def _batch(cfg, b=2, s=32, seed=0):
    key = jax.random.PRNGKey(seed)
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab)
    return {"tokens": tokens, "targets": jnp.roll(tokens, -1, 1)}


def test_forward_shapes_and_finite(arch):
    cfg = arch.smoke_config
    p = transformer.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    logits, aux = transformer.forward(p, batch["tokens"], cfg)
    assert logits.shape == (2, 32, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    loss = transformer.loss_fn(p, batch, cfg)
    assert bool(jnp.isfinite(loss))
    # untrained loss should be near ln(vocab)
    assert float(loss) < np.log(cfg.vocab) + 3.0


def test_train_step_updates_and_finite(arch):
    cfg = arch.smoke_config
    p = transformer.init_params(jax.random.PRNGKey(0), cfg)
    o = optimizer.init_state(p)
    opt_cfg = optimizer.AdamWConfig(warmup_steps=1)
    batch = _batch(cfg)

    @jax.jit
    def step(p, o, b):
        loss, g = jax.value_and_grad(transformer.loss_fn)(p, b, cfg, None)
        p2, o2, m = optimizer.apply_updates(opt_cfg, p, g, o)
        m["loss"] = loss
        return p2, o2, m

    p2, o2, m = step(p, o, batch)
    assert bool(jnp.isfinite(m["loss"]))
    assert bool(jnp.isfinite(m["grad_norm"]))
    # params actually changed
    diff = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), p, p2
    )
    assert max(jax.tree.leaves(diff)) > 0


def test_serve_step_decodes(arch):
    cfg = arch.smoke_config
    p = transformer.init_params(jax.random.PRNGKey(0), cfg)
    cache = transformer.init_cache(cfg, 2, 64)
    tok = jnp.zeros((2, 1), jnp.int32)
    step = jax.jit(
        lambda p, c, t, i: transformer.serve_step(p, c, t, i, cfg, None)
    )
    logits, cache = step(p, cache, tok, jnp.asarray(0, jnp.int32))
    assert logits.shape == (2, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    # cache got written at position 0
    assert float(jnp.abs(cache["k"][:, :, 0]).sum()) > 0
    logits2, cache = step(p, cache, tok, jnp.asarray(1, jnp.int32))
    assert bool(jnp.isfinite(logits2).all())


def test_decode_matches_prefill(arch):
    """Greedy decode logits must match teacher-forced forward logits."""
    cfg = arch.smoke_config
    p = transformer.init_params(jax.random.PRNGKey(2), cfg)
    b, s = 2, 8
    tokens = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0, cfg.vocab)
    full_logits, _ = transformer.forward(p, tokens, cfg)

    cache = transformer.init_cache(cfg, b, 16)
    step = jax.jit(
        lambda p, c, t, i: transformer.serve_step(p, c, t, i, cfg, None)
    )
    for i in range(s):
        logits, cache = step(
            p, cache, tokens[:, i: i + 1], jnp.asarray(i, jnp.int32)
        )
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(full_logits[:, -1]),
        rtol=2e-4, atol=2e-4,
    )


def test_full_config_param_counts():
    """Full configs must land on their nameplate sizes."""
    expected = {
        "granite-8b": (8.05e9, 0.1),
        "gemma3-1b": (1.0e9, 0.15),
        "qwen2-72b": (72.7e9, 0.1),
        "moonshot-v1-16b-a3b": (28.9e9, 0.2),   # assigned 48L variant
        "arctic-480b": (477e9, 0.1),
    }
    for name, (target, tol) in expected.items():
        n = get_arch(name).config.n_params()
        assert abs(n - target) / target < tol, (name, n)
    # MoE active params far below total
    moon = get_arch("moonshot-v1-16b-a3b").config
    assert lm_active_params(moon) < 0.25 * moon.n_params()
