"""Motif serving subsystem — correctness under multi-tenant concurrency.

The load-bearing guarantees:

* interleaved ingest+query across >= 2 tenant sessions answers exactly what
  batch ``batch_discover()`` answers on each session's closed prefix of admitted
  edges (Lemma 4.2 lifted to the serving layer);
* repeated queries within one epoch hit the snapshot cache — no re-mine —
  and the epoch counter bumps only when the closed prefix changes;
* the whole stack is thread-safe: concurrent ingest and query threads on
  disjoint sessions never corrupt state or serve non-snapshot answers.
"""

import threading
import time

import numpy as np
import pytest

from repro.core import TemporalGraph, transitions
from repro.core.streaming import StreamingMiner
from repro.serving.motif import (
    EpochCache,
    MotifService,
    QueryRequest,
    SessionManager,
)
from conftest import batch_discover, random_graph

DELTA, L_MAX, OMEGA = 20, 4, 3


def closed_prefix(g: TemporalGraph, closed_time: int) -> TemporalGraph:
    cut = int(np.searchsorted(g.t, closed_time, side="left"))
    return TemporalGraph(u=g.u[:cut], v=g.v[:cut], t=g.t[:cut],
                         n_nodes=g.n_nodes)


def make_service(**kw):
    params = dict(delta=DELTA, l_max=L_MAX, omega=OMEGA)
    params.update(kw)
    return MotifService(**params)


def assert_queries_match_batch(service, name, g, backend="ref"):
    """Every query op must agree with batch discover on the closed prefix."""
    sess = service.manager.get(name)
    expect = batch_discover(closed_prefix(g, sess.closed_time), delta=DELTA,
                      l_max=L_MAX, omega=OMEGA, backend=backend)
    tree = expect.tree()

    engine = sess.engine()
    assert engine.result.counts == expect.counts

    hist = service.query(QueryRequest(session=name, op="level_histogram"))
    assert hist.payload == expect.level_histogram()

    total = service.query(QueryRequest(session=name, op="total"))
    assert total.payload == expect.total_processes()

    for level in range(1, L_MAX + 1):
        top = service.query(
            QueryRequest(session=name, op="top_k", level=level, k=5))
        want = sorted(
            ((c, n) for c, n in expect.counts.items()
             if len(c) // 2 == level),
            key=lambda kv: (-kv[1], kv[0]))[:5]
        assert top.payload == want

    for code in list(expect.counts)[:10]:
        for lvl in range(2, len(code) + 1, 2):
            prefix = code[:lvl]
            cnt = service.query(
                QueryRequest(session=name, op="prefix_count", code=prefix))
            assert cnt.payload == tree.node(prefix).through
            probs = service.query(QueryRequest(
                session=name, op="transition_probs", code=prefix))
            want_rows = tree.node(prefix).transition_rows()
            assert [(r.code, r.count, r.share) for r in probs.payload] \
                == want_rows
            if want_rows:
                assert sum(r.share for r in probs.payload) \
                    == pytest.approx(1.0)


def test_interleaved_ingest_query_two_tenants_matches_batch():
    """The acceptance scenario: two tenants, ingest and queries interleaved
    chunk by chunk; answers always equal batch discover on the closed
    prefix of admitted edges."""
    graphs = {"a": random_graph(5, 600, 11, 2_200),
              "b": random_graph(13, 500, 9, 1_800)}
    service = make_service(ingest_batch=1)       # admit every chunk
    for name in graphs:
        service.create_session(name)

    chunk = 120
    for i in range(0, 600, chunk):
        for name, g in graphs.items():
            service.ingest(name, g.u[i:i + chunk], g.v[i:i + chunk],
                           g.t[i:i + chunk])
        # query both tenants between every pair of ingests
        for name, g in graphs.items():
            sess = service.manager.get(name)
            if sess.closed_time is None:
                continue
            expect = batch_discover(closed_prefix(g, sess.closed_time),
                              delta=DELTA, l_max=L_MAX, omega=OMEGA)
            assert sess.engine().result.counts == expect.counts, \
                f"{name} at edge {i}"

    for name, g in graphs.items():
        assert_queries_match_batch(service, name, g)


def test_batched_admission_defers_then_matches():
    """Edges below the admission threshold stay pending (one miner ingest
    per flush); after flush the served state matches batch discover."""
    g = random_graph(3, 400, 8, 1_500)
    service = make_service(ingest_batch=10_000)  # never auto-flush
    service.create_session("a")
    for i in range(0, g.n_edges, 37):
        ack = service.ingest("a", g.u[i:i + 37], g.v[i:i + 37],
                             g.t[i:i + 37])
        assert not ack.flushed
    sess = service.manager.get("a")
    assert sess.pending_edges == g.n_edges
    assert sess.miner.n_edges_ingested == 0
    assert sess.epoch == 0

    ack = service.flush("a")
    assert ack.flushed and ack.accepted == g.n_edges
    assert sess.pending_edges == 0
    assert sess.miner.n_edges_ingested == g.n_edges
    assert sess.flushes == 1                     # one miner ingest total
    assert_queries_match_batch(service, "a", g)


def test_admission_window_repairs_local_disorder():
    """Slightly out-of-order arrivals inside one admission window are
    stable-sorted at flush instead of rejected."""
    service = make_service(ingest_batch=10_000)
    service.create_session("a")
    service.ingest("a", [0, 1], [1, 2], [50, 40])     # locally out of order
    service.ingest("a", [2, 3], [3, 4], [10, 60])
    service.flush("a")
    sess = service.manager.get("a")
    assert sess.miner.n_edges_ingested == 4
    final = sess.miner.snapshot(final=True)
    assert final.total_processes() == 4


def test_rejected_flush_keeps_admission_buffer():
    """A window the miner rejects (an edge older than the stream head) must
    not lose the buffered edges — the buffer survives for inspection."""
    service = make_service(ingest_batch=10_000)
    service.create_session("a")
    service.ingest("a", [0, 1], [1, 2], [100, 200])
    service.flush("a")
    sess = service.manager.get("a")
    service.ingest("a", np.arange(9), np.arange(1, 10),
                   np.arange(300, 309))
    service.ingest("a", [9], [10], [50])         # older than the head
    with pytest.raises(ValueError, match="time-ordered"):
        service.flush("a")
    assert sess.pending_edges == 10              # nothing silently dropped
    assert sess.miner.n_edges_ingested == 2

    # recovery: discard the poisoned window, then the session serves again
    assert service.discard_pending("a") == 10
    assert sess.pending_edges == 0
    service.ingest("a", [20], [21], [400])
    service.flush("a")
    assert sess.miner.n_edges_ingested == 3
    assert sess.stats()["edges_discarded"] == 10


def test_cache_hit_no_remine_within_epoch():
    """Repeated queries within an epoch must reuse the mined snapshot."""
    g = random_graph(9, 500, 10, 2_000)
    service = make_service(ingest_batch=1)
    service.create_session("a")
    service.ingest("a", g.u[:400], g.v[:400], g.t[:400])
    sess = service.manager.get("a")

    for _ in range(5):
        service.query(QueryRequest(session="a", op="level_histogram"))
        service.query(QueryRequest(session="a", op="top_k", level=1))
    stats = sess.stats()
    assert stats["snapshots_mined"] == 1         # mined once, served 10x
    assert stats["cache"]["hits"] == 9
    epoch_before = sess.epoch

    # new edges advance the closed prefix -> exactly one more mine
    service.ingest("a", g.u[400:], g.v[400:], g.t[400:])
    assert sess.epoch > epoch_before
    for _ in range(3):
        service.query(QueryRequest(session="a", op="total"))
    stats = sess.stats()
    assert stats["snapshots_mined"] == 2
    assert stats["cache"]["hits"] == 9 + 2


def test_epoch_bumps_only_when_closed_prefix_changes():
    miner = StreamingMiner(delta=10, l_max=2, omega=2)
    assert miner.epoch == 0
    miner.ingest([0], [1], [100])
    e1 = miner.epoch
    assert e1 == 1                               # closed_time appeared
    miner.ingest([1], [2], [100])                # same t_head, no finalize
    assert miner.epoch == e1
    miner.ingest([2], [3], [500])                # head advances
    assert miner.epoch > e1


def test_query_response_protocol_fields():
    g = random_graph(2, 300, 7, 1_000)
    service = make_service(ingest_batch=1)
    service.create_session("a")
    service.ingest("a", g.u, g.v, g.t)
    sess = service.manager.get("a")
    resp = service.query(QueryRequest(session="a", op="prefix_count",
                                      code="01"))
    assert resp.session == "a"
    assert resp.op == "prefix_count"
    assert resp.epoch == sess.epoch
    assert resp.latency_s >= 0.0
    assert isinstance(resp.payload, int)

    with pytest.raises(ValueError, match="unknown op"):
        service.query(QueryRequest(session="a", op="nope"))
    with pytest.raises(KeyError, match="unknown session"):
        service.query(QueryRequest(session="ghost", op="total"))
    with pytest.raises(ValueError, match="odd length"):
        service.query(QueryRequest(session="a", op="prefix_count", code="0"))
    # unknown-but-well-formed codes are cheap misses, not errors —
    # in-alphabet ("ee"), out-of-alphabet ("ff", "zz"), and codes longer
    # than l_max edges ("01" * 8 with l_max=4) alike
    for code in ("ee", "ff", "zz", "01" * 8):
        empty = service.query(QueryRequest(
            session="a", op="transition_probs", code=code))
        assert empty.payload == []
        zero = service.query(QueryRequest(session="a", op="prefix_count",
                                          code=code))
        assert zero.payload == 0


def test_session_manager_lifecycle():
    manager = SessionManager(max_sessions=2, delta=DELTA, l_max=L_MAX,
                             omega=OMEGA)
    manager.create("a")
    manager.create("b", delta=50)                # per-tenant override
    assert manager.get("b").miner.delta == 50
    assert manager.names() == ["a", "b"]
    with pytest.raises(ValueError, match="already exists"):
        manager.create("a")
    with pytest.raises(RuntimeError, match="session limit"):
        manager.create("c")
    manager.drop("a")
    manager.create("c")
    with pytest.raises(KeyError, match="unknown session"):
        manager.get("a")
    stats = manager.stats()
    assert stats["n_sessions"] == 2


def test_epoch_cache_lru_and_stats():
    cache = EpochCache(capacity=2)
    assert cache.get(0) is None
    cache.put(0, "e0")
    cache.put(1, "e1")
    assert cache.get(0) == "e0"                  # refreshes LRU order
    cache.put(2, "e2")                           # evicts epoch 1
    assert cache.get(1) is None
    assert cache.get(0) == "e0"
    stats = cache.stats()
    assert stats == {"hits": 2, "misses": 2, "evictions": 1, "entries": 2}
    with pytest.raises(ValueError):
        EpochCache(capacity=0)


def test_concurrent_tenants_threaded():
    """Ingest threads and query threads race across two sessions; the final
    served state must still equal batch discover per tenant.  The numpy
    oracle backend keeps this pure host-side.

    The service runs with a live :class:`repro.obs.Observability` bundle
    and dedicated hammer threads pound the same ``MetricsRegistry`` the
    whole time — every increment must land exactly (per-instrument locks),
    the serving histograms must account for every query the drivers
    issued, and both export formats must render after the storm.
    """
    import repro.obs as obs_mod

    graphs = {"a": random_graph(21, 400, 8, 1_500),
              "b": random_graph(22, 400, 8, 1_500)}
    obs = obs_mod.enabled()
    service = make_service(backend="numpy", ingest_batch=64, obs=obs)
    for name in graphs:
        service.create_session(name)

    errors: list[Exception] = []
    done = threading.Event()
    n_queries: dict[str, int] = {}

    def ingester(name, g):
        try:
            for i in range(0, g.n_edges, 50):
                service.ingest(name, g.u[i:i + 50], g.v[i:i + 50],
                               g.t[i:i + 50])
        except Exception as exc:                 # pragma: no cover
            errors.append(exc)

    def querier(name):
        served = 0
        try:
            while not done.is_set():
                r = service.query(
                    QueryRequest(session=name, op="level_histogram"))
                assert isinstance(r.payload, dict)
                r = service.query(
                    QueryRequest(session=name, op="prefix_count", code="01"))
                assert r.payload >= 0
                served += 2
        except Exception as exc:                 # pragma: no cover
            errors.append(exc)
        n_queries[name] = served

    HAMMER_ITERS, HAMMER_THREADS = 4_000, 4

    def hammer(worker):
        try:
            c = obs.metrics.counter("test_hammer_total")
            h = obs.metrics.histogram("test_hammer_ms")
            g_ = obs.metrics.gauge("test_hammer_gauge", worker=str(worker))
            for k in range(HAMMER_ITERS):
                c.inc()
                h.observe(float(k % 7))
                g_.set(k)
        except Exception as exc:                 # pragma: no cover
            errors.append(exc)

    threads = [threading.Thread(target=ingester, args=(n, g))
               for n, g in graphs.items()]
    threads += [threading.Thread(target=querier, args=(n,)) for n in graphs]
    threads += [threading.Thread(target=hammer, args=(i,))
                for i in range(HAMMER_THREADS)]
    for t in threads:
        t.start()
    for t in threads[:2]:
        t.join()
    done.set()
    for t in threads[2:]:
        t.join()
    assert not errors, errors

    # every hammer increment landed exactly once
    total = HAMMER_ITERS * HAMMER_THREADS
    assert obs.metrics.counter("test_hammer_total").value == total
    assert obs.metrics.find("test_hammer_ms").count == total
    # the query histograms account for every query issued (first-call +
    # steady-state split must not lose observations)
    recorded = sum(
        inst.count for inst in obs.metrics.instruments()
        if inst.name in ("repro_serving_query_latency_ms",
                         "repro_serving_query_first_call_ms"))
    assert recorded == sum(n_queries.values())
    # settle one warm query per tenant: under an unlucky schedule every
    # query above raced an ingest (each saw a freshly invalidated index,
    # so every observation landed in first_call) and the steady-state
    # histogram would not exist yet
    for name in graphs:
        service.query(QueryRequest(session=name, op="level_histogram"))
        service.query(QueryRequest(session=name, op="level_histogram"))
    # exports render after concurrent mutation
    snap = obs.metrics.snapshot()
    assert any(c["name"] == "test_hammer_total" for c in snap["counters"])
    prom = obs.metrics.to_prometheus()
    assert "# TYPE test_hammer_total counter" in prom
    assert "# TYPE repro_serving_query_latency_ms histogram" in prom

    for name, g in graphs.items():
        service.flush(name)
        assert_queries_match_batch(service, name, g, backend="numpy")


def test_drop_races_concurrent_ingest_and_query():
    """``drop()`` mid-traffic: racing ingest/query either complete normally
    or see a clean ``KeyError`` — never corruption — and the returned
    session object stays exact for its holder (admitted edges are whole
    chunks, so the closed prefix still matches batch discover)."""
    g = random_graph(33, 1_200, 10, 4_000)
    service = make_service(backend="numpy", ingest_batch=32)
    service.create_session("t")
    service.ingest("t", g.u[:300], g.v[:300], g.t[:300])
    service.flush("t")

    errors: list[Exception] = []
    dropped = threading.Event()

    def ingester():
        try:
            for i in range(300, g.n_edges, 30):
                service.ingest("t", g.u[i:i + 30], g.v[i:i + 30],
                               g.t[i:i + 30])
        except KeyError:
            pass                                 # dropped under our feet
        except Exception as exc:                 # pragma: no cover
            errors.append(exc)

    def querier():
        try:
            while not dropped.is_set():
                try:
                    r = service.query(QueryRequest(session="t", op="total"))
                    assert r.payload >= 0
                except KeyError:
                    break                        # dropped under our feet
        except Exception as exc:                 # pragma: no cover
            errors.append(exc)

    threads = [threading.Thread(target=ingester),
               threading.Thread(target=querier)]
    for t in threads:
        t.start()
    time.sleep(0.05)
    sess = service.drop_session("t")
    dropped.set()
    for t in threads:
        t.join()
    assert not errors, errors
    assert "t" not in service.sessions()
    with pytest.raises(KeyError):
        service.query(QueryRequest(session="t", op="total"))

    # the detached session is still a live, exact miner
    sess.flush()
    expect = batch_discover(closed_prefix(g, sess.closed_time), delta=DELTA,
                            l_max=L_MAX, omega=OMEGA, backend="numpy")
    assert sess.engine().result.counts == expect.counts
    # and the name is immediately reusable
    service.create_session("t")


def test_restore_respects_max_sessions():
    manager = SessionManager(max_sessions=1, delta=DELTA, l_max=L_MAX,
                             omega=OMEGA)
    manager.create("a")
    state = dict(manager.get("a").checkpoint_state(), name="b")
    with pytest.raises(RuntimeError, match="session limit"):
        manager.restore(state)


def test_comine_with_tenant_dropped_mid_call():
    """Explicitly named tenants are a fixed set (missing -> KeyError);
    auto-selection treats a drop between listing and mining as benign."""
    g = random_graph(35, 300, 8, 1_000)
    service = make_service(ingest_batch=64)
    service.create_session("a")
    service.create_session("b")
    service.drop_session("b")
    with pytest.raises(KeyError, match="unknown session"):
        service.comine(g, ["a", "b"])

    # deterministic stand-in for the drop-between-names()-and-get() race:
    # auto-selection sees a tenant that is gone by fetch time
    manager = service.manager
    real_names = manager.names
    manager.names = lambda: real_names() + ["ghost"]
    try:
        results = service.comine(g)
    finally:
        manager.names = real_names
    assert sorted(results) == ["a"]
    assert results["a"].counts == batch_discover(
        g, delta=DELTA, l_max=L_MAX, omega=OMEGA).counts


def test_first_query_of_epoch_does_not_stall_ingest():
    """Regression: the cold-epoch snapshot mine must run OUTSIDE the
    session lock.  With the mine artificially held open, a concurrent
    ingest has to complete; before the fix it blocked for the whole mine
    (first-query-of-epoch stall)."""
    g = random_graph(37, 600, 10, 2_000)
    service = make_service(ingest_batch=64)
    service.create_session("t")
    service.ingest("t", g.u[:300], g.v[:300], g.t[:300])
    sess = service.manager.get("t")

    real_mine = sess.miner.mine_view
    in_mine = threading.Event()
    release = threading.Event()

    def held_mine(view):
        in_mine.set()
        assert release.wait(10), "test harness never released the mine"
        return real_mine(view)

    # patch the miner's snapshot mine (NOT the executor — ingest-side
    # flushes go through the executor too and must stay fast)
    sess.miner.mine_view = held_mine
    resp: dict = {}

    def query():
        resp["r"] = service.query(QueryRequest(session="t", op="total"))

    qt = threading.Thread(target=query)
    qt.start()
    assert in_mine.wait(10), "query never reached the snapshot mine"

    ingested = threading.Event()

    def ingest():
        service.ingest("t", g.u[300:], g.v[300:], g.t[300:])
        service.flush("t")
        ingested.set()

    it = threading.Thread(target=ingest)
    it.start()
    # the mine is still blocked (release unset) -- ingest+flush must
    # finish anyway because the lock was dropped for the device work
    assert ingested.wait(10), \
        "ingest stalled behind the first query of the epoch"
    assert not resp, "query returned before its mine was released"
    release.set()
    qt.join(10)
    assert not qt.is_alive()
    sess.miner.mine_view = real_mine

    assert resp["r"].payload >= 0
    # the raced snapshot stays exact: served counts on the final closed
    # prefix still equal batch discovery
    assert_queries_match_batch(service, "t", g)
