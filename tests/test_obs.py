"""Observability layer — registry exactness, span semantics, exports.

The guarantees the rest of the stack leans on:

* histograms are **exact** below the sample bound (nearest-rank, matching
  numpy's ``inverted_cdf``) and degrade to bucket interpolation above it;
* spans nest, time-contain their children, attribute first-call compile
  vs steady-state exec per compile key, and survive exceptions;
* the Chrome-trace and Prometheus exports are schema-valid and the JSON
  snapshot round-trips through ``json``;
* the disabled mode (``NULL_OBS``) is shared no-op singletons — no state,
  no files unless asked, identical call surface.
"""

import argparse
import json
import threading

import numpy as np
import pytest

import repro.obs as obs_mod
from repro.obs import NULL_OBS, Observability, get_obs
from repro.obs.metrics import (
    DEFAULT_MS_BUCKETS,
    MetricsRegistry,
    merged_percentile,
)
from repro.obs.timing import Stopwatch, latency_summary, percentile_ms
from repro.obs.tracing import Tracer


# -- metrics ----------------------------------------------------------------


def test_histogram_exact_below_sample_bound():
    reg = MetricsRegistry()
    h = reg.histogram("lat_ms", sample_bound=64)
    rng = np.random.default_rng(3)
    values = rng.uniform(0.01, 900.0, 50)
    for v in values:
        h.observe(v)
    assert h.exact
    for q in (50, 95, 99):
        want = float(np.percentile(values, q, method="inverted_cdf"))
        assert h.percentile(q) == pytest.approx(want)
    snap = h.snapshot()
    assert snap["count"] == 50 and snap["exact"]
    assert snap["p50"] == pytest.approx(h.percentile(50))


def test_histogram_interpolates_above_sample_bound():
    reg = MetricsRegistry()
    h = reg.histogram("lat_ms", sample_bound=8)
    values = [0.3, 0.4, 0.6, 1.5, 3.0, 4.0, 7.0, 8.0, 30.0, 700.0]
    for v in values:
        h.observe(v)
    assert not h.exact
    # interpolated percentiles stay inside the containing bucket
    p50 = h.percentile(50)
    assert 2.5 < p50 <= 5.0
    assert h.percentile(99) <= h.snapshot()["max"] == 700.0
    assert h.count == len(values)
    assert h.sum == pytest.approx(sum(values))


def test_histogram_rejects_bad_input():
    reg = MetricsRegistry()
    with pytest.raises(ValueError):
        reg.histogram("bad", buckets=(3.0, 1.0))
    h = reg.histogram("lat_ms")
    with pytest.raises(ValueError):
        h.percentile(101)
    with pytest.raises(ValueError):
        reg.counter("c_total").inc(-1)


def test_registry_get_or_create_and_kind_mismatch():
    reg = MetricsRegistry()
    c1 = reg.counter("x_total", path="a")
    c2 = reg.counter("x_total", path="a")
    assert c1 is c2
    assert reg.counter("x_total", path="b") is not c1
    with pytest.raises(TypeError):
        reg.gauge("x_total", path="a")
    assert reg.find("x_total", path="a") is c1
    assert reg.find("nope") is None


def test_merged_percentile_exact_and_bucketed():
    reg = MetricsRegistry()
    a = reg.histogram("h", tenant="a")
    b = reg.histogram("h", tenant="b")
    va, vb = [1.0, 5.0, 9.0], [2.0, 4.0]
    for v in va:
        a.observe(v)
    for v in vb:
        b.observe(v)
    pooled = np.array(va + vb)
    assert merged_percentile([a, b], 50) == pytest.approx(
        float(np.percentile(pooled, 50, method="inverted_cdf")))
    assert merged_percentile([], 50) == 0.0
    # non-exact path: same edges required
    reg2 = MetricsRegistry()
    big = reg2.histogram("h2", sample_bound=2)
    for v in (0.2, 0.7, 3.0, 40.0):
        big.observe(v)
    assert not big.exact
    p = merged_percentile([big], 50)
    assert 0.5 < p <= 40.0
    odd = reg2.histogram("h3", buckets=(1.0, 2.0))
    odd.observe(1.5)
    with pytest.raises(ValueError):
        merged_percentile([big, odd], 50)


def test_prometheus_exposition_schema():
    reg = MetricsRegistry()
    reg.counter("repro_mining_launches_total", path="fused").inc(3)
    reg.gauge("repro_mining_fused_slots").set(128)
    h = reg.histogram("repro_serving_query_latency_ms", tenant="t0")
    h.observe(1.2)
    h.observe(700.0)
    text = reg.to_prometheus()
    assert "# TYPE repro_mining_launches_total counter" in text
    assert 'repro_mining_launches_total{path="fused"} 3' in text
    assert "# TYPE repro_mining_fused_slots gauge" in text
    assert ("# TYPE repro_serving_query_latency_ms histogram" in text)
    assert ('repro_serving_query_latency_ms_bucket'
            '{le="+Inf",tenant="t0"} 2') in text
    assert "repro_serving_query_latency_ms_count" in text
    assert "repro_serving_query_latency_ms_sum" in text
    # every non-comment line is "name{labels} value"
    for line in text.strip().splitlines():
        if line.startswith("#"):
            continue
        name_part, value = line.rsplit(" ", 1)
        float(value)
        assert name_part.startswith("repro_")


def test_snapshot_is_json_roundtrippable():
    reg = MetricsRegistry()
    reg.counter("a_total").inc()
    reg.histogram("b_ms").observe(2.0)
    snap = reg.snapshot()
    assert set(snap) == {"counters", "gauges", "histograms"}
    assert json.loads(json.dumps(snap)) == snap


# -- tracing ----------------------------------------------------------------


def test_span_nesting_and_containment():
    tr = Tracer()
    with tr.span("outer", layer="engine"):
        with tr.span("inner"):
            pass
    events = tr.events()
    assert [e["name"] for e in events] == ["inner", "outer"]
    inner, outer = events
    assert outer["ts"] <= inner["ts"]
    assert outer["ts"] + outer["dur"] >= inner["ts"] + inner["dur"]
    assert outer["args"]["layer"] == "engine"
    assert all(e["ph"] == "X" for e in events)
    assert tr.span_names() == {"inner", "outer"}


def test_compile_exec_attribution():
    tr = Tracer()
    key = ("fused", "pallas", 90, 5)
    for _ in range(3):
        with tr.span("mine.fused", compile_key=key):
            pass
    phases = [e["args"]["phase"] for e in tr.events()]
    assert phases == ["compile", "exec", "exec"]
    att = tr.attribution()[repr(key)]
    assert att["span"] == "mine.fused"
    assert att["exec_calls"] == 2
    assert att["compile_ms"] >= 0.0
    assert att["exec_ms_min"] is not None


def test_span_error_and_set_and_sync():
    tr = Tracer()
    with pytest.raises(RuntimeError):
        with tr.span("boom"):
            raise RuntimeError("x")
    assert tr.events()[0]["args"]["error"] == "RuntimeError"
    with tr.span("ok") as sp:
        sp.set(zones=7).sync(np.zeros(4))  # block_until_ready accepts numpy
    assert tr.events()[-1]["args"]["zones"] == 7


def test_tracer_bounded_buffer():
    tr = Tracer(max_events=2)
    for i in range(4):
        with tr.span(f"s{i}"):
            pass
    assert len(tr.events()) == 2
    assert tr.dropped == 2
    assert tr.to_chrome_trace()["otherData"]["dropped_events"] == 2


def test_chrome_trace_schema(tmp_path):
    tr = Tracer()
    with tr.span("a", compile_key=("k",)):
        pass
    path = tmp_path / "trace.json"
    tr.write(str(path))
    doc = json.loads(path.read_text())
    assert doc["displayTimeUnit"] == "ms"
    events = doc["traceEvents"]
    assert events[0]["ph"] == "M"  # process_name metadata first
    for e in events[1:]:
        assert e["ph"] == "X"
        assert isinstance(e["ts"], (int, float))
        assert isinstance(e["dur"], (int, float))
        assert e["pid"] and e["tid"]
    assert repr(("k",)) in doc["otherData"]["attribution"]


def test_tracer_threads_keep_local_nesting():
    tr = Tracer()
    # barrier keeps all workers alive at once so thread ids are distinct
    gate = threading.Barrier(4)

    def worker(i):
        gate.wait()
        with tr.span(f"w{i}"):
            with tr.span(f"w{i}.child"):
                pass

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(tr.events()) == 8
    tids = {e["tid"] for e in tr.events()}
    assert len(tids) == 4


# -- bundle / disabled mode -------------------------------------------------


def test_null_obs_is_shared_noop():
    assert get_obs(None) is NULL_OBS
    assert not NULL_OBS.enabled
    # one shared span object, one shared instrument each — no allocation
    assert NULL_OBS.tracer.span("a") is NULL_OBS.tracer.span("b")
    assert (NULL_OBS.metrics.counter("x")
            is NULL_OBS.metrics.counter("y", l="v"))
    NULL_OBS.metrics.counter("x").inc()
    NULL_OBS.metrics.histogram("h").observe(1.0)
    NULL_OBS.metrics.gauge("g").set(2)
    assert NULL_OBS.metrics.snapshot() == {
        "counters": [], "gauges": [], "histograms": []}
    assert NULL_OBS.metrics.to_prometheus() == ""
    assert NULL_OBS.tracer.events() == []
    with NULL_OBS.tracer.span("nested") as sp:
        assert sp.set(a=1) is sp and sp.sync(None) is sp


def test_enabled_bundle_and_global_install():
    obs = obs_mod.enabled()
    assert obs.enabled
    assert isinstance(obs, Observability)
    try:
        obs_mod.install_global(obs)
        assert obs_mod.global_obs() is obs
    finally:
        obs_mod.install_global(None)
    assert obs_mod.global_obs() is NULL_OBS


def test_cli_helpers(tmp_path):
    ap = argparse.ArgumentParser()
    obs_mod.add_cli_args(ap)
    m_path = tmp_path / "metrics.json"
    t_path = tmp_path / "trace.json"
    args = ap.parse_args(
        ["--metrics-out", str(m_path), "--trace-out", str(t_path)])
    try:
        obs = obs_mod.from_cli_args(args)
        assert obs.enabled
        assert obs_mod.global_obs() is obs
        obs.metrics.counter("repro_mining_launches_total", path="fused").inc()
        with obs.tracer.span("mine.fused"):
            pass
        obs_mod.write_cli_outputs(obs, args)
    finally:
        obs_mod.install_global(None)
    metrics_doc = json.loads(m_path.read_text())
    assert set(metrics_doc) == {"metrics", "prometheus"}
    assert "# TYPE repro_mining_launches_total counter" \
        in metrics_doc["prometheus"]
    trace_doc = json.loads(t_path.read_text())
    assert any(e.get("name") == "mine.fused"
               for e in trace_doc["traceEvents"])
    # no flags → the null bundle, nothing installed, nothing written
    off = ap.parse_args([])
    assert obs_mod.from_cli_args(off) is NULL_OBS
    obs_mod.write_cli_outputs(NULL_OBS, off)


# -- timing helpers ---------------------------------------------------------


def test_stopwatch_and_latency_summary():
    with Stopwatch() as sw:
        live = sw.seconds
    assert 0.0 <= live <= sw.seconds
    frozen = sw.seconds
    assert sw.seconds == frozen  # frozen after exit
    assert sw.ms == pytest.approx(frozen * 1e3)

    lats = [0.001, 0.002, 0.004, 0.010]
    assert percentile_ms([], 50) == 0.0
    assert percentile_ms(lats, 50) == pytest.approx(
        float(np.percentile(np.array(lats) * 1e3, 50)))
    digest = latency_summary(lats)
    assert set(digest) == {"count", "mean_ms", "p50_ms", "p95_ms",
                           "p99_ms", "max_ms"}
    assert digest["count"] == 4
    assert digest["max_ms"] == pytest.approx(10.0)
