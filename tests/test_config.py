"""MiningConfig: validation, precedence, serialization, CLI round-trip,
and the deprecated one-shot shims."""

import argparse
import dataclasses
import warnings

import pytest

from repro.core import MiningConfig, discover, discover_sequential
from repro.core.executor import AGG_MODES

from conftest import random_graph


# -- validation -------------------------------------------------------------

@pytest.mark.parametrize("bad", [
    dict(delta=0), dict(delta=-5), dict(l_max=0), dict(l_max=-1),
])
def test_nonpositive_delta_l_max_rejected(bad):
    with pytest.raises(ValueError, match="delta and l_max"):
        MiningConfig(**bad)


def test_omega_floor_rejected():
    with pytest.raises(ValueError, match="omega must be >= 2"):
        MiningConfig(omega=1)


def test_unknown_backend_rejected_with_listing():
    with pytest.raises(ValueError, match="unknown backend.*available"):
        MiningConfig(backend="no-such-backend")


def test_unknown_agg_mode_rejected():
    with pytest.raises(ValueError, match="agg"):
        MiningConfig(agg="bogus")


@pytest.mark.parametrize("bad", [
    dict(e_cap=0), dict(merge_cap=0), dict(zone_chunk=-1),
    dict(memory_budget_mb=0.0), dict(memory_budget_mb=-2.0),
])
def test_nonpositive_capacities_rejected(bad):
    with pytest.raises(ValueError):
        MiningConfig(**bad)


@pytest.mark.parametrize("bad", [
    dict(delta=599.9), dict(l_max=3.5), dict(e_cap=0.9),
])
def test_non_integral_values_rejected_not_truncated(bad):
    with pytest.raises(ValueError, match="must be an integer"):
        MiningConfig(**bad)
    # integral floats are fine and normalize to int
    assert MiningConfig(delta=600.0).delta == 600


def test_zone_chunk_beats_memory_budget_and_warns():
    """The one genuine conflict in the surface: explicit beats derived,
    loudly."""
    with pytest.warns(RuntimeWarning, match="zone_chunk takes precedence"):
        cfg = MiningConfig(delta=30, l_max=3, zone_chunk=4,
                           memory_budget_mb=64.0)
    from repro.core.executor import MiningExecutor

    ex = MiningExecutor.from_config(cfg)
    # the budget-derived plan is never consulted for the chunk
    assert ex._zone_chunk_for(1024, 128) == 4


def test_zone_chunk_zero_means_unchunked_even_with_budget():
    """zone_chunk=0 is an explicit 'unchunked' request — it beats the
    budget-derived chunk (and setting both warns) instead of silently
    falling through to budget-derived chunked mining."""
    from repro.core.executor import MiningExecutor

    with pytest.warns(RuntimeWarning, match="zone_chunk takes precedence"):
        cfg = MiningConfig(delta=30, l_max=3, zone_chunk=0,
                           memory_budget_mb=1.0)
    ex = MiningExecutor.from_config(cfg)
    assert ex._zone_chunk_for(4096, 1024) == 0
    # a budget alone (zone_chunk=None) still derives a chunk, silently
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        cfg2 = MiningConfig(delta=30, l_max=3, memory_budget_mb=1.0)
    assert MiningExecutor.from_config(cfg2)._zone_chunk_for(4096, 1024) > 0


# -- value semantics --------------------------------------------------------

def test_frozen_and_hashable():
    cfg = MiningConfig(delta=60, l_max=3)
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.delta = 10
    assert cfg == MiningConfig(delta=60, l_max=3)
    assert hash(cfg) == hash(MiningConfig(delta=60, l_max=3))
    assert len({cfg, MiningConfig(delta=60, l_max=3)}) == 1


def test_with_updates_revalidates():
    cfg = MiningConfig(delta=60, l_max=3)
    assert cfg.with_updates(omega=4).omega == 4
    assert cfg.with_updates(omega=4) is not cfg
    with pytest.raises(ValueError, match="omega"):
        cfg.with_updates(omega=0)


def test_l_b_derived():
    assert MiningConfig(delta=60, l_max=3).l_b == 180


# -- serialization ----------------------------------------------------------

def test_json_round_trip_exact():
    cfg = MiningConfig(delta=45, l_max=4, omega=6, e_cap=128,
                       backend="numpy", zone_chunk=2, agg="hierarchical",
                       merge_cap=2048, allow_overflow=True)
    back = MiningConfig.from_json(cfg.to_json())
    assert back == cfg and hash(back) == hash(cfg)
    # dict form too
    assert MiningConfig.from_json(cfg.to_dict()) == cfg


def test_from_json_rejects_unknown_fields():
    with pytest.raises(ValueError, match="unknown MiningConfig field"):
        MiningConfig.from_json({"delta": 60, "l_max": 3, "typo_field": 1})


# -- CLI surface ------------------------------------------------------------

def test_cli_defaults_match_dataclass_defaults():
    ap = argparse.ArgumentParser()
    MiningConfig.add_cli_args(ap)
    assert MiningConfig.from_cli_args(ap.parse_args([])) == MiningConfig()


def test_cli_round_trip_non_defaults():
    ap = argparse.ArgumentParser()
    MiningConfig.add_cli_args(ap)
    args = ap.parse_args([
        "--delta", "45", "--l-max", "4", "--omega", "6", "--e-cap", "128",
        "--backend", "numpy", "--zone-chunk", "2", "--agg", "pipelined",
        "--merge-cap", "512", "--allow-overflow",
    ])
    cfg = MiningConfig.from_cli_args(args)
    assert cfg == MiningConfig(
        delta=45, l_max=4, omega=6, e_cap=128, backend="numpy",
        zone_chunk=2, agg="pipelined", merge_cap=512, allow_overflow=True)


def test_cli_rejects_bad_choices():
    ap = argparse.ArgumentParser()
    MiningConfig.add_cli_args(ap)
    with pytest.raises(SystemExit):
        ap.parse_args(["--agg", "bogus"])
    assert set(AGG_MODES) >= {"auto", "legacy", "hierarchical", "pipelined"}


# -- removed shims ----------------------------------------------------------

def test_discover_shims_removed_with_engine_pointer():
    """The one-shot kwargs functions finished their deprecation cycle:
    still importable, but calling raises with migration instructions."""
    g = random_graph(3, 200, 20, 2_000)
    with pytest.raises(RuntimeError, match="PTMTEngine"):
        discover(g, delta=60, l_max=3, omega=4)
    with pytest.raises(RuntimeError, match="PTMTEngine"):
        discover_sequential(g, delta=60, l_max=3)
