"""Config-lattice co-mining differential + executor stats-threading tests.

Tentpole contract: ``engine.discover_many([cfg...])`` groups configs that
differ only in ``delta``/``l_max``/``omega`` into one lattice, runs ONE
Phase-1 expansion at the dominating ``(max delta, max l_max, max omega)``,
and splits per-config count tables during the Phase-2 fold by
prefix-truncating candidates on per-edge absorption timestamps.  Every test
here asserts the co-mined counts are *identical* to independent
``engine.discover`` runs — losslessness is the whole point.

Rider contracts: per-call run stats travel on the :class:`RunOutcome`
returned by ``run_layout``/``run_fused`` (the shared-executor
cross-attribution race), ingestion validates edge chunks before buffering
(silent int32 wrap / float truncation), and ``SessionManager.create``
builds sessions outside the manager-wide lock.
"""

import threading

import numpy as np
import pytest

from conftest import random_graph
from repro.core import MiningExecutor, transitions, tzp
from repro.core.config import MiningConfig
from repro.core.engine import PTMTEngine
from repro.core import planner
from repro.core.streaming import StreamingMiner, validate_edge_chunk

BACKENDS = ("ref", "numpy", "pallas")


def _dict(counts):
    return transitions.device_counts_to_dict(counts)


def _graph(seed=3, n=500, nodes=35, span=2500):
    return random_graph(seed, n, nodes, span)


def _bursty(seed, n=220, nodes=9):
    """Power-law burst sizes + quiet gaps: zone sizes span several
    power-of-two buckets, so dense and bucketed layouts disagree on
    bucket count (what the threading test needs to tell runs apart)."""
    from repro.core.temporal_graph import from_edges

    rng = np.random.default_rng(seed)
    us, vs, ts = [], [], []
    now = 0
    while len(ts) < n:
        burst = min(int(rng.pareto(0.9) * 3) + 1, 70)
        group = rng.integers(0, nodes, size=max(2, burst // 4 + 2))
        for _ in range(burst):
            a, b = rng.choice(group, 2, replace=True)
            us.append(a)
            vs.append(b)
            ts.append(now + int(rng.integers(0, 30)))
        now += int(rng.integers(150, 700))
    return from_edges(np.asarray(us[:n]), np.asarray(vs[:n]),
                      np.asarray(ts[:n]))


def _lattice_configs(backend, **extra):
    """A 4-member lattice: dominating member + strict delta/l_max/omega
    sub-configs (one varying each axis)."""
    base = MiningConfig(delta=50, l_max=4, omega=3, backend=backend, **extra)
    return [
        base,
        base.with_updates(delta=20, l_max=3),
        base.with_updates(delta=35, l_max=2, omega=2),
        base.with_updates(delta=50, l_max=4, omega=4),
    ]


# ---------------------------------------------------------------------------
# Lattice construction.
# ---------------------------------------------------------------------------


def test_lattice_groups_compatible_configs():
    cfgs = _lattice_configs("ref")
    lattices = planner.build_config_lattices(cfgs)
    assert len(lattices) == 1
    lat = lattices[0]
    assert lat.n_configs == 4
    assert lat.indices == (0, 1, 2, 3)
    assert lat.members == tuple(cfgs)
    # dominating = elementwise max over the free axes, other fields shared
    assert (lat.dominating.delta, lat.dominating.l_max,
            lat.dominating.omega) == (50, 4, 4)
    assert lat.dominating.backend == "ref"
    assert lat.params == ((50, 4), (20, 3), (35, 2), (50, 4))


def test_lattice_splits_on_non_free_fields():
    """Anything but delta/l_max/omega is a lattice boundary."""
    a = MiningConfig(delta=50, l_max=4, backend="ref")
    b = a.with_updates(delta=20)                    # same lattice as a
    c = a.with_updates(backend="numpy")             # different backend
    d = a.with_updates(zone_chunk=4)                # different scheduling
    lattices = planner.build_config_lattices([a, c, b, d])
    assert [lat.indices for lat in lattices] == [(0, 2), (1,), (3,)]


def test_dominating_config_is_elementwise_max():
    cfgs = _lattice_configs("ref")
    dom = planner.dominating_config(cfgs)
    assert (dom.delta, dom.l_max, dom.omega) == (50, 4, 4)


# ---------------------------------------------------------------------------
# Differential: co-mined == independent, across backends and layouts.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("layout", ["dense", "bucketed"])
def test_discover_many_matches_independent(backend, layout):
    g = _graph()
    cfgs = _lattice_configs(backend, zone_layout=layout)
    eng = PTMTEngine(cfgs[0])
    results = eng.discover_many(g, cfgs)
    assert len(results) == 4
    for cfg, res in zip(cfgs, results):
        solo = PTMTEngine(cfg).discover(g)
        assert res.counts == solo.counts, \
            f"{backend}/{layout} lattice member {cfg.delta}/{cfg.l_max} " \
            f"diverged from independent discover"
    exec_stats = results[0].layout["execution"]
    assert exec_stats["n_configs"] == 4
    assert exec_stats["path"] in ("per-bucket-multi", "fused-multi",
                                  "fused_xla-multi")
    assert eng.stats.discover_many_calls == 1
    assert eng.stats.comined_configs == 4


def test_discover_many_shares_one_sweep():
    """One lattice = one Phase-1 expansion: the engine's launch counter
    after a 4-config co-mine equals a single dominating discover's, not
    4x it."""
    g = _graph()
    cfgs = _lattice_configs("ref")
    solo = PTMTEngine(planner.dominating_config(cfgs))
    solo.discover(g)
    eng = PTMTEngine(cfgs[0])
    eng.discover_many(g, cfgs)
    assert eng.stats.launches == solo.stats.launches


def test_discover_many_fused_single_launch():
    g = _graph(seed=7)
    cfgs = _lattice_configs("pallas", zone_layout="bucketed", fused="on")
    eng = PTMTEngine(cfgs[0])
    results = eng.discover_many(g, cfgs)
    exec_stats = results[0].layout["execution"]
    assert exec_stats["path"] in ("fused-multi", "fused_xla-multi")
    assert exec_stats["launches"] == 1
    for cfg, res in zip(cfgs, results):
        assert res.counts == PTMTEngine(cfg).discover(g).counts
        ref_cfg = cfg.with_updates(backend="ref", fused="auto",
                                   fused_backend="auto")
        assert res.counts == PTMTEngine(ref_cfg).discover(g).counts


def test_discover_many_mixed_lattices_and_order():
    """Incompatible configs split into lattices but results come back in
    input order, each still equal to its independent run."""
    g = _graph(seed=9, n=300)
    a = MiningConfig(delta=40, l_max=3, backend="ref")
    cfgs = [a, a.with_updates(backend="numpy"), a.with_updates(delta=15),
            a.with_updates(backend="numpy", l_max=2)]
    eng = PTMTEngine(a)
    results = eng.discover_many(g, cfgs)
    for cfg, res in zip(cfgs, results):
        assert res.counts == PTMTEngine(cfg).discover(g).counts
        assert (res.delta, res.l_max) == (cfg.delta, cfg.l_max)


def test_discover_many_survives_tiny_merge_cap_retry():
    """Per-config bounded-carry spill: only spilled members' caps double,
    and the retry converges to exact counts."""
    g = _graph(seed=11)
    base = MiningConfig(delta=50, l_max=4, backend="ref", merge_cap=8,
                        zone_chunk=4)
    cfgs = [base, base.with_updates(delta=20, l_max=3),
            base.with_updates(delta=50, l_max=2)]
    eng = PTMTEngine(base)
    with pytest.warns(RuntimeWarning, match="co-mine.*spilled"):
        results = eng.discover_many(g, cfgs)
    assert results[0].layout["execution"]["spill_retries"] >= 1
    for cfg, res in zip(cfgs, results):
        solo = PTMTEngine(cfg.with_updates(merge_cap=None,
                                           zone_chunk=None)).discover(g)
        assert res.counts == solo.counts


def test_discover_many_empty_and_single():
    g = _graph(seed=2, n=120)
    cfg = MiningConfig(delta=40, l_max=3, backend="ref")
    eng = PTMTEngine(cfg)
    assert eng.discover_many(g, []) == []
    [res] = eng.discover_many(g, [cfg])
    assert res.counts == PTMTEngine(cfg).discover(g).counts


# ---------------------------------------------------------------------------
# Run-stats threading contract (the shared-executor race, satellite 1).
# ---------------------------------------------------------------------------


def test_run_stats_travel_with_outcome_under_concurrency():
    """Two threads mining different layouts through ONE executor must each
    see their own launch/path stats — the old ``last_run_stats`` attribute
    cross-attributed whichever run finished last."""
    g = _bursty(seed=5)
    cfg = MiningConfig(delta=12, l_max=3, omega=2, backend="ref")
    plan = tzp.plan_zones(g, delta=12, l_max=3, omega=2)
    lay_dense = tzp.build_zone_layout(g, plan, layout="dense")
    lay_buck = tzp.build_zone_layout(g, plan, layout="bucketed")
    assert lay_buck.n_buckets > lay_dense.n_buckets
    ex = MiningExecutor.from_config(cfg)
    # warm both executables so the threaded phase measures dispatch only
    expect = {
        id(lay_dense): (_dict(ex.run_layout(lay_dense).counts),
                        lay_dense.n_buckets),
        id(lay_buck): (_dict(ex.run_layout(lay_buck).counts),
                       lay_buck.n_buckets),
    }
    barrier = threading.Barrier(2)
    errors = []

    def worker(lay):
        want_counts, want_launches = expect[id(lay)]
        barrier.wait()
        for _ in range(8):
            out = ex.run_layout(lay)
            if out.stats["launches"] != want_launches:
                errors.append(
                    f"launches {out.stats['launches']} != {want_launches}")
            if _dict(out.counts) != want_counts:
                errors.append("counts cross-attributed")
    threads = [threading.Thread(target=worker, args=(lay,))
               for lay in (lay_dense, lay_buck)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors[:4]


def test_last_run_stats_removed_with_outcome_pointer():
    """The racy shared-state alias finished its deprecation cycle —
    reading it now raises and points at the per-run RunOutcome.stats."""
    cfg = MiningConfig(delta=40, l_max=3, backend="ref")
    ex = MiningExecutor.from_config(cfg)
    with pytest.raises(RuntimeError, match="RunOutcome"):
        ex.last_run_stats


# ---------------------------------------------------------------------------
# Ingest validation (satellite 2).
# ---------------------------------------------------------------------------


def test_validate_edge_chunk_rejects_floats_and_overflow():
    with pytest.raises(ValueError, match="integer-typed"):
        validate_edge_chunk([1], [2], [3.5])
    with pytest.raises(ValueError, match="int32 range"):
        validate_edge_chunk([2**31], [2], [3])
    with pytest.raises(ValueError, match="int32 range"):
        validate_edge_chunk([1], [-2**31 - 1], [3])
    u, v, t = validate_edge_chunk(
        np.array([1], np.int64), np.array([2], np.uint8), [3])
    assert (u.dtype, v.dtype, t.dtype) == (np.int32, np.int32, np.int64)


def test_streaming_miner_ingest_validates_before_buffering():
    miner = StreamingMiner(delta=40, l_max=3)
    with pytest.raises(ValueError, match="would silently wrap"):
        miner.ingest([2**31], [1], [10])
    with pytest.raises(ValueError, match="integer-typed"):
        miner.ingest([1], [2], np.array([10.0]))
    assert miner.n_edges_ingested == 0          # nothing buffered
    miner.ingest([1], [2], [10])                # valid chunk still works
    assert miner.n_edges_ingested == 1


def test_session_ingest_validates_before_buffering():
    from repro.serving.motif.session import MotifSession

    sess = MotifSession("t0", delta=40, l_max=3)
    with pytest.raises(ValueError, match="would silently wrap"):
        sess.ingest([2**31], [1], [10])
    with pytest.raises(ValueError, match="integer-typed"):
        sess.ingest([1], [2], [10.5])
    assert sess.pending_edges == 0
    sess.ingest([1], [2], [10])
    assert sess.pending_edges == 1


# ---------------------------------------------------------------------------
# Manager create outside the lock (satellite 3) + serving comine.
# ---------------------------------------------------------------------------


def test_manager_create_rolls_back_reservation_on_failure():
    from repro.serving.motif.manager import SessionManager

    mgr = SessionManager()
    with pytest.raises(Exception):
        mgr.create("bad", delta=-5, l_max=3)     # config validation fails
    assert "bad" not in mgr.names()
    assert len(mgr) == 0
    mgr.create("bad", delta=40, l_max=3)         # name immediately reusable
    assert mgr.names() == ["bad"]


def test_manager_create_does_not_hold_lock_during_construction(monkeypatch):
    """While one create is constructing, the registry stays responsive:
    get/names work, the in-flight name is invisible, and a duplicate
    create of the same name is rejected by the reservation."""
    from repro.serving.motif import manager as manager_mod

    mgr = manager_mod.SessionManager()
    mgr.create("ready", delta=40, l_max=3)
    real_session = manager_mod.MotifSession
    started, release = threading.Event(), threading.Event()

    class SlowSession(real_session):
        def __init__(self, name, **kw):
            if name == "slow":
                started.set()
                assert release.wait(5.0)
            super().__init__(name, **kw)

    monkeypatch.setattr(manager_mod, "MotifSession", SlowSession)
    worker = threading.Thread(
        target=mgr.create, args=("slow",), kwargs=dict(delta=40, l_max=3))
    worker.start()
    try:
        assert started.wait(5.0)
        # construction in flight: the manager lock is free ...
        assert mgr.names() == ["ready"]          # reservation invisible
        assert mgr.get("ready").name == "ready"
        with pytest.raises(KeyError):
            mgr.get("slow")                      # not yet committed
        with pytest.raises(ValueError, match="already exists"):
            mgr.create("slow", delta=40, l_max=3)   # but name is reserved
        assert len(mgr) == 2                     # reservation counts
    finally:
        release.set()
        worker.join(10.0)
    assert sorted(mgr.names()) == ["ready", "slow"]


def test_service_comine_matches_independent_discover():
    from repro.serving.motif.service import MotifService

    g = _graph(seed=13, n=300)
    base = MiningConfig(delta=50, l_max=4, backend="ref")
    svc = MotifService(engine=PTMTEngine(base))
    svc.create_session("a")
    svc.create_session("b", delta=20, l_max=3)
    svc.create_session("c", delta=35, l_max=2)
    results = svc.comine(g)
    assert sorted(results) == ["a", "b", "c"]
    for name, cfg in (("a", base),
                      ("b", base.with_updates(delta=20, l_max=3)),
                      ("c", base.with_updates(delta=35, l_max=2))):
        assert results[name].counts == PTMTEngine(cfg).discover(g).counts
    # subset selection routes through the same shared sweep
    sub = svc.comine(g, ["b", "c"])
    assert sorted(sub) == ["b", "c"]
    assert sub["b"].counts == results["b"].counts
