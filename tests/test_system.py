"""End-to-end behaviour tests for the paper's system."""

import os
import subprocess
import sys

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, ndev: int = 1, timeout: int = 540):
    env = dict(os.environ, PYTHONPATH="src")
    if ndev > 1:
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={ndev}"
        )
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=timeout, env=env, cwd=REPO,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_mine_cli_end_to_end():
    out = _run(
        "import sys; sys.argv=['mine','--dataset','collegemsg-like',"
        "'--delta','900','--l-max','3','--omega','6',"
        "'--check-sequential'];"
        "from repro.launch.mine import main; main()"
    )
    assert "exact match: True" in out


def test_distributed_mining_multi_device_exact():
    """The paper's parallel claim: 8-way sharded zones == oracle counts."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.core import MiningConfig, PTMTEngine, oracle
from repro.data import synthetic_graphs as sg

g = sg.triadic_stream(1500, 40, seed=5)
mesh = jax.make_mesh((8,), ("zones",))
engine = PTMTEngine(MiningConfig(delta=150, l_max=4, omega=4, zone_chunk=2))
res = engine.sharded(g, mesh, ("zones",))
expect = dict(oracle.count_codes(g.u, g.v, g.t, 150, 4))
keys = set(expect) | set(res.counts)
bad = [k for k in keys if expect.get(k, 0) != res.counts.get(k, 0)]
assert not bad, bad[:5]
print("OK", len(res.counts))
"""
    out = _run(code)
    assert "OK" in out


def test_quickstart_example():
    out = _run(open(os.path.join(REPO, "examples", "quickstart.py")).read())
    assert "exactness check vs sequential baseline: PASS" in out


def test_training_example_makes_progress():
    out = _run(
        "import sys; sys.argv=['t','--steps','30','--batch','4',"
        "'--seq-len','64','--ckpt-dir','/tmp/test_train_lm_e2e'];"
        "import shutil; shutil.rmtree('/tmp/test_train_lm_e2e',"
        "ignore_errors=True);"
        "exec(open('examples/train_lm.py').read())"
    )
    assert "loss" in out


def test_pallas_backend_full_pipeline():
    """backend='pallas' through the public API on a real-ish stream."""
    code = """
from repro.core import MiningConfig, PTMTEngine
from repro.data import synthetic_graphs as sg

g = sg.bursty_stream(900, 14, seed=12)
cfg = MiningConfig(delta=80, l_max=5, omega=4, backend="pallas")
a = PTMTEngine(cfg).discover(g)
b = PTMTEngine(cfg.with_updates(backend="ref")).discover(g)
assert a.counts == b.counts
print("OK", len(a.counts))
"""
    out = _run(code)
    assert "OK" in out


def test_hierarchical_merge_matches_flat_and_oracle():
    """The beyond-paper staged merge (§Perf iter 1) must stay exact."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np, jax.numpy as jnp
from repro.core import oracle, transitions, tzp
from repro.data import synthetic_graphs as sg
from repro.distributed import mining

g = sg.bursty_stream(1200, 18, seed=21)
delta, l_max = 90, 4
plan = tzp.plan_zones(g, delta=delta, l_max=l_max, omega=3)
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
batch = tzp.build_zone_batch(g, plan, pad_zones_to=8, n_shards=8)
expect = dict(oracle.count_codes(g.u, g.v, g.t, delta, l_max))
for mode in ("flat", "hierarchical"):
    fn = mining.make_mine_step(
        mesh, ("pod", "data", "model"), delta=delta, l_max=l_max,
        out_cap=4096, merge_mode=mode)
    counts, ovf = fn(jnp.asarray(batch.u), jnp.asarray(batch.v),
                     jnp.asarray(batch.t), jnp.asarray(batch.valid),
                     jnp.asarray(batch.sign))
    got = transitions.counts_to_dict(
        np.asarray(counts.codes), np.asarray(counts.counts),
        np.asarray(counts.unique_mask))
    keys = set(expect) | set(got)
    bad = [k for k in keys if expect.get(k, 0) != got.get(k, 0)]
    assert int(ovf) == 0 and not bad, (mode, bad[:5])
print("OK both modes")
"""
    out = _run(code)
    assert "OK both modes" in out
