"""MiningExecutor + backend registry: dispatch, chunk policy, oracle parity.

Covers the regression for the pre-refactor silent zone drop: ``_mine_batch``
computed ``nchunk = z // zone_chunk`` and discarded the remainder zones when
``zone_chunk`` did not divide the zone count.  The executor must pad (default)
or raise — never drop.
"""

import numpy as np
import pytest

from repro.core import (
    MiningExecutor,
    ZoneChunkError,
    available_backends,
    backends,
    get_backend,
    oracle,
    transitions,
    tzp,
)
from conftest import batch_discover, random_graph


def _counts_dict(counts):
    return transitions.counts_to_dict(
        np.asarray(counts.codes), np.asarray(counts.counts),
        np.asarray(counts.unique_mask),
    )


def _batch_for(g, *, delta, l_max, omega=2, pad_zones_to=1):
    plan = tzp.plan_zones(g, delta=delta, l_max=l_max, omega=omega)
    return plan, tzp.build_zone_batch(g, plan, pad_zones_to=pad_zones_to)


# ---------------------------------------------------------------------------
# Registry.
# ---------------------------------------------------------------------------


def test_builtin_backends_registered():
    assert {"ref", "pallas", "numpy"} <= set(available_backends())
    assert get_backend("ref").jittable
    assert not get_backend("numpy").jittable
    assert get_backend("numpy").grade == "oracle"
    assert get_backend("pallas").block_defaults["c_blk"] > 0


def test_unknown_backend_lists_available():
    with pytest.raises(ValueError, match="available"):
        get_backend("no-such-backend")
    with pytest.raises(ValueError, match="available"):
        MiningExecutor(delta=5, l_max=3, backend="no-such-backend")


def test_register_backend_rejects_duplicates_and_accepts_plugins():
    with pytest.raises(ValueError, match="already registered"):
        backends.register_backend("ref", lambda: None)
    spec = backends.register_backend(
        "test-plugin", lambda: get_backend("ref").scan, grade="reference",
    )
    try:
        assert "test-plugin" in available_backends()
        g = random_graph(0, 60, 6, 200)
        got = batch_discover(g, delta=20, l_max=3, omega=2, backend="test-plugin")
        expect = batch_discover(g, delta=20, l_max=3, omega=2, backend="ref")
        assert got.counts == expect.counts
        assert spec.scan is get_backend("ref").scan
    finally:
        backends._REGISTRY.pop("test-plugin", None)


# ---------------------------------------------------------------------------
# Zone-chunk divisibility (the silent-drop regression).
# ---------------------------------------------------------------------------


def test_executor_pads_non_divisible_zone_chunk():
    """z % zone_chunk != 0 must NOT drop the remainder zones."""
    g = random_graph(7, 350, 10, 900)
    delta, l_max = 30, 4
    plan, batch = _batch_for(g, delta=delta, l_max=l_max, omega=2)
    assert batch.n_zones % 2 == 1, "need an odd zone count for the repro"

    expect = dict(oracle.count_codes(g.u, g.v, g.t, delta, l_max))
    ex = MiningExecutor(delta=delta, l_max=l_max, zone_chunk=2)
    got = _counts_dict(ex.run(batch))
    assert got == expect


def test_executor_raise_policy():
    g = random_graph(7, 350, 10, 900)
    plan, batch = _batch_for(g, delta=30, l_max=4)
    assert batch.n_zones % 2 == 1
    ex = MiningExecutor(delta=30, l_max=4, zone_chunk=2, pad_policy="raise")
    with pytest.raises(ZoneChunkError, match="not divisible"):
        ex.run(batch)


def test_traceable_path_raises_on_non_divisible():
    """Inside a trace there is no host to pad: scan_aggregate must raise."""
    import jax.numpy as jnp

    ex = MiningExecutor(delta=10, l_max=3, zone_chunk=2)
    z, e = 5, 8
    with pytest.raises(ZoneChunkError, match="not divisible"):
        ex.scan_aggregate(
            jnp.zeros((z, e), jnp.int32), jnp.zeros((z, e), jnp.int32),
            jnp.zeros((z, e), jnp.int32), jnp.zeros((z, e), bool),
            jnp.ones(z, jnp.int32),
        )


def test_chunked_scan_matches_unchunked():
    g = random_graph(3, 240, 8, 600)
    delta, l_max = 25, 4
    plan, batch = _batch_for(g, delta=delta, l_max=l_max, pad_zones_to=4)
    assert batch.n_zones % 4 == 0
    base = MiningExecutor(delta=delta, l_max=l_max, zone_chunk=0)
    chunked = MiningExecutor(delta=delta, l_max=l_max, zone_chunk=4)
    assert _counts_dict(base.run(batch)) == _counts_dict(chunked.run(batch))


# ---------------------------------------------------------------------------
# NumPy oracle backend.
# ---------------------------------------------------------------------------


def test_numpy_backend_matches_oracle_end_to_end():
    for seed in range(3):
        g = random_graph(seed, 180, 9, 500)
        delta, l_max = 35, 4
        expect = dict(oracle.count_codes(g.u, g.v, g.t, delta, l_max))
        got = batch_discover(g, delta=delta, l_max=l_max, omega=3,
                       backend="numpy")
        assert got.counts == expect, f"seed={seed}"


def test_numpy_scan_matches_ref_scan_per_zone():
    from repro.core import expansion, scan_numpy

    g = random_graph(11, 120, 7, 400)
    plan, batch = _batch_for(g, delta=20, l_max=3)
    a = scan_numpy.scan_zones(batch.u, batch.v, batch.t, batch.valid,
                              delta=20, l_max=3)
    b = expansion.scan_zones(batch.u, batch.v, batch.t, batch.valid,
                             delta=20, l_max=3)
    np.testing.assert_array_equal(a.length, np.asarray(b.length))
    np.testing.assert_array_equal(a.code, np.asarray(b.code))


def test_numpy_backend_rejected_in_traced_context():
    ex = MiningExecutor(delta=10, l_max=3, backend="numpy")
    with pytest.raises(ValueError, match="host-only"):
        ex.scan_aggregate(
            np.zeros((2, 8), np.int32), np.zeros((2, 8), np.int32),
            np.zeros((2, 8), np.int32), np.zeros((2, 8), bool),
            np.ones(2, np.int32),
        )


def test_mesh_requires_jittable_backend():
    import jax

    from repro.distributed import mining

    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("z",))
    with pytest.raises(ValueError, match="host-only"):
        mining.make_mine_fn(mesh, ("z",), delta=10, l_max=3,
                            backend="numpy")


# ---------------------------------------------------------------------------
# Capacity planner: budgets instead of hardcoded hints.
# ---------------------------------------------------------------------------


def test_plan_capacity_monotone_in_budget():
    from repro.core import planner

    caps = [
        planner.plan_capacity(n_zones=4096, e_cap=1024, l_max=5,
                              memory_budget_mb=mb).zone_chunk
        for mb in (1, 16, 256, 4096)
    ]
    assert all(a <= b for a, b in zip(caps, caps[1:]))
    assert caps[0] >= 1
    assert all(c & (c - 1) == 0 for c in caps), "power-of-two chunks"


def test_plan_capacity_peak_fits_budget():
    from repro.core import planner

    plan = planner.plan_capacity(n_zones=2048, e_cap=512, l_max=4,
                                 memory_budget_mb=64)
    assert plan.fits
    assert plan.est_peak_bytes <= plan.budget_bytes
    # hierarchical peak is Z-independent: same plan at 16x the zones
    plan_big = planner.plan_capacity(n_zones=32768, e_cap=512, l_max=4,
                                     memory_budget_mb=64)
    assert plan_big.zone_chunk == plan.zone_chunk


def test_pallas_mem_model_exceeds_ref():
    """The Pallas kernel pads the edge axis to block multiples, so its
    planner model must never undercount vs the reference model."""
    from repro.core import planner

    for e_cap in (8, 100, 512, 4096):
        assert (planner.pallas_zone_bytes(e_cap, 5)
                >= planner.ref_zone_bytes(e_cap, 5))


def test_suggest_e_cap_power_of_two_and_budget_scaled():
    from repro.core import planner

    small = planner.suggest_e_cap(l_max=5, memory_budget_mb=4)
    big = planner.suggest_e_cap(l_max=5, memory_budget_mb=512)
    assert small & (small - 1) == 0
    assert big > small


def test_budget_derived_zone_chunk_is_exact():
    """An executor given only a memory budget must still be exact, and must
    actually chunk (derived zone_chunk smaller than the zone count)."""
    g = random_graph(13, 400, 10, 1_000)
    delta, l_max = 30, 4
    plan, batch = _batch_for(g, delta=delta, l_max=l_max, omega=2,
                             pad_zones_to=1)
    ex = MiningExecutor(delta=delta, l_max=l_max, memory_budget_mb=0.75)
    zc = ex._zone_chunk_for(batch.n_zones, batch.e_cap)
    assert 0 < zc < batch.n_zones
    expect = dict(oracle.count_codes(g.u, g.v, g.t, delta, l_max))
    assert _counts_dict(ex.run(batch)) == expect


def test_executor_rejects_unknown_agg_mode():
    with pytest.raises(ValueError, match="agg mode"):
        MiningExecutor(delta=5, l_max=3, agg="no-such-mode")
