"""Per-arch GNN smoke tests: reduced configs, one forward/train step on CPU."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.configs.gnn_common import GNNShape, _specialize
from repro.data.graph_data import random_graph_batch
from repro.models import equiformer, gnn
from repro.models.params import tree_init
from repro.training import optimizer

GNN_NAMES = ["gatedgcn", "gin-tu", "gat-cora", "equiformer-v2"]


def _setup(name, n_graphs=0, n_classes=4):
    arch = get_arch(name)
    is_eq = name == "equiformer-v2"
    shape = GNNShape("tiny", 48, 160, 12, n_classes, n_graphs=n_graphs)
    cfg = _specialize(arch.smoke_config, shape)
    g = random_graph_batch(
        n_nodes=48, n_edges=160, d_feat=12, n_classes=n_classes,
        n_graphs=n_graphs, with_positions=is_eq, seed=11,
    )
    mod = equiformer if is_eq else gnn
    specs = (equiformer.equiformer_param_specs(cfg) if is_eq
             else gnn.gnn_param_specs(cfg))
    params = tree_init(jax.random.PRNGKey(0), specs)
    return mod, cfg, params, g


@pytest.mark.parametrize("name", GNN_NAMES)
def test_forward_shapes_and_finite(name):
    mod, cfg, params, g = _setup(name)
    out = mod.forward(params, g, cfg)
    n_out = 4 if name == "equiformer-v2" else cfg.n_classes
    assert out.shape == (48, cfg.n_classes)
    assert bool(jnp.isfinite(out).all())


@pytest.mark.parametrize("name", GNN_NAMES)
def test_train_step_decreases_loss(name):
    mod, cfg, params, g = _setup(name)
    opt_cfg = optimizer.AdamWConfig(lr=3e-3, warmup_steps=1,
                                    weight_decay=0.0)
    state = optimizer.init_state(params)

    @jax.jit
    def step(p, o):
        l, grads = jax.value_and_grad(mod.loss_fn)(p, g, cfg, None)
        p2, o2, m = optimizer.apply_updates(opt_cfg, p, grads, o)
        return p2, o2, l

    losses = []
    for _ in range(12):
        params, state, loss = step(params, state)
        losses.append(float(loss))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]


@pytest.mark.parametrize("name", GNN_NAMES)
def test_graph_readout(name):
    mod, cfg, params, g = _setup(name, n_graphs=4,
                                 n_classes=1 if name == "equiformer-v2"
                                 else 3)
    out = mod.forward(params, g, cfg)
    assert out.shape[0] == 4
    assert bool(jnp.isfinite(out).all())


def test_equiformer_rotation_invariance():
    from scipy.spatial.transform import Rotation

    mod, cfg, params, g = _setup("equiformer-v2")
    out = mod.forward(params, g, cfg)
    r = jnp.asarray(Rotation.random(random_state=5).as_matrix(), jnp.float32)
    out_rot = mod.forward(
        params, dict(g, positions=g["positions"] @ r.T), cfg
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_rot),
                               rtol=2e-4, atol=2e-5)


def test_equiformer_edge_chunking_invariance():
    mod, cfg, params, g = _setup("equiformer-v2")
    out = mod.forward(params, g, cfg)
    cfg_c = dataclasses.replace(cfg, edge_chunk=40)
    out_c = mod.forward(params, g, cfg_c)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_c),
                               rtol=1e-5, atol=1e-6)


def test_neighbor_sampler_invariants():
    from repro.data.graph_data import make_csr
    from repro.data.graph_sampler import sample_subgraph

    rng = np.random.default_rng(0)
    n, e = 500, 4000
    src = rng.integers(0, n, e)
    dst = rng.integers(0, n, e)
    indptr, indices = make_csr(n, src, dst)
    seeds = rng.choice(n, 32, replace=False)
    sub = sample_subgraph(indptr, indices, seeds, fanouts=(5, 3), rng=rng,
                          pad_nodes=1024, pad_edges=2048)
    k = sub["n_real_nodes"]
    assert sub["n_seeds"] == 32
    # seeds occupy the first slots
    np.testing.assert_array_equal(np.sort(sub["nodes"][:32]),
                                  np.sort(seeds))
    # every edge references in-subgraph local ids
    ke = sub["n_real_edges"]
    assert (sub["edge_src"][:ke] < k).all()
    assert (sub["edge_dst"][:ke] < k).all()
    # fanout bound: <= 32*5 + 32*5*3 edges
    assert ke <= 32 * 5 + 32 * 5 * 3
    # edges exist in the original graph
    orig = set(zip(src.tolist(), dst.tolist()))
    nodes = sub["nodes"]
    for s, d in zip(sub["edge_src"][:ke], sub["edge_dst"][:ke]):
        # sampler stores (neighbor -> seed) direction; edge was (u, nbr)
        assert (int(nodes[d]), int(nodes[s])) in orig
