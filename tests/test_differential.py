"""Cross-backend differential harness for the full discovery path.

Every graph here flows through the complete ``plan_zones ->
build_zone_batch -> MiningExecutor.run`` pipeline for every backend
(``ref`` jnp reference, ``numpy`` brute-force oracle, ``pallas`` kernel —
interpret mode on CPU; the fused tests additionally sweep the compiled
``xla`` lowering against the interpreted Pallas one) and every
aggregation configuration (chunked vs
unchunked, legacy whole-batch vs hierarchical bounded-carry vs pipelined),
and all results must agree code-for-code — with the standalone oracle as
ground truth whenever the batch is exact (``overflow == 0``).

Two layers:
  * a deterministic corpus of adversarial regimes (bursty, repeated
    timestamps, self-loop-heavy, adaptive ``e_cap``-shrunk zones) that runs
    everywhere, hypothesis installed or not;
  * Hypothesis property tests over generated temporal graphs — the CI
    differential-fuzz step widens the search (profile "fuzz", pinned
    seeds), while tier-1 runs a small derandomized sample (profile
    "tier1", registered in conftest).
"""

import numpy as np
import pytest

from repro.core import MiningExecutor, oracle, transitions, tzp
from repro.core.temporal_graph import from_edges
from conftest import batch_discover, random_graph

BACKENDS = ("ref", "numpy", "pallas")


def _dict(counts):
    return transitions.device_counts_to_dict(counts)


# ---------------------------------------------------------------------------
# Deterministic adversarial corpus.
# ---------------------------------------------------------------------------


def _bursty(seed, n=160, nodes=7):
    """Dense bursts separated by dead gaps (zone-boundary stress)."""
    rng = np.random.default_rng(seed)
    t, now = [], 0
    while len(t) < n:
        now += int(rng.integers(40, 120))
        burst = int(rng.integers(3, 18))
        t.extend((now + np.sort(rng.integers(0, 12, burst))).tolist())
    t = np.asarray(t[:n])
    return from_edges(rng.integers(0, nodes, n), rng.integers(0, nodes, n), t)


def _repeated_ts(seed, n=140, nodes=6):
    """Heavy timestamp ties (t > last_t gating, stable-sort order)."""
    rng = np.random.default_rng(seed)
    t = np.sort(rng.integers(0, n // 4, n))      # ~4 edges per timestamp
    return from_edges(rng.integers(0, nodes, n), rng.integers(0, nodes, n), t)


def _self_loops(seed, n=120, nodes=5):
    """~1/3 self-loops (u == v: single-node seeding + same_uv relabeling)."""
    rng = np.random.default_rng(seed)
    u = rng.integers(0, nodes, n)
    v = np.where(rng.random(n) < 0.35, u, rng.integers(0, nodes, n))
    return from_edges(u, v, np.sort(rng.integers(0, 400, n)))


CORPUS = [
    ("bursty", _bursty, dict(delta=20, l_max=4, omega=2, e_cap=None)),
    ("repeated-ts", _repeated_ts, dict(delta=6, l_max=3, omega=2,
                                       e_cap=None)),
    ("self-loops", _self_loops, dict(delta=30, l_max=4, omega=3,
                                     e_cap=None)),
    # adaptive zoning: e_cap forces the planner to shrink dense zones
    ("adaptive-ecap", _bursty, dict(delta=15, l_max=3, omega=4, e_cap=24)),
]


@pytest.mark.parametrize("name,gen,params",
                         CORPUS, ids=[c[0] for c in CORPUS])
def test_full_path_backends_agree_on_corpus(name, gen, params):
    g = gen(seed=11)
    e_cap = params["e_cap"]
    results = {}
    for backend in BACKENDS:
        res = batch_discover(g, delta=params["delta"], l_max=params["l_max"],
                       omega=params["omega"], e_cap=e_cap, backend=backend,
                       allow_overflow=True)
        results[backend] = res
    base = results["ref"]
    for backend in BACKENDS[1:]:
        assert results[backend].counts == base.counts, \
            f"{backend} != ref on {name}"
    if base.overflow == 0:
        expect = dict(oracle.count_codes(
            g.u, g.v, g.t, params["delta"], params["l_max"]))
        assert base.counts == expect, f"ref != oracle on {name}"


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("agg", ["legacy", "hierarchical", "pipelined"])
def test_chunked_agg_modes_match_unchunked(backend, agg):
    """Chunked x {legacy, hierarchical, pipelined} == one-shot whole batch,
    for jittable and host-only backends alike."""
    g = _bursty(seed=3, n=140)
    delta, l_max = 20, 4
    plan = tzp.plan_zones(g, delta=delta, l_max=l_max, omega=2)
    batch = tzp.build_zone_batch(g, plan, pad_zones_to=4)
    base = MiningExecutor(delta=delta, l_max=l_max, backend=backend,
                          zone_chunk=0)
    chunked = MiningExecutor(delta=delta, l_max=l_max, backend=backend,
                             zone_chunk=4, agg=agg)
    assert _dict(chunked.run(batch)) == _dict(base.run(batch))


def test_hierarchical_survives_tiny_merge_cap():
    """The spill/retry policy must converge to exact counts from any cap."""
    g = random_graph(9, 260, 9, 700)
    delta, l_max = 25, 4
    plan = tzp.plan_zones(g, delta=delta, l_max=l_max, omega=2)
    batch = tzp.build_zone_batch(g, plan, pad_zones_to=2)
    base = MiningExecutor(delta=delta, l_max=l_max, zone_chunk=0)
    tiny = MiningExecutor(delta=delta, l_max=l_max, zone_chunk=2,
                          agg="hierarchical", merge_cap=8)
    with pytest.warns(RuntimeWarning, match="merge spilled"):
        got = _dict(tiny.run(batch))
    assert got == _dict(base.run(batch))


def test_merge_cap_retry_terminates_at_full_saturation():
    """Every candidate a distinct live code: the retry ceiling must leave
    room for the all-zero padding row (z*e + 1), or the spill/retry loop
    re-derives the same cap forever (regression: infinite hang)."""
    # temporal path 0-1-2-...: each seed walk absorbs a unique node
    # sequence, so all 8 candidates carry distinct codes
    n = 8
    g = from_edges(np.arange(n), np.arange(n) + 1,
                   2 * np.arange(n))
    delta, l_max = 5, 8
    plan = tzp.plan_zones(g, delta=delta, l_max=l_max, omega=2)
    batch = tzp.build_zone_batch(g, plan)
    ex = MiningExecutor(delta=delta, l_max=l_max, zone_chunk=1,
                        agg="hierarchical", merge_cap=8)
    base = MiningExecutor(delta=delta, l_max=l_max, zone_chunk=0)
    with pytest.warns(RuntimeWarning, match="merge spilled"):
        got = _dict(ex.run(batch))
    assert got == _dict(base.run(batch))


def test_mesh_hierarchical_matches_single_device():
    """Per-shard hierarchical fold inside shard_map == plain discover."""
    import jax

    from repro.distributed import mining

    g = _bursty(seed=7, n=120)
    delta, l_max = 20, 3
    plan = tzp.plan_zones(g, delta=delta, l_max=l_max, omega=2)
    batch = tzp.build_zone_batch(g, plan, pad_zones_to=4)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("z",))
    ex = MiningExecutor(delta=delta, l_max=l_max, zone_chunk=2,
                        agg="hierarchical")
    counts = mining.mine_on_mesh(batch, mesh, ("z",), executor=ex)
    expect = batch_discover(g, delta=delta, l_max=l_max, omega=2)
    assert _dict(counts) == expect.counts


# ---------------------------------------------------------------------------
# Ragged zone layouts: bucketed == dense == oracle, every backend.
# ---------------------------------------------------------------------------


def _powerlaw_bursty(seed, n=220, nodes=9):
    """Power-law burst sizes + quiet gaps: zone sizes span several
    power-of-two buckets (the skew regime the bucketed layout targets)."""
    rng = np.random.default_rng(seed)
    us, vs, ts = [], [], []
    now = 0
    while len(ts) < n:
        burst = min(int(rng.pareto(0.9) * 3) + 1, 70)
        group = rng.integers(0, nodes, size=max(2, burst // 4 + 2))
        for _ in range(burst):
            a, b = rng.choice(group, 2, replace=True)
            us.append(a)
            vs.append(b)
            ts.append(now + int(rng.integers(0, 30)))
        now += int(rng.integers(150, 700))
    return from_edges(np.asarray(us[:n]), np.asarray(vs[:n]),
                      np.asarray(ts[:n]))


def _layout_counts(g, *, backend, layout, zone_chunk, delta, l_max, omega,
                   e_cap=None, agg="auto"):
    plan = tzp.plan_zones(g, delta=delta, l_max=l_max, omega=omega,
                          e_cap=e_cap)
    lay = tzp.build_zone_layout(g, plan, layout=layout, e_cap=e_cap)
    ex = MiningExecutor(delta=delta, l_max=l_max, backend=backend,
                        zone_chunk=zone_chunk, agg=agg)
    return lay, _dict(ex.run_layout(lay, allow_overflow=True).counts)


def test_bursty_corpus_spans_three_buckets():
    """Guard: the layout-differential corpus really exercises >= 3 buckets
    (otherwise the bucketed-vs-dense comparison degenerates)."""
    g = _powerlaw_bursty(seed=5)
    plan = tzp.plan_zones(g, delta=12, l_max=3, omega=2)
    lay = tzp.build_zone_layout(g, plan, layout="bucketed")
    assert lay.n_buckets >= 3, lay.bucket_shapes()
    assert lay.padding_ratio < tzp.build_zone_layout(
        g, plan, layout="dense").padding_ratio


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("zone_chunk", [0, 4])
def test_bucketed_matches_dense_and_oracle(backend, zone_chunk):
    """bucketed == dense == standalone numpy oracle through the full
    plan -> layout -> run_layout path, chunked and unchunked."""
    g = _powerlaw_bursty(seed=5)
    delta, l_max, omega = 12, 3, 2
    dense_lay, dense = _layout_counts(
        g, backend=backend, layout="dense", zone_chunk=zone_chunk,
        delta=delta, l_max=l_max, omega=omega)
    buck_lay, bucketed = _layout_counts(
        g, backend=backend, layout="bucketed", zone_chunk=zone_chunk,
        delta=delta, l_max=l_max, omega=omega)
    assert buck_lay.n_buckets >= 3
    assert bucketed == dense, f"bucketed != dense on {backend}"
    assert buck_lay.overflow == dense_lay.overflow == 0
    expect = dict(oracle.count_codes(g.u, g.v, g.t, delta, l_max))
    assert bucketed == expect, f"{backend} bucketed != oracle"


@pytest.mark.parametrize("layout", ["dense", "bucketed"])
def test_layout_survives_tiny_merge_cap_retry(layout):
    """The cross-bucket bounded-carry merge must converge to exact counts
    from any starting cap (spill -> warn -> doubled-cap retry)."""
    g = _powerlaw_bursty(seed=8, n=160)
    delta, l_max = 12, 3
    plan = tzp.plan_zones(g, delta=delta, l_max=l_max, omega=2)
    lay = tzp.build_zone_layout(g, plan, layout=layout)
    base = MiningExecutor(delta=delta, l_max=l_max, zone_chunk=0)
    tiny = MiningExecutor(delta=delta, l_max=l_max, zone_chunk=2,
                          agg="hierarchical", merge_cap=8)
    with pytest.warns(RuntimeWarning, match="merge spilled"):
        got = _dict(tiny.run_layout(lay).counts)
    assert got == _dict(base.run_layout(
        tzp.build_zone_layout(g, plan, layout="dense")).counts)


def test_layout_overflow_names_offending_bucket():
    """Edge-dropping buckets are named in the one layout-wide error."""
    g, delta, l_max = _overflowing_setup()
    plan = tzp.plan_zones(g, delta=delta, l_max=l_max, omega=2, e_cap=16)
    lay = tzp.build_zone_layout(g, plan, layout="bucketed", e_cap=16)
    assert lay.overflow > 0
    from repro.core import ZoneOverflowError

    ex = MiningExecutor(delta=delta, l_max=l_max)
    with pytest.raises(ZoneOverflowError, match=r"bucket.*cap16"):
        ex.run_layout(lay)
    with pytest.warns(RuntimeWarning, match="dropped"):
        got = ex.run_layout(lay, allow_overflow=True).counts
    # overflow is layout-invariant: the dense batch drops the same edges
    dense = tzp.build_zone_layout(g, plan, layout="dense", e_cap=16)
    assert dense.overflow == lay.overflow
    with pytest.warns(RuntimeWarning, match="dropped"):
        dense_got = ex.run_layout(dense, allow_overflow=True).counts
    assert _dict(got) == _dict(dense_got)


# ---------------------------------------------------------------------------
# Fused single-launch path: one kernel launch == per-bucket == oracle.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fused_backend,want_path",
                         [("pallas", "fused"), ("xla", "fused_xla")])
@pytest.mark.parametrize("layout", ["dense", "bucketed"])
def test_fused_matches_per_bucket_and_oracle(layout, fused_backend,
                                             want_path):
    """run_layout(fused=True) — one bucket-native launch with the Phase-2
    fold on-device — must be code-for-code identical to the per-bucket
    path and the standalone numpy oracle, on the >= 3-bucket power-law
    corpus, for BOTH fused lowerings (Pallas interpret on CPU, and the
    compiled xla formulation of the same ``_edge_update`` rule)."""
    g = _powerlaw_bursty(seed=5)
    delta, l_max = 12, 3
    plan = tzp.plan_zones(g, delta=delta, l_max=l_max, omega=2)
    lay = tzp.build_zone_layout(g, plan, layout=layout)
    if layout == "bucketed":
        assert lay.n_buckets >= 3, lay.bucket_shapes()
    ex = MiningExecutor(delta=delta, l_max=l_max, backend="pallas",
                        fused_backend=fused_backend)
    fused_out = ex.run_layout(lay, fused=True)
    fused = _dict(fused_out.counts)
    assert fused_out.stats["path"] == want_path
    assert fused_out.stats["backend"] == fused_backend
    assert fused_out.stats["launches"] == 1
    pb_out = ex.run_layout(lay, fused=False)
    per_bucket = _dict(pb_out.counts)
    assert pb_out.stats["path"] == "per-bucket"
    assert pb_out.stats["launches"] == lay.n_buckets
    assert fused == per_bucket, "fused != per-bucket"
    expect = dict(oracle.count_codes(g.u, g.v, g.t, delta, l_max))
    assert fused == expect, "fused != oracle"


@pytest.mark.parametrize("bounds", ["full", "live"])
def test_fused_xla_matches_pallas_interpret_byte_identical(bounds):
    """The compiled xla lowering == pallas-interpret == ref == numpy on
    the power-law bursty corpus, under BOTH sweep-bound plans — and the
    live plan dispatches strictly less modeled sweep work."""
    g = _powerlaw_bursty(seed=5)
    delta, l_max = 12, 3
    plan = tzp.plan_zones(g, delta=delta, l_max=l_max, omega=2)
    lay = tzp.build_zone_layout(g, plan, layout="bucketed")
    results = {}
    for fb in ("pallas", "xla"):
        ex = MiningExecutor(delta=delta, l_max=l_max, backend="pallas",
                            fused_backend=fb, fused_bounds=bounds)
        out = ex.run_layout(lay, fused=True)
        assert out.stats["bounds"] == bounds
        results[fb] = _dict(out.counts)
    assert results["xla"] == results["pallas"]
    for backend in ("ref", "numpy"):
        ex = MiningExecutor(delta=delta, l_max=l_max, backend=backend)
        assert results["xla"] == _dict(
            ex.run_layout(lay, fused=False).counts), backend
    if bounds == "live":
        # never MORE work than the full plan...
        full = tzp.concat_layout(lay, blk=512)
        live = tzp.concat_layout(lay, blk=512, delta=delta, l_max=l_max,
                                 bounds="live")
        assert live.sweep_slots <= full.sweep_slots
        # ...and strictly less on a corpus whose zone time spans exceed
        # the Lemma-4.1 horizon (this one's zones all fit inside it, so
        # the cut cannot bite there)
        from repro.data import synthetic_graphs as sg

        gappy = sg.bursty_stream(2_500, 250, burst_size=120, burst_span=200,
                                 gap_span=30_000, seed=13)
        gplan = tzp.plan_zones(gappy, delta=90, l_max=5, omega=2)
        glay = tzp.build_zone_layout(gappy, gplan, layout="bucketed")
        gfull = tzp.concat_layout(glay, blk=512)
        glive = tzp.concat_layout(glay, blk=512, delta=90, l_max=5,
                                  bounds="live")
        assert glive.sweep_slots < gfull.sweep_slots


def test_fused_compacted_bounds_identical_at_kernel_level():
    """Host-planned [lo, hi) compaction is output-exact at the raw kernel
    level: full == live slot streams, slot for slot, on both lowerings."""
    import jax.numpy as jnp

    from repro.kernels.zone_scan import ops, xla

    g = _powerlaw_bursty(seed=8, n=160)
    delta, l_max = 12, 3
    plan = tzp.plan_zones(g, delta=delta, l_max=l_max, omega=2)
    lay = tzp.build_zone_layout(g, plan, layout="bucketed")
    outs = {}
    for bounds in ("full", "live"):
        fl = tzp.concat_layout(lay, blk=64, delta=delta, l_max=l_max,
                               bounds=bounds)
        args = tuple(jnp.asarray(x) for x in
                     (fl.u, fl.v, fl.t, fl.valid, fl.zone_id, fl.lo, fl.hi))
        outs[bounds, "xla"] = xla.scan_flat_xla(
            *args, delta=delta, l_max=l_max, blk=64, with_ts=True)
        outs[bounds, "pallas"] = ops.scan_flat(
            *args, delta=delta, l_max=l_max, blk=64, interpret=True,
            with_ts=True)
    base = outs["full", "pallas"]
    for key, got in outs.items():
        for a, b in zip(base, got):
            assert np.array_equal(np.asarray(a), np.asarray(b)), key


@pytest.mark.parametrize("fused_backend", ["pallas", "xla"])
def test_fused_survives_tiny_merge_cap_retry(fused_backend):
    """The on-device bounded fold spills exactly and the host retry with a
    doubled cap must converge to exact counts from any starting cap."""
    g = _powerlaw_bursty(seed=8, n=160)
    delta, l_max = 12, 3
    plan = tzp.plan_zones(g, delta=delta, l_max=l_max, omega=2)
    lay = tzp.build_zone_layout(g, plan, layout="bucketed")
    base = MiningExecutor(delta=delta, l_max=l_max, backend="pallas")
    tiny = MiningExecutor(delta=delta, l_max=l_max, backend="pallas",
                          fused_backend=fused_backend, merge_cap=8)
    with pytest.warns(RuntimeWarning, match="fused on-device merge spilled"):
        outcome = tiny.run_layout(lay, fused=True)
    got = _dict(outcome.counts)
    assert outcome.stats["spill_retries"] >= 1
    assert outcome.stats["launches"] == 1
    assert got == _dict(base.run_layout(lay, fused=True).counts)


def test_fused_dispatch_policy():
    """"auto" fuses exactly when the resolved fused backend has a flat
    kernel; forcing fused with none available is an error, not a silent
    fallback; fused_backend reroutes (and validates) the lowering."""
    kw = dict(delta=12, l_max=3)
    assert MiningExecutor(backend="pallas", **kw).resolve_fused() is True
    assert MiningExecutor(backend="ref", **kw).resolve_fused() is False
    assert MiningExecutor(backend="numpy", **kw).resolve_fused() is False
    assert MiningExecutor(backend="pallas", fused="off",
                          **kw).resolve_fused() is False
    with pytest.raises(ValueError, match="no fused single-launch scan"):
        MiningExecutor(backend="ref", **kw).resolve_fused(True)
    with pytest.raises(ValueError, match="no fused single-launch scan"):
        MiningExecutor(backend="ref", fused="on", **kw).resolve_fused()
    with pytest.raises(ValueError, match="unknown fused mode"):
        MiningExecutor(backend="ref", fused="always", **kw)
    # an explicit fused_backend opens the fused path from ANY backend...
    rx = MiningExecutor(backend="ref", fused_backend="xla", **kw)
    assert rx.resolve_fused() is True
    assert rx._fused_spec().name == "xla"
    # ...but must itself publish a flat kernel
    with pytest.raises(ValueError, match="no fused single-launch scan"):
        MiningExecutor(backend="pallas", fused_backend="ref", **kw)
    with pytest.raises(ValueError, match="unknown fused bounds"):
        MiningExecutor(backend="pallas", fused_bounds="tight", **kw)
    # on CPU (every CI host) the pallas kernel would interpret, so auto
    # dispatch must reroute fused runs to the compiled xla lowering
    import jax

    if jax.default_backend() == "cpu":
        auto = MiningExecutor(backend="pallas", **kw)
        assert auto._fused_spec().name == "xla"
        pinned = MiningExecutor(backend="pallas", fused_backend="pallas",
                                **kw)
        assert pinned._fused_spec().name == "pallas"


def test_fused_engine_single_launch_and_cache():
    """Through the engine: a pallas discover is served by ONE launch, the
    result records it, and a repeated discover is a compile-cache hit on
    the fused execution key."""
    from repro.core.engine import PTMTEngine

    g = _powerlaw_bursty(seed=5)
    eng = PTMTEngine(delta=12, l_max=3, omega=2, backend="pallas")
    res = eng.discover(g)
    assert res.layout["execution"]["path"] in ("fused", "fused_xla")
    assert res.layout["execution"]["launches"] == 1
    assert eng.stats.fused_runs == 1
    assert eng.stats.launches == 1
    ref = PTMTEngine(delta=12, l_max=3, omega=2, backend="ref").discover(g)
    assert res.counts == ref.counts
    eng.discover(g)
    assert eng.stats.compile_cache_hits == 1
    assert eng.stats.launches == 2


# ---------------------------------------------------------------------------
# pad_policy="pad" x bucketed layout (regression: shared pad_zone_arrays).
# ---------------------------------------------------------------------------


def test_pad_zone_arrays_appends_inert_rows():
    """The shared helper pads with all-invalid zero-sign rows and is a
    no-op at the current row count."""
    g = _bursty(seed=3, n=80)
    plan = tzp.plan_zones(g, delta=20, l_max=4, omega=2)
    batch = tzp.build_zone_batch(g, plan)
    z = batch.n_zones
    u, v, t, valid, signs = tzp.pad_zone_arrays(
        batch.u, batch.v, batch.t, batch.valid, batch.sign, n_rows=z + 3)
    assert u.shape[0] == z + 3
    assert not valid[z:].any() and not signs[z:].any()
    same = tzp.pad_zone_arrays(batch.u, batch.v, batch.t, batch.valid,
                               batch.sign, n_rows=z)
    assert all(a is b for a, b in
               zip(same, (batch.u, batch.v, batch.t, batch.valid,
                          batch.sign)))
    with pytest.raises(ValueError, match="cannot pad"):
        tzp.pad_zone_arrays(batch.u, batch.v, batch.t, batch.valid,
                            batch.sign, n_rows=z - 1)


@pytest.mark.parametrize("backend", BACKENDS)
def test_pad_policy_with_bucketed_layout_non_divisor_chunk(backend):
    """A zone_chunk that divides no bucket's zone count exercises the pad
    path on every bucket of a bucketed layout; counts must match the
    unchunked run exactly, and pad_policy='raise' must refuse."""
    g = _powerlaw_bursty(seed=5)
    delta, l_max = 12, 3
    plan = tzp.plan_zones(g, delta=delta, l_max=l_max, omega=2)
    lay = tzp.build_zone_layout(g, plan, layout="bucketed")
    assert lay.n_buckets >= 3
    # pick a chunk size that divides none of the buckets' zone counts
    chunk = 4
    assert all(b.n_zones % chunk for b in lay.buckets), lay.bucket_shapes()
    base = MiningExecutor(delta=delta, l_max=l_max, backend=backend,
                          zone_chunk=0)
    padded = MiningExecutor(delta=delta, l_max=l_max, backend=backend,
                            zone_chunk=chunk, pad_policy="pad")
    got = _dict(padded.run_layout(lay, fused=False).counts)
    assert got == _dict(base.run_layout(lay, fused=False).counts)
    from repro.core.executor import ZoneChunkError

    strict = MiningExecutor(delta=delta, l_max=l_max, backend=backend,
                            zone_chunk=chunk, pad_policy="raise")
    with pytest.raises(ZoneChunkError, match="not divisible"):
        strict.run_layout(lay, fused=False)


# ---------------------------------------------------------------------------
# Overflow must never masquerade as exact counts (regression).
# ---------------------------------------------------------------------------


def _overflowing_setup():
    """A burst denser than e_cap inside the 2*L_b shrink floor: the adaptive
    planner cannot split it further, so edges are genuinely dropped."""
    delta, l_max = 10, 3
    rng = np.random.default_rng(0)
    n = 120
    g = from_edges(rng.integers(0, 6, n), rng.integers(0, 6, n),
                   np.sort(rng.integers(0, 2 * delta * l_max, n)))
    return g, delta, l_max


def test_executor_refuses_overflowed_batch():
    g, delta, l_max = _overflowing_setup()
    plan = tzp.plan_zones(g, delta=delta, l_max=l_max, omega=2, e_cap=16)
    batch = tzp.build_zone_batch(g, plan, e_cap=16)
    assert batch.overflow > 0, "setup must actually drop edges"
    ex = MiningExecutor(delta=delta, l_max=l_max)
    from repro.core import ZoneOverflowError

    with pytest.raises(ZoneOverflowError, match="dropped"):
        ex.run(batch)
    with pytest.warns(RuntimeWarning, match="dropped"):
        got = ex.run(batch, allow_overflow=True)
    # and the opted-in run really does undercount vs the oracle
    expect = dict(oracle.count_codes(g.u, g.v, g.t, delta, l_max))
    assert sum(_dict(got).values()) < sum(expect.values())


def test_discover_refuses_overflow_and_allows_optin():
    g, delta, l_max = _overflowing_setup()
    from repro.core import ZoneOverflowError

    with pytest.raises(ZoneOverflowError, match="dropped"):
        batch_discover(g, delta=delta, l_max=l_max, omega=2, e_cap=16)
    with pytest.warns(RuntimeWarning, match="dropped"):
        res = batch_discover(g, delta=delta, l_max=l_max, omega=2, e_cap=16,
                       allow_overflow=True)
    assert res.overflow > 0


# ---------------------------------------------------------------------------
# Hypothesis fuzz layer (optional: the corpus above runs without it).
# ---------------------------------------------------------------------------

try:
    import hypothesis as hyp
    from hypothesis import strategies as st
except ImportError:
    hyp = None

if hyp is not None:

    @st.composite
    def temporal_graphs(draw):
        """Small adversarial temporal graphs: bursty gaps, timestamp ties
        and self-loops all arise naturally from the ranges chosen here."""
        n = draw(st.integers(1, 36))
        nodes = draw(st.integers(1, 6))
        u = draw(st.lists(st.integers(0, nodes - 1), min_size=n, max_size=n))
        v = draw(st.lists(st.integers(0, nodes - 1), min_size=n, max_size=n))
        gaps = draw(st.lists(st.integers(0, 9), min_size=n, max_size=n))
        t = np.cumsum(np.asarray(gaps, np.int64))
        return from_edges(np.asarray(u, np.int64), np.asarray(v, np.int64),
                          t)

    @hyp.given(
        g=temporal_graphs(),
        delta=st.integers(2, 8),
        l_max=st.integers(2, 4),
        omega=st.sampled_from([2, 3]),
        e_cap=st.sampled_from([None, 8, 16]),
    )
    def test_fuzz_full_path_ref_vs_numpy_oracle(g, delta, l_max, omega,
                                                e_cap):
        """ref == numpy through the full path on generated graphs; both
        equal the standalone oracle whenever no edges were dropped."""
        kw = dict(delta=delta, l_max=l_max, omega=omega, e_cap=e_cap,
                  allow_overflow=True)
        a = batch_discover(g, backend="ref", **kw)
        b = batch_discover(g, backend="numpy", **kw)
        assert a.counts == b.counts
        assert a.overflow == b.overflow
        if a.overflow == 0:
            expect = dict(oracle.count_codes(g.u, g.v, g.t, delta, l_max))
            assert a.counts == expect

    @hyp.given(
        g=temporal_graphs(),
        delta=st.integers(2, 8),
        l_max=st.integers(2, 4),
        zone_chunk=st.sampled_from([2, 3, 4]),
        agg=st.sampled_from(["hierarchical", "pipelined"]),
    )
    def test_fuzz_hierarchical_agg_matches_legacy(g, delta, l_max,
                                                  zone_chunk, agg):
        """Bounded-carry folds == legacy whole-batch flatten on any batch,
        including zone counts the chunk size does not divide (pad
        policy)."""
        plan = tzp.plan_zones(g, delta=delta, l_max=l_max, omega=2)
        batch = tzp.build_zone_batch(g, plan)
        legacy = MiningExecutor(delta=delta, l_max=l_max, zone_chunk=0)
        folded = MiningExecutor(delta=delta, l_max=l_max,
                                zone_chunk=zone_chunk, agg=agg)
        assert _dict(folded.run(batch)) == _dict(legacy.run(batch))

    @hyp.given(
        g=temporal_graphs(),
        delta=st.integers(2, 6),
        l_max=st.integers(2, 3),
    )
    @hyp.settings(max_examples=10)
    def test_fuzz_pallas_interpret_matches_ref(g, delta, l_max):
        """Pallas (interpret mode on CPU) == ref through the full path.

        Kept to few examples: interpret mode executes the kernel grid in
        Python.  The corpus test covers the adversarial regimes for pallas
        deterministically.
        """
        a = batch_discover(g, delta=delta, l_max=l_max, omega=2, backend="pallas")
        b = batch_discover(g, delta=delta, l_max=l_max, omega=2, backend="ref")
        assert a.counts == b.counts
