"""embedding_bag Pallas kernel vs pure-jnp oracle."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels.embedding_bag import ops, ref


@pytest.mark.parametrize("v,d,b,k", [(1000, 16, 64, 4), (5000, 64, 100, 1),
                                     (300, 128, 257, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embedding_bag_matches_ref(v, d, b, k, dtype):
    rng = np.random.default_rng(v + b)
    table = jnp.asarray(rng.standard_normal((v, d)), dtype)
    ids = jnp.asarray(rng.integers(0, v, (b, k)), jnp.int32)
    weights = jnp.asarray(rng.standard_normal((b, k)), jnp.float32)
    got = ops.embedding_bag(table, ids, weights)
    want = ref.embedding_bag(table, ids, weights)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


def test_duplicate_ids_in_bag():
    """Repeated ids must accumulate (bag semantics, not set semantics)."""
    table = jnp.asarray(np.eye(8, 4, dtype=np.float32))
    ids = jnp.asarray([[2, 2, 2, 0]], jnp.int32)
    weights = jnp.asarray([[1.0, 2.0, 3.0, 10.0]], jnp.float32)
    out = np.asarray(ops.embedding_bag(table, ids, weights))
    want = np.asarray(ref.embedding_bag(table, ids, weights))
    np.testing.assert_allclose(out, want)
    assert out[0, 2] == 6.0 and out[0, 0] == 10.0


def test_recsys_model_with_pallas_path():
    import dataclasses

    import jax

    from repro.configs import get_arch
    from repro.models import recsys
    from repro.models.params import tree_init

    cfg = get_arch("dcn-v2").smoke_config
    p = tree_init(jax.random.PRNGKey(0), recsys.dcn_param_specs(cfg))
    rng = np.random.default_rng(0)
    b = 16
    batch = {
        "dense": jnp.asarray(
            rng.standard_normal((b, cfg.n_dense)), jnp.float32),
        "sparse_ids": jnp.asarray(np.stack(
            [rng.integers(0, v, (b, cfg.bag_size))
             for v in cfg.vocab_sizes], 1), jnp.int32),
        "sparse_weights": jnp.ones((b, cfg.n_sparse, cfg.bag_size),
                                   jnp.float32),
    }
    a = recsys.forward(p, batch, cfg)
    b2 = recsys.forward(p, batch, dataclasses.replace(cfg, use_pallas=True))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b2),
                               rtol=1e-5, atol=1e-5)
