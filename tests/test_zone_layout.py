"""Ragged zone batching: size-bucketed layout invariants.

The differential guarantees (bucketed == dense == oracle across backends)
live in ``tests/test_differential.py``; this file covers the layout
machinery itself — bucket capacity math, padding/occupancy accounting,
empty-zone dropping, plan serialization, the engine-level zone-plan cache,
and the bucket-named error paths.
"""

import argparse
import warnings

import numpy as np
import pytest

from repro.core import (
    MiningConfig,
    MiningExecutor,
    PTMTEngine,
    StreamingMiner,
    ZoneChunkError,
    planner,
    transitions,
    tzp,
)
from repro.core.temporal_graph import TemporalGraph, from_edges


@pytest.fixture(autouse=True, scope="module")
def _fresh_jit_caches():
    """The mesh tests below compile large SPMD (shard_map) executables;
    at the tail of a full-suite run the jit caches hold every executable
    the preceding ~300 tests compiled, and that accumulated state has
    been observed to push the XLA:CPU compiler into a segfault on this
    module's first shard_map compile (jax 0.4.37 — the same test passes
    in isolation and after either suite half alone).  Every test here
    compiles its own executables anyway, so start from a clean cache."""
    import jax

    jax.clear_caches()


def _skewed_graph(seed=0, n=300, nodes=10):
    """Bursts of very different sizes + quiet gaps: >= 3 buckets."""
    rng = np.random.default_rng(seed)
    us, vs, ts = [], [], []
    now = 0
    for burst in (3, 50, 7, 28, 2, 60, 12, 40, 5, 33, 9, 51):
        group = rng.integers(0, nodes, size=max(2, burst // 4 + 2))
        for _ in range(burst):
            a, b = rng.choice(group, 2, replace=True)
            us.append(a)
            vs.append(b)
            ts.append(now + int(rng.integers(0, 25)))
        now += 400 + int(rng.integers(0, 200))
    return from_edges(np.asarray(us[:n]), np.asarray(vs[:n]),
                      np.asarray(ts[:n]))


PARAMS = dict(delta=10, l_max=3, omega=2)


# ---------------------------------------------------------------------------
# Bucket capacity math.
# ---------------------------------------------------------------------------


def test_bucket_caps_power_of_two_floor_and_clip():
    counts = np.asarray([0, 1, 7, 8, 9, 100, 4000])
    caps = tzp.bucket_caps(counts, max_cap=512, pad_edges_to=8)
    assert caps.tolist() == [8, 8, 8, 8, 16, 128, 512]
    # non-pow2 pad_edges_to: caps stay aligned to what build_zone_batch
    # will allocate (pow2 floor 16, re-rounded to the 12-multiple 24)
    assert tzp.bucket_caps(np.asarray([1]), max_cap=512,
                           pad_edges_to=12).tolist() == [24]


def test_non_pow2_pad_edges_to_keeps_labels_and_shapes_aligned():
    g = _skewed_graph()
    plan = tzp.plan_zones(g, **PARAMS)
    lay = tzp.build_zone_layout(g, plan, layout="bucketed", pad_edges_to=12)
    for b in lay.buckets:
        assert b.label == f"cap{b.e_cap}"       # label == allocated shape
    caps = [b.e_cap for b in lay.buckets]
    assert len(caps) == len(set(caps))          # one bucket per geometry


def test_empty_plan_bucketed_layout_honors_shard_padding():
    g = TemporalGraph(u=np.zeros(0, np.int32), v=np.zeros(0, np.int32),
                      t=np.zeros(0, np.int32), n_nodes=0)
    plan = tzp.plan_zones(g, delta=5, l_max=2)
    lay = tzp.build_zone_layout(g, plan, layout="bucketed",
                                pad_zones_to=4, n_shards=4)
    assert lay.buckets[0].n_zones % 4 == 0      # shardable zone axis


def test_layout_padding_strictly_lower_on_skewed_plan():
    g = _skewed_graph()
    plan = tzp.plan_zones(g, **PARAMS)
    dense = tzp.build_zone_layout(g, plan, layout="dense")
    buck = tzp.build_zone_layout(g, plan, layout="bucketed")
    assert buck.n_buckets >= 3
    assert buck.padding_ratio < dense.padding_ratio
    assert buck.sweep_slots < dense.sweep_slots
    # same real edges, identical overflow, top bucket == dense capacity
    assert buck.valid_edges == dense.valid_edges
    assert buck.overflow == dense.overflow == 0
    assert buck.e_cap == dense.e_cap
    # every zone of the plan is either placed once or empty
    placed = np.concatenate([b.perm[b.perm >= 0] for b in buck.buckets])
    expected = np.flatnonzero(np.asarray(plan.count) > 0)
    assert sorted(placed.tolist()) == expected.tolist()


def test_empty_zones_are_dropped_not_padded():
    g = _skewed_graph()
    plan = tzp.plan_zones(g, **PARAMS)
    assert (np.asarray(plan.count) == 0).any(), "need empty zones"
    buck = tzp.build_zone_layout(g, plan, layout="bucketed")
    assert buck.n_zones == int((np.asarray(plan.count) > 0).sum())


def test_all_empty_plan_builds_inert_bucket():
    g = TemporalGraph(u=np.zeros(0, np.int32), v=np.zeros(0, np.int32),
                      t=np.zeros(0, np.int32), n_nodes=0)
    plan = tzp.plan_zones(g, delta=5, l_max=2)
    lay = tzp.build_zone_layout(g, plan, layout="bucketed")
    ex = MiningExecutor(delta=5, l_max=2)
    assert transitions.device_counts_to_dict(ex.run_layout(lay).counts) == {}


def test_resolve_layout_rules():
    g = _skewed_graph()
    plan = tzp.plan_zones(g, **PARAMS)
    assert tzp.resolve_layout(plan, "auto") == "bucketed"
    assert tzp.resolve_layout(plan, "dense") == "dense"
    single = tzp.single_zone_plan(g, l_b=30)
    assert tzp.resolve_layout(single, "auto") == "dense"
    with pytest.raises(ValueError, match="unknown zone layout"):
        tzp.resolve_layout(plan, "ragged")


# ---------------------------------------------------------------------------
# ZonePlan serialization + graph fingerprint.
# ---------------------------------------------------------------------------


def test_zone_plan_json_round_trip():
    g = _skewed_graph()
    plan = tzp.plan_zones(g, **PARAMS)
    back = tzp.ZonePlan.from_json(plan.to_json())
    assert back == plan
    assert tzp.ZonePlan.from_json(
        {"lo": [], "count": [], "sign": [], "t_start": [], "t_end": [],
         "l_b": 30}).n_zones == 0
    with pytest.raises(ValueError, match="unknown ZonePlan field"):
        tzp.ZonePlan.from_json('{"lo": [], "bogus": 1}')


def test_graph_fingerprint_tracks_content():
    g1 = _skewed_graph(seed=0)
    g2 = _skewed_graph(seed=0)
    g3 = _skewed_graph(seed=1)
    assert tzp.graph_fingerprint(g1) == tzp.graph_fingerprint(g2)
    assert tzp.graph_fingerprint(g1) != tzp.graph_fingerprint(g3)


# ---------------------------------------------------------------------------
# Engine integration: plan cache, per-bucket compile keys, stats.
# ---------------------------------------------------------------------------


def test_engine_plan_cache_skips_replanning():
    g = _skewed_graph()
    eng = PTMTEngine(MiningConfig(zone_layout="bucketed", **PARAMS))
    r1 = eng.discover(g)
    assert eng.stats.plan_cache_misses == 1
    assert eng.stats.plan_cache_hits == 0
    r2 = eng.discover(g)
    assert eng.stats.plan_cache_hits == 1
    assert r1.counts == r2.counts
    # a different stream is a miss, not a poisoned hit
    eng.discover(_skewed_graph(seed=3))
    assert eng.stats.plan_cache_misses == 2


def test_engine_compile_cache_counts_bucket_shapes():
    g = _skewed_graph()
    eng = PTMTEngine(MiningConfig(zone_layout="bucketed", **PARAMS))
    r1 = eng.discover(g)
    n_buckets = len(r1.layout["buckets"])
    assert n_buckets >= 3
    assert eng.stats.compile_cache_misses == n_buckets
    eng.discover(g)
    assert eng.stats.compile_cache_hits == n_buckets


def test_engine_stats_and_result_carry_layout_summary():
    g = _skewed_graph()
    eng = PTMTEngine(MiningConfig(zone_layout="bucketed", **PARAMS))
    res = eng.discover(g)
    assert res.layout["kind"] == "bucketed"
    assert 0.0 <= res.layout["padding_ratio"] < 1.0
    assert eng.stats.padding_ratio == res.layout["padding_ratio"]
    assert set(eng.stats.bucket_occupancy) == {
        b["label"] for b in res.layout["buckets"]}
    dense = PTMTEngine(MiningConfig(zone_layout="dense", **PARAMS))
    dres = dense.discover(g)
    assert dres.layout["kind"] == "dense"
    assert res.layout["padding_ratio"] < dres.layout["padding_ratio"]
    assert res.counts == dres.counts


def test_streaming_inherits_layout_and_stays_exact():
    g = _skewed_graph()
    eng = PTMTEngine(MiningConfig(zone_layout="bucketed", **PARAMS))
    batch = eng.discover(g)
    m = eng.stream()
    for i in range(0, g.n_edges, 53):
        m.ingest(g.u[i:i + 53], g.v[i:i + 53], g.t[i:i + 53])
    assert m.snapshot(final=True).counts == batch.counts
    assert m.last_tail_layout is None or "kind" in m.last_tail_layout


def test_streaming_tail_cache_keyed_by_layout_signature():
    g = _skewed_graph()
    m = StreamingMiner(config=MiningConfig(zone_layout="bucketed", **PARAMS))
    m.ingest(g.u, g.v, g.t)
    m.snapshot()
    m.snapshot()
    assert (m.tail_cache_misses, m.tail_cache_hits) == (1, 1)
    # a layout-affecting change invalidates the cached tail mine
    object.__setattr__(m.config, "zone_layout", "dense")
    m.snapshot()
    assert m.tail_cache_misses == 2


# ---------------------------------------------------------------------------
# Bucket-named error paths.
# ---------------------------------------------------------------------------


def test_zone_chunk_raise_names_bucket():
    g = _skewed_graph()
    plan = tzp.plan_zones(g, **PARAMS)
    lay = tzp.build_zone_layout(g, plan, layout="bucketed")
    odd = max(lay.buckets, key=lambda b: b.n_zones)
    assert odd.n_zones >= 3, "corpus must give a multi-zone bucket"
    zc = odd.n_zones - 1          # never divides n_zones (remainder 1)
    ex = MiningExecutor(delta=PARAMS["delta"], l_max=PARAMS["l_max"],
                        zone_chunk=zc, pad_policy="raise")
    with pytest.raises(ZoneChunkError, match=odd.label):
        ex.run(odd)
    # pad policy pads the same bucket silently and stays exact
    pad_ex = MiningExecutor(delta=PARAMS["delta"], l_max=PARAMS["l_max"],
                            zone_chunk=zc, pad_policy="pad")
    base = MiningExecutor(delta=PARAMS["delta"], l_max=PARAMS["l_max"],
                          zone_chunk=0)
    assert transitions.device_counts_to_dict(pad_ex.run(odd)) == \
        transitions.device_counts_to_dict(base.run(odd))


# ---------------------------------------------------------------------------
# Config + planner surface.
# ---------------------------------------------------------------------------


def test_config_zone_layout_validation_and_cli():
    with pytest.raises(ValueError, match="unknown zone layout"):
        MiningConfig(zone_layout="ragged")
    ap = argparse.ArgumentParser()
    MiningConfig.add_cli_args(ap)
    cfg = MiningConfig.from_cli_args(
        ap.parse_args(["--zone-layout", "bucketed"]))
    assert cfg.zone_layout == "bucketed"
    assert MiningConfig.from_json(cfg.to_json()) == cfg


def test_planner_per_bucket_capacity_beats_global_max():
    g = _skewed_graph()
    plan = tzp.plan_zones(g, **PARAMS)
    lay = tzp.build_zone_layout(g, plan, layout="bucketed")
    plans = planner.plan_layout_capacity(
        lay.bucket_shapes(), l_max=PARAMS["l_max"], memory_budget_mb=0.5)
    assert set(plans) == set(lay.bucket_shapes())
    # at a fixed zone count, a smaller bucket capacity admits at least as
    # large a chunk — the per-bucket derivation the dense global max loses
    tight = dict(l_max=PARAMS["l_max"], memory_budget_mb=0.05)
    assert planner.plan_capacity(n_zones=256, e_cap=16, **tight).zone_chunk \
        > planner.plan_capacity(n_zones=256, e_cap=2048, **tight).zone_chunk
    assert planner.layout_peak_bytes(plans) == max(
        p.est_peak_bytes for p in plans.values())
    dense_slots = planner.padded_sweep_slots(
        [(lay.n_zones, lay.e_cap)])
    assert planner.padded_sweep_slots(lay.bucket_shapes()) < dense_slots


def test_executor_capacity_plan_memoized_per_bucket_geometry():
    ex = MiningExecutor(delta=10, l_max=3, memory_budget_mb=0.5)
    p_small = ex.capacity_plan(8, 16)
    p_big = ex.capacity_plan(8, 1024)
    assert p_small.zone_chunk >= p_big.zone_chunk
    assert ex.capacity_plan(8, 16) is p_small


def test_merge_partial_counts_requires_input():
    from repro.core.executor import merge_partial_counts

    with pytest.raises(ValueError):
        merge_partial_counts([])


def test_engine_zone_plan_cache_is_bounded():
    eng = PTMTEngine(MiningConfig(**PARAMS))
    eng._zone_plan_cap = 2
    graphs = [_skewed_graph(seed=s, n=60) for s in range(4)]
    for g in graphs:
        eng.discover(g)
    assert len(eng._zone_plans) == 2
    # the most recent graph is still a hit, the oldest was evicted
    eng.discover(graphs[-1])
    assert eng.stats.plan_cache_hits == 1
    eng.discover(graphs[0])
    assert eng.stats.plan_cache_misses == 5


def test_mine_layout_on_mesh_matches_and_enforces_overflow():
    import jax

    from repro.core import ZoneOverflowError
    from repro.distributed import mining as dm

    g = _skewed_graph()
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("z",))
    cfg = MiningConfig(**PARAMS)
    plan = tzp.plan_zones(g, **PARAMS)
    lay = tzp.build_zone_layout(g, plan, layout="bucketed")
    counts = dm.mine_layout_on_mesh(lay, mesh, ("z",), config=cfg)
    expect = PTMTEngine(cfg).discover(g).counts
    assert transitions.device_counts_to_dict(counts) == expect

    # overflowed layouts are refused, same policy as the local run_layout
    tight = tzp.plan_zones(g, delta=PARAMS["delta"],
                           l_max=PARAMS["l_max"], omega=2, e_cap=4)
    tight_lay = tzp.build_zone_layout(g, tight, layout="bucketed", e_cap=4)
    assert tight_lay.overflow > 0
    with pytest.raises(ZoneOverflowError, match="bucket"):
        dm.mine_layout_on_mesh(tight_lay, mesh, ("z",), config=cfg)
    with pytest.warns(RuntimeWarning, match="dropped"):
        dm.mine_layout_on_mesh(tight_lay, mesh, ("z",), config=cfg,
                               allow_overflow=True)
