"""Pallas zone-scan kernel vs pure-jnp oracle (interpret mode on CPU)."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import tzp
from repro.data import synthetic_graphs as sg
from repro.kernels.zone_scan import ops, ref


def _assert_zone_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a.code), np.asarray(b.code))
    np.testing.assert_array_equal(np.asarray(a.length), np.asarray(b.length))


@pytest.mark.parametrize(
    "gen,delta,l_max,c_blk,e_blk",
    [
        (lambda: sg.poisson_stream(300, 8, rate=2.0, seed=1), 3, 3, 128, 64),
        (lambda: sg.bursty_stream(400, 12, seed=2), 90, 6, 256, 256),
        (lambda: sg.triadic_stream(300, 20, seed=3), 150, 7, 128, 128),
        (lambda: sg.poisson_stream(200, 6, rate=1.0, seed=4), 5, 12, 128, 256),
        (lambda: sg.poisson_stream(130, 5, rate=0.2, seed=5), 40, 1, 128, 128),
    ],
)
def test_kernel_matches_ref(gen, delta, l_max, c_blk, e_blk):
    g = gen()
    u, v, t = jnp.asarray(g.u), jnp.asarray(g.v), jnp.asarray(g.t)
    valid = jnp.ones(g.n_edges, bool)
    a = ref.scan_zone(u, v, t, valid, delta=delta, l_max=l_max)
    b = ops.scan_zone(u, v, t, valid, delta=delta, l_max=l_max,
                      c_blk=c_blk, e_blk=e_blk)
    _assert_zone_equal(a, b)


def test_kernel_vmap_zone_batch():
    g = sg.bursty_stream(800, 15, seed=7)
    plan = tzp.plan_zones(g, delta=60, l_max=5, omega=2)
    batch = tzp.build_zone_batch(g, plan, pad_zones_to=4)
    u, v, t, valid = map(
        jnp.asarray, (batch.u, batch.v, batch.t, batch.valid)
    )
    a = ref.scan_zones(u, v, t, valid, delta=60, l_max=5)
    b = ops.scan_zones(u, v, t, valid, delta=60, l_max=5,
                       c_blk=128, e_blk=128)
    _assert_zone_equal(a, b)


def test_kernel_partial_validity_and_padding():
    """Invalid tails + interleaved t padding must not change results."""
    rng = np.random.default_rng(11)
    n, real = 384, 200
    u = jnp.asarray(rng.integers(0, 6, n), jnp.int32)
    v = jnp.asarray(rng.integers(0, 6, n), jnp.int32)
    t_real = np.sort(rng.integers(0, 500, real))
    t = jnp.asarray(np.concatenate([t_real, np.zeros(n - real)]), jnp.int32)
    valid = jnp.asarray(np.arange(n) < real)
    a = ref.scan_zone(u, v, t, valid, delta=25, l_max=4)
    b = ops.scan_zone(u, v, t, valid, delta=25, l_max=4,
                      c_blk=128, e_blk=128)
    _assert_zone_equal(a, b)


def test_kernel_self_loops_and_ties():
    rng = np.random.default_rng(13)
    n = 256
    u = jnp.asarray(rng.integers(0, 3, n), jnp.int32)
    v = jnp.asarray(rng.integers(0, 3, n), jnp.int32)
    t = jnp.asarray(np.sort(rng.integers(0, 40, n)), jnp.int32)
    valid = jnp.ones(n, bool)
    a = ref.scan_zone(u, v, t, valid, delta=4, l_max=6)
    b = ops.scan_zone(u, v, t, valid, delta=4, l_max=6,
                      c_blk=128, e_blk=64)
    _assert_zone_equal(a, b)


def test_kernel_end_to_end_discovery():
    """Full pipeline with backend='pallas' equals brute-force oracle."""
    from repro.core import MiningConfig, PTMTEngine, oracle

    g = sg.triadic_stream(400, 18, seed=9)
    expect = dict(oracle.count_codes(g.u, g.v, g.t, 100, 4))
    got = PTMTEngine(MiningConfig(
        delta=100, l_max=4, omega=3, backend="pallas")).discover(g)
    keys = set(expect) | set(got.counts)
    bad = {k for k in keys if expect.get(k, 0) != got.counts.get(k, 0)}
    assert not bad


@pytest.mark.parametrize("layout,blk", [("bucketed", 64), ("dense", 128)])
def test_fused_flat_kernel_matches_ref(layout, blk):
    """Single-launch flat-stream kernel == per-zone reference scan
    scattered back to slot positions (zone gating + chunk skip exact)."""
    g = sg.bursty_stream(600, 14, seed=9)
    plan = tzp.plan_zones(g, delta=60, l_max=4, omega=2)
    lay = tzp.build_zone_layout(g, plan, layout=layout)
    fl = tzp.concat_layout(lay, blk=blk)
    code, length = ops.scan_flat(fl.u, fl.v, fl.t, fl.valid, fl.zone_id,
                                 fl.lo, fl.hi, delta=60, l_max=4, blk=blk)
    a = ref.scan_flat_ref(fl.u, fl.v, fl.t, fl.valid, fl.zone_id,
                          delta=60, l_max=4)
    np.testing.assert_array_equal(np.asarray(code), a.code)
    np.testing.assert_array_equal(np.asarray(length), a.length)


def test_fused_flat_kernel_all_pad_stream():
    """An all-padding stream (no real zones) yields zero lengths."""
    s = 128
    zeros = jnp.zeros(s, jnp.int32)
    code, length = ops.scan_flat(
        zeros, zeros, zeros, zeros, jnp.full(s, -1, jnp.int32),
        jnp.asarray([0], jnp.int32), jnp.asarray([s], jnp.int32),
        delta=5, l_max=3, blk=128)
    assert not np.asarray(length).any()
    assert not np.asarray(code).any()
