"""segment_spmm Pallas kernel vs pure-jnp oracle: shape/dtype sweeps."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels.segment_spmm import ops, ref


@pytest.mark.parametrize("e,n,d", [(100, 40, 8), (1000, 128, 64),
                                   (513, 300, 70), (2048, 64, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_scatter_sum_matches_ref(e, n, d, dtype):
    rng = np.random.default_rng(e + n + d)
    values = jnp.asarray(rng.standard_normal((e, d)), dtype)
    seg = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    got = ops.scatter_sum(values, seg, n)
    # compare against the f32 oracle (the kernel accumulates at f32)
    want = ref.scatter_sum(values.astype(jnp.float32), seg, n)
    tol, atol = (1e-5, 1e-5) if dtype == jnp.float32 else (2e-2, 0.15)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=atol,
    )


def test_scatter_sum_with_mask():
    rng = np.random.default_rng(7)
    e, n, d = 500, 100, 32
    values = jnp.asarray(rng.standard_normal((e, d)), jnp.float32)
    seg = jnp.asarray(rng.integers(0, n, e), jnp.int32)
    mask = jnp.asarray(rng.random(e) < 0.7)
    got = ops.scatter_sum(values, seg, n, mask)
    want = ref.scatter_sum(values, seg, n, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_scatter_sum_empty_and_hot_segments():
    """Skew: most rows land in one segment, many segments empty."""
    rng = np.random.default_rng(9)
    e, n, d = 800, 256, 16
    values = jnp.asarray(rng.standard_normal((e, d)), jnp.float32)
    seg = jnp.asarray(
        np.where(rng.random(e) < 0.8, 3, rng.integers(0, n, e)), jnp.int32
    )
    got = ops.scatter_sum(values, seg, n)
    want = ref.scatter_sum(values, seg, n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_gnn_layer_with_pallas_path():
    """GNN forward with use_pallas=True equals the jnp path."""
    import dataclasses

    import jax

    from repro.configs import get_arch
    from repro.configs.gnn_common import GNNShape, _specialize
    from repro.data.graph_data import random_graph_batch
    from repro.models import gnn
    from repro.models.params import tree_init

    cfg = _specialize(get_arch("gin-tu").smoke_config,
                      GNNShape("tiny", 50, 200, 16, 4))
    g = random_graph_batch(n_nodes=50, n_edges=200, d_feat=16, n_classes=4,
                           seed=3)
    p = tree_init(jax.random.PRNGKey(0), gnn.gnn_param_specs(cfg))
    a = gnn.forward(p, g, cfg)
    b = gnn.forward(p, g, dataclasses.replace(cfg, use_pallas=True))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)
