"""Transition-tree invariants (core/transitions.py).

The tree is a prefix aggregation of final-code counts; its defining
invariants are

  * ``through`` at a node == processes whose code extends-or-equals it;
  * ``evolved == through - stopped`` everywhere;
  * children's ``through`` sum to the parent's ``evolved`` (every evolving
    process takes exactly one next step), so ``transition_rows`` shares sum
    to 1 at every branching node.
"""

import pytest

from repro.core import transitions
from conftest import batch_discover, random_graph

KNOWN = {"01": 5, "0101": 3, "0102": 2, "010201": 1}


def _walk(tree):
    stack = [tree.root]
    while stack:
        node = stack.pop()
        yield node
        stack.extend(node.children.values())


def test_build_tree_from_final_counts():
    tree = transitions.build_tree(KNOWN)
    total = sum(KNOWN.values())
    assert tree.root.through == total
    n01 = tree.node("01")
    assert n01.through == total          # every code extends "01"
    assert n01.stopped == 5
    assert n01.evolved == 6
    n0101 = tree.node("0101")
    assert (n0101.through, n0101.stopped, n0101.evolved) == (3, 3, 0)
    n0102 = tree.node("0102")
    assert (n0102.through, n0102.stopped, n0102.evolved) == (3, 2, 1)
    n010201 = tree.node("010201")
    assert (n010201.through, n010201.stopped) == (1, 1)
    with pytest.raises(KeyError):
        tree.node("0103")


@pytest.fixture(scope="module")
def mined_tree():
    g = random_graph(7, 900, 10, 3_000)
    res = batch_discover(g, delta=25, l_max=4, omega=3)
    assert res.overflow == 0
    return transitions.build_tree(res.counts), res


def test_evolved_invariant_everywhere(mined_tree):
    tree, _ = mined_tree
    for node in _walk(tree):
        assert node.evolved == node.through - node.stopped
        assert node.evolved >= 0
        assert node.stopped >= 0


def test_children_partition_evolved(mined_tree):
    tree, _ = mined_tree
    for node in _walk(tree):
        child_through = sum(ch.through for ch in node.children.values())
        assert child_through == node.evolved, node.code


def test_transition_rows_shares_sum_to_one(mined_tree):
    tree, _ = mined_tree
    branching = 0
    for node in _walk(tree):
        rows = node.transition_rows()
        assert len(rows) == len(node.children)
        if rows:
            branching += 1
            assert sum(share for _, _, share in rows) == pytest.approx(1.0)
            for code, count, share in rows:
                assert code.startswith(node.code)
                assert len(code) == len(node.code) + 2
                assert count == node.children[code].through
                assert share == pytest.approx(count / node.evolved)
    assert branching > 0                 # the graph actually branched


def test_level_histogram_matches_tree(mined_tree):
    tree, res = mined_tree
    hist = transitions.level_histogram(res.counts)
    assert sum(hist.values()) == tree.root.through == res.total_processes()
    for level, total in hist.items():
        assert total == sum(
            cnt for code, cnt in res.counts.items()
            if len(code) // 2 == level
        )
