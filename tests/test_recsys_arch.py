"""dcn-v2 smoke tests: reduced config, train/serve/retrieval on CPU."""

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import recsys
from repro.models.params import tree_init
from repro.training import optimizer


def _batch(cfg, b, seed=0, labels=True):
    rng = np.random.default_rng(seed)
    out = {
        "dense": jnp.asarray(
            rng.standard_normal((b, cfg.n_dense)), jnp.float32),
        "sparse_ids": jnp.asarray(np.stack(
            [rng.integers(0, v, (b, cfg.bag_size))
             for v in cfg.vocab_sizes], 1), jnp.int32),
        "sparse_weights": jnp.ones((b, cfg.n_sparse, cfg.bag_size),
                                   jnp.float32),
    }
    if labels:
        # learnable structure: label correlated with first dense feature
        out["labels"] = jnp.asarray(
            (np.asarray(out["dense"])[:, 0] > 0).astype(np.float32))
    return out


def test_forward_and_loss_finite():
    cfg = get_arch("dcn-v2").smoke_config
    p = tree_init(jax.random.PRNGKey(0), recsys.dcn_param_specs(cfg))
    batch = _batch(cfg, 32)
    logits = recsys.forward(p, batch, cfg)
    assert logits.shape == (32,)
    loss = recsys.loss_fn(p, batch, cfg)
    # untrained BCE should be ~ln 2
    assert abs(float(loss) - np.log(2)) < 0.2


def test_training_decreases_loss():
    cfg = get_arch("dcn-v2").smoke_config
    p = tree_init(jax.random.PRNGKey(0), recsys.dcn_param_specs(cfg))
    o = optimizer.init_state(p)
    opt_cfg = optimizer.AdamWConfig(lr=3e-3, warmup_steps=1,
                                    weight_decay=0.0)
    batch = _batch(cfg, 256)

    @jax.jit
    def step(p, o):
        l, g = jax.value_and_grad(recsys.loss_fn)(p, batch, cfg, None)
        p2, o2, _ = optimizer.apply_updates(opt_cfg, p, g, o)
        return p2, o2, l

    losses = []
    for _ in range(25):
        p, o, loss = step(p, o)
        losses.append(float(loss))
    assert losses[-1] < 0.55 < losses[0] + 0.2


def test_retrieval_scores_consistent():
    """Top-k from the batched dot must equal brute-force numpy scoring."""
    cfg = get_arch("dcn-v2").smoke_config
    p = tree_init(jax.random.PRNGKey(1), recsys.dcn_param_specs(cfg))
    batch = _batch(cfg, 4, labels=False)
    cand = jnp.arange(cfg.n_items, dtype=jnp.int32)
    top_s, top_i = recsys.retrieval_step(p, batch, cand, cfg, top_k=10)
    q = np.asarray(recsys.query_embedding(p, batch, cfg))
    items = np.asarray(p["item_table"])
    scores = q @ items.T
    want = np.sort(scores, axis=1)[:, ::-1][:, :10]
    np.testing.assert_allclose(np.asarray(top_s), want, rtol=1e-5,
                               atol=1e-5)


def test_multi_hot_bag_weights():
    """Weighted bags: doubling a weight doubles that row's contribution."""
    cfg = get_arch("dcn-v2").smoke_config
    p = tree_init(jax.random.PRNGKey(2), recsys.dcn_param_specs(cfg))
    b = _batch(cfg, 2, labels=False)
    x0_a = recsys.interact_features(
        p, b["dense"], b["sparse_ids"], b["sparse_weights"], cfg)
    w2 = b["sparse_weights"] * 2.0
    x0_b = recsys.interact_features(
        p, b["dense"], b["sparse_ids"], w2, cfg)
    emb_a = np.asarray(x0_a)[:, cfg.n_dense:]
    emb_b = np.asarray(x0_b)[:, cfg.n_dense:]
    np.testing.assert_allclose(emb_b, 2 * emb_a, rtol=1e-5, atol=1e-6)
