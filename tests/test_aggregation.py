"""Phase-2 aggregation edge cases + the merge algebra tree-merging rests on.

``count_codes``/``merge_counts``/``merge_bounded`` are the primitives every
aggregation path (whole-batch, hierarchical carry, mesh collective, stream
finalization) composes, so their edge cases — empty inputs, all-padding
batches, fully-cancelled signed counts, near-int32 saturation — and the
associativity of merging (merge order must not change results, the algebraic
precondition for *any* merge tree) are pinned here.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import transitions
from repro.core.aggregation import (
    CodeCounts,
    count_codes,
    empty_counts,
    merge_bounded,
    merge_counts,
)

LIMBS = 2


def _counts_of(pairs, capacity=None):
    """CodeCounts from [(code_row, count), ...] via count_codes."""
    n = capacity or max(len(pairs), 1)
    codes = np.zeros((n, LIMBS), np.int32)
    w = np.zeros(n, np.int32)
    for i, (row, cnt) in enumerate(pairs):
        codes[i] = row
        w[i] = cnt
    return count_codes(jnp.asarray(codes), jnp.asarray(w))


def _as_dict(c: CodeCounts) -> dict:
    codes = np.asarray(c.codes)
    counts = np.asarray(c.counts)
    mask = np.asarray(c.unique_mask) & (counts != 0)
    return {tuple(int(x) for x in codes[i]): int(counts[i])
            for i in np.flatnonzero(mask)}


# ---------------------------------------------------------------------------
# Empty / all-padding inputs.
# ---------------------------------------------------------------------------


def test_count_codes_empty_input():
    out = count_codes(jnp.zeros((0, LIMBS), jnp.int32),
                      jnp.zeros((0,), jnp.int32))
    assert out.codes.shape == (0, LIMBS)
    assert out.counts.shape == (0,)
    assert not np.asarray(out.unique_mask).any()
    assert _as_dict(out) == {}


def test_merge_counts_of_empties_is_empty():
    a = empty_counts(0, LIMBS)
    b = empty_counts(4, LIMBS)
    assert _as_dict(merge_counts(a, b)) == {}
    assert _as_dict(merge_counts(b, b)) == {}


def test_all_padding_batch_counts_nothing():
    """All-zero codes with zero weights — the fully-padded zone chunk."""
    out = count_codes(jnp.zeros((16, LIMBS), jnp.int32),
                      jnp.zeros((16,), jnp.int32))
    assert not np.asarray(out.unique_mask).any()
    assert _as_dict(out) == {}
    assert transitions.device_counts_to_dict(out) == {}


def test_padding_code_with_nonzero_weight_stays_masked():
    """The all-zero code is padding by contract even if a weight leaks in."""
    out = count_codes(jnp.zeros((4, LIMBS), jnp.int32),
                      jnp.asarray([3, 0, 0, 0], jnp.int32))
    assert _as_dict(out) == {}


# ---------------------------------------------------------------------------
# Signed cancellation.
# ---------------------------------------------------------------------------


def test_fully_cancelled_counts_disappear():
    c = _counts_of([((7, 0), 5), ((7, 0), -5), ((9, 1), 2)], capacity=8)
    assert _as_dict(c) == {(9, 1): 2}
    assert transitions.device_counts_to_dict(c) == \
        transitions.counts_to_dict(np.asarray(c.codes), np.asarray(c.counts),
                                   np.asarray(c.unique_mask))


def test_merge_cancels_across_tables():
    a = _counts_of([((7, 0), 5), ((3, 2), 1)])
    b = _counts_of([((7, 0), -5), ((4, 0), 1)])
    assert _as_dict(merge_counts(a, b)) == {(3, 2): 1, (4, 0): 1}


def test_merge_bounded_reclaims_cancelled_slots():
    """A cancelled code must not hold a bounded-carry slot forever."""
    a = _counts_of([((7, 0), 5), ((7, 0), -5)], capacity=4)   # cancelled
    b = _counts_of([((3, 1), 1), ((4, 1), 1), ((5, 1), 1)], capacity=4)
    merged, spilled = merge_bounded(a, b, cap=4)
    # 3 live codes + the padding group fit in 4 rows only because the
    # cancelled (7, 0) row was reclaimed
    assert int(spilled) == 0
    assert _as_dict(merged) == {(3, 1): 1, (4, 1): 1, (5, 1): 1}


# ---------------------------------------------------------------------------
# Bounded merge: spill detection and exactness.
# ---------------------------------------------------------------------------


def test_merge_bounded_exact_when_it_fits():
    a = _counts_of([((2, 0), 1), ((3, 0), 2)], capacity=8)
    b = _counts_of([((3, 0), 40), ((9, 9), -1)], capacity=8)
    merged, spilled = merge_bounded(a, b, cap=8)
    assert int(spilled) == 0
    assert _as_dict(merged) == {(2, 0): 1, (3, 0): 42, (9, 9): -1}


def test_merge_bounded_detects_spill_exactly():
    pairs_a = [((i + 1, 0), 1) for i in range(6)]
    pairs_b = [((i + 1, 1), 1) for i in range(6)]
    a = _counts_of(pairs_a, capacity=8)
    b = _counts_of(pairs_b, capacity=8)
    merged, spilled = merge_bounded(a, b, cap=4)
    # 12 live codes, one leading padding-group row possible; at most 4 rows
    # kept -> at least 8 must be reported lost, never silently dropped
    assert int(spilled) >= 8
    assert len(_as_dict(merged)) <= 4


def test_merge_bounded_pads_small_inputs_to_cap():
    a = _counts_of([((5, 0), 1)], capacity=2)
    b = _counts_of([((6, 0), 1)], capacity=2)
    merged, spilled = merge_bounded(a, b, cap=16)
    assert merged.counts.shape == (16,)
    assert int(spilled) == 0
    assert _as_dict(merged) == {(5, 0): 1, (6, 0): 1}


# ---------------------------------------------------------------------------
# int32 saturation boundary.
# ---------------------------------------------------------------------------


def test_counts_near_int32_max_survive_exactly():
    big = 2**30
    rest = 2**31 - 1 - big          # big + rest == int32 max
    a = _counts_of([((11, 0), big)], capacity=4)
    b = _counts_of([((11, 0), rest), ((12, 0), -(2**31 - 1))], capacity=4)
    merged = merge_counts(a, b)
    d = _as_dict(merged)
    assert d[(11, 0)] == 2**31 - 1
    assert d[(12, 0)] == -(2**31 - 1)


def test_duplicate_rows_accumulate_near_saturation():
    quarter = 2**29
    c = _counts_of([((2, 3), quarter)] * 3, capacity=4)
    assert _as_dict(c) == {(2, 3): 3 * quarter}


# ---------------------------------------------------------------------------
# Associativity / commutativity (the tree-merge precondition).
# ---------------------------------------------------------------------------


def _random_counts(rng, n_codes=12, capacity=16):
    pairs = []
    for _ in range(rng.integers(0, n_codes)):
        code = (int(rng.integers(0, 5)), int(rng.integers(0, 5)))
        if code == (0, 0):
            continue
        pairs.append((code, int(rng.integers(-6, 7))))
    return _counts_of(pairs, capacity=capacity)


@pytest.mark.parametrize("seed", range(6))
def test_merge_counts_associative_and_commutative(seed):
    rng = np.random.default_rng(seed)
    a, b, c = (_random_counts(rng) for _ in range(3))
    left = merge_counts(merge_counts(a, b), c)
    right = merge_counts(a, merge_counts(b, c))
    flipped = merge_counts(c, merge_counts(b, a))
    assert _as_dict(left) == _as_dict(right) == _as_dict(flipped)


def test_merge_bounded_order_invariant_when_no_spill():
    """Folding parts in any order gives the same table (cap generous)."""
    rng = np.random.default_rng(42)
    parts = [_random_counts(rng) for _ in range(5)]

    def fold(order):
        acc = empty_counts(64, LIMBS)
        for i in order:
            acc, spilled = merge_bounded(acc, parts[i], cap=64)
            assert int(spilled) == 0
        return _as_dict(acc)

    expect = fold(range(5))
    assert fold([4, 2, 0, 3, 1]) == expect
    assert fold([1, 0, 3, 2, 4]) == expect


def test_hypothesis_merge_associativity():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    code = st.tuples(st.integers(0, 6), st.integers(0, 6)).filter(
        lambda c: c != (0, 0))
    table = st.lists(st.tuples(code, st.integers(-50, 50)), max_size=10).map(
        lambda pairs: _counts_of(pairs, capacity=16))

    @hyp.given(a=table, b=table, c=table)
    @hyp.settings(deadline=None)
    def check(a, b, c):
        left = _as_dict(merge_counts(merge_counts(a, b), c))
        right = _as_dict(merge_counts(a, merge_counts(b, c)))
        assert left == right

    check()
