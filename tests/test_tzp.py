"""TZP invariants (Lemma 4.1/4.2 preconditions) via property tests."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import tzp
from conftest import random_graph


graph_params = st.tuples(
    st.integers(0, 10_000),   # seed
    st.integers(1, 400),      # n_edges
    st.integers(1, 30),       # n_nodes
    st.integers(1, 5_000),    # t_span
)


@settings(deadline=None, max_examples=60)
@given(graph_params, st.integers(1, 50), st.integers(1, 6),
       st.integers(2, 8))
def test_zone_invariants(gp, delta, l_max, omega):
    g = random_graph(*gp)
    plan = tzp.plan_zones(g, delta=delta, l_max=l_max, omega=omega)
    l_b = delta * l_max
    growth = np.flatnonzero(plan.sign > 0)
    bound = np.flatnonzero(plan.sign < 0)

    # interleaving: G B G B ... G
    assert plan.n_zones == 2 * len(growth) - 1 or len(growth) <= 1
    # growth zones at least 2*L_b long (correctness floor)
    for gi in growth:
        assert plan.t_end[gi] - plan.t_start[gi] >= 2 * l_b
    # consecutive growth zones overlap by exactly L_b; boundary = the overlap
    for k in range(len(growth) - 1):
        a, b = growth[k], growth[k + 1]
        assert plan.t_start[b] == plan.t_end[a] - l_b
        bz = bound[k]
        assert plan.t_start[bz] == plan.t_start[b]
        assert plan.t_end[bz] == plan.t_end[a]
    # coverage: first zone starts at t[0], last ends beyond t[-1]
    if g.n_edges:
        assert plan.t_start[growth[0]] <= g.t[0]
        assert plan.t_end[growth[-1]] > g.t[-1]
    # edge ranges consistent with windows
    t64 = g.t.astype(np.int64)
    for zi in range(plan.n_zones):
        lo, cnt = int(plan.lo[zi]), int(plan.count[zi])
        sel = t64[lo:lo + cnt]
        assert (sel >= plan.t_start[zi]).all()
        assert (sel < plan.t_end[zi]).all()
        # no eligible edge excluded
        inside = ((t64 >= plan.t_start[zi]) & (t64 < plan.t_end[zi])).sum()
        assert inside == cnt


@settings(deadline=None, max_examples=30)
@given(graph_params, st.integers(1, 20), st.integers(1, 5),
       st.integers(2, 6), st.integers(4, 64))
def test_adaptive_cap_respected(gp, delta, l_max, omega, cap):
    g = random_graph(*gp)
    plan = tzp.plan_zones(g, delta=delta, l_max=l_max, omega=omega, e_cap=cap)
    growth = np.flatnonzero(plan.sign > 0)
    l_b = delta * l_max
    for gi in growth[:-1]:  # the final zone may exceed cap (tail)
        min_len = plan.t_end[gi] - plan.t_start[gi] == 2 * l_b
        assert plan.count[gi] <= cap or min_len


def test_batch_padding_and_balance():
    g = random_graph(3, 300, 10, 2000)
    plan = tzp.plan_zones(g, delta=10, l_max=4, omega=2)
    batch = tzp.build_zone_batch(g, plan, n_shards=4, pad_zones_to=4)
    assert batch.n_zones % 4 == 0
    assert batch.overflow == 0
    # all real edges appear exactly once in growth zones minus boundary...
    # simpler invariant: per-zone valid count matches the plan
    row_of = {int(z): r for r, z in enumerate(batch.perm) if z >= 0}
    for zi in range(plan.n_zones):
        assert batch.valid[row_of[zi]].sum() == plan.count[zi]
        np.testing.assert_array_equal(
            batch.t[row_of[zi], : int(plan.count[zi])],
            g.t[plan.lo[zi]: plan.lo[zi] + plan.count[zi]],
        )
    # padded rows are fully invalid
    for r in range(batch.n_zones):
        if int(batch.perm[r]) == -1:
            assert not batch.valid[r].any()
            assert batch.sign[r] == 0
