"""Checkpoint/restart, resume-after-crash, elastic re-mesh, serving engine."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import transformer
from repro.training import checkpoint, elastic, optimizer, train_loop


@pytest.fixture
def tiny_setup():
    cfg = get_arch("granite-8b").smoke_config
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    opt = optimizer.init_state(params)
    return cfg, params, opt


def test_checkpoint_roundtrip(tmp_path, tiny_setup):
    cfg, params, opt = tiny_setup
    tree = {"params": params, "opt": opt}
    checkpoint.save(str(tmp_path), 7, tree)
    restored, step = checkpoint.restore(str(tmp_path), tree)
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_and_latest(tmp_path, tiny_setup):
    cfg, params, opt = tiny_setup
    tree = {"params": params}
    for s in (1, 2, 3, 4, 5):
        checkpoint.save(str(tmp_path), s, tree, keep=2)
    assert checkpoint.latest_step(str(tmp_path)) == 5
    import os

    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(kept) == 2


def test_checkpoint_atomicity_partial_save(tmp_path, tiny_setup):
    """A leftover .tmp dir must not shadow the last good checkpoint."""
    import os

    cfg, params, opt = tiny_setup
    tree = {"params": params}
    checkpoint.save(str(tmp_path), 1, tree)
    os.makedirs(tmp_path / "step_000000002.tmp")   # simulated crash
    assert checkpoint.latest_step(str(tmp_path)) == 1
    restored, step = checkpoint.restore(str(tmp_path), tree)
    assert step == 1


def test_train_loop_resume(tmp_path, tiny_setup):
    cfg, params, opt = tiny_setup
    opt_cfg = optimizer.AdamWConfig(lr=1e-3, warmup_steps=1)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg.vocab)
    batch = {"tokens": tokens, "targets": jnp.roll(tokens, -1, 1)}

    @jax.jit
    def step_fn(p, o, b):
        loss, g = jax.value_and_grad(transformer.loss_fn)(p, b, cfg, None)
        p2, o2, m = optimizer.apply_updates(opt_cfg, p, g, o)
        m["loss"] = loss
        return p2, o2, m

    def batches():
        while True:
            yield batch

    loop_cfg = train_loop.TrainLoopConfig(
        total_steps=5, ckpt_dir=str(tmp_path), ckpt_every=2)
    p1, o1, hist1 = train_loop.run(
        step_fn=step_fn, params=params, opt_state=opt,
        batches=batches(), loop_cfg=loop_cfg)
    assert len(hist1) == 5
    assert checkpoint.latest_step(str(tmp_path)) == 5

    # resume: pretend a fresh process with re-initialized state
    loop_cfg2 = train_loop.TrainLoopConfig(
        total_steps=8, ckpt_dir=str(tmp_path), ckpt_every=2)
    p2, o2, hist2 = train_loop.run(
        step_fn=step_fn, params=params, opt_state=opt,
        batches=batches(), loop_cfg=loop_cfg2)
    assert [h["step"] for h in hist2] == [6, 7, 8]
    assert int(o2.step) == 8


def test_elastic_mesh_choice():
    assert elastic.choose_mesh_shape(512, model_parallel=16,
                                     pod_size=256) == (
        (2, 16, 16), ("pod", "data", "model"))
    assert elastic.choose_mesh_shape(256, model_parallel=16,
                                     pod_size=256) == (
        (16, 16), ("data", "model"))
    # degraded: 448 devices (1.75 pods) -> flat data x model
    shape, names = elastic.choose_mesh_shape(448, model_parallel=16,
                                             pod_size=256)
    assert int(np.prod(shape)) <= 448
    assert names[-1] == "model"
    # tiny CPU case
    shape, names = elastic.choose_mesh_shape(1)
    assert int(np.prod(shape)) == 1


def test_serving_engine_batched_requests(tiny_setup):
    from repro.serving.engine import Request, ServingEngine

    cfg, params, _ = tiny_setup
    eng = ServingEngine(cfg, params, slots=2, max_len=64)
    reqs = [
        Request(prompt=[1, 2, 3], max_new_tokens=5),
        Request(prompt=[4, 5], max_new_tokens=4),
        Request(prompt=[6], max_new_tokens=3),
    ]
    done = eng.run(reqs)
    assert all(r.done for r in done)
    assert [len(r.out) for r in done] == [5, 4, 3]
    for r in done:
        assert all(0 <= t < cfg.vocab for t in r.out)


def test_grad_compression_unbiased():
    """int8 stochastic-rounding psum ~= exact psum in expectation."""
    import os
    import subprocess
    import sys

    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from repro.distributed.collectives import compressed_psum_int8

from repro.distributed.collectives import shard_map_compat

mesh = jax.make_mesh((4,), ("d",))
x = jnp.asarray(np.random.default_rng(0).standard_normal((4, 256)),
                jnp.float32)

@partial(shard_map_compat, mesh=mesh,
         in_specs=jax.sharding.PartitionSpec("d"),
         out_specs=jax.sharding.PartitionSpec("d"))
def reduce_exact(x):
    return jax.lax.psum(x, "d")

@partial(shard_map_compat, mesh=mesh,
         in_specs=jax.sharding.PartitionSpec("d"),
         out_specs=jax.sharding.PartitionSpec("d"))
def reduce_q(x):
    key = jax.random.PRNGKey(jax.lax.axis_index("d"))
    return compressed_psum_int8(x, "d", key)

exact = np.asarray(reduce_exact(x))[0]
qs = np.stack([np.asarray(reduce_q(x))[0] for _ in range(1)])
err = np.abs(qs.mean(0) - exact).max() / (np.abs(exact).max() + 1e-9)
assert err < 0.05, err
print("OK", err)
"""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], cwd=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))),
        env=env, capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
