"""Shared test helpers. NB: XLA_FLAGS device-count overrides are only ever
set in subprocess tests — the main process must see 1 CPU device."""

import numpy as np
import pytest

from repro.core.temporal_graph import TemporalGraph, from_edges


def random_graph(seed: int, n_edges: int, n_nodes: int,
                 t_span: int) -> TemporalGraph:
    rng = np.random.default_rng(seed)
    u = rng.integers(0, n_nodes, n_edges)
    v = rng.integers(0, n_nodes, n_edges)
    t = np.sort(rng.integers(0, t_span, n_edges))
    return from_edges(u, v, t)


@pytest.fixture
def rng():
    return np.random.default_rng(0)
