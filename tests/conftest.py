"""Shared test helpers. NB: XLA_FLAGS device-count overrides are only ever
set in subprocess tests — the main process must see 1 CPU device."""

import os

import numpy as np
import pytest

from repro.core.temporal_graph import TemporalGraph, from_edges

try:        # hypothesis is optional for tier-1 (tests importorskip it)
    from hypothesis import settings as _hyp_settings

    # tier1 (default): small, derandomized — property tests ride along in
    # the ordinary suite without bloating it.  fuzz: the dedicated CI
    # differential-fuzz step (REPRO_HYPOTHESIS_PROFILE=fuzz) buys a wider
    # search; seeds are pinned there via --hypothesis-seed.
    _hyp_settings.register_profile("tier1", max_examples=10, deadline=None,
                                   derandomize=True)
    _hyp_settings.register_profile("fuzz", max_examples=50, deadline=None,
                                   print_blob=True)
    _hyp_settings.load_profile(
        os.environ.get("REPRO_HYPOTHESIS_PROFILE", "tier1"))
except ImportError:
    pass


def random_graph(seed: int, n_edges: int, n_nodes: int,
                 t_span: int) -> TemporalGraph:
    rng = np.random.default_rng(seed)
    u = rng.integers(0, n_nodes, n_edges)
    v = rng.integers(0, n_nodes, n_edges)
    t = np.sort(rng.integers(0, t_span, n_edges))
    return from_edges(u, v, t)


def batch_discover(graph, *, mesh=None, zone_axes=None, **config_kwargs):
    """One-shot engine-API discovery for tests sweeping many configs.

    Tests that hammer a single config should hold a warm
    :class:`~repro.core.engine.PTMTEngine` instead — this helper pays a
    fresh engine per call by design (each parametrized config is mined
    once).
    """
    from repro.core import MiningConfig, PTMTEngine

    engine = PTMTEngine(MiningConfig(**config_kwargs))
    if mesh is not None:
        return engine.sharded(graph, mesh, zone_axes)
    return engine.discover(graph)


def batch_sequential(graph, *, delta, l_max, backend="ref"):
    """One-shot TMC-analog baseline (single zone, no TZP)."""
    from repro.core import MiningConfig, PTMTEngine

    return PTMTEngine(MiningConfig(
        delta=delta, l_max=l_max, backend=backend, zone_chunk=0,
    )).sequential(graph)


@pytest.fixture
def rng():
    return np.random.default_rng(0)
