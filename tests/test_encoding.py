"""Phase-3 deterministic relabeling encoding: host + device agreement."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import encoding


def test_limb_count():
    assert encoding.n_limbs(6) == 2
    assert encoding.n_limbs(3) == 1
    assert encoding.n_limbs(7) == 2
    assert encoding.n_limbs(12) == 4
    with pytest.raises(ValueError):
        encoding.n_limbs(15)


def test_roundtrip_simple():
    code = encoding.encode_label_string_np("010212", l_max=6)
    assert encoding.decode_code_np(code) == "010212"
    assert encoding.code_length_np(code) == 3


def test_paper_example_triangle():
    # Fig 2: (A,B),(B,C),(A,C) -> 010212? labels: A=0,B=1 then B=1,C=2 then
    # A=0,C=2 -> digits 01|12|02 -> "011202"... the motif string per paper's
    # scheme: first-occurrence relabeling concatenated in temporal order.
    code = encoding.encode_process_np([(7, 9), (9, 4), (7, 4)], l_max=3)
    assert encoding.decode_code_np(code) == "011202"


def test_prefix_property_sorts_together():
    parent = encoding.encode_label_string_np("0101", l_max=6)
    child = encoding.encode_label_string_np("010121", l_max=6)
    other = encoding.encode_label_string_np("0102", l_max=6)
    # parent < child < other in limb-lexicographic order
    assert tuple(parent) < tuple(child) < tuple(other)


def test_prefix_truncation():
    code = encoding.encode_label_string_np("010212", l_max=6)
    p2 = encoding.prefix_code_np(code, 2)
    assert encoding.decode_code_np(p2) == "0102"
    p1 = encoding.prefix_code_np(code, 1)
    assert encoding.decode_code_np(p1) == "01"
    p0 = encoding.prefix_code_np(code, 0)
    assert encoding.decode_code_np(p0) == ""


def test_append_digit_matches_host():
    l_max = 6
    code = encoding.empty_code((1,), l_max)
    digits = [1, 2, 2, 3, 1, 3]
    for pos, d in enumerate(digits):
        code = encoding.append_digit(
            code, jnp.full((1,), pos, jnp.int32), jnp.full((1,), d, jnp.int32)
        )
    host = encoding.encode_digits_np(digits, l_max)
    np.testing.assert_array_equal(np.asarray(code)[0], host)


@settings(deadline=None, max_examples=50)
@given(
    st.lists(st.tuples(st.integers(0, 9), st.integers(0, 9)),
             min_size=1, max_size=6)
)
def test_encode_process_injective_on_label_sequence(edges):
    """Processes with different label sequences get different codes."""
    l_max = 6
    code = encoding.encode_process_np(edges, l_max)
    s = encoding.decode_code_np(code)
    assert len(s) == 2 * len(edges)
    # decoding is the exact label sequence
    labels: dict[int, int] = {}
    expect = []
    for u, v in edges:
        for node in (u, v):
            labels.setdefault(node, len(labels))
        expect.append(format(labels[u], "x"))
        expect.append(format(labels[v], "x"))
    assert s == "".join(expect)


@settings(deadline=None, max_examples=30)
@given(st.integers(1, 14), st.data())
def test_roundtrip_random(l_max, data):
    n_digits = data.draw(st.integers(1, 2 * l_max))
    digits = data.draw(
        st.lists(st.integers(1, min(15, l_max + 1)),
                 min_size=n_digits, max_size=n_digits)
    )
    code = encoding.encode_digits_np(digits, l_max)
    assert [int(c, 16) + 1 for c in encoding.decode_code_np(code)] == digits
