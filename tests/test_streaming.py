"""Streaming-vs-batch equivalence (the Lemma 4.2 incremental argument).

``StreamingMiner.snapshot()`` must reproduce batch ``batch_discover()`` **exactly,
per motif code** on the closed prefix (edges with ``t < t_head - L_b``),
for arbitrary chunk boundaries — including chunk sizes that do not divide
the edge count — and for both the reference and the NumPy oracle backends.
"""

import numpy as np
import pytest

from repro.core import StreamingMiner, TemporalGraph, oracle
from conftest import batch_discover, random_graph


def _prefix(g: TemporalGraph, cut_time: int) -> TemporalGraph:
    cut = int(np.searchsorted(g.t, cut_time, side="left"))
    return TemporalGraph(u=g.u[:cut], v=g.v[:cut], t=g.t[:cut],
                         n_nodes=g.n_nodes)


def _feed(miner: StreamingMiner, g: TemporalGraph, chunk: int) -> None:
    for i in range(0, g.n_edges, chunk):
        miner.ingest(g.u[i:i + chunk], g.v[i:i + chunk], g.t[i:i + chunk])


@pytest.mark.parametrize("backend", ["ref", "numpy"])
@pytest.mark.parametrize("chunk", [64, 97, 10_000])   # 97 is a non-divisor
def test_snapshot_matches_batch_on_closed_prefix(backend, chunk):
    g = random_graph(5, 700, 11, 2_500)
    delta, l_max, omega = 20, 4, 3
    miner = StreamingMiner(delta=delta, l_max=l_max, omega=omega,
                           backend=backend)
    _feed(miner, g, chunk)

    snap = miner.snapshot()
    expect = batch_discover(_prefix(g, miner.closed_time), delta=delta,
                      l_max=l_max, omega=omega, backend=backend)
    assert snap.counts == expect.counts, f"chunk={chunk}"

    final = miner.snapshot(final=True)
    full = batch_discover(g, delta=delta, l_max=l_max, omega=omega,
                    backend=backend)
    assert final.counts == full.counts, f"chunk={chunk} (final)"


def test_intermediate_snapshots_are_exact():
    """Every mid-stream snapshot equals batch discovery on its prefix, and
    total process count tracks the prefix edge count (no-fork property)."""
    g = random_graph(8, 600, 9, 2_000)
    delta, l_max, omega = 25, 3, 2
    miner = StreamingMiner(delta=delta, l_max=l_max, omega=omega)
    chunk = 150
    for i in range(0, g.n_edges, chunk):
        miner.ingest(g.u[i:i + chunk], g.v[i:i + chunk], g.t[i:i + chunk])
        snap = miner.snapshot()
        prefix = _prefix(g, miner.closed_time)
        expect = batch_discover(prefix, delta=delta, l_max=l_max, omega=omega)
        assert snap.counts == expect.counts, f"at edge {i}"
        assert snap.total_processes() == prefix.n_edges


def test_streaming_with_adaptive_e_cap():
    from repro.data import synthetic_graphs as sg

    g = sg.bursty_stream(500, 12, seed=3)
    delta, l_max = 60, 4
    miner = StreamingMiner(delta=delta, l_max=l_max, omega=4, e_cap=64)
    _feed(miner, g, 120)
    final = miner.snapshot(final=True)
    expect = dict(oracle.count_codes(g.u, g.v, g.t, delta, l_max))
    assert final.counts == expect


def test_frontier_retires_edges():
    """The sliding buffer must actually shrink (memory-bounded streaming)."""
    g = random_graph(2, 900, 10, 9_000)
    miner = StreamingMiner(delta=10, l_max=3, omega=2)
    _feed(miner, g, 100)
    assert miner.n_edges_retired > 0
    assert miner.buffered_edges < g.n_edges
    assert miner.buffered_edges + miner.n_edges_retired == g.n_edges
    assert miner.n_zones_finalized > 0


def test_quiet_gap_is_skipped_not_walked():
    """A long idle period must not spin one finalization per empty window."""
    miner = StreamingMiner(delta=1, l_max=3, omega=2)
    miner.ingest([0], [1], [0])
    miner.ingest([1], [2], [100_000_000])       # would be ~33M empty pairs
    assert miner.n_zones_finalized <= 4
    miner.ingest([2], [3], [100_000_050])
    final = miner.snapshot(final=True)
    # gaps dwarf delta: every edge is its own 1-edge process (oracle truth;
    # batch discover would itself walk the gap zone-by-zone here)
    assert final.counts == {"01": 3}


def test_invalid_parameters_rejected():
    """delta/l_max < 1 must raise up front (not loop forever in _advance)."""
    with pytest.raises(ValueError, match="delta and l_max"):
        StreamingMiner(delta=0, l_max=3)
    with pytest.raises(ValueError, match="delta and l_max"):
        StreamingMiner(delta=10, l_max=0)
    with pytest.raises(ValueError, match="omega"):
        StreamingMiner(delta=10, l_max=3, omega=1)


def test_out_of_order_chunk_rejected():
    miner = StreamingMiner(delta=10, l_max=3)
    miner.ingest([0], [1], [100])
    with pytest.raises(ValueError, match="time-ordered"):
        miner.ingest([1], [2], [50])
    with pytest.raises(ValueError, match="non-decreasing"):
        miner.ingest([0, 1], [1, 2], [200, 150])


def test_large_epoch_timestamps():
    """int64 wall-clock timestamps must not overflow the int32 device batch
    (batches are rebased per zone pair; counts are shift-invariant)."""
    g = random_graph(4, 400, 8, 1_500)
    delta, l_max, omega = 20, 3, 2
    offset = np.int64(3_000_000_000)          # > 2**31
    miner = StreamingMiner(delta=delta, l_max=l_max, omega=omega)
    for i in range(0, g.n_edges, 90):
        miner.ingest(g.u[i:i + 90], g.v[i:i + 90],
                     g.t[i:i + 90].astype(np.int64) + offset)
    final = miner.snapshot(final=True)
    expect = batch_discover(g, delta=delta, l_max=l_max, omega=omega)
    assert final.counts == expect.counts


def test_snapshot_reuses_tail_within_epoch():
    """Repeated snapshots in one epoch reuse the cached open-tail mine
    (exact, epoch-keyed); an epoch bump invalidates it."""
    g = random_graph(6, 800, 10, 2_600)
    delta, l_max, omega = 20, 4, 3
    miner = StreamingMiner(delta=delta, l_max=l_max, omega=omega)
    _feed(miner, g, 200)

    first = miner.snapshot()
    assert miner.tail_cache_misses == 1
    again = miner.snapshot()
    assert miner.tail_cache_hits == 1 and miner.tail_cache_misses == 1
    assert again.counts == first.counts
    assert again.n_zones == first.n_zones

    # final=True must bypass the cache (different cut), not poison it
    fin = miner.snapshot(final=True)
    assert miner.tail_cache_misses == 1
    expect_fin = batch_discover(g, delta=delta, l_max=l_max, omega=omega)
    assert fin.counts == expect_fin.counts

    # an epoch-advancing ingest invalidates: next snapshot re-mines
    epoch = miner.epoch
    t0 = int(miner.t_head)          # == g.t[-1]: the stream is fully fed
    i = 0
    while miner.epoch == epoch:
        i += 1
        miner.ingest([0], [1], [t0 + 50 * i])
    snap = miner.snapshot()
    assert miner.tail_cache_misses == 2
    expect = batch_discover(_prefix_with_extra(g, miner, 50, i),
                      delta=delta, l_max=l_max, omega=omega)
    assert snap.counts == expect.counts


def _prefix_with_extra(g, miner, step, n_extra):
    """The ingested stream (g + the n_extra appended edges) cut at the
    miner's closed time."""
    t0 = int(g.t[-1])
    u = np.concatenate([g.u, np.zeros(n_extra, g.u.dtype)])
    v = np.concatenate([g.v, np.ones(n_extra, g.v.dtype)])
    t = np.concatenate(
        [g.t, t0 + step * np.arange(1, n_extra + 1, dtype=g.t.dtype)])
    full = TemporalGraph(u=u, v=v, t=t, n_nodes=g.n_nodes)
    return _prefix(full, miner.closed_time)


def test_empty_and_tiny_streams():
    miner = StreamingMiner(delta=10, l_max=3)
    assert miner.snapshot().counts == {}
    miner.ingest(np.array([], int), np.array([], int), np.array([], int))
    assert miner.snapshot().counts == {}
    miner.ingest([3], [8], [100])
    assert miner.snapshot().counts == {}          # head not yet closed
    assert miner.snapshot(final=True).counts == {"01": 1}
