"""Dry-run smoke: lower+compile representative cells on a small virtual mesh.

The full 256/512-chip sweep runs via ``python -m repro.launch.dryrun
--orchestrate`` (results under benchmarks/results/dryrun).  Here we prove the
machinery end to end in-process-light subprocesses with 8 virtual devices —
smoke configs, every workload kind, plus the sharding resolver paths
(batch=1 long-context, MoE expert sharding, mining shard_map).
"""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_TEMPLATE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.configs import get_arch
from repro.configs.common import LMShape
from repro.configs.gnn_common import GNNShape
from repro.configs.dcn_v2 import RecsysShape
from repro.configs.ptmt import MiningShape
from repro.launch.mesh import make_test_mesh

mesh = make_test_mesh((2, 2, 2), ("pod", "data", "model"))
arch = get_arch({arch!r})
shape = {shape}
wl = arch.workload_fn(arch.smoke_config, shape, mesh)
if wl.in_shardings is None:
    jitted = jax.jit(wl.fn)
else:
    jitted = jax.jit(wl.fn, in_shardings=wl.in_shardings,
                     out_shardings=wl.out_shardings)
compiled = jitted.lower(*wl.in_sds).compile()
ma = compiled.memory_analysis()
assert ma.temp_size_in_bytes >= 0
print("OK", wl.name, ma.temp_size_in_bytes)
"""

CASES = [
    ("granite-8b", "LMShape('train_4k', 256, 16, 'train')"),
    ("gemma3-1b", "LMShape('prefill_32k', 2048, 4, 'prefill')"),
    ("qwen2-72b", "LMShape('decode_32k', 2048, 8, 'decode')"),
    ("moonshot-v1-16b-a3b", "LMShape('train_4k', 128, 8, 'train')"),
    ("arctic-480b", "LMShape('long_500k', 16384, 1, 'decode')"),
    ("gat-cora", "GNNShape('full_graph_sm', 512, 2048, 16, 4)"),
    ("equiformer-v2", "GNNShape('molecule', 240, 512, 8, 1, n_graphs=8)"),
    ("dcn-v2", "RecsysShape('train_batch', 1024, 'train')"),
    ("dcn-v2", "RecsysShape('retrieval_cand', 1, 'retrieval', "
               "n_candidates=4096)"),
    ("ptmt-mining", "MiningShape('mine_sm', 64, 256)"),
]


@pytest.mark.parametrize("arch,shape", CASES,
                         ids=[f"{a}-{i}" for i, (a, s) in enumerate(CASES)])
def test_cell_lowers_and_compiles(arch, shape):
    code = _TEMPLATE.format(arch=arch, shape=shape)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=540, env=env, cwd=REPO,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
