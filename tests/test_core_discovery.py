"""End-to-end PTMT correctness: oracle equivalence + Lemma 4.2 exactness.

This is the paper's Fig. 7 ("complete consistency validation") at test scale:
the partitioned parallel pipeline must reproduce the sequential TMC-analog
and the brute-force oracle *exactly*, for every motif code.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis"
)
from hypothesis import given, settings, strategies as st

from repro.core import oracle
from repro.data import synthetic_graphs as sg
from conftest import batch_discover, batch_sequential, random_graph


def assert_counts_equal(a: dict, b: dict, tag=""):
    keys = set(a) | set(b)
    bad = {k: (a.get(k, 0), b.get(k, 0)) for k in keys
           if a.get(k, 0) != b.get(k, 0)}
    assert not bad, f"{tag}: {len(bad)} mismatching codes, e.g. " \
                    f"{dict(list(bad.items())[:5])}"


@settings(deadline=None, max_examples=25)
@given(
    st.tuples(st.integers(0, 10_000), st.integers(1, 120),
              st.integers(1, 15), st.integers(1, 600)),
    st.integers(1, 30), st.integers(1, 6), st.integers(2, 5),
)
def test_partitioned_matches_oracle(gp, delta, l_max, omega):
    """Lemma 4.2: inclusion-exclusion over zones is exact."""
    g = random_graph(*gp)
    expect = dict(oracle.count_codes(g.u, g.v, g.t, delta, l_max))
    got = batch_discover(g, delta=delta, l_max=l_max, omega=omega)
    assert got.overflow == 0
    assert_counts_equal(expect, got.counts, "partitioned vs oracle")


@settings(deadline=None, max_examples=10)
@given(
    st.tuples(st.integers(0, 10_000), st.integers(1, 100),
              st.integers(1, 10), st.integers(1, 400)),
    st.integers(1, 25), st.integers(1, 5),
)
def test_sequential_matches_oracle(gp, delta, l_max):
    g = random_graph(*gp)
    expect = dict(oracle.count_codes(g.u, g.v, g.t, delta, l_max))
    got = batch_sequential(g, delta=delta, l_max=l_max)
    assert_counts_equal(expect, got.counts, "sequential vs oracle")


@settings(deadline=None, max_examples=10)
@given(st.integers(0, 100), st.integers(2, 4))
def test_partitioned_matches_sequential_bursty(seed, omega):
    """Accuracy validation on the bursty regime (paper Section 5.2)."""
    g = sg.bursty_stream(500, 12, seed=seed)
    seq = batch_sequential(g, delta=75, l_max=5)
    par = batch_discover(g, delta=75, l_max=5, omega=omega)
    assert_counts_equal(seq.counts, par.counts, "par vs seq")


def test_total_process_count_equals_edges():
    """Every edge seeds exactly one process (no-fork property)."""
    g = sg.poisson_stream(800, 40, rate=0.5, seed=9)
    res = batch_discover(g, delta=20, l_max=4, omega=3)
    assert res.total_processes() == g.n_edges


def test_adaptive_capacity_still_exact():
    g = sg.bursty_stream(600, 10, seed=3)
    expect = dict(oracle.count_codes(g.u, g.v, g.t, 120, 6))
    got = batch_discover(g, delta=120, l_max=6, omega=4, e_cap=64)
    assert got.overflow == 0
    assert_counts_equal(expect, got.counts, "adaptive-cap")


def test_zone_chunking_invariance():
    g = sg.poisson_stream(400, 15, rate=1.0, seed=5)
    a = batch_discover(g, delta=15, l_max=4, omega=2, zone_chunk=None)
    b = batch_discover(g, delta=15, l_max=4, omega=2, zone_chunk=2)
    assert_counts_equal(a.counts, b.counts, "chunked vs unchunked")


def test_self_loops_and_ties():
    rng = np.random.default_rng(17)
    n = 150
    u = rng.integers(0, 4, n)
    v = rng.integers(0, 4, n)
    t = np.sort(rng.integers(0, 30, n))  # heavy timestamp ties
    from repro.core import from_edges

    g = from_edges(u, v, t)
    expect = dict(oracle.count_codes(g.u, g.v, g.t, 5, 5))
    got = batch_discover(g, delta=5, l_max=5, omega=2)
    assert_counts_equal(expect, got.counts, "ties+selfloops")


def test_transition_tree_consistency():
    g = sg.triadic_stream(600, 25, seed=2)
    res = batch_discover(g, delta=120, l_max=4, omega=3)
    tree = res.tree()
    # root through == total processes; children sum <= parent's through
    assert tree.root.through == res.total_processes()
    for code, node in tree.root.children.items():
        child_sum = sum(c.through for c in node.children.values())
        assert node.evolved == child_sum
        assert node.through >= node.stopped
    # level histogram consistent with per-code lengths
    hist = res.level_histogram()
    assert sum(hist.values()) == res.total_processes()


def test_empty_and_single_edge():
    from repro.core import from_edges

    g0 = from_edges(np.array([], int), np.array([], int), np.array([], int))
    assert batch_discover(g0, delta=5, l_max=3).counts == {}
    g1 = from_edges(np.array([3]), np.array([8]), np.array([100]))
    res = batch_discover(g1, delta=5, l_max=3)
    assert res.counts == {"01": 1}
