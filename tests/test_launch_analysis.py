"""Unit tests for roofline extraction (HLO collective parsing, terms)."""

import numpy as np

from repro.launch import analysis


def test_shape_bytes():
    assert analysis._shape_bytes("bf16[128,256]{1,0}") == 128 * 256 * 2
    assert analysis._shape_bytes("f32[8]{0}") == 32
    assert analysis._shape_bytes("pred[16]") == 16
    # tuples: sum of members
    assert analysis._shape_bytes("(f32[4]{0}, s32[4]{0})") == 32


def test_collective_bytes_parsing():
    hlo = """
  %ag = bf16[2048,512]{1,0} all-gather(bf16[128,512]{1,0} %x), dims={0}
  %ar = f32[1024]{0} all-reduce(f32[1024]{0} %y), to_apply=%sum
  %rs = f32[128,64]{1,0} reduce-scatter(f32[1024,64]{1,0} %z), dims={0}
  %cp = u8[256]{0} collective-permute(u8[256]{0} %w)
  %a2a = s32[64,32]{1,0} all-to-all(s32[64,32]{1,0} %v), dims={0}
"""
    out = analysis.collective_bytes(hlo)
    k = out["per_kind_bytes"]
    assert k["all-gather"] == 2048 * 512 * 2
    assert k["all-reduce"] == 2 * 1024 * 4            # ring: 2x
    assert k["reduce-scatter"] == 1024 * 64 * 4       # input-sized
    assert k["collective-permute"] == 256
    assert k["all-to-all"] == 64 * 32 * 4
    assert out["per_kind_counts"]["all-gather"] == 1
    assert out["total_bytes"] == sum(k.values())


def test_collective_bytes_ignores_non_collectives():
    hlo = "%d = f32[128,128]{1,0} dot(f32[128,128] %a, f32[128,128] %b)"
    assert analysis.collective_bytes(hlo)["total_bytes"] == 0


def test_roofline_terms_and_dominance():
    rec = {
        "flops_per_chip": 1.97e12,        # 10 ms of compute
        "bytes_per_chip": 819e6,          # 1 ms of HBM
        "collective_bytes_per_chip": 50e9 * 0.05,  # 50 ms of ICI
        "n_chips": 256,
        "model_flops": 1.97e12 * 256 * 0.5,
    }
    out = analysis.roofline(rec)
    np.testing.assert_allclose(out["compute_s"], 0.01)
    np.testing.assert_allclose(out["memory_s"], 1e-3)
    np.testing.assert_allclose(out["collective_s"], 0.05)
    assert out["dominant"] == "collective"
    np.testing.assert_allclose(out["useful_flops_ratio"], 0.5)
    # fraction: useful flops / (chips * peak * bound)
    np.testing.assert_allclose(out["roofline_fraction"], 0.1)


def test_roofline_peak_override():
    rec = {
        "flops_per_chip": 3.85e12,
        "bytes_per_chip": 0.0,
        "collective_bytes_per_chip": 0.0,
        "n_chips": 1,
        "model_flops": 3.85e12,
        "peak_flops": analysis.VPU_PEAK,
    }
    out = analysis.roofline(rec)
    np.testing.assert_allclose(out["compute_s"], 1.0)
    np.testing.assert_allclose(out["roofline_fraction"], 1.0)
