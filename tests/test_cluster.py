"""Cluster availability layer — checkpoint exactness, placement, admission,
failover, and the kill -9 restart contract.

The load-bearing guarantee everywhere: TZP makes streaming state exactly
serializable (config + finalized counts + epoch + open tail), so a session
restored from a checkpoint and fed the remainder of its stream is
**byte-identical** to one that never stopped — across in-process restore,
worker failover, a cold coordinator restart, and an actual ``kill -9`` of
the replay harness.
"""

import json
import os
import subprocess
import sys
import threading

import numpy as np
import pytest

from repro.core import MiningConfig, PTMTEngine
from repro.serving.cluster import (
    AdmissionController,
    CheckpointError,
    CheckpointStore,
    ClusterCoordinator,
    SessionCheckpoint,
    WorkerDown,
    place,
    rendezvous_owner,
)
from repro.serving.motif import MotifService, MotifSession, QueryRequest
from conftest import random_graph

DELTA, L_MAX, OMEGA = 20, 4, 3


def _cfg(**kw):
    params = dict(delta=DELTA, l_max=L_MAX, omega=OMEGA)
    params.update(kw)
    return MiningConfig(**params)


def _feed(target, name, g, *, chunk, start=0, end=None):
    end = g.n_edges if end is None else end
    i = start
    while i < end:
        j = min(i + chunk, end)
        ack = target.ingest(name, g.u[i:j], g.v[i:j], g.t[i:j])
        if getattr(ack, "throttled", False):
            target.flush(name)
            continue
        i = j
    return i


def _counts(service_or_session, name=None):
    sess = (service_or_session.manager.get(name)
            if name is not None else service_or_session)
    return sess.engine().result.counts


def _reference(g, *, chunk=200, ingest_batch=256):
    svc = MotifService(engine=PTMTEngine(_cfg()), ingest_batch=ingest_batch)
    svc.create_session("ref")
    _feed(svc, "ref", g, chunk=chunk)
    svc.flush("ref")
    return _counts(svc, "ref")


# ---------------------------------------------------------------------------
# Checkpoint format: round-trip exactness, atomicity, corruption rejection.
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_restores_byte_identical_counts(tmp_path):
    """Snapshot mid-stream, restore into a fresh manager, feed the rest:
    final counts equal an uninterrupted session's, byte for byte."""
    g = random_graph(3, 600, 12, 2_000)
    svc = MotifService(engine=PTMTEngine(_cfg()), ingest_batch=128)
    svc.create_session("alice")
    cut = 300
    _feed(svc, "alice", g, chunk=100, end=cut)

    ckpt = SessionCheckpoint.capture(svc.manager.get("alice"),
                                     {"offset": cut})
    path = ckpt.save(str(tmp_path / "alice.ckpt.json"))
    loaded = SessionCheckpoint.load(path)
    assert loaded.tenant == "alice"
    assert loaded.meta == {"offset": cut}

    svc2 = MotifService(engine=PTMTEngine(_cfg()), ingest_batch=128)
    restored = svc2.manager.restore(loaded.payload)
    # the admission window survives: pending edges were checkpointed too
    assert restored.pending_edges == svc.manager.get("alice").pending_edges
    _feed(svc2, "alice", g, chunk=100, start=cut)
    svc2.flush("alice")

    _feed(svc, "alice", g, chunk=100, start=cut)
    svc.flush("alice")
    assert _counts(svc2, "alice") == _counts(svc, "alice")
    assert _counts(svc2, "alice") == _reference(g)


def test_checkpoint_restore_shares_warm_engine_when_configs_agree(tmp_path):
    engine = PTMTEngine(_cfg())
    svc = MotifService(engine=engine, ingest_batch=64)
    svc.create_session("t")
    g = random_graph(1, 200, 8, 800)
    _feed(svc, "t", g, chunk=64)
    state = svc.manager.get("t").checkpoint_state()

    svc2 = MotifService(engine=engine, ingest_batch=64)
    restored = svc2.manager.restore(state)
    # same config -> the restored miner rides the shared warm executor
    assert restored.miner.executor is engine.executor


def test_checkpoint_rejects_crc_corruption(tmp_path):
    g = random_graph(5, 120, 6, 500)
    svc = MotifService(engine=PTMTEngine(_cfg()), ingest_batch=32)
    svc.create_session("x")
    _feed(svc, "x", g, chunk=40)
    path = str(tmp_path / "x.ckpt.json")
    SessionCheckpoint.capture(svc.manager.get("x")).save(path)

    doc = json.load(open(path))
    # flip durable state without updating the CRC — must be rejected
    doc["payload"]["edges_accepted"] = 10_000
    open(path, "w").write(json.dumps(doc))
    with pytest.raises(CheckpointError, match="CRC"):
        SessionCheckpoint.load(path)

    open(path, "w").write("{not json")
    with pytest.raises(CheckpointError, match="JSON"):
        SessionCheckpoint.load(path)


def test_checkpoint_rejects_unknown_version_and_format(tmp_path):
    g = random_graph(5, 80, 6, 300)
    svc = MotifService(engine=PTMTEngine(_cfg()), ingest_batch=32)
    svc.create_session("x")
    path = str(tmp_path / "x.ckpt.json")
    SessionCheckpoint.capture(svc.manager.get("x")).save(path)
    doc = json.load(open(path))
    doc2 = dict(doc, version=99)
    open(path, "w").write(json.dumps(doc2))
    with pytest.raises(CheckpointError, match="version"):
        SessionCheckpoint.load(path)
    doc3 = dict(doc, format="something-else")
    open(path, "w").write(json.dumps(doc3))
    with pytest.raises(CheckpointError, match="format"):
        SessionCheckpoint.load(path)


def test_restore_state_rejects_mismatched_session():
    g = random_graph(7, 150, 8, 600)
    svc = MotifService(engine=PTMTEngine(_cfg()), ingest_batch=32)
    svc.create_session("t")
    _feed(svc, "t", g, chunk=50)
    state = svc.manager.get("t").checkpoint_state()
    # a session built under a different config must refuse the state
    # rather than silently mine under the wrong parameters
    with pytest.raises(ValueError, match="does not match"):
        MotifSession("t", config=_cfg(delta=DELTA + 5)).restore_state(state)
    with pytest.raises(ValueError, match="tenant"):
        MotifSession("other", config=_cfg()).restore_state(state)
    # the manager path adopts the checkpointed config instead: restoring
    # against a manager whose defaults differ still rebuilds faithfully
    svc2 = MotifService(config=_cfg(delta=DELTA + 5), ingest_batch=32)
    restored = svc2.manager.restore(state)
    assert restored.config.delta == DELTA


def test_checkpoint_store_tenant_files(tmp_path):
    store = CheckpointStore(str(tmp_path))
    svc = MotifService(engine=PTMTEngine(_cfg()), ingest_batch=32)
    for name in ("a", "weird/name:x", "a" * 80):
        svc.create_session(name)
        store.save(SessionCheckpoint.capture(svc.manager.get(name)))
    assert store.tenants() == sorted(["a", "weird/name:x", "a" * 80])
    assert store.load("weird/name:x").tenant == "weird/name:x"
    assert store.delete("a") and not store.delete("a")
    with pytest.raises(CheckpointError, match="no checkpoint"):
        store.load("a")


# ---------------------------------------------------------------------------
# Rendezvous placement.
# ---------------------------------------------------------------------------


def test_rendezvous_is_deterministic_and_moves_minimally():
    tenants = [f"tenant{i}" for i in range(60)]
    workers = ["w0", "w1", "w2", "w3"]
    before = place(tenants, workers)
    assert before == place(tenants, workers)          # deterministic
    assert set(before.values()) == set(workers)       # all workers used

    survivors = [w for w in workers if w != "w2"]
    after = place(tenants, survivors)
    for t in tenants:
        if before[t] != "w2":
            # minimal movement: only the dead worker's tenants re-home
            assert after[t] == before[t]
        else:
            assert after[t] in survivors


def test_rendezvous_requires_workers():
    with pytest.raises(ValueError, match="no live workers"):
        rendezvous_owner("t", [])


# ---------------------------------------------------------------------------
# Admission control.
# ---------------------------------------------------------------------------


def test_admission_tenant_and_global_budgets():
    adm = AdmissionController(tenant_budget=100, global_budget=150)
    assert adm.offer("a", 80)
    d = adm.offer("a", 30)                 # 80 + 30 > 100
    assert not d and d.reason == "tenant_budget"
    assert adm.offer("b", 60)              # b is fine per-tenant...
    d = adm.offer("b", 20)                 # ...but 80 + 60 + 20 > 150
    assert not d and d.reason == "global_budget"
    assert adm.deferred_edges == 50
    # draining repays debt and re-admits
    adm.settle("a", 0)
    assert adm.offer("b", 20)
    assert adm.pending() == 80


def test_admission_settle_and_forget_reconcile_debt():
    adm = AdmissionController(tenant_budget=50, global_budget=None)
    adm.offer("a", 40)
    adm.settle("a", 10)                    # a flush admitted 30 to the miner
    assert adm.pending("a") == 10 and adm.pending() == 10
    adm.offer("a", 40)                     # fits again
    adm.forget("a")
    assert adm.pending() == 0
    adm.shed("a", 7)
    assert adm.stats()["shed_edges"] == 7


def test_admission_throttles_cluster_ingest_without_buffering():
    g = random_graph(11, 400, 10, 1_500)
    co = ClusterCoordinator(1, config=_cfg(), tenant_budget=100,
                            ingest_batch=10_000)   # never auto-flushes
    co.create_tenant("t")
    ack = co.ingest("t", g.u[:80], g.v[:80], g.t[:80])
    assert not ack.throttled and ack.pending == 80
    ack = co.ingest("t", g.u[80:160], g.v[80:160], g.t[80:160])
    assert ack.throttled and ack.reason == "tenant_budget"
    assert ack.accepted == 0
    # nothing was buffered by the throttled call
    assert co.workers["w0"].service.manager.get("t").pending_edges == 80
    co.flush("t")                          # drain, then the retry fits
    ack = co.ingest("t", g.u[80:160], g.v[80:160], g.t[80:160])
    assert not ack.throttled


# ---------------------------------------------------------------------------
# Coordinator: routing, failover, cold restart.
# ---------------------------------------------------------------------------


def test_failover_restores_byte_identical_counts(tmp_path):
    """Feed half, checkpoint, kill the owner: victims re-home, rewind to
    their checkpointed offsets, finish — counts match an undisturbed run."""
    g = random_graph(13, 700, 14, 2_500)
    co = ClusterCoordinator(3, config=_cfg(), checkpoint_dir=str(tmp_path),
                            ingest_batch=128)
    names = [f"tenant{i}" for i in range(4)]
    for n in names:
        co.create_tenant(n)
        co.checkpoint(n, {"offset": 0})
    offsets = {n: _feed(co, n, g, chunk=100, end=400) for n in names}
    co.checkpoint_all({n: {"offset": offsets[n]} for n in names})

    victim = co.owner_of(names[0])
    recovered = co.kill_worker(victim)
    assert names[0] in recovered
    assert co.owner_of(names[0]) != victim
    assert victim not in co.live_workers()
    for n, meta in recovered.items():
        offsets[n] = int(meta["offset"])

    for n in names:
        _feed(co, n, g, chunk=100, start=offsets[n])
        co.flush(n)
    expect = _reference(g)
    for n in names:
        worker = co.workers[co.owner_of(n)]
        assert _counts(worker.service.manager.get(n)) == expect, n
    assert co.stats()["failovers"] == len(recovered)


def test_cold_restart_from_store_is_byte_identical(tmp_path):
    g = random_graph(17, 500, 12, 2_000)
    co = ClusterCoordinator(2, config=_cfg(), checkpoint_dir=str(tmp_path),
                            ingest_batch=96)
    for n in ("a", "b"):
        co.create_tenant(n)
        off = _feed(co, n, g, chunk=90, end=270)
        co.checkpoint(n, {"offset": off})

    # brand-new coordinator (fresh engines, nothing in memory)
    co2 = ClusterCoordinator(2, config=_cfg(), checkpoint_dir=str(tmp_path),
                             ingest_batch=96)
    recovered = co2.restore_all()
    assert sorted(recovered) == ["a", "b"]
    expect = _reference(g)
    for n, meta in recovered.items():
        _feed(co2, n, g, chunk=90, start=int(meta["offset"]))
        co2.flush(n)
        worker = co2.workers[co2.owner_of(n)]
        assert _counts(worker.service.manager.get(n)) == expect, n


def test_queries_route_to_owner_across_failover(tmp_path):
    g = random_graph(19, 300, 10, 1_200)
    co = ClusterCoordinator(2, config=_cfg(), checkpoint_dir=str(tmp_path),
                            ingest_batch=64)
    co.create_tenant("t")
    _feed(co, "t", g, chunk=64, end=192)
    co.checkpoint("t", {"offset": 192})
    before = co.query(QueryRequest(session="t", op="total")).payload

    recovered = co.kill_worker(co.owner_of("t"))
    # served state is rebuilt from the checkpoint — the answer either
    # matches (same durable prefix) and MUST be identical after replay
    _feed(co, "t", g, chunk=64, start=int(recovered["t"]["offset"]),
          end=192)
    after = co.query(QueryRequest(session="t", op="total")).payload
    assert after == before


def test_dead_worker_rejects_calls_and_lost_tenant_without_checkpoint():
    co = ClusterCoordinator(2, config=_cfg(), ingest_batch=64,
                            store=None)
    co.create_tenant("t")
    owner = co.owner_of("t")
    with pytest.raises(CheckpointError, match="no checkpoint store"):
        co.checkpoint("t")
    recovered = co.kill_worker(owner)
    # no store -> the tenant is lost, reported as None, and unrouted
    assert recovered == {"t": None}
    assert co.stats()["tenants_lost"] == 1
    with pytest.raises(KeyError):
        co.owner_of("t")
    with pytest.raises(WorkerDown):
        co.workers[owner].tenants()
    with pytest.raises(WorkerDown):
        co.kill_worker(owner)              # already down


def test_comine_groups_by_owner_and_matches_independent(tmp_path):
    g = random_graph(23, 400, 10, 1_500)
    co = ClusterCoordinator(2, config=_cfg(), ingest_batch=64)
    co.create_tenant("a")
    co.create_tenant("b", delta=DELTA // 2)
    results = co.comine(g)
    assert sorted(results) == ["a", "b"]
    for name, cfg in (("a", _cfg()), ("b", _cfg(delta=DELTA // 2))):
        solo = PTMTEngine(cfg).discover(g)
        assert results[name].counts == solo.counts, name


def test_worker_sharded_mine_matches_plain_discover():
    import jax

    g = random_graph(29, 300, 9, 1_200)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("z",))
    co = ClusterCoordinator(1, config=_cfg(zone_chunk=2), mesh=mesh,
                            mesh_axes=("z",), ingest_batch=64)
    sharded = co.workers["w0"].sharded_mine(g)
    plain = PTMTEngine(_cfg(zone_chunk=2)).discover(g)
    assert sharded.counts == plain.counts


# ---------------------------------------------------------------------------
# The real thing: kill -9 the replay harness mid-ingest, restart, compare.
# ---------------------------------------------------------------------------


def test_harness_kill_and_restart_counts_equal(tmp_path):
    """End-to-end restart contract through the actual CLI: the harness is
    killed abruptly mid-ingest (exit 73, no cleanup), restarted from the
    checkpoint dir, and must report counts byte-identical to an
    uninterrupted replay (the harness exits nonzero otherwise)."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, JAX_PLATFORMS="cpu",
               PYTHONPATH=os.path.join(repo, "src"))
    ckdir = str(tmp_path / "ck")
    out = str(tmp_path / "report.json")
    base = [
        sys.executable, "-m", "repro.launch.serve_motifs",
        "--dataset", "collegemsg-like", "--delta", "60", "--l-max", "3",
        "--backend", "ref", "--tenants", "2", "--workers", "2",
        "--chunk-edges", "1024", "--ingest-batch", "2048",
        "--queries-per-chunk", "0", "--checkpoint-dir", ckdir,
        "--checkpoint-every", "2048",
    ]
    killed = subprocess.run(base + ["--kill-after", "6000"], env=env,
                            capture_output=True, text=True, timeout=600)
    assert killed.returncode == 73, killed.stderr[-2000:]

    restarted = subprocess.run(base + ["--restart", "--out-json", out],
                               env=env, capture_output=True, text=True,
                               timeout=600)
    assert restarted.returncode == 0, (restarted.stdout[-2000:],
                                       restarted.stderr[-2000:])
    report = json.load(open(out))
    assert report["mode"] == "restart"
    assert report["counts_equal"] is True
    assert report["query_p50_ms"] >= 0 and report["query_p99_ms"] >= 0
