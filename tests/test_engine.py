"""PTMTEngine: every mode agrees, compiled plans are reused, and the
serving layer can share one engine across sessions."""

import warnings

import numpy as np
import pytest

from repro.core import (
    MiningConfig,
    PTMTEngine,
    StreamingMiner,
    ZoneOverflowError,
    oracle,
    tzp,
)
from repro.serving.motif import MotifSession

from conftest import random_graph

CFG = MiningConfig(delta=60, l_max=3, omega=4)


def _graph(seed=5, n=300):
    return random_graph(seed, n, 25, 3_000)


# -- mode agreement ---------------------------------------------------------

def test_discover_sequential_stream_agree_and_match_oracle():
    g = _graph()
    engine = PTMTEngine(CFG)
    res = engine.discover(g)
    seq = engine.sequential(g)
    assert res.counts == seq.counts
    assert seq.n_zones == 1

    miner = engine.stream()
    assert miner.executor is engine.executor     # shared warm backend
    for i in range(0, g.n_edges, 64):
        miner.ingest(g.u[i:i + 64], g.v[i:i + 64], g.t[i:i + 64])
    assert miner.snapshot(final=True).counts == res.counts

    expect = dict(oracle.count_codes(g.u, g.v, g.t, CFG.delta, CFG.l_max))
    assert res.counts == expect


def test_sequential_routes_through_zone_batch_padding():
    """The baseline's padding comes from build_zone_batch (pad_edges_to=8),
    not a hand-rolled zero block."""
    g = _graph(seed=2, n=29)
    plan = tzp.single_zone_plan(g, l_b=CFG.l_b)
    assert plan.n_zones == 1 and int(plan.count[0]) == 29
    batch = tzp.build_zone_batch(g, plan)
    assert batch.e_cap == 32 and batch.overflow == 0
    res = PTMTEngine(CFG).sequential(g)
    assert res.e_cap == 32


def test_engine_overrides_and_config_reuse():
    engine = PTMTEngine(CFG, backend="numpy")
    assert engine.config.backend == "numpy"
    assert engine.config.delta == CFG.delta
    assert engine.backend == "numpy"
    # stream(**overrides) derives a new config without touching the engine's
    miner = engine.stream(omega=6)
    assert miner.omega == 6 and engine.config.omega == 4
    assert miner.executor is not engine.executor


# -- compiled-plan reuse ----------------------------------------------------

def test_same_shape_discover_registers_compile_cache_hit():
    g = _graph()
    engine = PTMTEngine(CFG)
    res = engine.discover(g)
    misses = engine.stats.compile_cache_misses
    n_buckets = len(res.layout["buckets"])
    assert engine.stats.compile_cache_hits == 0
    assert misses == n_buckets       # one executable per bucket shape
    engine.discover(g)
    assert engine.stats.compile_cache_hits == n_buckets
    assert engine.stats.compile_cache_misses == misses
    assert engine.stats.discover_calls == 2


def test_different_shape_is_a_miss():
    engine = PTMTEngine(CFG)
    engine.discover(_graph(seed=1, n=300))
    engine.discover(_graph(seed=2, n=2_000))   # different zone geometry
    assert engine.stats.compile_cache_misses >= 2


def test_execution_key_mirrors_padding_and_agg_resolution():
    from repro.core.executor import MiningExecutor

    ex = MiningExecutor(delta=60, l_max=3, zone_chunk=4, agg="auto")
    key_pad = ex.execution_key(10, 64)     # pads 10 -> 12 zones
    assert key_pad == ex.execution_key(12, 64)
    assert key_pad[3] == 12 and key_pad[6] == "hierarchical"
    key_small = ex.execution_key(2, 64)    # zc >= z: unchunked, legacy
    assert key_small[6] == "legacy" and key_small[7] == 0


def test_allow_overflow_flows_from_config():
    g = _graph(seed=7, n=400)
    tight = CFG.with_updates(e_cap=8)
    engine = PTMTEngine(tight)
    with pytest.raises(ZoneOverflowError):
        engine.discover(g)
    # a failed run compiled nothing — it must not poison the reuse stats
    assert engine.stats.compile_cache_misses == 0
    assert engine.stats.zones_mined == 0
    with pytest.warns(RuntimeWarning, match="allow_overflow"):
        res = PTMTEngine(tight.with_updates(allow_overflow=True)).discover(g)
    assert res.overflow > 0


def test_capacity_plan_memoized_per_geometry():
    engine = PTMTEngine(CFG, memory_budget_mb=8.0)
    a = engine.capacity_plan(512, 128)
    assert a is engine.capacity_plan(512, 128)    # same object: memoized
    assert a is not engine.capacity_plan(1024, 128)
    assert PTMTEngine(CFG).capacity_plan(512, 128) is None  # no budget


# -- serving integration ----------------------------------------------------

def test_motif_session_shares_engine_executor():
    engine = PTMTEngine(CFG)
    sess = MotifSession("t0", engine=engine, ingest_batch=64)
    assert sess.miner.executor is engine.executor
    assert sess.config == CFG
    assert engine.stats.stream_sessions == 1

    g = _graph(seed=9, n=256)
    sess.ingest(g.u, g.v, g.t)
    sess.flush()
    total = sess.engine().total_processes()
    # closed-prefix consistency: served totals equal a snapshot's
    assert total == sess.miner.snapshot().total_processes()


def test_motif_session_engine_with_per_tenant_overrides():
    """SessionManager's deployment shape: engine= in session_defaults,
    per-tenant create(**params) overrides win (via engine.stream)."""
    engine = PTMTEngine(CFG)
    sess = MotifSession("t0", engine=engine, omega=6)
    assert sess.config.omega == 6 and engine.config.omega == 4
    assert sess.miner.executor is not engine.executor   # derived config
    with pytest.raises(ValueError, match="not both"):
        MotifSession("t0", engine=engine, config=CFG)


def test_streaming_miner_rejects_config_plus_params():
    with pytest.raises(ValueError, match="not both"):
        StreamingMiner(config=CFG, delta=60)


def test_streaming_miner_requires_delta_l_max_without_config():
    """No silent fallback to the MiningConfig defaults — a forgotten delta
    must fail loudly, not mine with delta=600."""
    with pytest.raises(ValueError, match="delta and l_max are required"):
        StreamingMiner(omega=8)
    with pytest.raises(ValueError, match="delta and l_max are required"):
        MotifSession("t0", l_max=3)


def test_streaming_miner_rejects_disagreeing_executor():
    from repro.core import MiningExecutor

    with pytest.raises(ValueError, match="disagrees with config"):
        StreamingMiner(config=CFG,
                       executor=MiningExecutor(delta=50, l_max=3))


def test_legacy_streaming_kwargs_still_build_a_config():
    miner = StreamingMiner(delta=60, l_max=3, omega=4, backend="ref")
    assert miner.config == CFG


# -- mesh path --------------------------------------------------------------

def test_sharded_caches_mesh_step_and_matches_single_device():
    import jax

    g = _graph(seed=11, n=256)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("z",))
    engine = PTMTEngine(CFG, zone_chunk=2)
    engine.discover(g)
    hits_before = engine.stats.compile_cache_hits
    a = engine.sharded(g, mesh, ("z",))
    # a first sharded call compiles its own SPMD step even after a
    # same-shaped local discover — it must NOT register as a cache hit
    assert engine.stats.compile_cache_hits == hits_before
    b = engine.sharded(g, mesh, ("z",))
    assert engine.stats.compile_cache_hits == \
        hits_before + len(a.layout["buckets"])
    assert a.counts == b.counts
    assert len(engine._mesh_steps) == 1      # step compiled once, reused
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        assert a.counts == PTMTEngine(CFG).discover(g).counts
