"""Roofline-term extraction from compiled dry-run artifacts.

Hardware model (TPU v5e): 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.

``cost_analysis()`` on an SPMD executable reports PER-PARTITION flops/bytes
(verified empirically), so the three terms are:

  compute_s    = flops_per_chip / PEAK_FLOPS
  memory_s     = bytes_per_chip / HBM_BW
  collective_s = collective_bytes_per_chip / ICI_BW

collective bytes are not in cost_analysis — we parse the post-SPMD HLO text
and sum per-op traffic with ring-algorithm weights (all-reduce counts 2x:
reduce-scatter + all-gather phases).
"""

from __future__ import annotations

import math
import re

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")

# traffic weight per collective kind (ring algorithms, large-n limit)
_COLL_WEIGHTS = {
    "all-reduce": 2.0,          # reduce-scatter + all-gather
    "all-gather": 1.0,
    "reduce-scatter": 1.0,      # counted on the (larger) input
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_COLL_RE = re.compile(
    r"=\s+(\S+)\s+(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\("
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum per-chip collective traffic by op kind from post-SPMD HLO."""
    out = {k: 0.0 for k in _COLL_WEIGHTS}
    counts = {k: 0 for k in _COLL_WEIGHTS}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        result_shape, kind = m.groups()
        if kind == "reduce-scatter":
            # input is output * group size; operands appear inside (...)
            args = line[m.end():]
            size = _shape_bytes(args.split("),")[0])
            if size == 0:
                size = _shape_bytes(result_shape)
        else:
            size = _shape_bytes(result_shape)
        out[kind] += size * _COLL_WEIGHTS[kind]
        counts[kind] += 1
    return {
        "per_kind_bytes": out,
        "per_kind_counts": counts,
        "total_bytes": sum(out.values()),
    }


VPU_PEAK = 3.85e12   # int/elementwise ops/s per chip (8x128 lanes, ~4 ALUs)


def roofline(record: dict) -> dict:
    """record: flops_per_chip, bytes_per_chip, collective_bytes_per_chip,
    n_chips, model_flops (global), optional peak_flops override (VPU
    workloads like the mining sweep use VPU_PEAK)."""
    peak = record.get("peak_flops", PEAK_FLOPS)
    compute_s = record["flops_per_chip"] / peak
    memory_s = record["bytes_per_chip"] / HBM_BW
    collective_s = record["collective_bytes_per_chip"] / ICI_BW
    dominant = max(
        ("compute", compute_s), ("memory", memory_s),
        ("collective", collective_s), key=lambda kv: kv[1],
    )[0]
    hlo_flops_global = record["flops_per_chip"] * record["n_chips"]
    useful = (
        record["model_flops"] / hlo_flops_global if hlo_flops_global else 0.0
    )
    bound_s = max(compute_s, memory_s, collective_s)
    # roofline fraction: useful model flops vs what the chips could do in
    # the bound time
    frac = (
        record["model_flops"]
        / (record["n_chips"] * peak * bound_s)
        if bound_s else 0.0
    )
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "useful_flops_ratio": useful,
        "roofline_fraction": frac,
    }
