import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell we ``jit(step).lower(*ShapeDtypeStructs).compile()`` on the
production mesh (single-pod 16x16 and multi-pod 2x16x16) and record:
  * memory_analysis()  — proves the cell fits per-device HBM;
  * cost_analysis()    — per-chip HLO flops / bytes for the roofline;
  * the collective schedule (parsed from post-SPMD HLO) — per-chip traffic.

Results are cached as one JSON per cell under --out; reruns skip finished
cells.  ``--orchestrate`` runs every remaining cell in a fresh subprocess
(compile state does not accumulate; one failing cell cannot kill the sweep).

Usage:
  python -m repro.launch.dryrun --arch granite-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --orchestrate          # full sweep
  python -m repro.launch.dryrun --report               # print the table
"""

import argparse          # noqa: E402
import json              # noqa: E402
import subprocess        # noqa: E402
import sys               # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402

from repro.configs import all_cells, get_arch        # noqa: E402
from repro.launch import analysis                    # noqa: E402
from repro.launch.mesh import make_production_mesh   # noqa: E402
from repro.obs.timing import Stopwatch               # noqa: E402

DEFAULT_OUT = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))),
    "benchmarks", "results", "dryrun",
)


def cell_path(out_dir, arch, shape, mesh_kind, tag=""):
    safe = lambda s: s.replace("/", "_")
    suffix = f"__{tag}" if tag else ""
    return os.path.join(
        out_dir, f"{safe(arch)}__{safe(shape)}__{mesh_kind}{suffix}.json"
    )


def _apply_overrides(arch, overrides: str):
    if not overrides:
        return arch
    import dataclasses as _dc

    kv = {}
    for part in overrides.split(","):
        key, val = part.split("=", 1)
        field_type = type(getattr(arch.config, key))
        kv[key] = field_type(val) if field_type is not bool else (
            val.lower() in ("1", "true", "yes"))
    return _dc.replace(arch, config=_dc.replace(arch.config, **kv))


def _compile_workload(wl):
    if wl.in_shardings is None:
        jitted = jax.jit(wl.fn)
    else:
        jitted = jax.jit(wl.fn, in_shardings=wl.in_shardings,
                         out_shardings=wl.out_shardings)
    return jitted.lower(*wl.in_sds).compile()


def _measure(compiled) -> dict:
    cost = compiled.cost_analysis()
    coll = analysis.collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": coll,
    }


def run_cell(arch_name: str, shape_name: str, mesh_kind: str,
             out_dir: str, overrides: str = "", tag: str = "") -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    arch = _apply_overrides(get_arch(arch_name), overrides)
    wl = arch.workload(shape_name, mesh)

    with Stopwatch() as sw:
        compiled = _compile_workload(wl)
    t_compile = sw.seconds

    mem = compiled.memory_analysis()
    full = _measure(compiled)

    # --- scan-depth calibration -------------------------------------------
    # XLA cost_analysis counts a while/scan body ONCE; layer-stacked models
    # would under-report flops by ~n_layers.  Lower depth-1 and depth-2
    # variants: body = f(2) - f(1); corrected = (f(1) - body) + L * body.
    calib = None
    n_layers = getattr(arch.config, "n_layers", 0)
    if n_layers > 2 and arch.family != "mining":
        wl1 = arch.workload_with_depth(shape_name, mesh, 1)
        wl2 = arch.workload_with_depth(shape_name, mesh, 2)
        m1 = _measure(_compile_workload(wl1))
        m2 = _measure(_compile_workload(wl2))

        def corrected(key):
            body = max(m2[key] - m1[key], 0.0)
            outside = max(m1[key] - body, 0.0)
            return outside + n_layers * body

        calib = {
            "flops": corrected("flops"),
            "bytes": corrected("bytes"),
            "coll_bytes": (
                max(m1["coll"]["total_bytes"]
                    - (m2["coll"]["total_bytes"] - m1["coll"]["total_bytes"]),
                    0.0)
                + n_layers * max(
                    m2["coll"]["total_bytes"] - m1["coll"]["total_bytes"],
                    0.0)
            ),
        }

    flops_per_chip = calib["flops"] if calib else full["flops"]
    hlo_bytes_per_chip = calib["bytes"] if calib else full["bytes"]
    coll_bytes_per_chip = (
        calib["coll_bytes"] if calib else full["coll"]["total_bytes"]
    )

    # roofline memory term: unique bytes touched (args + temps + outputs),
    # the TPU-fusion-realistic traffic floor.  The raw op-level HLO bytes
    # (every operand of every op) are kept as an upper bound.
    mem_traffic = (
        mem.argument_size_in_bytes + mem.output_size_in_bytes
        + mem.temp_size_in_bytes
    )

    peak_flops = analysis.PEAK_FLOPS
    if arch.family == "mining":
        # integer VPU workload: HLO float-flops are meaningless; use the
        # analytic op count (see configs/ptmt.py) against the VPU peak.
        from repro.configs.ptmt import analytic_mining_terms

        shape_obj = arch._shape(shape_name)
        terms = analytic_mining_terms(arch.config, shape_obj, int(n_chips))
        flops_per_chip = terms["ops_per_chip"]
        mem_traffic = max(mem_traffic, terms["hbm_bytes_per_chip"])
        peak_flops = analysis.VPU_PEAK

    record = {
        "arch": arch_name,
        "shape": shape_name,
        "mesh": mesh_kind,
        "n_chips": int(n_chips),
        "kind": wl.kind,
        "model_flops": wl.model_flops,
        "peak_flops": peak_flops,
        "flops_per_chip": flops_per_chip,
        "bytes_per_chip": mem_traffic,
        "hlo_bytes_per_chip_upper": hlo_bytes_per_chip,
        "flops_per_chip_raw": full["flops"],
        "collective_bytes_per_chip": coll_bytes_per_chip,
        "collectives": full["coll"]["per_kind_counts"],
        "collective_bytes_by_kind": full["coll"]["per_kind_bytes"],
        "scan_calibrated": calib is not None,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "compile_s": t_compile,
        "overrides": overrides,
        "tag": tag,
        "status": "ok",
    }
    record.update(analysis.roofline(record))
    os.makedirs(out_dir, exist_ok=True)
    with open(cell_path(out_dir, arch_name, shape_name, mesh_kind, tag),
              "w") as f:
        json.dump(record, f, indent=1)
    return record


def orchestrate(out_dir: str, meshes=("single", "multi"), force=False,
                only_arch=None, timeout=3600):
    cells = [
        (a, s, m) for (a, s) in all_cells() for m in meshes
        if only_arch is None or a == only_arch
    ]
    todo = []
    for a, s, m in cells:
        path = cell_path(out_dir, a, s, m)
        if not force and os.path.exists(path):
            with open(path) as f:
                if json.load(f).get("status") == "ok":
                    continue
        todo.append((a, s, m))
    print(f"dry-run sweep: {len(todo)} cells to run "
          f"({len(cells) - len(todo)} cached)")
    failures = []
    for i, (a, s, m) in enumerate(todo):
        with Stopwatch() as sw:
            proc = subprocess.run(
                [sys.executable, "-m", "repro.launch.dryrun",
                 "--arch", a, "--shape", s, "--mesh", m, "--out", out_dir],
                capture_output=True, text=True, timeout=timeout,
                env=dict(os.environ),
            )
        dt = sw.seconds
        if proc.returncode != 0:
            failures.append((a, s, m))
            err = (proc.stderr or "")[-1500:]
            os.makedirs(out_dir, exist_ok=True)
            with open(cell_path(out_dir, a, s, m), "w") as f:
                json.dump({"arch": a, "shape": s, "mesh": m,
                           "status": "error", "stderr": err}, f, indent=1)
            print(f"[{i+1}/{len(todo)}] FAIL {a}/{s}/{m} ({dt:.0f}s)")
            print(err.splitlines()[-3:] if err else "")
        else:
            print(f"[{i+1}/{len(todo)}] ok   {a}/{s}/{m} ({dt:.0f}s)")
    print(f"done; {len(failures)} failures: {failures}")
    return failures


def report(out_dir: str):
    rows = []
    for fn in sorted(os.listdir(out_dir)):
        if not fn.endswith(".json"):
            continue
        with open(os.path.join(out_dir, fn)) as f:
            rows.append(json.load(f))
    hdr = (f"{'arch':22s} {'shape':15s} {'mesh':6s} {'status':6s} "
           f"{'comp_ms':>8s} {'mem_ms':>8s} {'coll_ms':>8s} {'dom':>9s} "
           f"{'useful':>7s} {'roofline':>8s} {'temp_GB':>8s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        if r.get("status") != "ok":
            print(f"{r['arch']:22s} {r['shape']:15s} {r['mesh']:6s} ERROR")
            continue
        print(
            f"{r['arch']:22s} {r['shape']:15s} {r['mesh']:6s} "
            f"{r['status']:6s} "
            f"{r['compute_s']*1e3:8.2f} {r['memory_s']*1e3:8.2f} "
            f"{r['collective_s']*1e3:8.2f} {r['dominant']:>9s} "
            f"{r['useful_flops_ratio']:7.3f} {r['roofline_fraction']:8.3f} "
            f"{r['memory']['temp_bytes']/1e9:8.2f}"
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--orchestrate", action="store_true")
    ap.add_argument("--report", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--only-arch")
    ap.add_argument("--override", default="",
                    help="config overrides, e.g. gather_dtype=bf16")
    ap.add_argument("--tag", default="",
                    help="result-file suffix for optimized variants")
    args = ap.parse_args()

    if args.report:
        report(args.out)
        return
    if args.orchestrate:
        failures = orchestrate(args.out, force=args.force,
                               only_arch=args.only_arch)
        sys.exit(1 if failures else 0)
    if not (args.arch and args.shape):
        ap.error("--arch and --shape required (or --orchestrate/--report)")
    try:
        rec = run_cell(args.arch, args.shape, args.mesh, args.out,
                       overrides=args.override, tag=args.tag)
    except Exception:
        traceback.print_exc()
        sys.exit(1)
    print(json.dumps(
        {k: rec[k] for k in
         ("arch", "shape", "mesh", "compute_s", "memory_s", "collective_s",
          "dominant", "useful_flops_ratio", "roofline_fraction",
          "compile_s")},
        indent=1,
    ))
    print("memory:", rec["memory"])
    print("collectives:", rec["collectives"])


if __name__ == "__main__":
    main()
