"""Training CLI: ``python -m repro.launch.train --arch granite-8b [...]``.

Runs the fault-tolerant training loop on the current device set (smoke
configs on CPU; the same step function lowers onto the production meshes —
see dryrun.py).  Resumes automatically from the newest checkpoint.
"""

from __future__ import annotations

import argparse

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import arch_names, get_arch
from repro.data import lm_pipeline
from repro.models import transformer
from repro.training import optimizer, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=arch_names())
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--metrics", default=None)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    if arch.family != "lm":
        raise SystemExit(
            f"{args.arch} is a {arch.family} arch — use examples/ drivers "
            "for GNN/recsys/mining training"
        )
    cfg = arch.smoke_config if args.smoke else arch.config
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = optimizer.init_state(params)
    opt_cfg = optimizer.AdamWConfig(
        lr=args.lr, warmup_steps=max(args.steps // 20, 1),
        total_steps=args.steps,
    )

    @jax.jit
    def step_fn(p, o, batch):
        loss, grads = jax.value_and_grad(transformer.loss_fn)(
            p, batch, cfg, None
        )
        p2, o2, m = optimizer.apply_updates(opt_cfg, p, grads, o)
        m["loss"] = loss
        return p2, o2, m

    def batches():
        gen = lm_pipeline.batches(
            0, batch=args.batch, seq_len=args.seq_len, vocab=cfg.vocab)
        for tokens, targets in gen:
            yield {"tokens": jnp.asarray(tokens),
                   "targets": jnp.asarray(targets)}

    loop_cfg = train_loop.TrainLoopConfig(
        total_steps=args.steps, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, metrics_path=args.metrics,
    )
    params, opt_state, history = train_loop.run(
        step_fn=step_fn, params=params, opt_state=opt_state,
        batches=batches(), loop_cfg=loop_cfg,
    )
    losses = [h["loss"] for h in history]
    if losses:
        print(f"trained {len(losses)} steps: loss {losses[0]:.4f} -> "
              f"{losses[-1]:.4f}")
    print(f"checkpoints under {args.ckpt_dir}")


if __name__ == "__main__":
    main()
