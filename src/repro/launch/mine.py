"""Mining CLI: PTMT motif-transition discovery end to end.

``python -m repro.launch.mine --dataset wikitalk-like --delta 600 --l-max 6``

Runs TZP partitioning + parallel expansion + signed aggregation through one
:class:`repro.core.engine.PTMTEngine`, prints the transition tree, and can
cross-check against the sequential TMC-analog baseline.

The mining parameter surface (``--delta/--l-max/--omega/--e-cap/--backend/
--zone-chunk/--agg/--merge-cap/--memory-budget-mb/--allow-overflow``) is
declared by :meth:`repro.core.config.MiningConfig.add_cli_args` — shared
verbatim with ``launch/serve_motifs.py`` — and parsed back into the
validated config the engine is built from.

``--stream --chunk-edges N`` replays the dataset as an incremental stream
through ``engine.stream()`` (per-chunk latency + sustained edges/sec);
combine with ``--check-sequential`` to verify the final snapshot against
the sequential baseline.

Batch and stream runs emit the **same** end-of-run summary, and
``--out-json FILE`` writes it with one schema for both modes (stream-only
frontier stats live under a ``stream`` key that is ``null`` for batch
runs) — downstream tooling never special-cases stream output.  The legacy
``--json-out`` counts-only dump was removed; read ``counts`` out of the
``--out-json`` summary instead.
"""

from __future__ import annotations

import argparse
import json
import time

import repro.obs as obs_mod
from repro.core import MiningConfig, PTMTEngine
from repro.core.streaming import replay_stream
from repro.data import synthetic_graphs
from repro.obs.timing import latency_summary


def _print_result(res, dt: float, label: str) -> None:
    print(f"{label}: {res.n_zones} zones (cap {res.e_cap}), "
          f"{len(res.counts)} motif types, "
          f"{res.total_processes()} processes in {dt:.2f}s")
    if res.layout:
        buckets = ", ".join(f"{b['label']}×{b['real_zones']}"
                            for b in res.layout["buckets"])
        print(f"zone layout: {res.layout['kind']} [{buckets}], "
              f"padding_ratio={res.layout['padding_ratio']:.1%}")
    print("level histogram:", dict(sorted(res.level_histogram().items())))
    print("\ntransition tree (top levels):")
    tree = res.tree()
    rows = tree.root.transition_rows()
    for code, count, share in sorted(rows, key=lambda r: -r[1])[:6]:
        print(f"  {code}: {count} ({share:.1%})")
        node = tree.node(code)
        for ccode, ccount, cshare in sorted(
                node.transition_rows(), key=lambda r: -r[1])[:4]:
            print(f"    -> {ccode}: {ccount} ({cshare:.1%})")


def _summary(args, config: MiningConfig, graph, res, dt: float, mode: str,
             stream_stats: dict | None) -> dict:
    """One schema for batch and stream runs (``stream`` is null for batch)."""
    return {
        "mode": mode,
        "dataset": args.dataset,
        "seed": args.seed,
        **config.to_dict(),
        "n_edges": graph.n_edges,
        "n_nodes": graph.n_nodes,
        "seconds": dt,
        "edges_per_s": graph.n_edges / dt if dt else 0.0,
        "n_zones": res.n_zones,
        "zone_e_cap": res.e_cap,
        # resolved device layout (the config's ``zone_layout`` above is the
        # *requested* kind; this is what the run actually built)
        "layout": res.layout,
        "overflow": res.overflow,
        "motif_types": len(res.counts),
        "total_processes": res.total_processes(),
        "level_histogram": {
            str(k): v for k, v in sorted(res.level_histogram().items())
        },
        "counts": res.counts,
        "stream": stream_stats,
    }


def _run_stream(args, engine: PTMTEngine, graph):
    if args.chunk_edges < 1:
        raise SystemExit("--chunk-edges must be >= 1")
    miner = engine.stream()
    chunk = args.chunk_edges
    latencies, dt = replay_stream(miner, graph, chunk)
    res = miner.snapshot(final=True)
    digest = latency_summary(latencies)
    stream_stats = {
        "chunk_edges": chunk,
        "chunks": digest["count"],
        "mean_chunk_ms": digest["mean_ms"],
        "max_chunk_ms": digest["max_ms"],
        "p50_chunk_ms": digest["p50_ms"],
        "p99_chunk_ms": digest["p99_ms"],
        "zones_finalized": miner.n_zones_finalized,
        "edges_retired": miner.n_edges_retired,
        "buffered_edges": miner.buffered_edges,
        "epoch": miner.epoch,
    }
    if latencies:
        print(f"stream: {len(latencies)} chunks of {chunk} edges, "
              f"{graph.n_edges / dt:.0f} edges/s sustained, "
              f"per-chunk latency "
              f"mean {stream_stats['mean_chunk_ms']:.1f}ms "
              f"max {stream_stats['max_chunk_ms']:.1f}ms")
    print(f"frontier: {miner.n_zones_finalized} zones finalized, "
          f"{miner.n_edges_retired} edges retired, "
          f"{miner.buffered_edges} still buffered")
    _print_result(res, dt, "PTMT-stream")
    return res, dt, stream_stats


def main():
    ap = argparse.ArgumentParser()
    MiningConfig.add_cli_args(ap)
    ap.add_argument("--dataset", default="wikitalk-like",
                    choices=sorted(synthetic_graphs.DATASET_ANALOGS))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--stream", action="store_true",
                    help="replay the dataset incrementally through "
                         "engine.stream()")
    ap.add_argument("--chunk-edges", type=int, default=4096,
                    help="edges per ingested chunk in --stream mode")
    ap.add_argument("--check-sequential", action="store_true")
    ap.add_argument("--tree-depth", type=int, default=2)
    ap.add_argument("--out-json", default=None,
                    help="write the full run summary (same schema for "
                         "batch and stream modes)")
    obs_mod.add_cli_args(ap)
    args = ap.parse_args()

    config = MiningConfig.from_cli_args(args)
    obs = obs_mod.from_cli_args(args)
    engine = PTMTEngine(config, obs=obs)
    graph = synthetic_graphs.make(args.dataset, seed=args.seed)
    print(f"{args.dataset}: {graph.n_edges} edges, {graph.n_nodes} nodes, "
          f"span {graph.time_span}s")

    if args.stream:
        res, dt, stream_stats = _run_stream(args, engine, graph)
        mode = "stream"
    else:
        t0 = time.perf_counter()
        res = engine.discover(graph)
        dt = time.perf_counter() - t0
        stream_stats = None
        mode = "batch"
        _print_result(res, dt, "PTMT")

    if args.check_sequential:
        t0 = time.perf_counter()
        seq = engine.sequential(graph)
        dt_seq = time.perf_counter() - t0
        match = seq.counts == res.counts
        print(f"\nsequential TMC-analog: {dt_seq:.2f}s, "
              f"exact match: {match}")
        if not match:
            raise SystemExit("MISMATCH between PTMT and sequential baseline")

    if args.out_json:
        with open(args.out_json, "w") as f:
            json.dump(_summary(args, config, graph, res, dt, mode,
                               stream_stats),
                      f, indent=1, sort_keys=True)
        print(f"summary written to {args.out_json}")

    obs_mod.write_cli_outputs(obs, args)


if __name__ == "__main__":
    main()
