"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state.  The single-pod mesh
is 16x16 = 256 chips (v5e pod); multi-pod adds a leading ``pod`` axis for
2 pods = 512 chips.  The ``pod`` axis is pure data parallelism (its
collectives cross DCN); ``data`` carries FSDP + batch; ``model`` carries
TP/EP/sequence shards over ICI.
"""

from __future__ import annotations

import math

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512"
        )
    devs = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(devs, axes)


def make_test_mesh(shape=(2, 2, 2), axes=("pod", "data", "model")):
    """Small mesh for CPU subprocess tests (8 virtual devices)."""
    n = math.prod(shape)
    devs = np.asarray(jax.devices()[:n]).reshape(shape)
    return jax.sharding.Mesh(devs, axes)
