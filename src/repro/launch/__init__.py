# Launch layer: production mesh, dry-run driver, training/mining CLIs.
# NB: dryrun.py must be executed as a script/module so its XLA_FLAGS lines
# run before jax initializes devices — do not import it from here.
