"""Serving driver: replay a temporal graph into N tenant sessions under a
mixed query workload — single-service or multi-worker cluster mode.

``python -m repro.launch.serve_motifs --tenants 4 --dataset sms-a-like``

The dataset's edge stream is strided into ``--tenants`` time-ordered tenant
streams, replayed round-robin in ``--chunk-edges`` arrival chunks, and
after every chunk each tenant receives ``--queries-per-chunk`` queries
drawn from a fixed mix (top-k, transition probabilities, prefix counts,
level histogram).  Without ``--workers`` all tenants are served by one
:class:`repro.serving.motif.MotifService` over ONE shared
:class:`repro.core.engine.PTMTEngine` (one resolved backend, one warm
compile cache — the deployment shape).  The report is the serving SLO
view: sustained ingest edges/sec, query p50/p99 latency per op, and
snapshot-cache effectiveness.  ``--verify`` cross-checks every tenant's
final engine against batch discovery on its closed prefix (exact by
Lemma 4.2); ``--out-json`` writes the full report for tooling.

Cluster mode (``--workers N``) routes the same replay through a
:class:`repro.serving.cluster.ClusterCoordinator` — tenants sharded over N
workers by rendezvous hashing, per-tenant/global admission budgets whose
throttle signal the replay honors (drain, then retry the chunk), and
periodic per-tenant checkpoints carrying the stream offset in their
``meta``.  Fault injection::

    # healthy baseline (records suites.serving_harness.runs.healthy)
    ... --workers 2 --checkpoint-dir ck --bench-json BENCH_serving.json
    # die abruptly mid-ingest after ~50k edges (exit code 73, no cleanup
    # — everything since the last periodic checkpoint is lost, exactly
    # like kill -9)
    ... --workers 2 --checkpoint-dir ck --kill-after 50000
    # restart: restore every tenant from its checkpoint, rewind each feed
    # to the checkpointed offset, finish the stream, and assert final
    # counts are byte-identical to an uninterrupted run
    ... --workers 2 --checkpoint-dir ck --restart --bench-json BENCH_serving.json

``--bench-json`` merges the run's SLO report into ``BENCH_serving.json``
under ``suites.serving_harness.runs.<mode>`` so healthy and
failure/restart numbers live side by side (the CI kill/restart smoke
asserts ``counts_equal`` and the p50/p99 fields there).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

import repro.obs as obs_mod
from repro.core import MiningConfig, PTMTEngine
from repro.core.temporal_graph import TemporalGraph
from repro.data import synthetic_graphs
from repro.obs.timing import percentile_ms
from repro.serving.motif import MotifService, QueryRequest

#: Exit code of a ``--kill-after`` abrupt death (distinguishes the
#: injected kill from a real crash in the CI smoke).
KILL_EXIT_CODE = 73

#: (op, kwargs-builder) workload mix — weights sum to 1.
QUERY_MIX = (
    (0.40, "top_k"),
    (0.25, "transition_probs"),
    (0.20, "prefix_count"),
    (0.15, "level_histogram"),
)


def tenant_streams(graph: TemporalGraph, tenants: int) -> list[TemporalGraph]:
    """Stride the stream into per-tenant streams (each stays time-ordered)."""
    return [
        TemporalGraph(u=graph.u[i::tenants], v=graph.v[i::tenants],
                      t=graph.t[i::tenants], n_nodes=graph.n_nodes)
        for i in range(tenants)
    ]


def sample_request(rng: np.random.Generator, session: str,
                   known_codes: list[str]) -> QueryRequest:
    r = float(rng.random())
    acc = 0.0
    op = QUERY_MIX[-1][1]
    for weight, name in QUERY_MIX:
        acc += weight
        if r < acc:
            op = name
            break
    code = ""
    if op in ("transition_probs", "prefix_count") and known_codes:
        code = known_codes[int(rng.integers(len(known_codes)))]
    level = int(rng.integers(1, 4)) if op == "top_k" else None
    return QueryRequest(session=session, op=op, code=code, level=level, k=8)


def run_workload(
    service: MotifService,
    streams: list[TemporalGraph],
    names: list[str],
    *,
    chunk_edges: int,
    queries_per_chunk: int,
    seed: int = 0,
):
    """Round-robin replay + query mix.

    Returns ``(ingest_lat, query_lat_by_op, first_call_lat_by_op)`` —
    first calls of a (tenant, op) pair pay one-time JAX trace/compile and
    index-build cost (``QueryResponse.first_call``), so they are kept out
    of the steady-state ``query_lat`` series and reported separately.
    """
    rng = np.random.default_rng(seed)
    ingest_lat: list[float] = []
    query_lat: dict[str, list[float]] = {name: [] for _, name in QUERY_MIX}
    first_call_lat: dict[str, list[float]] = {
        name: [] for _, name in QUERY_MIX}
    known: dict[str, list[str]] = {n: [] for n in names}
    offsets = [0] * len(streams)
    live = True
    while live:
        live = False
        for name, g, idx in zip(names, streams, range(len(streams))):
            i = offsets[idx]
            if i >= g.n_edges:
                continue
            live = True
            offsets[idx] = i + chunk_edges
            t0 = time.perf_counter()
            service.ingest(name, g.u[i:i + chunk_edges],
                           g.v[i:i + chunk_edges], g.t[i:i + chunk_edges])
            ingest_lat.append(time.perf_counter() - t0)
            for _ in range(queries_per_chunk):
                req = sample_request(rng, name, known[name])
                resp = service.query(req)
                if resp.first_call:
                    first_call_lat[req.op].append(resp.latency_s)
                else:
                    query_lat[req.op].append(resp.latency_s)
                if req.op == "top_k" and resp.payload:
                    known[name] = [c for c, _ in resp.payload][:8]
    return ingest_lat, query_lat, first_call_lat


def build_report(service, names, n_edges, wall, ingest_lat, query_lat,
                 first_call_lat=None):
    all_q = [x for lats in query_lat.values() for x in lats]
    all_first = [x for lats in (first_call_lat or {}).values() for x in lats]
    stats = service.stats()
    lookups = stats["cache_hits"] + stats["cache_misses"]
    return {
        "tenants": len(names),
        "edges": n_edges,
        "seconds": wall,
        "ingest_edges_per_s": n_edges / wall if wall else 0.0,
        "ingest_chunks": len(ingest_lat),
        "ingest_p50_ms": percentile_ms(ingest_lat, 50),
        "ingest_p99_ms": percentile_ms(ingest_lat, 99),
        # steady-state only: first calls (compile + index build) are
        # reported under first_call_* so p50/p99 describe the warm service
        "queries": len(all_q),
        "query_p50_ms": percentile_ms(all_q, 50),
        "query_p99_ms": percentile_ms(all_q, 99),
        "first_calls": len(all_first),
        "first_call_max_ms": (1e3 * max(all_first)) if all_first else 0.0,
        "per_op": {
            op: {
                "count": len(lats),
                "p50_ms": percentile_ms(lats, 50),
                "p99_ms": percentile_ms(lats, 99),
            }
            for op, lats in sorted(query_lat.items())
        },
        "snapshots_mined": stats["snapshots_mined"],
        "cache_hit_rate": stats["cache_hits"] / lookups if lookups else 0.0,
        "sessions": stats["sessions"],
    }


def verify_against_batch(service, names, streams, *, delta, l_max, omega,
                         e_cap=None, backend="ref") -> list[dict]:
    """Per-tenant cross-check of served counts against batch discovery on
    the closed prefix — the serving-layer restatement of the Lemma 4.2 test.

    Returns one row per tenant.  A row with ``batch_overflow > 0`` means the
    batch *reference* overflowed zone capacity and undercounts (the stream
    side is the exact one — see ``core/streaming.py``); strict equality is
    only meaningful when ``batch_overflow == 0``, so ``match`` is ``None``
    for those rows and callers must not fail on them.
    """
    ref_engine = PTMTEngine(MiningConfig(
        delta=delta, l_max=l_max, omega=omega, e_cap=e_cap,
        backend=backend, allow_overflow=True,
    ))
    rows = []
    for name, g in zip(names, streams):
        service.flush(name)
        sess = service.manager.get(name)
        engine = sess.engine()
        closed = sess.closed_time
        cut = 0 if closed is None else int(
            np.searchsorted(g.t, closed, side="left"))
        if cut == 0:
            rows.append({"tenant": name, "prefix_edges": 0,
                         "motif_types": 0, "batch_overflow": 0,
                         "match": engine.result.counts == {}})
            continue
        prefix = TemporalGraph(u=g.u[:cut], v=g.v[:cut], t=g.t[:cut],
                               n_nodes=g.n_nodes)
        expect = ref_engine.discover(prefix)
        rows.append({
            "tenant": name,
            "prefix_edges": prefix.n_edges,
            "motif_types": len(expect.counts),
            "batch_overflow": expect.overflow,
            "match": (engine.result.counts == expect.counts
                      if expect.overflow == 0 else None),
        })
    return rows


# -- cluster mode ------------------------------------------------------------


def run_cluster_workload(
    coordinator,
    streams: list[TemporalGraph],
    names: list[str],
    *,
    chunk_edges: int,
    queries_per_chunk: int,
    seed: int = 0,
    offsets: dict[str, int] | None = None,
    checkpoint_every: int = 0,
    kill_after: int | None = None,
):
    """Round-robin cluster replay honoring backpressure + fault injection.

    Per tenant the feed starts at ``offsets[name]`` (a restart resumes
    from the checkpointed offset).  A throttled ingest is **deferred, not
    dropped**: the chunk is retried after draining the tenant's admission
    window, so backpressure costs latency, never edges.  Every
    ``checkpoint_every`` fed edges a tenant is checkpointed with its
    post-chunk offset in the ``meta`` — the durable point a restart
    rewinds to.  ``kill_after`` N fed edges the process dies abruptly
    (``os._exit``, no flush, no final checkpoint, exit
    :data:`KILL_EXIT_CODE`) — the closest in-process stand-in for
    ``kill -9`` mid-ingest.
    """
    rng = np.random.default_rng(seed)
    ingest_lat: list[float] = []
    query_lat: dict[str, list[float]] = {name: [] for _, name in QUERY_MIX}
    first_call_lat: dict[str, list[float]] = {
        name: [] for _, name in QUERY_MIX}
    known: dict[str, list[str]] = {n: [] for n in names}
    pos = {n: int((offsets or {}).get(n, 0)) for n in names}
    since_ckpt = {n: 0 for n in names}
    throttle_events = 0
    checkpoints_written = 0
    total_fed = 0
    live = True
    while live:
        live = False
        for name, g in zip(names, streams):
            i = pos[name]
            if i >= g.n_edges:
                continue
            live = True
            u = g.u[i:i + chunk_edges]
            v = g.v[i:i + chunk_edges]
            t = g.t[i:i + chunk_edges]
            t0 = time.perf_counter()
            while True:
                ack = coordinator.ingest(name, u, v, t)
                if not ack.throttled:
                    break
                # budget bound: drain this tenant's window, then retry —
                # the replay honors the throttle instead of buffering past
                # the budget (deferred, never dropped)
                throttle_events += 1
                coordinator.flush(name)
            ingest_lat.append(time.perf_counter() - t0)
            pos[name] = i + int(np.asarray(t).size)
            total_fed += int(np.asarray(t).size)
            since_ckpt[name] += int(np.asarray(t).size)
            if kill_after is not None and total_fed >= kill_after:
                # abrupt death mid-ingest: skip flushes, skip the final
                # checkpoint — state since the last periodic checkpoint
                # is lost, exactly the kill -9 contract
                os._exit(KILL_EXIT_CODE)
            if checkpoint_every and since_ckpt[name] >= checkpoint_every:
                coordinator.checkpoint(name, {"offset": pos[name]})
                checkpoints_written += 1
                since_ckpt[name] = 0
            for _ in range(queries_per_chunk):
                req = sample_request(rng, name, known[name])
                resp = coordinator.query(req)
                if resp.first_call:
                    first_call_lat[req.op].append(resp.latency_s)
                else:
                    query_lat[req.op].append(resp.latency_s)
                if req.op == "top_k" and resp.payload:
                    known[name] = [c for c, _ in resp.payload][:8]
    return {
        "ingest_lat": ingest_lat,
        "query_lat": query_lat,
        "first_call_lat": first_call_lat,
        "offsets": pos,
        "throttle_events": throttle_events,
        "checkpoints_written": checkpoints_written,
        "edges_fed": total_fed,
    }


def tenant_counts(coordinator, name: str) -> dict:
    """A tenant's full served count table (closed prefix + open tail)."""
    worker = coordinator.workers[coordinator.owner_of(name)]
    return worker.service.manager.get(name).engine().result.counts


def reference_counts(config, streams, names, *, ingest_batch) -> dict:
    """Uninterrupted single-process replay — the byte-identity baseline."""
    service = MotifService(engine=PTMTEngine(config),
                           ingest_batch=ingest_batch)
    out = {}
    for name, g in zip(names, streams):
        service.create_session(name)
        service.ingest(name, g.u, g.v, g.t)
        service.flush(name)
        out[name] = service.manager.get(name).engine().result.counts
    return out


def build_cluster_report(coordinator, names, run, n_edges, wall, *,
                         mode: str) -> dict:
    all_q = [x for lats in run["query_lat"].values() for x in lats]
    all_first = [x for lats in run["first_call_lat"].values() for x in lats]
    stats = coordinator.stats()
    services = [w["service"] for w in stats["workers"].values()
                if w["service"] is not None]
    hits = sum(s["cache_hits"] for s in services)
    lookups = hits + sum(s["cache_misses"] for s in services)
    deferred = sum(w["admission"]["deferred_edges"]
                   for w in stats["workers"].values())
    shed = sum(w["admission"]["shed_edges"]
               for w in stats["workers"].values())
    return {
        "mode": mode,
        "workers": stats["n_workers"],
        "live_workers": stats["live_workers"],
        "placement": stats["placement"],
        "tenants": len(names),
        "edges_fed": run["edges_fed"],
        "edges_total": n_edges,
        "seconds": wall,
        "ingest_edges_per_s": run["edges_fed"] / wall if wall else 0.0,
        "ingest_p50_ms": percentile_ms(run["ingest_lat"], 50),
        "ingest_p99_ms": percentile_ms(run["ingest_lat"], 99),
        "queries": len(all_q),
        "query_p50_ms": percentile_ms(all_q, 50),
        "query_p99_ms": percentile_ms(all_q, 99),
        "first_calls": len(all_first),
        "throttle_events": run["throttle_events"],
        "deferred_edges": deferred,
        "shed_edges": shed,
        "checkpoints_written": run["checkpoints_written"],
        "failovers": stats["failovers"],
        "snapshots_mined": sum(s["snapshots_mined"] for s in services),
        "cache_hit_rate": hits / lookups if lookups else 0.0,
    }


def merge_bench_json(path: str, mode: str, report: dict) -> None:
    """Land ``report`` under ``suites.serving_harness.runs[mode]``.

    Same document shape as ``benchmarks/run.py --out-json`` (top-level
    ``suites`` keyed by suite name), so the harness and the benchmark
    driver can share one ``BENCH_serving.json``.
    """
    doc = {"suites": {}}
    if os.path.exists(path):
        with open(path) as f:
            doc = json.load(f)
        doc.setdefault("suites", {})
    suite = doc["suites"].setdefault(
        "serving_harness", {"suite": "serving_harness", "runs": {}})
    suite.setdefault("runs", {})[mode] = report
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    os.replace(tmp, path)


def run_cluster_mode(args, config, obs, graph, streams, names) -> dict:
    from repro.serving.cluster import ClusterCoordinator

    if not args.checkpoint_dir and (args.restart or args.kill_after):
        raise SystemExit("--kill-after/--restart require --checkpoint-dir")
    coordinator = ClusterCoordinator(
        args.workers, config=config, checkpoint_dir=args.checkpoint_dir,
        tenant_budget=args.tenant_budget, global_budget=args.global_budget,
        ingest_batch=args.ingest_batch, obs=obs)
    mode = "restart" if args.restart else (
        "killed" if args.kill_after else "healthy")
    offsets: dict[str, int] = {}
    if args.restart:
        recovered = coordinator.restore_all()
        missing = sorted(set(names) - set(recovered))
        if missing:
            raise SystemExit(
                f"--restart found no checkpoint for tenants {missing} "
                f"under {args.checkpoint_dir}")
        offsets = {n: int(m.get("offset", 0)) for n, m in recovered.items()}
        print(f"restored {len(recovered)} tenants from "
              f"{args.checkpoint_dir}; resuming at offsets "
              f"{[offsets[n] for n in names]}")
    else:
        for name in names:
            coordinator.create_tenant(name)
            if args.checkpoint_dir:
                # durable from birth: a kill before the first periodic
                # checkpoint restarts the tenant from offset 0, never
                # loses the tenant itself
                coordinator.checkpoint(name, {"offset": 0})
    print(f"cluster: {args.workers} workers, placement "
          f"{coordinator.placement()}")

    t0 = time.perf_counter()
    run = run_cluster_workload(
        coordinator, streams, names, chunk_edges=args.chunk_edges,
        queries_per_chunk=args.queries_per_chunk, seed=args.seed,
        offsets=offsets,
        checkpoint_every=(args.checkpoint_every if args.checkpoint_dir
                          else 0),
        kill_after=args.kill_after,
    )
    coordinator.flush_all()
    wall = time.perf_counter() - t0
    if args.checkpoint_dir:
        coordinator.checkpoint_all(
            {n: {"offset": run["offsets"][n]} for n in names})
    report = build_cluster_report(coordinator, names, run, graph.n_edges,
                                  wall, mode=mode)

    print(f"ingest: {report['ingest_edges_per_s']:.0f} edges/s sustained, "
          f"chunk p50 {report['ingest_p50_ms']:.1f}ms "
          f"p99 {report['ingest_p99_ms']:.1f}ms, "
          f"{report['throttle_events']} throttle events "
          f"({report['deferred_edges']} edges deferred)")
    print(f"query: {report['queries']} served steady-state, "
          f"p50 {report['query_p50_ms']:.2f}ms "
          f"p99 {report['query_p99_ms']:.2f}ms, "
          f"cache hit rate {report['cache_hit_rate']:.1%}; "
          f"{report['checkpoints_written']} checkpoints written")

    if args.restart or args.verify:
        ref = reference_counts(config, streams, names,
                               ingest_batch=args.ingest_batch)
        equal = all(tenant_counts(coordinator, n) == ref[n] for n in names)
        report["counts_equal"] = equal
        print(f"counts_equal={'true' if equal else 'FALSE'} vs "
              f"uninterrupted replay"
              + (" after restart-from-checkpoint" if args.restart else ""))
        if not equal:
            raise SystemExit(
                "restored counts diverged from uninterrupted run")
    return report


def main():
    ap = argparse.ArgumentParser()
    MiningConfig.add_cli_args(ap)
    ap.add_argument("--dataset", default="sms-a-like",
                    choices=sorted(synthetic_graphs.DATASET_ANALOGS))
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--chunk-edges", type=int, default=2048,
                    help="edges per tenant arrival chunk")
    ap.add_argument("--ingest-batch", type=int, default=8192,
                    help="admission buffer flush threshold per session")
    ap.add_argument("--queries-per-chunk", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verify", action="store_true",
                    help="cross-check every tenant against batch discover")
    ap.add_argument("--out-json", default=None)
    ap.add_argument("--workers", type=int, default=0,
                    help="cluster mode: shard tenants over N workers "
                         "(0 = single shared service)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="cluster mode: per-tenant checkpoint directory")
    ap.add_argument("--checkpoint-every", type=int, default=4096,
                    help="edges fed per tenant between periodic checkpoints")
    ap.add_argument("--kill-after", type=int, default=None, metavar="EDGES",
                    help=f"die abruptly (os._exit {KILL_EXIT_CODE}, no "
                         f"cleanup) after feeding EDGES edges — kill -9 "
                         f"fault injection")
    ap.add_argument("--restart", action="store_true",
                    help="restore tenants from --checkpoint-dir, rewind "
                         "feeds to checkpointed offsets, finish the "
                         "stream, and verify counts byte-identical to an "
                         "uninterrupted run")
    ap.add_argument("--tenant-budget", type=int, default=65536,
                    help="cluster mode: per-tenant pending-edge budget")
    ap.add_argument("--global-budget", type=int, default=None,
                    help="cluster mode: per-worker global pending budget")
    ap.add_argument("--bench-json", default=None, metavar="PATH",
                    help="merge this run's SLO report into PATH under "
                         "suites.serving_harness.runs.<mode>")
    obs_mod.add_cli_args(ap)
    args = ap.parse_args()
    if args.tenants < 1:
        raise SystemExit("--tenants must be >= 1")

    config = MiningConfig.from_cli_args(args)
    obs = obs_mod.from_cli_args(args)
    graph = synthetic_graphs.make(args.dataset, seed=args.seed)
    streams = tenant_streams(graph, args.tenants)
    names = [f"tenant{i}" for i in range(args.tenants)]

    if args.workers > 0:
        report = run_cluster_mode(args, config, obs, graph, streams, names)
        if args.out_json:
            with open(args.out_json, "w") as f:
                json.dump(report, f, indent=1, sort_keys=True)
            print(f"report written to {args.out_json}")
        if args.bench_json:
            merge_bench_json(args.bench_json, report["mode"], report)
            print(f"SLO report merged into {args.bench_json} "
                  f"(runs.{report['mode']})")
        obs_mod.write_cli_outputs(obs, args)
        return
    if args.restart or args.kill_after or args.checkpoint_dir:
        raise SystemExit(
            "--checkpoint-dir/--kill-after/--restart need cluster mode "
            "(--workers N)")

    engine = PTMTEngine(config, obs=obs)
    service = MotifService(engine=engine, ingest_batch=args.ingest_batch,
                           obs=obs)
    for name in names:
        service.create_session(name)
    print(f"{args.dataset}: {graph.n_edges} edges over {args.tenants} "
          f"tenants, chunk {args.chunk_edges}, "
          f"admission batch {args.ingest_batch}")

    t0 = time.perf_counter()
    ingest_lat, query_lat, first_call_lat = run_workload(
        service, streams, names, chunk_edges=args.chunk_edges,
        queries_per_chunk=args.queries_per_chunk, seed=args.seed,
    )
    wall = time.perf_counter() - t0
    report = build_report(service, names, graph.n_edges, wall,
                          ingest_lat, query_lat, first_call_lat)

    print(f"ingest: {report['ingest_edges_per_s']:.0f} edges/s sustained, "
          f"chunk p50 {report['ingest_p50_ms']:.1f}ms "
          f"p99 {report['ingest_p99_ms']:.1f}ms")
    print(f"query: {report['queries']} served steady-state, "
          f"p50 {report['query_p50_ms']:.2f}ms "
          f"p99 {report['query_p99_ms']:.2f}ms, "
          f"cache hit rate {report['cache_hit_rate']:.1%} "
          f"({report['snapshots_mined']} snapshots mined); "
          f"{report['first_calls']} first calls excluded "
          f"(max {report['first_call_max_ms']:.1f}ms)")
    for op, row in report["per_op"].items():
        print(f"  {op}: n={row['count']} p50 {row['p50_ms']:.2f}ms "
              f"p99 {row['p99_ms']:.2f}ms")

    if args.verify:
        failed = False
        for row in verify_against_batch(
                service, names, streams, delta=args.delta,
                l_max=args.l_max, omega=args.omega, e_cap=args.e_cap,
                backend=args.backend):
            if row["match"] is None:
                print(f"verify {row['tenant']}: strict check skipped — "
                      f"batch reference overflowed "
                      f"{row['batch_overflow']} edges (the stream side "
                      f"is the exact one; rerun without --e-cap)")
                continue
            status = ("exact match" if row["match"] else "MISMATCH")
            print(f"verify {row['tenant']}: {status} on closed prefix "
                  f"({row['prefix_edges']} edges, "
                  f"{row['motif_types']} motif types)")
            failed = failed or not row["match"]
        if failed:
            raise SystemExit("served counts != batch discover")

    if args.out_json:
        with open(args.out_json, "w") as f:
            json.dump(report, f, indent=1, sort_keys=True)
        print(f"report written to {args.out_json}")

    obs_mod.write_cli_outputs(obs, args)


if __name__ == "__main__":
    main()
