"""Serving driver: replay a temporal graph into N tenant sessions under a
mixed query workload.

``python -m repro.launch.serve_motifs --tenants 4 --dataset sms-a-like``

The dataset's edge stream is strided into ``--tenants`` time-ordered tenant
streams, replayed round-robin in ``--chunk-edges`` arrival chunks through
:class:`repro.serving.motif.MotifService`, and after every chunk each tenant
receives ``--queries-per-chunk`` queries drawn from a fixed mix (top-k,
transition probabilities, prefix counts, level histogram).  All tenants
mine through ONE shared :class:`repro.core.engine.PTMTEngine` (one
resolved backend, one warm compile cache — the deployment shape), built
from the same :meth:`repro.core.config.MiningConfig.add_cli_args` flag
surface as ``launch/mine.py``.  The report is
the serving SLO view: sustained ingest edges/sec, query p50/p99 latency
per op, and snapshot-cache effectiveness.  ``--verify`` cross-checks every
tenant's final engine against batch discovery on its closed prefix
(exact by Lemma 4.2); ``--out-json`` writes the full report for tooling.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

import repro.obs as obs_mod
from repro.core import MiningConfig, PTMTEngine
from repro.core.temporal_graph import TemporalGraph
from repro.data import synthetic_graphs
from repro.obs.timing import percentile_ms
from repro.serving.motif import MotifService, QueryRequest

#: (op, kwargs-builder) workload mix — weights sum to 1.
QUERY_MIX = (
    (0.40, "top_k"),
    (0.25, "transition_probs"),
    (0.20, "prefix_count"),
    (0.15, "level_histogram"),
)


def tenant_streams(graph: TemporalGraph, tenants: int) -> list[TemporalGraph]:
    """Stride the stream into per-tenant streams (each stays time-ordered)."""
    return [
        TemporalGraph(u=graph.u[i::tenants], v=graph.v[i::tenants],
                      t=graph.t[i::tenants], n_nodes=graph.n_nodes)
        for i in range(tenants)
    ]


def sample_request(rng: np.random.Generator, session: str,
                   known_codes: list[str]) -> QueryRequest:
    r = float(rng.random())
    acc = 0.0
    op = QUERY_MIX[-1][1]
    for weight, name in QUERY_MIX:
        acc += weight
        if r < acc:
            op = name
            break
    code = ""
    if op in ("transition_probs", "prefix_count") and known_codes:
        code = known_codes[int(rng.integers(len(known_codes)))]
    level = int(rng.integers(1, 4)) if op == "top_k" else None
    return QueryRequest(session=session, op=op, code=code, level=level, k=8)


def run_workload(
    service: MotifService,
    streams: list[TemporalGraph],
    names: list[str],
    *,
    chunk_edges: int,
    queries_per_chunk: int,
    seed: int = 0,
):
    """Round-robin replay + query mix.

    Returns ``(ingest_lat, query_lat_by_op, first_call_lat_by_op)`` —
    first calls of a (tenant, op) pair pay one-time JAX trace/compile and
    index-build cost (``QueryResponse.first_call``), so they are kept out
    of the steady-state ``query_lat`` series and reported separately.
    """
    rng = np.random.default_rng(seed)
    ingest_lat: list[float] = []
    query_lat: dict[str, list[float]] = {name: [] for _, name in QUERY_MIX}
    first_call_lat: dict[str, list[float]] = {
        name: [] for _, name in QUERY_MIX}
    known: dict[str, list[str]] = {n: [] for n in names}
    offsets = [0] * len(streams)
    live = True
    while live:
        live = False
        for name, g, idx in zip(names, streams, range(len(streams))):
            i = offsets[idx]
            if i >= g.n_edges:
                continue
            live = True
            offsets[idx] = i + chunk_edges
            t0 = time.perf_counter()
            service.ingest(name, g.u[i:i + chunk_edges],
                           g.v[i:i + chunk_edges], g.t[i:i + chunk_edges])
            ingest_lat.append(time.perf_counter() - t0)
            for _ in range(queries_per_chunk):
                req = sample_request(rng, name, known[name])
                resp = service.query(req)
                if resp.first_call:
                    first_call_lat[req.op].append(resp.latency_s)
                else:
                    query_lat[req.op].append(resp.latency_s)
                if req.op == "top_k" and resp.payload:
                    known[name] = [c for c, _ in resp.payload][:8]
    return ingest_lat, query_lat, first_call_lat


def build_report(service, names, n_edges, wall, ingest_lat, query_lat,
                 first_call_lat=None):
    all_q = [x for lats in query_lat.values() for x in lats]
    all_first = [x for lats in (first_call_lat or {}).values() for x in lats]
    stats = service.stats()
    lookups = stats["cache_hits"] + stats["cache_misses"]
    return {
        "tenants": len(names),
        "edges": n_edges,
        "seconds": wall,
        "ingest_edges_per_s": n_edges / wall if wall else 0.0,
        "ingest_chunks": len(ingest_lat),
        "ingest_p50_ms": percentile_ms(ingest_lat, 50),
        "ingest_p99_ms": percentile_ms(ingest_lat, 99),
        # steady-state only: first calls (compile + index build) are
        # reported under first_call_* so p50/p99 describe the warm service
        "queries": len(all_q),
        "query_p50_ms": percentile_ms(all_q, 50),
        "query_p99_ms": percentile_ms(all_q, 99),
        "first_calls": len(all_first),
        "first_call_max_ms": (1e3 * max(all_first)) if all_first else 0.0,
        "per_op": {
            op: {
                "count": len(lats),
                "p50_ms": percentile_ms(lats, 50),
                "p99_ms": percentile_ms(lats, 99),
            }
            for op, lats in sorted(query_lat.items())
        },
        "snapshots_mined": stats["snapshots_mined"],
        "cache_hit_rate": stats["cache_hits"] / lookups if lookups else 0.0,
        "sessions": stats["sessions"],
    }


def verify_against_batch(service, names, streams, *, delta, l_max, omega,
                         e_cap=None, backend="ref") -> list[dict]:
    """Per-tenant cross-check of served counts against batch discovery on
    the closed prefix — the serving-layer restatement of the Lemma 4.2 test.

    Returns one row per tenant.  A row with ``batch_overflow > 0`` means the
    batch *reference* overflowed zone capacity and undercounts (the stream
    side is the exact one — see ``core/streaming.py``); strict equality is
    only meaningful when ``batch_overflow == 0``, so ``match`` is ``None``
    for those rows and callers must not fail on them.
    """
    ref_engine = PTMTEngine(MiningConfig(
        delta=delta, l_max=l_max, omega=omega, e_cap=e_cap,
        backend=backend, allow_overflow=True,
    ))
    rows = []
    for name, g in zip(names, streams):
        service.flush(name)
        sess = service.manager.get(name)
        engine = sess.engine()
        closed = sess.closed_time
        cut = 0 if closed is None else int(
            np.searchsorted(g.t, closed, side="left"))
        if cut == 0:
            rows.append({"tenant": name, "prefix_edges": 0,
                         "motif_types": 0, "batch_overflow": 0,
                         "match": engine.result.counts == {}})
            continue
        prefix = TemporalGraph(u=g.u[:cut], v=g.v[:cut], t=g.t[:cut],
                               n_nodes=g.n_nodes)
        expect = ref_engine.discover(prefix)
        rows.append({
            "tenant": name,
            "prefix_edges": prefix.n_edges,
            "motif_types": len(expect.counts),
            "batch_overflow": expect.overflow,
            "match": (engine.result.counts == expect.counts
                      if expect.overflow == 0 else None),
        })
    return rows


def main():
    ap = argparse.ArgumentParser()
    MiningConfig.add_cli_args(ap)
    ap.add_argument("--dataset", default="sms-a-like",
                    choices=sorted(synthetic_graphs.DATASET_ANALOGS))
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--chunk-edges", type=int, default=2048,
                    help="edges per tenant arrival chunk")
    ap.add_argument("--ingest-batch", type=int, default=8192,
                    help="admission buffer flush threshold per session")
    ap.add_argument("--queries-per-chunk", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verify", action="store_true",
                    help="cross-check every tenant against batch discover")
    ap.add_argument("--out-json", default=None)
    obs_mod.add_cli_args(ap)
    args = ap.parse_args()
    if args.tenants < 1:
        raise SystemExit("--tenants must be >= 1")

    config = MiningConfig.from_cli_args(args)
    obs = obs_mod.from_cli_args(args)
    engine = PTMTEngine(config, obs=obs)
    graph = synthetic_graphs.make(args.dataset, seed=args.seed)
    streams = tenant_streams(graph, args.tenants)
    names = [f"tenant{i}" for i in range(args.tenants)]
    service = MotifService(engine=engine, ingest_batch=args.ingest_batch,
                           obs=obs)
    for name in names:
        service.create_session(name)
    print(f"{args.dataset}: {graph.n_edges} edges over {args.tenants} "
          f"tenants, chunk {args.chunk_edges}, "
          f"admission batch {args.ingest_batch}")

    t0 = time.perf_counter()
    ingest_lat, query_lat, first_call_lat = run_workload(
        service, streams, names, chunk_edges=args.chunk_edges,
        queries_per_chunk=args.queries_per_chunk, seed=args.seed,
    )
    wall = time.perf_counter() - t0
    report = build_report(service, names, graph.n_edges, wall,
                          ingest_lat, query_lat, first_call_lat)

    print(f"ingest: {report['ingest_edges_per_s']:.0f} edges/s sustained, "
          f"chunk p50 {report['ingest_p50_ms']:.1f}ms "
          f"p99 {report['ingest_p99_ms']:.1f}ms")
    print(f"query: {report['queries']} served steady-state, "
          f"p50 {report['query_p50_ms']:.2f}ms "
          f"p99 {report['query_p99_ms']:.2f}ms, "
          f"cache hit rate {report['cache_hit_rate']:.1%} "
          f"({report['snapshots_mined']} snapshots mined); "
          f"{report['first_calls']} first calls excluded "
          f"(max {report['first_call_max_ms']:.1f}ms)")
    for op, row in report["per_op"].items():
        print(f"  {op}: n={row['count']} p50 {row['p50_ms']:.2f}ms "
              f"p99 {row['p99_ms']:.2f}ms")

    if args.verify:
        failed = False
        for row in verify_against_batch(
                service, names, streams, delta=args.delta,
                l_max=args.l_max, omega=args.omega, e_cap=args.e_cap,
                backend=args.backend):
            if row["match"] is None:
                print(f"verify {row['tenant']}: strict check skipped — "
                      f"batch reference overflowed "
                      f"{row['batch_overflow']} edges (the stream side "
                      f"is the exact one; rerun without --e-cap)")
                continue
            status = ("exact match" if row["match"] else "MISMATCH")
            print(f"verify {row['tenant']}: {status} on closed prefix "
                  f"({row['prefix_edges']} edges, "
                  f"{row['motif_types']} motif types)")
            failed = failed or not row["match"]
        if failed:
            raise SystemExit("served counts != batch discover")

    if args.out_json:
        with open(args.out_json, "w") as f:
            json.dump(report, f, indent=1, sort_keys=True)
        print(f"report written to {args.out_json}")

    obs_mod.write_cli_outputs(obs, args)


if __name__ == "__main__":
    main()
