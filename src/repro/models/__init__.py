from . import sharding  # noqa: F401

__all__ = ["sharding"]
