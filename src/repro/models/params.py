"""Parameter declaration: shapes + dtypes + logical shardings in one tree.

No flax in this environment — parameters are plain pytrees (nested dicts of
``jnp`` arrays).  Each model declares a matching tree of :class:`ParamSpec`;
from it we derive ShapeDtypeStructs (dry-run), NamedShardings (pjit) and
initialized arrays (smoke tests / real training).
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from . import sharding as shd


class ParamSpec(NamedTuple):
    shape: tuple
    dtype: any = jnp.float32
    logical: tuple = ()          # logical partition spec, same rank as shape
    init: str = "normal"         # normal | zeros | ones | embed
    scale: float | None = None   # stddev override


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_sds(specs):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs,
        is_leaf=is_spec,
    )


def tree_shardings(mesh, specs):
    return jax.tree.map(
        lambda s: shd.named_sharding(mesh, s.logical, s.shape), specs,
        is_leaf=is_spec,
    )


def _fan_in(shape) -> int:
    if len(shape) == 1:
        return shape[0]
    return int(np.prod(shape[:-1]))


def init_param(key, spec: ParamSpec):
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "embed":
        scale = spec.scale or 1.0
        return (
            jax.random.normal(key, spec.shape, jnp.float32) * scale
        ).astype(spec.dtype)
    scale = spec.scale or 1.0 / np.sqrt(max(_fan_in(spec.shape), 1))
    return (
        jax.random.normal(key, spec.shape, jnp.float32) * scale
    ).astype(spec.dtype)


def tree_init(key, specs):
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(
        treedef, [init_param(k, s) for k, s in zip(keys, leaves)]
    )


def count_params(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=is_spec)
    return int(sum(np.prod(s.shape) for s in leaves))
