"""EquiformerV2-style equivariant graph attention with eSCN SO(2) convs.

[arXiv:2306.12059] + eSCN [arXiv:2302.03655].  Features are SO(3) irreps
``X[N, (l_max+1)^2, C]`` (real spherical-harmonic basis).  Per edge:

  1. build the rotation aligning the edge direction with +z;
  2. rotate source irreps into the edge frame with Wigner-D matrices;
  3. apply the eSCN SO(2) convolution — in the aligned frame an equivariant
     linear map only mixes components of equal |m|, and truncating to
     ``m <= m_max`` reduces the O(L^6) tensor product to O(L^3) mixes;
  4. modulate by radial features + graph-attention weights (invariant);
  5. rotate back and scatter-sum to the destination node.

Wigner-D matrices are built *numerically but exactly*: real SH satisfy
``Y_l(R x) = D_l(R) Y_l(x)``, so with a fixed generic sample set X we
precompute ``pinv(Y_l(X))`` once and per edge evaluate
``D_l = (pinv(Y_l(X)) @ Y_l(R X))^T`` — two small matmuls per degree, no
Euler-angle recursions.  Exact to fp32 lstsq conditioning (checked in tests
against the equivariance property itself).

Simplifications vs the released model (documented in DESIGN.md): single
radial MLP (no per-block MLPs), gate nonlinearity instead of S2 pointwise
activation, attention logits from invariant channels only.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

import jax
import jax.numpy as jnp

from . import sharding as shd
from .params import ParamSpec


# ---------------------------------------------------------------------------
# real spherical harmonics (vectorized, arbitrary l_max)
# ---------------------------------------------------------------------------

def real_sph_harm(dirs, l_max: int):
    """Real spherical harmonics Y_lm for unit vectors.

    dirs: [..., 3] -> [..., (l_max+1)^2] ordered (l, m) with
    m = -l..l (flat index l^2 + l + m).  Uses the standard associated
    Legendre recursion at fp64-free fp32 (adequate for l <= 8).
    """
    x, y, z = dirs[..., 0], dirs[..., 1], dirs[..., 2]
    rxy = jnp.sqrt(jnp.clip(x * x + y * y, 1e-24, None))
    cos_t = jnp.clip(z, -1.0, 1.0)
    sin_t = rxy
    cos_p = x / rxy
    sin_p = y / rxy

    # P_l^m(cos_t) via stable recursion, including sin_t powers
    p = {}
    p[(0, 0)] = jnp.ones_like(cos_t)
    for m in range(1, l_max + 1):
        p[(m, m)] = -(2 * m - 1) * sin_t * p[(m - 1, m - 1)]
    for m in range(0, l_max):
        p[(m + 1, m)] = (2 * m + 1) * cos_t * p[(m, m)]
    for m in range(0, l_max + 1):
        for l in range(m + 2, l_max + 1):
            p[(l, m)] = (
                (2 * l - 1) * cos_t * p[(l - 1, m)]
                - (l + m - 1) * p[(l - 2, m)]
            ) / (l - m)

    # cos(m phi), sin(m phi) by recursion
    cosm = [jnp.ones_like(cos_p), cos_p]
    sinm = [jnp.zeros_like(sin_p), sin_p]
    for m in range(2, l_max + 1):
        cosm.append(2 * cos_p * cosm[-1] - cosm[-2])
        sinm.append(2 * cos_p * sinm[-1] - sinm[-2])

    from math import factorial, pi, sqrt

    out = []
    for l in range(l_max + 1):
        for m in range(-l, l + 1):
            am = abs(m)
            norm = sqrt(
                (2 * l + 1) / (4 * pi)
                * factorial(l - am) / factorial(l + am)
            )
            if m == 0:
                val = norm * p[(l, 0)]
            elif m > 0:
                val = sqrt(2.0) * norm * p[(l, am)] * cosm[am]
            else:
                val = sqrt(2.0) * norm * p[(l, am)] * sinm[am]
            out.append(val)
    return jnp.stack(out, axis=-1)


@functools.lru_cache(maxsize=8)
def _sample_pinv(l_max: int, n_samples: int = 24, seed: int = 7):
    """Fixed generic sample directions + per-degree pinv(Y_l(X))."""
    rng = np.random.default_rng(seed)
    pts = rng.standard_normal((n_samples, 3))
    pts /= np.linalg.norm(pts, axis=1, keepdims=True)
    pts = pts.astype(np.float32)
    with jax.ensure_compile_time_eval():   # may be called inside a trace
        y = np.asarray(real_sph_harm(jnp.asarray(pts), l_max))
    pinvs = []
    for l in range(l_max + 1):
        block = y[:, l * l: (l + 1) * (l + 1)]          # [K, 2l+1]
        pinvs.append(np.linalg.pinv(block).astype(np.float32))
    # cache numpy only — jnp arrays created inside a trace must not leak
    return pts, pinvs


def edge_alignment_rotation(rhat):
    """Rotation matrices R with R @ rhat = +z.  rhat: [E, 3] -> [E, 3, 3]."""
    x, y, z = rhat[:, 0], rhat[:, 1], rhat[:, 2]
    rxy = jnp.sqrt(jnp.clip(x * x + y * y, 1e-24, None))
    cos_a, sin_a = x / rxy, y / rxy      # azimuth
    cos_b, sin_b = z, rxy                # polar
    # R = Ry(-beta) @ Rz(-alpha)
    row0 = jnp.stack([cos_b * cos_a, cos_b * sin_a, -sin_b], -1)
    row1 = jnp.stack([-sin_a, cos_a, jnp.zeros_like(x)], -1)
    row2 = jnp.stack([sin_b * cos_a, sin_b * sin_a, cos_b], -1)
    return jnp.stack([row0, row1, row2], axis=1)


def wigner_blocks(rot, l_max: int):
    """Per-degree Wigner-D for real SH. rot: [E, 3, 3] -> list of [E, 2l+1, 2l+1]."""
    pts, pinvs = _sample_pinv(l_max)
    rot_pts = jnp.einsum("kj,eij->eki", pts, rot)        # [E, K, 3]  (R @ x_k)
    y_rot = real_sph_harm(rot_pts, l_max)                # [E, K, (L+1)^2]
    blocks = []
    for l in range(l_max + 1):
        yl = y_rot[..., l * l: (l + 1) * (l + 1)]        # [E, K, 2l+1]
        d_t = jnp.einsum("mk,ekn->emn", pinvs[l], yl)    # D^T
        blocks.append(jnp.swapaxes(d_t, 1, 2))
    return blocks


def rotate_irreps(x, blocks, *, inverse=False):
    """x: [E, (L+1)^2, C]; apply block-diag Wigner (or its transpose)."""
    outs = []
    for l, d in enumerate(blocks):
        seg = x[:, l * l: (l + 1) * (l + 1), :]
        eq = "enm,enc->emc" if inverse else "emn,enc->emc"
        outs.append(jnp.einsum(eq, d, seg))
    return jnp.concatenate(outs, axis=1)


# ---------------------------------------------------------------------------
# config / params
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EquiformerConfig:
    name: str
    n_layers: int = 12
    d_hidden: int = 128          # channels per irrep component
    l_max: int = 6
    m_max: int = 2
    n_heads: int = 8
    n_radial: int = 32           # radial basis size
    n_classes: int = 1           # regression target / class count
    readout: str = "graph"
    n_graphs: int = 0
    d_node_in: int = 16          # scalar input features
    edge_chunk: int = 0          # stream edges in chunks (0 = all at once)
    unroll_scans: bool = False   # calibration only (see launch/dryrun)

    @property
    def n_irreps(self) -> int:
        return (self.l_max + 1) ** 2

    def m_rows(self, m: int) -> int:
        """Number of l-degrees carrying an |m|=m component."""
        return self.l_max + 1 - m

    def n_params(self) -> int:
        from .params import count_params

        return count_params(equiformer_param_specs(self))


def equiformer_param_specs(cfg: EquiformerConfig) -> dict:
    f32 = jnp.float32
    l, c = cfg.n_layers, cfg.d_hidden
    layer: dict[str, ParamSpec] = {
        # SO(2) conv weights per |m|: mix (l-degree x channel) jointly
        "w_m0": ParamSpec(
            (l, cfg.m_rows(0) * c, cfg.m_rows(0) * c), f32,
            (None, None, shd.MODEL)),
        "ln_scale": ParamSpec((l, cfg.l_max + 1, c), f32,
                              (None, None, None), init="ones"),
        "gate_w": ParamSpec((l, c, cfg.l_max * c), f32,
                            (None, None, shd.MODEL)),
        "attn_w": ParamSpec((l, c + cfg.n_radial, cfg.n_heads), f32,
                            (None, None, None)),
        "radial_w1": ParamSpec((l, cfg.n_radial, c), f32,
                               (None, None, shd.MODEL)),
        "radial_b1": ParamSpec((l, c), f32, (None, None), init="zeros"),
        "ffn_w1": ParamSpec((l, c, c), f32, (None, None, shd.MODEL)),
        "ffn_w2": ParamSpec((l, c, c), f32, (None, shd.MODEL, None)),
    }
    for m in range(1, cfg.m_max + 1):
        rows = cfg.m_rows(m) * c
        layer[f"w_m{m}_r"] = ParamSpec((l, rows, rows), f32,
                                       (None, None, shd.MODEL))
        layer[f"w_m{m}_i"] = ParamSpec((l, rows, rows), f32,
                                       (None, None, shd.MODEL))
    return {
        "embed_w": ParamSpec((cfg.d_node_in, c), f32, (None, shd.MODEL)),
        "layers": layer,
        "head_w": ParamSpec((c, cfg.n_classes), f32, (None, None)),
        "head_b": ParamSpec((cfg.n_classes,), f32, (None,), init="zeros"),
    }


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _radial_basis(dist, n_radial: int, r_cut: float = 6.0):
    """Gaussian radial basis [E, n_radial]."""
    centers = jnp.linspace(0.0, r_cut, n_radial)
    gamma = n_radial / r_cut
    return jnp.exp(-gamma * jnp.square(dist[:, None] - centers[None, :]))


def _m_index_sets(cfg: EquiformerConfig):
    """Flat irrep indices carrying each |m| (per sign)."""
    idx0 = [l * l + l for l in range(cfg.l_max + 1)]
    pos, neg = {}, {}
    for m in range(1, cfg.m_max + 1):
        pos[m] = [l * l + l + m for l in range(m, cfg.l_max + 1)]
        neg[m] = [l * l + l - m for l in range(m, cfg.l_max + 1)]
    return idx0, pos, neg


def _so2_conv(x_edge, lp, cfg: EquiformerConfig):
    """eSCN SO(2) convolution in the aligned frame. x_edge: [E, I, C]."""
    e, _, c = x_edge.shape
    idx0, pos, neg = _m_index_sets(cfg)

    out = jnp.zeros_like(x_edge)
    # m = 0: plain linear over (l, channel)
    x0 = x_edge[:, jnp.asarray(idx0), :].reshape(e, -1)
    y0 = (x0 @ lp["w_m0"]).reshape(e, len(idx0), c)
    out = out.at[:, jnp.asarray(idx0), :].set(y0)

    # |m| > 0: complex-structured pair mixing (SO(2) equivariance)
    for m in range(1, cfg.m_max + 1):
        ip = jnp.asarray(pos[m])
        im = jnp.asarray(neg[m])
        xp = x_edge[:, ip, :].reshape(e, -1)
        xm = x_edge[:, im, :].reshape(e, -1)
        wr, wi = lp[f"w_m{m}_r"], lp[f"w_m{m}_i"]
        yp = (xp @ wr - xm @ wi).reshape(e, len(pos[m]), c)
        ym = (xp @ wi + xm @ wr).reshape(e, len(pos[m]), c)
        out = out.at[:, ip, :].set(yp)
        out = out.at[:, im, :].set(ym)
    # components with |m| > m_max are truncated (the eSCN speedup)
    return out


def _equivariant_ln(x, scale, cfg: EquiformerConfig):
    """Norm over each degree-l block, learned per-(l, channel) scale."""
    outs = []
    for l in range(cfg.l_max + 1):
        seg = x[:, l * l: (l + 1) * (l + 1), :]
        norm = jnp.sqrt(jnp.mean(jnp.sum(seg * seg, axis=1), axis=-1) + 1e-6)
        outs.append(seg / norm[:, None, None] * scale[l][None, None, :])
    return jnp.concatenate(outs, axis=1)


def forward(params, g, cfg: EquiformerConfig, mesh=None):
    """g: node_feat [N, d_in], positions [N, 3], edge_src/dst, masks.

    When ``cfg.edge_chunk > 0`` the per-edge irrep pipeline (Wigner blocks,
    SO(2) conv, rotate-back) streams edge chunks through a scan so its
    intermediates are O(chunk * (l_max+1)^2 * C) instead of O(E * ...) —
    required for the 62M-edge ogb_products cell.  Attention uses invariant
    node scalars + distances only, so the softmax normalizer is computed
    globally *before* the chunked sweep (two-pass attention).
    """
    n = g["node_feat"].shape[0]
    c = cfg.d_hidden
    src, dst = g["edge_src"], g["edge_dst"]

    rel = g["positions"][src] - g["positions"][dst]
    dist = jnp.sqrt(jnp.sum(rel * rel, axis=-1) + 1e-12)
    # zero-length edges (self-loops / padding) have no direction: their
    # alignment rotation would be singular and break equivariance — mask them.
    edge_mask = g["edge_mask"] & (dist > 1e-5)
    big = src.shape[0] > 1_000_000
    e_spec = shd.EDGE if big else shd.BATCH
    rhat = shd.constrain(rel / dist[:, None], mesh, e_spec, None)
    rbf = shd.constrain(_radial_basis(dist, cfg.n_radial), mesh,
                        e_spec, None)

    # init: scalar channel from inputs, higher degrees zero.  Nodes shard
    # over (pod, data); channels over model — the layer-scan carry is the
    # dominant state at ogb_products scale and must use the whole mesh.
    x = jnp.zeros((n, cfg.n_irreps, c))
    x = x.at[:, 0, :].set(g["node_feat"] @ params["embed_w"])
    x = shd.constrain(x, mesh, shd.BATCH, None, shd.MODEL)

    e_total = src.shape[0]
    chunk = cfg.edge_chunk or e_total
    n_chunks = max(e_total // chunk, 1)
    chunk = e_total // n_chunks

    def layer(x, lp):
        y = _equivariant_ln(x, lp["ln_scale"], cfg)
        # pass 1 — invariant attention logits from node scalars + distance
        inv = jnp.concatenate(
            [y[src][:, 0, :] + y[dst][:, 0, :], rbf], axis=-1
        )
        logits = inv @ lp["attn_w"]                        # [E, heads]
        from .gnn import segment_softmax

        alpha = jax.vmap(
            lambda s: segment_softmax(s, dst, n, edge_mask),
            in_axes=1, out_axes=1,
        )(logits)                                          # [E, heads]
        alpha_c = jnp.repeat(alpha, c // cfg.n_heads, axis=1)  # [E, C]
        alpha_c = shd.constrain(alpha_c, mesh, e_spec, None)
        radial = jax.nn.silu(rbf @ lp["radial_w1"] + lp["radial_b1"])
        radial = shd.constrain(radial, mesh, e_spec, None)

        # pass 2 — chunked equivariant messages (remat: per-chunk irrep
        # intermediates are recomputed in the backward pass, so peak temp
        # stays O(chunk) instead of O(E))
        @functools.partial(
            jax.checkpoint,
            policy=jax.checkpoint_policies.nothing_saveable)
        def msg_chunk(agg, ce):
            c_src, c_dst, c_rhat, c_radial, c_alpha, c_mask = ce
            rot = edge_alignment_rotation(c_rhat)
            blocks = wigner_blocks(rot, cfg.l_max)
            x_e = rotate_irreps(y[c_src], blocks)
            x_e = shd.constrain(x_e, mesh, shd.BATCH, None, shd.MODEL)
            msg = _so2_conv(x_e, lp, cfg)
            msg = msg * (c_radial * c_alpha)[:, None, :]
            msg = rotate_irreps(msg, blocks, inverse=True)
            msg = jnp.where(c_mask[:, None, None], msg, 0.0)
            return agg.at[c_dst].add(msg), None

        reshape = lambda a: a.reshape(n_chunks, chunk, *a.shape[1:])
        agg, _ = jax.lax.scan(
            msg_chunk, jnp.zeros_like(x),
            (reshape(src), reshape(dst), reshape(rhat),
             reshape(radial), reshape(alpha_c), reshape(edge_mask)),
        )
        x = x + agg

        # gated equivariant FFN
        y2 = _equivariant_ln(x, lp["ln_scale"], cfg)
        scalar = y2[:, 0, :]
        h0 = jax.nn.silu(scalar @ lp["ffn_w1"]) @ lp["ffn_w2"]
        gates = jax.nn.sigmoid(scalar @ lp["gate_w"])      # [N, l_max*C]
        gates = gates.reshape(n, cfg.l_max, c)
        upd = [h0[:, None, :]]
        for l in range(1, cfg.l_max + 1):
            seg = y2[:, l * l: (l + 1) * (l + 1), :]
            upd.append(seg * gates[:, l - 1][:, None, :])
        x = x + jnp.concatenate(upd, axis=1)
        x = shd.constrain(x, mesh, shd.BATCH, None, shd.MODEL)
        return x, None

    # checkpoint whole layers on big graphs: only the [N, irreps, C] carry
    # survives the forward; everything per-edge is recomputed in backward
    if big:
        layer = jax.checkpoint(
            layer, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(layer, x, params["layers"],
                        unroll=cfg.unroll_scans)

    scalars = x[:, 0, :]
    scalars = jnp.where(g["node_mask"][:, None], scalars, 0.0)
    if cfg.readout == "graph":
        pooled = jax.ops.segment_sum(
            scalars, g["graph_ids"], num_segments=cfg.n_graphs
        )
        return pooled @ params["head_w"] + params["head_b"]
    return scalars @ params["head_w"] + params["head_b"]


def loss_fn(params, batch, cfg: EquiformerConfig, mesh=None):
    out = forward(params, batch, cfg, mesh)
    if cfg.n_classes == 1:   # regression (molecule energies)
        target = batch["targets"].astype(jnp.float32)
        return jnp.mean(jnp.square(out[:, 0] - target))
    logp = jax.nn.log_softmax(out.astype(jnp.float32), -1)
    nll = -jnp.take_along_axis(logp, batch["labels"][:, None], -1)[:, 0]
    if cfg.readout == "graph":
        return jnp.mean(nll)
    mask = batch["node_mask"].astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
