"""Mixture-of-Experts block: top-k routing with sort-based dispatch.

Dispatch strategy (beyond the naive GShard one-hot einsum, whose dispatch
tensor costs as many FLOPs as the experts themselves): token->expert
assignments are sorted by expert id, compacted into a capacity-bounded
[E, C, D] buffer, run through a batched per-expert GEMM (MXU-friendly), and
scattered back with combine weights.  Capacity overflow drops tokens
(standard GShard semantics); ``capacity_factor`` controls slack.

Sharding: experts ride the ``model`` axis (expert parallelism), the capacity
dim rides ``data``; GSPMD lowers the gather/scatter to all-to-all style
collectives — the same traffic pattern as a hand-written MoE all-to-all.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import sharding as shd
from .layers import swiglu


def router_topk(x, w_router, *, top_k: int, dtype=jnp.float32):
    """Softmax router with renormalized top-k weights.

    x: [T, D] -> (weights [T, k] f32, experts [T, k] int32)
    """
    logits = jnp.einsum("td,de->te", x.astype(dtype), w_router.astype(dtype))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    return top_p, top_e.astype(jnp.int32)


def _dispatch_group(xs, es, *, n_experts: int, capacity: int, top_k: int):
    """Sort-dispatch one token group. xs: [S, D], es: [S, k] ->
    (buf [E, C, D], slot [S*k], keep [S*k], order [S*k])."""
    s, d = xs.shape
    flat_e = es.reshape(-1)
    sk = flat_e.shape[0]
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=n_experts)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(sk, dtype=jnp.int32) - starts[sorted_e]
    keep = rank < capacity
    slot = jnp.where(keep, sorted_e * capacity + rank, n_experts * capacity)
    gathered = jnp.take(xs, order // top_k, axis=0)           # [S*k, D]
    buf = jnp.zeros((n_experts * capacity + 1, d), xs.dtype)
    buf = buf.at[slot].set(gathered, mode="drop")
    return buf[: n_experts * capacity].reshape(n_experts, capacity, d), \
        slot, keep, order


def _combine_group(out_buf, slot, keep, order, weights, *, top_k: int):
    """Inverse of _dispatch_group. out_buf: [E, C, D] -> [S, D]."""
    e, c, d = out_buf.shape
    rows = out_buf.reshape(e * c, d)
    picked = jnp.take(rows, jnp.minimum(slot, e * c - 1), axis=0)
    picked = jnp.where(keep[:, None], picked, 0.0)
    sk = slot.shape[0]
    unsorted = jnp.zeros((sk, d), out_buf.dtype).at[order].set(picked)
    unsorted = unsorted.reshape(sk // top_k, top_k, d)
    w = weights.astype(jnp.float32)[..., None]
    return jnp.sum(unsorted.astype(jnp.float32) * w, axis=1).astype(
        out_buf.dtype)


def moe_block(
    x, *, w_router, w_gate, w_up, w_down, top_k: int,
    capacity_factor: float = 1.25, mesh=None, group_size: int = 4096,
):
    """Apply the expert MLPs to a flat token batch.

    x: [T, D]; w_router: [D, E]; w_gate/w_up: [E, D, F]; w_down: [E, F, D].
    Returns [T, D].

    Dispatch is **group-local**: tokens are split into groups that ride the
    data axis, and the sort/scatter/capacity machinery is vmapped per group
    — so no dispatch index ever crosses a shard.  The only cross-device
    traffic is the expert dimension meeting the ``model`` axis (classic
    expert parallelism) plus the FSDP weight gathers.
    """
    t, d = x.shape
    e = w_router.shape[1]
    groups = max(t // group_size, 1)
    while t % groups:
        groups -= 1
    s = t // groups
    capacity = max(int(s * top_k * capacity_factor / e), 1)

    weights, experts = router_topk(x, w_router, top_k=top_k)   # [T, k]
    xg = x.reshape(groups, s, d)
    eg = experts.reshape(groups, s, top_k)
    wg = weights.reshape(groups, s, top_k)

    buf, slot, keep, order = jax.vmap(
        lambda xs, es: _dispatch_group(
            xs, es, n_experts=e, capacity=capacity, top_k=top_k)
    )(xg, eg)
    # [G(data), E(model), C, D] — groups ride data, experts ride model
    buf = shd.constrain(buf, mesh, shd.BATCH, shd.MODEL, None, None)

    gate = jnp.einsum("gecd,edf->gecf", buf, w_gate.astype(x.dtype))
    up = jnp.einsum("gecd,edf->gecf", buf, w_up.astype(x.dtype))
    hidden = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    out_buf = jnp.einsum("gecf,efd->gecd", hidden, w_down.astype(x.dtype))
    out_buf = shd.constrain(out_buf, mesh, shd.BATCH, shd.MODEL, None, None)

    out = jax.vmap(
        lambda ob, sl, kp, od, ws: _combine_group(
            ob, sl, kp, od, ws, top_k=top_k)
    )(out_buf, slot, keep, order, wg)
    out = out.reshape(t, d)
    return shd.constrain(out, mesh, shd.BATCH, None)


def aux_load_balance_loss(x, w_router, *, top_k: int) -> jax.Array:
    """Switch-style auxiliary load-balancing loss (fraction * probability)."""
    logits = jnp.einsum(
        "td,de->te", x.astype(jnp.float32), w_router.astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)
    e = probs.shape[-1]
    _, top_e = jax.lax.top_k(probs, top_k)
    onehot = jax.nn.one_hot(top_e, e, dtype=jnp.float32).sum(axis=1)
    frac_tokens = jnp.mean(onehot, axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    return e * jnp.sum(frac_tokens * frac_probs)
