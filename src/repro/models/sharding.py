"""Logical-axis sharding resolver.

Models annotate arrays with *logical* partition specs (tuples of mesh-axis
names / axis groups / None).  ``resolve`` adapts a logical spec to a concrete
mesh: axes missing from the mesh are dropped, and any axis group that does
not divide the corresponding dimension is dropped (e.g. 8 KV heads cannot
shard over a 16-way ``model`` axis → replicated; batch=1 in ``long_500k``
→ replicated).  This keeps one set of model annotations valid across the
single-pod (16,16), multi-pod (2,16,16) and 1-device CPU test meshes.
"""

from __future__ import annotations

import math

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

# logical spec vocabulary used by the models
BATCH = ("pod", "data")     # batch dim: DP over pods and the data axis
FSDP = "data"               # parameter shards gathered on use
MODEL = "model"             # tensor parallel axis
SEQ = ("data", "model")     # sequence sharding for giant KV caches (batch=1)
EDGE = ("pod", "data", "model")  # GNN edge streams: use the whole mesh


def _axes_in_mesh(entry, mesh) -> tuple[str, ...]:
    if entry is None:
        return ()
    if isinstance(entry, str):
        entry = (entry,)
    return tuple(a for a in entry if a in mesh.shape)


def resolve(spec, shape, mesh) -> P:
    """Adapt a logical spec to `mesh` given the concrete `shape`.

    Drops axes that are absent from the mesh, do not divide the dimension,
    or were already consumed by an earlier dimension (e.g. batch=1 frees
    ``data`` for the KV-cache sequence dim in ``long_500k``).
    """
    out = []
    used: set[str] = set()
    for dim, entry in enumerate(spec):
        axes = [a for a in _axes_in_mesh(entry, mesh) if a not in used]
        # shrink the axis group until it divides the dimension
        while axes and shape[dim] % math.prod(
            mesh.shape[a] for a in axes
        ) != 0:
            axes = axes[:-1]
        if axes:
            used.update(axes)
            out.append(axes[0] if len(axes) == 1 else tuple(axes))
        else:
            out.append(None)
    return P(*out)


def named_sharding(mesh, spec, shape) -> NamedSharding:
    return NamedSharding(mesh, resolve(spec, shape, mesh))


def constrain(x, mesh, *spec):
    """with_sharding_constraint using the logical resolver (no-op on 1 dev)."""
    if mesh is None or np.prod(list(mesh.shape.values())) == 1:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, resolve(spec, x.shape, mesh))
    )
