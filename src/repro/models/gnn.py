"""GNN layers + models via edge-index scatter (segment ops).

JAX sparse is BCOO-only, so message passing is implemented directly over an
edge list: ``gather(src) -> edge MLP -> segment_sum/max(dst)``.  This *is*
the system's SpMM/SDDMM substrate (kernels/segment_spmm provides the Pallas
fast path for the gather-GEMM-scatter hot loop).

Graphs are padded, fixed-shape batches:
  node_feat [N, F] f32, edge_src/edge_dst int32[E], node_mask bool[N],
  edge_mask bool[E], plus optional graph_ids int32[N] for batched small
  graphs and labels.  Invalid edges point at node N-1 with mask 0 and are
  zeroed inside every aggregation.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from . import sharding as shd
from .params import ParamSpec


def segment_softmax(scores, segment_ids, num_segments, mask):
    """Numerically-stable softmax over edges grouped by destination."""
    scores = jnp.where(mask, scores, -jnp.inf)
    seg_max = jax.ops.segment_max(scores, segment_ids, num_segments)
    seg_max = jnp.where(jnp.isfinite(seg_max), seg_max, 0.0)
    exp = jnp.where(mask, jnp.exp(scores - seg_max[segment_ids]), 0.0)
    seg_sum = jax.ops.segment_sum(exp, segment_ids, num_segments)
    return exp / (seg_sum[segment_ids] + 1e-9)


def scatter_mean(values, segment_ids, num_segments, mask):
    vals = jnp.where(mask[:, None], values, 0.0)
    tot = jax.ops.segment_sum(vals, segment_ids, num_segments)
    cnt = jax.ops.segment_sum(mask.astype(values.dtype), segment_ids,
                              num_segments)
    return tot / (cnt[:, None] + 1e-9)


# ---------------------------------------------------------------------------
# configs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    kind: str                  # gcn | gin | gat | gatedgcn
    n_layers: int
    d_in: int
    d_hidden: int
    n_classes: int
    n_heads: int = 1
    readout: str = "node"      # node | graph
    n_graphs: int = 0          # static graph count for graph readout
    eps_learnable: bool = True  # GIN
    dropout: float = 0.0        # structural only; inference-time ignored
    use_pallas: bool = False
    remat: bool = True          # checkpoint layer bodies (full-batch bwd)
    unroll_scans: bool = False  # calibration only (see launch/dryrun)

    def n_params(self) -> int:
        from .params import count_params

        return count_params(gnn_param_specs(self))


def gnn_param_specs(cfg: GNNConfig) -> dict:
    f32 = jnp.float32
    l, dh = cfg.n_layers, cfg.d_hidden
    specs: dict[str, Any] = {
        "w_in": ParamSpec((cfg.d_in, dh), f32, (None, shd.MODEL)),
        "b_in": ParamSpec((dh,), f32, (None,), init="zeros"),
        "w_out": ParamSpec((dh, cfg.n_classes), f32, (None, None)),
        "b_out": ParamSpec((cfg.n_classes,), f32, (None,), init="zeros"),
    }
    layer: dict[str, ParamSpec] = {}
    if cfg.kind == "gin":
        layer["mlp_w1"] = ParamSpec((l, dh, dh), f32, (None, None, shd.MODEL))
        layer["mlp_b1"] = ParamSpec((l, dh), f32, (None, None), init="zeros")
        layer["mlp_w2"] = ParamSpec((l, dh, dh), f32, (None, shd.MODEL, None))
        layer["mlp_b2"] = ParamSpec((l, dh), f32, (None, None), init="zeros")
        layer["eps"] = ParamSpec((l,), f32, (None,), init="zeros")
    elif cfg.kind == "gat":
        hd = dh // cfg.n_heads
        layer["w"] = ParamSpec((l, dh, cfg.n_heads, hd), f32,
                               (None, None, shd.MODEL, None))
        layer["a_src"] = ParamSpec((l, cfg.n_heads, hd), f32,
                                   (None, shd.MODEL, None))
        layer["a_dst"] = ParamSpec((l, cfg.n_heads, hd), f32,
                                   (None, shd.MODEL, None))
    elif cfg.kind == "gatedgcn":
        for nm in ("wu", "wv", "wa", "wb", "wc"):
            layer[nm] = ParamSpec((l, dh, dh), f32, (None, None, shd.MODEL))
        layer["bn_n"] = ParamSpec((l, dh), f32, (None, None), init="zeros")
        layer["bn_e"] = ParamSpec((l, dh), f32, (None, None), init="zeros")
        specs["w_edge_in"] = ParamSpec((1, dh), f32, (None, None))
    else:  # gcn
        layer["w"] = ParamSpec((l, dh, dh), f32, (None, None, shd.MODEL))
        layer["b"] = ParamSpec((l, dh), f32, (None, None), init="zeros")
    specs["layers"] = layer
    return specs


# ---------------------------------------------------------------------------
# layer forward passes (single layer; stacked via lax.scan)
# ---------------------------------------------------------------------------

def _gather_agg(h_src_val, edge_dst, n, edge_mask, *, use_pallas=False):
    if use_pallas:
        from repro.kernels.segment_spmm import ops as spmm_ops

        return spmm_ops.scatter_sum(h_src_val, edge_dst, n, edge_mask)
    vals = jnp.where(edge_mask[:, None], h_src_val, 0.0)
    return jax.ops.segment_sum(vals, edge_dst, num_segments=n)


def gcn_layer(h, lp, g, cfg):
    n = h.shape[0]
    deg = jax.ops.segment_sum(
        g["edge_mask"].astype(jnp.float32), g["edge_dst"], n
    )
    norm = jax.lax.rsqrt(jnp.maximum(deg, 1.0))
    msg = h[g["edge_src"]] * norm[g["edge_src"], None]
    agg = _gather_agg(msg, g["edge_dst"], n, g["edge_mask"],
                      use_pallas=cfg.use_pallas)
    agg = agg * norm[:, None]
    out = agg @ lp["w"] + lp["b"]
    return jax.nn.relu(out) + h, None


def gin_layer(h, lp, g, cfg):
    n = h.shape[0]
    agg = _gather_agg(h[g["edge_src"]], g["edge_dst"], n, g["edge_mask"],
                      use_pallas=cfg.use_pallas)
    mixed = (1.0 + lp["eps"]) * h + agg
    out = jax.nn.relu(mixed @ lp["mlp_w1"] + lp["mlp_b1"])
    out = out @ lp["mlp_w2"] + lp["mlp_b2"]
    return jax.nn.relu(out) + h, None


def gat_layer(h, lp, g, cfg):
    n = h.shape[0]
    hd = cfg.d_hidden // cfg.n_heads
    hw = jnp.einsum("nd,dhk->nhk", h, lp["w"])            # [N, H, hd]
    s_src = jnp.einsum("nhk,hk->nh", hw, lp["a_src"])
    s_dst = jnp.einsum("nhk,hk->nh", hw, lp["a_dst"])
    scores = jax.nn.leaky_relu(
        s_src[g["edge_src"]] + s_dst[g["edge_dst"]], 0.2
    )                                                      # [E, H]
    alpha = jax.vmap(
        lambda s: segment_softmax(s, g["edge_dst"], n, g["edge_mask"]),
        in_axes=1, out_axes=1,
    )(scores)                                              # [E, H]
    msg = hw[g["edge_src"]] * alpha[..., None]             # [E, H, hd]
    agg = _gather_agg(
        msg.reshape(msg.shape[0], -1), g["edge_dst"], n, g["edge_mask"],
        use_pallas=cfg.use_pallas,
    )
    out = jax.nn.elu(agg.reshape(n, cfg.d_hidden))
    return out + h, None


def gatedgcn_layer(state, lp, g, cfg):
    h, e = state
    n = h.shape[0]
    src, dst = g["edge_src"], g["edge_dst"]
    gate_in = h[src] @ lp["wa"] + h[dst] @ lp["wb"] + e @ lp["wc"]
    e_new = gate_in                                        # new edge features
    eta = jax.nn.sigmoid(e_new)
    msg = eta * (h[src] @ lp["wv"])
    num = _gather_agg(msg, dst, n, g["edge_mask"], use_pallas=cfg.use_pallas)
    den = _gather_agg(eta, dst, n, g["edge_mask"], use_pallas=cfg.use_pallas)
    agg = num / (den + 1e-6)
    h_new = h @ lp["wu"] + agg
    # lightweight norm standing in for batchnorm (full-batch graphs)
    h_new = h_new - h_new.mean(-1, keepdims=True)
    h_new = h_new / (h_new.std(-1, keepdims=True) + 1e-6) * (
        1.0 + lp["bn_n"]
    )
    e_new = e_new - e_new.mean(-1, keepdims=True)
    e_new = e_new / (e_new.std(-1, keepdims=True) + 1e-6) * (
        1.0 + lp["bn_e"]
    )
    return (jax.nn.relu(h_new) + h, jax.nn.relu(e_new) + e), None


# ---------------------------------------------------------------------------
# model forward
# ---------------------------------------------------------------------------

def forward(params, g, cfg: GNNConfig, mesh=None):
    """g: graph batch dict -> logits ([N, classes] or [G, classes])."""
    h = g["node_feat"] @ params["w_in"] + params["b_in"]
    h = jax.nn.relu(h)
    h = shd.constrain(h, mesh, shd.BATCH, None)

    big = g["edge_src"].shape[0] > 1_000_000

    def _constrain_state(s):
        # node tensors over (pod, data); edge tensors over the whole mesh
        # when the graph is large enough to amortize the finer sharding
        def one(a):
            spec = (shd.EDGE if big else shd.BATCH) \
                if a.shape[0] == g["edge_src"].shape[0] else shd.BATCH
            return shd.constrain(a, mesh, spec, None)

        return jax.tree.map(one, s)

    if cfg.kind == "gatedgcn":
        e = jnp.ones((g["edge_src"].shape[0], 1)) @ params["w_edge_in"]
        base_fn = lambda s, lp: gatedgcn_layer(s, lp, g, cfg)
        state = (h, e)
    else:
        layer_fn = {"gcn": gcn_layer, "gin": gin_layer, "gat": gat_layer}[
            cfg.kind
        ]
        base_fn = lambda s, lp: layer_fn(s, lp, g, cfg)
        state = h

    def layer(s, lp):
        # constrain both the consumed and the saved (carried) state so the
        # scan's per-layer checkpoints stay sharded across the whole mesh
        out, aux = base_fn(_constrain_state(s), lp)
        return _constrain_state(out), aux

    state = _constrain_state(state)
    if cfg.remat:
        layer = jax.checkpoint(
            layer, policy=jax.checkpoint_policies.nothing_saveable)
    state, _ = jax.lax.scan(layer, state, params["layers"],
                            unroll=cfg.unroll_scans)
    h = state[0] if cfg.kind == "gatedgcn" else state

    h = jnp.where(g["node_mask"][:, None], h, 0.0)
    if cfg.readout == "graph":
        pooled = jax.ops.segment_sum(
            h, g["graph_ids"], num_segments=cfg.n_graphs
        )
        return pooled @ params["w_out"] + params["b_out"]
    return h @ params["w_out"] + params["b_out"]


def loss_fn(params, batch, cfg: GNNConfig, mesh=None):
    logits = forward(params, batch, cfg, mesh)
    if cfg.n_classes == 1:   # regression (molecule energies)
        target = batch["targets"].astype(jnp.float32)
        return jnp.mean(jnp.square(logits[:, 0] - target))
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    if cfg.readout == "graph":
        return jnp.mean(nll)
    mask = batch["node_mask"].astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
