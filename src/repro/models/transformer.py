"""Decoder-only transformer LM (dense + MoE) with scan-over-layers.

Design points for 512-chip lowering:
  * layer parameters are stacked on a leading [L] axis and consumed by
    ``lax.scan`` — HLO size is depth-independent (compile time and SPMD
    partitioning stay tractable for 80-layer × 512-device dry-runs);
  * every parameter carries a *logical* partition spec (see models.sharding):
    d_model dims shard over ``data`` (FSDP, gathered on use), head/FF/expert
    dims over ``model`` (TP/EP), batch over ``(pod, data)``;
  * local/global attention patterns (gemma3's 5:1) blend masks inside one
    code path so the scanned layer body stays single-shaped;
  * KV caches shard their sequence dim over whatever axes the batch leaves
    free — 524k-token caches spread over the full mesh when batch=1.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

from . import attention, moe as moe_lib, sharding as shd
from .layers import cross_entropy_loss, rms_norm, apply_rope, swiglu
from .params import ParamSpec, tree_init, tree_sds


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    rope_theta: float = 1e4
    rope_theta_local: float | None = None
    qkv_bias: bool = False
    tie_embeddings: bool = False
    embed_scale: bool = False
    window: int | None = None         # sliding window for local layers
    pattern_local: int = 0            # e.g. 5 local : 1 global (gemma3)
    pattern_global: int = 1
    # MoE
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    n_shared_experts: int = 0
    dense_residual: bool = False      # arctic: dense FFN parallel to MoE
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    # numerics / memory
    dtype: Any = jnp.bfloat16
    remat: str = "full"               # full | dots | none
    q_chunk: int = 512
    unroll_scans: bool = False        # calibration only (see launch/dryrun)
    gather_dtype: str = "f32"         # "bf16": cast params before FSDP
                                      # gathers (halves collective traffic)
    microbatch_override: int = 0      # force grad-accumulation factor

    @property
    def has_dense_mlp(self) -> bool:
        return (not self.moe) or self.dense_residual

    def n_params(self) -> int:
        from .params import count_params

        return count_params(param_specs(self))


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------

def param_specs(cfg: TransformerConfig) -> dict:
    l, d = cfg.n_layers, cfg.d_model
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    f32 = jnp.float32
    layer: dict[str, ParamSpec] = {
        "ln1": ParamSpec((l, d), f32, (None, None), init="zeros"),
        "ln2": ParamSpec((l, d), f32, (None, None), init="zeros"),
        "wq": ParamSpec((l, d, h, dh), f32, (None, shd.FSDP, shd.MODEL, None)),
        "wk": ParamSpec((l, d, kv, dh), f32, (None, shd.FSDP, shd.MODEL, None)),
        "wv": ParamSpec((l, d, kv, dh), f32, (None, shd.FSDP, shd.MODEL, None)),
        "wo": ParamSpec((l, h, dh, d), f32, (None, shd.MODEL, None, shd.FSDP)),
    }
    if cfg.qkv_bias:
        layer["bq"] = ParamSpec((l, h, dh), f32, (None, shd.MODEL, None),
                                init="zeros")
        layer["bk"] = ParamSpec((l, kv, dh), f32, (None, shd.MODEL, None),
                                init="zeros")
        layer["bv"] = ParamSpec((l, kv, dh), f32, (None, shd.MODEL, None),
                                init="zeros")
    if cfg.has_dense_mlp:
        f = cfg.d_ff
        layer["wg"] = ParamSpec((l, d, f), f32, (None, shd.FSDP, shd.MODEL))
        layer["wu"] = ParamSpec((l, d, f), f32, (None, shd.FSDP, shd.MODEL))
        layer["wd"] = ParamSpec((l, f, d), f32, (None, shd.MODEL, shd.FSDP))
    if cfg.moe:
        e, fe = cfg.n_experts, cfg.d_ff_expert
        layer["w_router"] = ParamSpec((l, d, e), f32, (None, shd.FSDP, None))
        # experts over `model` (EP), d_model FSDP over `data` (gathered
        # on use — matches the dispatch buffer's [E(model), C(data), D]
        # layout so the expert GEMMs need no activation resharding)
        layer["we_gate"] = ParamSpec(
            (l, e, d, fe), f32, (None, shd.MODEL, shd.FSDP, None))
        layer["we_up"] = ParamSpec(
            (l, e, d, fe), f32, (None, shd.MODEL, shd.FSDP, None))
        layer["we_down"] = ParamSpec(
            (l, e, fe, d), f32, (None, shd.MODEL, None, shd.FSDP))
        if cfg.n_shared_experts:
            fs = cfg.n_shared_experts * fe
            layer["ws_gate"] = ParamSpec(
                (l, d, fs), f32, (None, shd.FSDP, shd.MODEL))
            layer["ws_up"] = ParamSpec(
                (l, d, fs), f32, (None, shd.FSDP, shd.MODEL))
            layer["ws_down"] = ParamSpec(
                (l, fs, d), f32, (None, shd.MODEL, shd.FSDP))
    specs = {
        "embed": ParamSpec((cfg.vocab, d), f32, (shd.MODEL, None),
                           init="embed", scale=d ** -0.5),
        "layers": layer,
        "final_norm": ParamSpec((d,), f32, (None,), init="zeros"),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((d, cfg.vocab), f32,
                                     (shd.FSDP, shd.MODEL))
    return specs


def init_params(key, cfg: TransformerConfig):
    return tree_init(key, param_specs(cfg))


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _is_local_layer(cfg: TransformerConfig, idx):
    if cfg.window is None or cfg.pattern_local == 0:
        return jnp.asarray(False)
    period = cfg.pattern_local + cfg.pattern_global
    return (idx % period) < cfg.pattern_local


def _rope_theta(cfg: TransformerConfig, is_local):
    if cfg.rope_theta_local is None:
        return cfg.rope_theta
    return jnp.where(is_local, cfg.rope_theta_local, cfg.rope_theta)


def _apply_rope_blended(x, positions, cfg, is_local):
    """RoPE with per-layer theta (local vs global layers)."""
    if cfg.rope_theta_local is None:
        return apply_rope(x, positions, theta=cfg.rope_theta)
    a = apply_rope(x, positions, theta=cfg.rope_theta)
    b = apply_rope(x, positions, theta=cfg.rope_theta_local)
    return jnp.where(is_local, b, a)


def _layer_fwd(cfg: TransformerConfig, mesh, x, lp, idx, positions):
    """One decoder layer. x: [B, S, D] (bf16); lp: per-layer param slice."""
    dt = cfg.dtype
    scale = cfg.d_head ** -0.5
    is_local = _is_local_layer(cfg, idx)

    h = rms_norm(x, lp["ln1"])
    q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", h, lp["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + lp["bq"].astype(dt)
        k = k + lp["bk"].astype(dt)
        v = v + lp["bv"].astype(dt)
    q = _apply_rope_blended(q, positions[None, :], cfg, is_local)
    k = _apply_rope_blended(k, positions[None, :], cfg, is_local)
    q = shd.constrain(q, mesh, shd.BATCH, None, shd.MODEL, None)
    k = shd.constrain(k, mesh, shd.BATCH, None, shd.MODEL, None)

    out = attention.attend_chunked(
        q, k, v, q_positions=positions, kv_positions=positions,
        causal=True, window=cfg.window, is_local=is_local, scale=scale,
        q_chunk=min(cfg.q_chunk, x.shape[1]),
    )
    out = jnp.einsum("bshk,hkd->bsd", out, lp["wo"].astype(dt))
    x = x + shd.constrain(out, mesh, shd.BATCH, None, None)

    h = rms_norm(x, lp["ln2"])
    mlp_out = 0.0
    if cfg.has_dense_mlp:
        mlp_out = swiglu(h, lp["wg"], lp["wu"], lp["wd"])
    aux = jnp.asarray(0.0, jnp.float32)
    if cfg.moe:
        b, s, d = h.shape
        flat = h.reshape(b * s, d)
        moe_out = moe_lib.moe_block(
            flat, w_router=lp["w_router"], w_gate=lp["we_gate"],
            w_up=lp["we_up"], w_down=lp["we_down"], top_k=cfg.top_k,
            capacity_factor=cfg.capacity_factor, mesh=mesh,
        ).reshape(b, s, d)
        mlp_out = mlp_out + moe_out
        if cfg.n_shared_experts:
            mlp_out = mlp_out + swiglu(
                h, lp["ws_gate"], lp["ws_up"], lp["ws_down"]
            )
        aux = moe_lib.aux_load_balance_loss(
            flat, lp["w_router"], top_k=cfg.top_k
        )
    x = x + shd.constrain(mlp_out, mesh, shd.BATCH, None, None)
    return x, aux


def _remat_policy(cfg: TransformerConfig):
    if cfg.remat == "full":
        return jax.checkpoint_policies.nothing_saveable
    if cfg.remat == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint_policies.everything_saveable


def forward(params, tokens, cfg: TransformerConfig, mesh=None):
    """tokens [B, S] -> logits [B, S, V] (f32), aux losses."""
    dt = cfg.dtype
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), dt)
    x = shd.constrain(x, mesh, shd.BATCH, None, None)
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)

    layer_params = params["layers"]
    if cfg.gather_dtype == "bf16":
        # cast while still FSDP-sharded: the per-layer all-gathers inside
        # the scan then move bf16 payloads (2x less collective traffic)
        layer_params = jax.tree.map(
            lambda w: w.astype(cfg.dtype), layer_params)

    layer_fn = functools.partial(_layer_fwd, cfg, mesh)
    layer_fn = jax.checkpoint(
        layer_fn, policy=_remat_policy(cfg), static_argnums=()
    )

    def body(carry, scanned):
        lp, idx = scanned
        x = carry
        x, aux = layer_fn(x, lp, idx, positions)
        return x, aux

    idxs = jnp.arange(cfg.n_layers, dtype=jnp.int32)
    x, auxes = jax.lax.scan(body, x, (layer_params, idxs),
                            unroll=cfg.unroll_scans)

    x = rms_norm(x, params["final_norm"])
    head = (
        params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    )
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(dt))
    logits = shd.constrain(logits, mesh, shd.BATCH, None, shd.MODEL)
    return logits.astype(jnp.float32), jnp.sum(auxes)


def loss_fn(params, batch, cfg: TransformerConfig, mesh=None):
    logits, aux = forward(params, batch["tokens"], cfg, mesh)
    loss = cross_entropy_loss(logits, batch["targets"])
    if cfg.moe:
        loss = loss + cfg.aux_loss_weight * aux / cfg.n_layers
    return loss


# ---------------------------------------------------------------------------
# serving (decode with KV cache)
# ---------------------------------------------------------------------------

def cache_specs(cfg: TransformerConfig, batch: int, max_len: int) -> dict:
    l, kv, dh = cfg.n_layers, cfg.n_kv_heads, cfg.d_head
    shape = (l, batch, max_len, kv, dh)
    logical = (None, shd.BATCH, shd.SEQ, shd.MODEL, None)
    return {
        "k": ParamSpec(shape, cfg.dtype, logical, init="zeros"),
        "v": ParamSpec(shape, cfg.dtype, logical, init="zeros"),
    }


def init_cache(cfg: TransformerConfig, batch: int, max_len: int):
    return tree_init(jax.random.PRNGKey(0), cache_specs(cfg, batch, max_len))


def serve_step(params, cache, tokens, cache_len, cfg: TransformerConfig,
               mesh=None):
    """Decode one token. tokens [B, 1]; cache_len: valid entries so far.

    Returns (logits [B, V], updated cache).
    """
    dt = cfg.dtype
    scale = cfg.d_head ** -0.5
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), dt)
    pos = jnp.full((1,), cache_len, jnp.int32)   # position of the new token

    def body(carry, scanned):
        x = carry
        lp, k_cache, v_cache, idx = scanned
        is_local = _is_local_layer(cfg, idx)
        h = rms_norm(x, lp["ln1"])
        q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"].astype(dt))
        k = jnp.einsum("bsd,dhk->bshk", h, lp["wk"].astype(dt))
        v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"].astype(dt))
        if cfg.qkv_bias:
            q = q + lp["bq"].astype(dt)
            k = k + lp["bk"].astype(dt)
            v = v + lp["bv"].astype(dt)
        q = _apply_rope_blended(q, pos[None, :], cfg, is_local)
        k = _apply_rope_blended(k, pos[None, :], cfg, is_local)
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k, (0, cache_len, 0, 0)
        )
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v, (0, cache_len, 0, 0)
        )
        out = attention.attend_decode(
            q, k_cache, v_cache, cache_len=cache_len + 1,
            window=cfg.window, is_local=is_local, scale=scale,
        )
        out = jnp.einsum("bshk,hkd->bsd", out, lp["wo"].astype(dt))
        x = x + out

        h2 = rms_norm(x, lp["ln2"])
        mlp_out = 0.0
        if cfg.has_dense_mlp:
            mlp_out = swiglu(h2, lp["wg"], lp["wu"], lp["wd"])
        if cfg.moe:
            b, s, d = h2.shape
            moe_out = moe_lib.moe_block(
                h2.reshape(b * s, d), w_router=lp["w_router"],
                w_gate=lp["we_gate"], w_up=lp["we_up"],
                w_down=lp["we_down"], top_k=cfg.top_k,
                capacity_factor=cfg.capacity_factor, mesh=mesh,
            ).reshape(b, s, d)
            mlp_out = mlp_out + moe_out
            if cfg.n_shared_experts:
                mlp_out = mlp_out + swiglu(
                    h2, lp["ws_gate"], lp["ws_up"], lp["ws_down"]
                )
        x = x + mlp_out
        return x, (k_cache, v_cache)

    idxs = jnp.arange(cfg.n_layers, dtype=jnp.int32)
    x, (new_k, new_v) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"], idxs),
        unroll=cfg.unroll_scans,
    )
    x = rms_norm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(dt))[:, 0]
    new_cache = {"k": new_k, "v": new_v}
    return logits.astype(jnp.float32), new_cache
