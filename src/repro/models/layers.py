"""Shared NN layers: RMSNorm, rotary embeddings, SwiGLU MLP (pure jnp)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x, weight, *, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def rope_frequencies(d_head: int, theta: float):
    return theta ** (
        -jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head
    )


def apply_rope(x, positions, *, theta: float = 10_000.0):
    """Rotary position embedding.

    x: [..., seq, heads, d_head]; positions: [..., seq] int32.
    """
    d_head = x.shape[-1]
    freqs = rope_frequencies(d_head, theta)                  # [d/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., s, d/2]
    angles = angles[..., None, :]                            # [..., s, 1, d/2]
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    """SwiGLU MLP: silu(x @ w_gate) * (x @ w_up) @ w_down."""
    dtype = x.dtype
    gate = jnp.einsum("...d,df->...f", x, w_gate.astype(dtype))
    up = jnp.einsum("...d,df->...f", x, w_up.astype(dtype))
    hidden = jax.nn.silu(gate.astype(jnp.float32)).astype(dtype) * up
    return jnp.einsum("...f,fd->...d", hidden, w_down.astype(dtype))


def gelu_mlp(x, w_up, w_down):
    dtype = x.dtype
    h = jnp.einsum("...d,df->...f", x, w_up.astype(dtype))
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(dtype)
    return jnp.einsum("...f,fd->...d", h, w_down.astype(dtype))


def cross_entropy_loss(logits, targets, *, z_loss: float = 0.0):
    """Mean token cross-entropy at fp32 with optional z-loss."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, targets[..., None].astype(jnp.int32), axis=-1
    )[..., 0]
    loss = logz - gold
    if z_loss:
        loss = loss + z_loss * jnp.square(logz)
    return jnp.mean(loss)
