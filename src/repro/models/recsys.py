"""DCN-v2 ranking model [arXiv:2008.13535] + two-tower retrieval scoring.

The hot path is the sparse embedding lookup: JAX has no native EmbeddingBag,
so bags are ``jnp.take`` + masked weighted-sum (kernels/embedding_bag holds
the Pallas fast path).  Tables are row-sharded over the ``model`` axis — the
tables *are* the memory footprint; GSPMD turns the gathers into all-to-all
style collectives, which is exactly a production embedding shard layout.

Structure (stacked DCN-v2): x0 = [dense || embedding bags] -> n cross layers
``x_{l+1} = x0 * (W x_l + b) + x_l`` -> deep MLP -> logit.
"""

from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from . import sharding as shd
from .params import ParamSpec


# Criteo-like vocabulary spread: a few huge fields, a body of medium ones
DEFAULT_VOCABS = tuple(
    [10_000_000, 8_000_000] + [1_000_000] * 4 + [100_000] * 8
    + [10_000] * 7 + [1_000] * 5
)


@dataclasses.dataclass(frozen=True)
class DCNConfig:
    name: str
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 16
    n_cross_layers: int = 3
    mlp: tuple = (1024, 1024, 512)
    vocab_sizes: tuple = DEFAULT_VOCABS
    bag_size: int = 4             # multi-hot ids per field (padded)
    d_retrieval: int = 64
    n_items: int = 4_000_000      # retrieval corpus size
    use_pallas: bool = False

    @property
    def d_interact(self) -> int:
        return self.n_dense + self.n_sparse * self.embed_dim

    def n_params(self) -> int:
        from .params import count_params

        return count_params(dcn_param_specs(self))


def dcn_param_specs(cfg: DCNConfig) -> dict:
    f32 = jnp.float32
    d = cfg.d_interact
    specs: dict = {
        "tables": {
            f"t{i}": ParamSpec((v, cfg.embed_dim), f32, (shd.MODEL, None),
                               init="embed", scale=cfg.embed_dim ** -0.5)
            for i, v in enumerate(cfg.vocab_sizes)
        },
        "cross_w": ParamSpec((cfg.n_cross_layers, d, d), f32,
                             (None, None, shd.MODEL)),
        "cross_b": ParamSpec((cfg.n_cross_layers, d), f32, (None, None),
                             init="zeros"),
        "item_table": ParamSpec((cfg.n_items, cfg.d_retrieval), f32,
                                (shd.MODEL, None), init="embed",
                                scale=cfg.d_retrieval ** -0.5),
        "query_proj": ParamSpec((cfg.mlp[-1], cfg.d_retrieval), f32,
                                (None, None)),
    }
    dims = (d,) + tuple(cfg.mlp)
    for i in range(len(cfg.mlp)):
        specs[f"mlp_w{i}"] = ParamSpec((dims[i], dims[i + 1]), f32,
                                       (None, shd.MODEL if i == 0 else None))
        specs[f"mlp_b{i}"] = ParamSpec((dims[i + 1],), f32, (None,),
                                       init="zeros")
    specs["out_w"] = ParamSpec((cfg.mlp[-1], 1), f32, (None, None))
    specs["out_b"] = ParamSpec((1,), f32, (None,), init="zeros")
    return specs


def embedding_bag(table, ids, weights, *, use_pallas=False):
    """Sum-reduce a bag of rows: ids [B, bag], weights [B, bag] -> [B, D]."""
    if use_pallas:
        from repro.kernels.embedding_bag import ops as bag_ops

        return bag_ops.embedding_bag(table, ids, weights)
    rows = jnp.take(table, ids, axis=0)              # [B, bag, D]
    return jnp.einsum("bkd,bk->bd", rows, weights)


def interact_features(params, dense, sparse_ids, sparse_weights, cfg,
                      mesh=None):
    """Build x0 = [dense || 26 embedding bags]."""
    bags = []
    for i in range(cfg.n_sparse):
        bags.append(embedding_bag(
            params["tables"][f"t{i}"], sparse_ids[:, i],
            sparse_weights[:, i], use_pallas=cfg.use_pallas,
        ))
    x0 = jnp.concatenate([dense] + bags, axis=-1)
    return shd.constrain(x0, mesh, shd.BATCH, None)


def forward(params, batch, cfg: DCNConfig, mesh=None):
    """batch: dense [B, 13] f32, sparse_ids [B, 26, bag] i32,
    sparse_weights [B, 26, bag] f32 -> logits [B]."""
    x0 = interact_features(
        params, batch["dense"], batch["sparse_ids"],
        batch["sparse_weights"], cfg, mesh,
    )
    x = x0
    for i in range(cfg.n_cross_layers):
        w = params["cross_w"][i]
        b = params["cross_b"][i]
        x = x0 * (x @ w + b) + x          # DCN-v2 cross
    h = x
    for i in range(len(cfg.mlp)):
        h = jax.nn.relu(h @ params[f"mlp_w{i}"] + params[f"mlp_b{i}"])
    logit = h @ params["out_w"] + params["out_b"]
    return logit[:, 0]


def loss_fn(params, batch, cfg: DCNConfig, mesh=None):
    logits = forward(params, batch, cfg, mesh).astype(jnp.float32)
    y = batch["labels"].astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def query_embedding(params, batch, cfg: DCNConfig, mesh=None):
    """User/query tower: DCN trunk -> d_retrieval embedding."""
    x0 = interact_features(
        params, batch["dense"], batch["sparse_ids"],
        batch["sparse_weights"], cfg, mesh,
    )
    h = x0
    for i in range(len(cfg.mlp)):
        h = jax.nn.relu(h @ params[f"mlp_w{i}"] + params[f"mlp_b{i}"])
    q = h @ params["query_proj"]
    return q / (jnp.linalg.norm(q, axis=-1, keepdims=True) + 1e-9)


def retrieval_step(params, batch, candidate_ids, cfg: DCNConfig, mesh=None,
                   top_k: int = 100):
    """Score one query against a candidate corpus slice (batched dot).

    candidate_ids: int32[n_cand] -> (top scores [B, k], top ids [B, k]).
    """
    q = query_embedding(params, batch, cfg, mesh)         # [B, dr]
    items = jnp.take(params["item_table"], candidate_ids, axis=0)
    scores = q @ items.T                                  # [B, n_cand]
    scores = shd.constrain(scores, mesh, None, shd.MODEL)
    top_s, top_i = jax.lax.top_k(scores, top_k)
    return top_s, jnp.take(candidate_ids, top_i)
