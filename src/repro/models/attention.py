"""GQA attention: query-chunked training/prefill path + cached decode path.

The training path streams query chunks with ``lax.map`` so the per-chunk
score tensor is [B, H, q_chunk, T] — bounded activation memory without a
custom kernel (flash-style chunking; the HLO stays compact because lax.map
lowers to a scan).  Sliding-window (local) layers and global layers share one
code path via mask blending, which keeps the scanned-layer HLO single-shaped.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _gqa_scores(q, k, scale):
    """q: [B, Sq, KV, G, dh]; k: [B, T, KV, dh] -> scores [B, KV, G, Sq, T]."""
    return jnp.einsum("bqkgd,btkd->bkgqt", q, k) * scale


def attend_chunked(
    q, k, v, *,
    q_positions, kv_positions, causal: bool = True,
    window: int | None = None, is_local=None,
    scale: float, q_chunk: int = 512, soft_cap: float | None = None,
):
    """Chunked-query GQA attention.

    Args:
      q: [B, S, n_q, dh] queries (n_q = kv_heads * group).
      k, v: [B, T, n_kv, dh].
      q_positions: int32[S]; kv_positions: int32[T] (global positions).
      window: sliding-window width for local layers.
      is_local: scalar bool (traced) — blend window mask when True.
    Returns: [B, S, n_q, dh]
    """
    b, s, n_q, dh = q.shape
    t = k.shape[1]
    n_kv = k.shape[2]
    g = n_q // n_kv
    q = q.reshape(b, s, n_kv, g, dh)

    n_chunks = max(s // q_chunk, 1)
    chunk = s // n_chunks
    qc = q.reshape(b, n_chunks, chunk, n_kv, g, dh)
    pc = q_positions.reshape(n_chunks, chunk)

    if is_local is None:
        is_local = jnp.asarray(False)

    def one_chunk(args):
        q_i, pos_i = args                       # [B, chunk, KV, G, dh], [chunk]
        scores = _gqa_scores(q_i, k, scale)     # [B, KV, G, chunk, T]
        if soft_cap is not None:
            scores = jnp.tanh(scores / soft_cap) * soft_cap
        mask = jnp.ones((chunk, t), bool)
        if causal:
            mask &= pos_i[:, None] >= kv_positions[None, :]
        if window is not None:
            local = mask & (
                kv_positions[None, :] > pos_i[:, None] - window
            )
            mask = jnp.where(is_local, local, mask)
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
        probs = probs.astype(v.dtype)
        return jnp.einsum("bkgqt,btkd->bqkgd", probs, v)

    out = jax.lax.map(one_chunk, (qc.swapaxes(0, 1), pc))   # [n_chunks, ...]
    out = out.swapaxes(0, 1).reshape(b, s, n_kv, g, dh)
    return out.reshape(b, s, n_q, dh)


def attend_decode(
    q, k_cache, v_cache, *, cache_len, window: int | None = None,
    is_local=None, scale: float, soft_cap: float | None = None,
):
    """Single-position decode attention against a (possibly huge) KV cache.

    q: [B, 1, n_q, dh]; k_cache/v_cache: [B, T_max, n_kv, dh];
    cache_len: scalar int32 — number of valid cache positions (the new
    token's position is cache_len - 1 after insertion).
    """
    b, _, n_q, dh = q.shape
    t = k_cache.shape[1]
    n_kv = k_cache.shape[2]
    g = n_q // n_kv
    q = q.reshape(b, 1, n_kv, g, dh)

    scores = _gqa_scores(q, k_cache, scale)       # [B, KV, G, 1, T]
    if soft_cap is not None:
        scores = jnp.tanh(scores / soft_cap) * soft_cap
    pos = jnp.arange(t, dtype=jnp.int32)
    mask = pos[None, :] < cache_len
    if window is not None:
        local = mask & (pos[None, :] > cache_len - 1 - window)
        blended = jnp.where(
            is_local if is_local is not None else False, local, mask
        )
        mask = blended
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    probs = probs.astype(v_cache.dtype)
    out = jnp.einsum("bkgqt,btkd->bqkgd", probs, v_cache)
    return out.reshape(b, 1, n_q, dh)
