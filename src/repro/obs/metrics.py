"""Metrics registry — counters, gauges, and fixed-bucket histograms.

One process-wide (or per-run) :class:`MetricsRegistry` holds every
instrument the mining/serving stack emits.  Design constraints, in order:

* **thread-safe** — the serving layer increments from concurrent ingest and
  query threads; every instrument carries its own lock and the registry
  lock is held only for get-or-create, so tenants never contend on the hot
  paths;
* **exact tails below a bound** — histograms record raw samples up to
  ``sample_bound`` and compute p50/p95/p99 *exactly* from them; past the
  bound they degrade gracefully to fixed-bucket interpolation (the buckets
  are always maintained, so the Prometheus exposition never changes shape);
* **two export formats** — :meth:`MetricsRegistry.snapshot` (a plain JSON
  dict for ``--metrics-out`` files and ``BENCH_*.json`` payloads) and
  :meth:`MetricsRegistry.to_prometheus` (text exposition format 0.0.4, the
  scrape surface a real deployment would mount);
* **near-zero overhead when disabled** — :data:`NULL_REGISTRY` is a no-op
  singleton whose instruments are shared dummies; call sites never branch
  on "is observability on", they just talk to whatever registry they hold.

Naming convention: ``repro_mining_*`` for engine/executor/streaming,
``repro_serving_*`` for the motif service, ``repro_kernel_*`` for kernel
trace accounting.  Counters end in ``_total``; histogram values are
milliseconds unless the name says otherwise.
"""

from __future__ import annotations

import bisect
import threading

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NULL_REGISTRY",
    "NullRegistry",
    "DEFAULT_MS_BUCKETS",
    "merged_percentile",
]

#: Default histogram buckets (milliseconds): spans sub-100µs kernel
#: dispatches up to multi-second cold compiles.
DEFAULT_MS_BUCKETS = (
    0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0,
    250.0, 500.0, 1000.0, 2500.0, 5000.0, 10000.0,
)

#: Raw samples kept per histogram before percentiles fall back to bucket
#: interpolation.  Below this bound p50/p95/p99 are exact.
DEFAULT_SAMPLE_BOUND = 8192


def _label_key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


def _format_labels(labels: dict, extra: dict | None = None) -> str:
    merged = dict(labels)
    if extra:
        merged.update(extra)
    if not merged:
        return ""
    inner = ",".join(
        '{}="{}"'.format(k, str(v).replace("\\", r"\\").replace('"', r"\""))
        for k, v in sorted(merged.items())
    )
    return "{" + inner + "}"


class Counter:
    """Monotone counter.  ``inc`` is atomic under the instrument lock."""

    __slots__ = ("name", "labels", "_lock", "_value")

    def __init__(self, name: str, labels: dict):
        self.name = name
        self.labels = dict(labels)
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: int | float = 1) -> None:
        if n < 0:
            raise ValueError("counters only go up; use a Gauge")
        with self._lock:
            self._value += n

    @property
    def value(self):
        return self._value


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("name", "labels", "_lock", "_value")

    def __init__(self, name: str, labels: dict):
        self.name = name
        self.labels = dict(labels)
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, value) -> None:
        with self._lock:
            self._value = value

    def add(self, delta) -> None:
        with self._lock:
            self._value += delta

    @property
    def value(self):
        return self._value


class Histogram:
    """Fixed-bucket histogram with exact percentiles below a sample bound.

    Every ``observe`` updates the cumulative bucket counts, the running sum
    and count, and — up to ``sample_bound`` samples — a raw sample list.
    :meth:`percentile` is exact (nearest-rank on the sorted samples) while
    the sample list is complete; beyond the bound it interpolates linearly
    within the containing bucket, which is the standard Prometheus
    ``histogram_quantile`` estimate.
    """

    __slots__ = ("name", "labels", "buckets", "sample_bound", "_lock",
                 "_bucket_counts", "_count", "_sum", "_max", "_samples")

    def __init__(self, name: str, labels: dict,
                 buckets: tuple = DEFAULT_MS_BUCKETS,
                 sample_bound: int = DEFAULT_SAMPLE_BOUND):
        if list(buckets) != sorted(buckets) or len(set(buckets)) != len(
                tuple(buckets)):
            raise ValueError("histogram buckets must be sorted and unique")
        self.name = name
        self.labels = dict(labels)
        self.buckets = tuple(float(b) for b in buckets)
        self.sample_bound = int(sample_bound)
        self._lock = threading.Lock()
        self._bucket_counts = [0] * (len(self.buckets) + 1)  # + overflow
        self._count = 0
        self._sum = 0.0
        self._max = 0.0
        self._samples: list[float] = []

    def observe(self, value) -> None:
        value = float(value)
        idx = bisect.bisect_left(self.buckets, value)
        with self._lock:
            self._bucket_counts[idx] += 1
            self._count += 1
            self._sum += value
            if value > self._max:
                self._max = value
            if len(self._samples) < self.sample_bound:
                self._samples.append(value)

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def exact(self) -> bool:
        """True while every observation is still in the raw sample list."""
        return self._count <= self.sample_bound

    def percentile(self, q: float) -> float:
        """q-th percentile (0..100): exact below the sample bound, bucket
        interpolation above it, 0.0 when empty."""
        if not 0 <= q <= 100:
            raise ValueError(f"percentile q must be in [0, 100], got {q}")
        with self._lock:
            if self._count == 0:
                return 0.0
            if self._count <= self.sample_bound:
                ordered = sorted(self._samples)
                # nearest-rank (ceil) — matches numpy's
                # method="inverted_cdf" and is exact for any sample set
                rank = max(int(-(-q * len(ordered) // 100)), 1)
                return ordered[rank - 1]
            target = q / 100.0 * self._count
            cum = 0
            for i, n in enumerate(self._bucket_counts):
                prev = cum
                cum += n
                if cum >= target:
                    lo = 0.0 if i == 0 else self.buckets[i - 1]
                    hi = self._max if i == len(self.buckets) \
                        else self.buckets[i]
                    frac = (target - prev) / n if n else 0.0
                    # clamp: an interpolated estimate must never exceed
                    # the largest value actually observed
                    return min(lo + (hi - lo) * frac, self._max)
            return self._max

    def samples(self) -> list[float]:
        """Copy of the raw sample list (complete only while :attr:`exact`)."""
        with self._lock:
            return list(self._samples)

    def snapshot(self) -> dict:
        with self._lock:
            counts = list(self._bucket_counts)
            count, total, mx = self._count, self._sum, self._max
        cum, cum_counts = 0, {}
        for edge, n in zip(self.buckets, counts):
            cum += n
            cum_counts[repr(edge)] = cum
        cum_counts["+Inf"] = count
        snap = {
            "count": count,
            "sum": total,
            "max": mx,
            "exact": count <= self.sample_bound,
            "buckets": cum_counts,
        }
        for q in (50, 95, 99):
            snap[f"p{q}"] = self.percentile(q)
        return snap


def merged_percentile(hists, q: float) -> float:
    """q-th percentile pooled across several histograms of one quantity
    (e.g. per-tenant latency histograms merged into a fleet-wide tail).

    Exact (nearest-rank over the pooled raw samples) while every input is
    still :attr:`Histogram.exact`; otherwise falls back to bucket
    interpolation over the summed cumulative counts, which requires every
    input to share the same bucket edges.  Empty inputs contribute nothing;
    an empty pool returns 0.0.
    """
    if not 0 <= q <= 100:
        raise ValueError(f"percentile q must be in [0, 100], got {q}")
    hists = [h for h in hists if h is not None and h.count]
    if not hists:
        return 0.0
    if all(h.exact for h in hists):
        ordered = sorted(s for h in hists for s in h.samples())
        rank = max(int(-(-q * len(ordered) // 100)), 1)
        return ordered[rank - 1]
    edges = hists[0].buckets
    if any(h.buckets != edges for h in hists[1:]):
        raise ValueError("merged_percentile needs identical bucket edges")
    counts = [0] * (len(edges) + 1)
    total, mx = 0, 0.0
    for h in hists:
        with h._lock:
            for i, n in enumerate(h._bucket_counts):
                counts[i] += n
            total += h._count
            mx = max(mx, h._max)
    target = q / 100.0 * total
    cum = 0
    for i, n in enumerate(counts):
        prev = cum
        cum += n
        if cum >= target:
            lo = 0.0 if i == 0 else edges[i - 1]
            hi = mx if i == len(edges) else edges[i]
            frac = (target - prev) / n if n else 0.0
            return min(lo + (hi - lo) * frac, mx)
    return mx


class MetricsRegistry:
    """Get-or-create instrument registry with JSON + Prometheus export.

    ``registry.counter("repro_mining_launches_total", path="fused")``
    returns the one shared :class:`Counter` for that (name, labels) pair,
    creating it on first use.  Re-requesting an existing instrument with a
    different kind raises — a name means one thing.
    """

    enabled = True

    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: dict[tuple, object] = {}

    def _get(self, kind, name: str, labels: dict, factory):
        key = (name, _label_key(labels))
        inst = self._instruments.get(key)
        if inst is None:
            with self._lock:
                inst = self._instruments.get(key)
                if inst is None:
                    inst = factory()
                    self._instruments[key] = inst
        if not isinstance(inst, kind):
            raise TypeError(
                f"metric {name!r}{labels!r} already registered as "
                f"{type(inst).__name__}, requested {kind.__name__}")
        return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels,
                         lambda: Counter(name, labels))

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels, lambda: Gauge(name, labels))

    def histogram(self, name: str, *, buckets: tuple = DEFAULT_MS_BUCKETS,
                  sample_bound: int = DEFAULT_SAMPLE_BOUND,
                  **labels) -> Histogram:
        return self._get(
            Histogram, name, labels,
            lambda: Histogram(name, labels, buckets=buckets,
                              sample_bound=sample_bound))

    def find(self, name: str, **labels):
        """Already-registered instrument, or None (never creates)."""
        return self._instruments.get((name, _label_key(labels)))

    def instruments(self) -> list:
        with self._lock:
            return list(self._instruments.values())

    # -- export -------------------------------------------------------------

    def snapshot(self) -> dict:
        """JSON-ready dict: every instrument with labels and values."""
        counters, gauges, histograms = [], [], []
        for inst in self.instruments():
            entry = {"name": inst.name, "labels": dict(inst.labels)}
            if isinstance(inst, Counter):
                counters.append({**entry, "value": inst.value})
            elif isinstance(inst, Gauge):
                gauges.append({**entry, "value": inst.value})
            else:
                histograms.append({**entry, **inst.snapshot()})
        key = lambda e: (e["name"], sorted(e["labels"].items()))
        return {
            "counters": sorted(counters, key=key),
            "gauges": sorted(gauges, key=key),
            "histograms": sorted(histograms, key=key),
        }

    def to_prometheus(self) -> str:
        """Text exposition format 0.0.4 (one ``# TYPE`` header per name)."""
        by_name: dict[str, list] = {}
        for inst in self.instruments():
            by_name.setdefault(inst.name, []).append(inst)
        lines = []
        for name in sorted(by_name):
            group = by_name[name]
            kind = ("counter" if isinstance(group[0], Counter)
                    else "gauge" if isinstance(group[0], Gauge)
                    else "histogram")
            lines.append(f"# TYPE {name} {kind}")
            for inst in sorted(group, key=lambda i: sorted(i.labels.items())):
                if isinstance(inst, (Counter, Gauge)):
                    lines.append(
                        f"{name}{_format_labels(inst.labels)} {inst.value}")
                    continue
                snap = inst.snapshot()
                cum = 0
                with inst._lock:
                    counts = list(inst._bucket_counts)
                for edge, n in zip(inst.buckets, counts):
                    cum += n
                    lines.append(
                        f"{name}_bucket"
                        f"{_format_labels(inst.labels, {'le': edge})} {cum}")
                lines.append(
                    f"{name}_bucket"
                    f"{_format_labels(inst.labels, {'le': '+Inf'})} "
                    f"{snap['count']}")
                lines.append(
                    f"{name}_sum{_format_labels(inst.labels)} {snap['sum']}")
                lines.append(
                    f"{name}_count{_format_labels(inst.labels)} "
                    f"{snap['count']}")
        return "\n".join(lines) + ("\n" if lines else "")


class _NullCounter:
    __slots__ = ()
    name, labels, value = "", {}, 0

    def inc(self, n=1):
        pass


class _NullGauge:
    __slots__ = ()
    name, labels, value = "", {}, 0.0

    def set(self, value):
        pass

    def add(self, delta):
        pass


class _NullHistogram:
    __slots__ = ()
    name, labels = "", {}
    count, sum, exact = 0, 0.0, True

    def observe(self, value):
        pass

    def percentile(self, q):
        return 0.0

    def snapshot(self):
        return {"count": 0, "sum": 0.0, "max": 0.0, "exact": True,
                "buckets": {}, "p50": 0.0, "p95": 0.0, "p99": 0.0}


_NULL_COUNTER = _NullCounter()
_NULL_GAUGE = _NullGauge()
_NULL_HISTOGRAM = _NullHistogram()


class NullRegistry:
    """No-op registry: shared dummy instruments, records nothing.

    The disabled-mode singleton (:data:`NULL_REGISTRY`).  Call sites hold
    a registry unconditionally; when observability is off every ``inc``/
    ``observe``/``set`` is a constant-time no-op on a shared object — no
    allocation, no locking, nothing to export.
    """

    enabled = False

    def counter(self, name, **labels):
        return _NULL_COUNTER

    def gauge(self, name, **labels):
        return _NULL_GAUGE

    def histogram(self, name, **kw):
        return _NULL_HISTOGRAM

    def find(self, name, **labels):
        return None

    def instruments(self):
        return []

    def snapshot(self):
        return {"counters": [], "gauges": [], "histograms": []}

    def to_prometheus(self):
        return ""


NULL_REGISTRY = NullRegistry()
