"""Unified observability layer: metrics, spans, and timing helpers.

The mining stack (engine → executor → kernels → streaming → serving) emits
all its telemetry through ONE :class:`Observability` bundle — a
:class:`~repro.obs.metrics.MetricsRegistry` (counters / gauges /
histograms, exportable as JSON and Prometheus text) plus a
:class:`~repro.obs.tracing.Tracer` (nested spans with device-accurate
timing and compile-vs-exec attribution, exportable as Chrome-trace JSON).

Opt-in by construction: the default everywhere is :data:`NULL_OBS`, whose
registry and tracer are shared no-op singletons, so instrumented code pays
a constant-time method call when observability is off.  Turn it on by
passing a live bundle where you build the stack::

    obs = repro.obs.enabled()
    engine = PTMTEngine(config, obs=obs)
    engine.discover(graph)
    obs.metrics.snapshot()          # JSON dict
    obs.metrics.to_prometheus()     # scrape text
    obs.tracer.write("trace.json")  # open in chrome://tracing / Perfetto

or, from the CLIs, via ``--metrics-out``/``--trace-out`` on
``launch/mine.py`` and ``launch/serve_motifs.py``.

A process-global bundle (:func:`install_global` / :func:`global_obs`)
exists for layers with no construction-time injection point — currently
kernel trace accounting (:func:`repro.kernels.common.note_trace`).  It
defaults to :data:`NULL_OBS` and the CLIs install their bundle into it.
"""

from __future__ import annotations

import dataclasses
import json

from . import metrics, timing, tracing
from .metrics import NULL_REGISTRY, MetricsRegistry, NullRegistry
from .tracing import NULL_TRACER, NullTracer, Tracer

__all__ = [
    "Observability",
    "NULL_OBS",
    "add_cli_args",
    "from_cli_args",
    "write_cli_outputs",
    "MetricsRegistry",
    "NullRegistry",
    "NULL_REGISTRY",
    "Tracer",
    "NullTracer",
    "NULL_TRACER",
    "enabled",
    "get_obs",
    "global_obs",
    "install_global",
    "metrics",
    "timing",
    "tracing",
]


@dataclasses.dataclass(frozen=True)
class Observability:
    """One bundle holding the registry + tracer a component emits into."""

    metrics: object = NULL_REGISTRY
    tracer: object = NULL_TRACER

    @property
    def enabled(self) -> bool:
        return bool(getattr(self.metrics, "enabled", False)
                    or getattr(self.tracer, "enabled", False))

    @classmethod
    def enabled_bundle(cls) -> "Observability":
        """A fresh live registry + tracer."""
        return cls(metrics=MetricsRegistry(), tracer=Tracer())


def enabled() -> Observability:
    """Module-level convenience: ``obs = repro.obs.enabled()``."""
    return Observability.enabled_bundle()


NULL_OBS = Observability()


def get_obs(obs: Observability | None) -> Observability:
    """Normalize an optional obs argument to a bundle (None → NULL_OBS)."""
    return obs if obs is not None else NULL_OBS


_GLOBAL: Observability = NULL_OBS


def install_global(obs: Observability | None) -> Observability:
    """Install the process-global bundle (None resets to NULL_OBS)."""
    global _GLOBAL
    _GLOBAL = get_obs(obs)
    return _GLOBAL


def global_obs() -> Observability:
    return _GLOBAL


# -- CLI plumbing (shared by launch/mine.py and launch/serve_motifs.py) ------


def add_cli_args(ap) -> None:
    """Add the ``--metrics-out`` / ``--trace-out`` opt-in flags."""
    ap.add_argument(
        "--metrics-out", default=None, metavar="FILE",
        help="write a metrics snapshot (JSON with embedded Prometheus "
             "text) at exit; also enables metric collection")
    ap.add_argument(
        "--trace-out", default=None, metavar="FILE",
        help="write a Chrome-trace JSON (chrome://tracing / Perfetto) of "
             "all spans at exit; also enables span collection")


def from_cli_args(args) -> Observability:
    """Bundle from parsed flags: live (and installed as the process
    global, so kernel-layer accounting reaches it) when either output was
    requested, else :data:`NULL_OBS`."""
    if getattr(args, "metrics_out", None) or getattr(args, "trace_out", None):
        return install_global(enabled())
    return NULL_OBS


def write_cli_outputs(obs: Observability, args) -> None:
    """Write the requested ``--metrics-out`` / ``--trace-out`` files."""
    path = getattr(args, "metrics_out", None)
    if path:
        with open(path, "w") as f:
            json.dump({"metrics": obs.metrics.snapshot(),
                       "prometheus": obs.metrics.to_prometheus()},
                      f, indent=1, sort_keys=True)
        print(f"metrics written to {path}")
    path = getattr(args, "trace_out", None)
    if path:
        obs.tracer.write(path)
        print(f"trace written to {path} "
              f"(load at https://ui.perfetto.dev or chrome://tracing)")
