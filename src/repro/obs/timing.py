"""Shared wall-clock timing helpers — ONE copy of the percentile math.

Before this module, three call sites hand-rolled the same latency
bookkeeping: ``core/streaming.replay_stream`` built per-chunk latency lists
with raw ``perf_counter`` pairs, ``launch/serve_motifs.percentile_ms`` did
its own p50/p99 conversion, and ``launch/dryrun`` timed compiles with a
third inline pattern.  They all route through here now, so "p99" means the
same computation everywhere it is printed or exported.

These helpers are for *host wall-clock* measurement (replay drivers,
compile timing).  Device-accurate span timing lives in
:mod:`repro.obs.tracing`; streaming percentile state lives in
:class:`repro.obs.metrics.Histogram`.
"""

from __future__ import annotations

import time

import numpy as np

__all__ = ["Stopwatch", "percentile_ms", "latency_summary"]


class Stopwatch:
    """Context-manager timer: ``with Stopwatch() as sw: ...; sw.seconds``.

    Reading :attr:`seconds` inside the block returns the running elapsed
    time; after exit it is frozen at the block's duration.
    """

    __slots__ = ("_t0", "_elapsed")

    def __init__(self):
        self._t0 = None
        self._elapsed = 0.0

    def __enter__(self) -> "Stopwatch":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self._elapsed = time.perf_counter() - self._t0
        self._t0 = None
        return False

    @property
    def seconds(self) -> float:
        if self._t0 is not None:
            return time.perf_counter() - self._t0
        return self._elapsed

    @property
    def ms(self) -> float:
        return self.seconds * 1e3


def percentile_ms(latencies_s, q: float) -> float:
    """q-th percentile of a list of second-valued latencies, in ms.

    Empty input returns 0.0 — a report row for an op that never ran prints
    zeros rather than raising.
    """
    lat = np.asarray(list(latencies_s), dtype=np.float64)
    if lat.size == 0:
        return 0.0
    return float(np.percentile(lat, q) * 1e3)


def latency_summary(latencies_s) -> dict:
    """Standard latency digest (count / mean / p50 / p95 / p99 / max, ms)."""
    lat = np.asarray(list(latencies_s), dtype=np.float64)
    if lat.size == 0:
        return {"count": 0, "mean_ms": 0.0, "p50_ms": 0.0, "p95_ms": 0.0,
                "p99_ms": 0.0, "max_ms": 0.0}
    return {
        "count": int(lat.size),
        "mean_ms": float(lat.mean() * 1e3),
        "p50_ms": float(np.percentile(lat, 50) * 1e3),
        "p95_ms": float(np.percentile(lat, 95) * 1e3),
        "p99_ms": float(np.percentile(lat, 99) * 1e3),
        "max_ms": float(lat.max() * 1e3),
    }
