"""Structured spans with device-accurate timing and compile attribution.

JAX dispatch is asynchronous: ``fn(x)`` returns as soon as the computation
is *enqueued*, so a naive ``perf_counter`` pair around a jitted call times
the Python dispatch, not the device execution — and the first call at a new
shape silently includes trace + XLA compile time.  :class:`Tracer` fixes
both:

* a span can carry a **sync target** (``sp.sync(out)``): at span exit the
  tracer calls ``jax.block_until_ready`` on it *before* taking the end
  timestamp, so the recorded duration covers actual device execution;
* a span can carry a **compile key** (the executor's execution key): the
  first span observed for a key is attributed ``phase="compile"`` (its
  duration is trace + compile + first run), every later span for the same
  key is ``phase="exec"`` (steady state).  :meth:`Tracer.attribution`
  aggregates ``compile_ms`` vs ``exec_ms`` per key — the split that keeps
  serving p99 and benchmark numbers honest about warmup.

Spans nest: each thread keeps a depth counter, so the exported events
reconstruct the call tree (Chrome's trace viewer nests complete events on
one thread by time containment).  :meth:`Tracer.to_chrome_trace` emits the
Chrome tracing / Perfetto JSON format — load the ``--trace-out`` file at
``chrome://tracing`` or https://ui.perfetto.dev directly.

:data:`NULL_TRACER` is the disabled-mode singleton: ``span()`` returns one
shared no-op context manager, so an instrumented hot path costs a single
dict-free method call when tracing is off.
"""

from __future__ import annotations

import json
import os
import threading
import time

__all__ = ["Span", "Tracer", "NULL_TRACER", "NullTracer"]


class Span:
    """One in-flight span; use as a context manager (``with tracer.span(...)
    as sp``).  Mutate via :meth:`set` (attach attributes) and :meth:`sync`
    (block on a jax value before the end timestamp)."""

    __slots__ = ("name", "args", "_tracer", "_compile_key", "_sync",
                 "_t0", "_depth")

    def __init__(self, tracer: "Tracer", name: str, compile_key, args: dict):
        self.name = name
        self.args = args
        self._tracer = tracer
        self._compile_key = compile_key
        self._sync = None
        self._t0 = 0.0
        self._depth = 0

    def set(self, **attrs) -> "Span":
        self.args.update(attrs)
        return self

    def sync(self, value) -> "Span":
        """Block on ``value`` (any jax pytree) at span exit, before the end
        timestamp — makes the duration device-accurate."""
        self._sync = value
        return self

    def __enter__(self) -> "Span":
        self._depth = self._tracer._enter()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if self._sync is not None:
            import jax

            jax.block_until_ready(self._sync)
        t1 = time.perf_counter()
        if exc_type is not None:
            self.args["error"] = exc_type.__name__
        self._tracer._finish(self, self._t0, t1)
        return False


class Tracer:
    """Collects finished spans; exports Chrome-trace JSON + attribution.

    Thread-safe: spans may open/close concurrently on any thread (each
    event records its thread id, and per-thread depth counters keep nesting
    local).  The event buffer is bounded (``max_events``) so a runaway loop
    cannot exhaust memory — overflow increments :attr:`dropped` instead.
    """

    enabled = True

    def __init__(self, max_events: int = 200_000):
        self.max_events = int(max_events)
        self.dropped = 0
        self._lock = threading.Lock()
        self._events: list[dict] = []
        self._seen_keys: set = set()
        self._attribution: dict = {}
        self._local = threading.local()
        self._origin = time.perf_counter()

    def span(self, name: str, *, compile_key=None, **args) -> Span:
        return Span(self, name, compile_key, args)

    # -- span plumbing ------------------------------------------------------

    def _enter(self) -> int:
        depth = getattr(self._local, "depth", 0)
        self._local.depth = depth + 1
        return depth

    def _finish(self, span: Span, t0: float, t1: float) -> None:
        self._local.depth = max(getattr(self._local, "depth", 1) - 1, 0)
        dur_ms = (t1 - t0) * 1e3
        phase = None
        if span._compile_key is not None:
            key = span._compile_key
            with self._lock:
                if key in self._seen_keys:
                    phase = "exec"
                    att = self._attribution[key]
                    att["exec_calls"] += 1
                    att["exec_ms_total"] += dur_ms
                    att["exec_ms_min"] = min(att["exec_ms_min"], dur_ms)
                else:
                    phase = "compile"
                    self._seen_keys.add(key)
                    self._attribution[key] = {
                        "span": span.name,
                        "compile_ms": dur_ms,
                        "exec_calls": 0,
                        "exec_ms_total": 0.0,
                        "exec_ms_min": float("inf"),
                    }
        args = span.args
        if phase is not None:
            args["phase"] = phase
        event = {
            "name": span.name,
            "cat": "repro",
            "ph": "X",
            "ts": (t0 - self._origin) * 1e6,
            "dur": (t1 - t0) * 1e6,
            "pid": os.getpid(),
            "tid": threading.get_ident(),
            "args": args,
        }
        with self._lock:
            if len(self._events) < self.max_events:
                self._events.append(event)
            else:
                self.dropped += 1

    # -- introspection / export --------------------------------------------

    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def span_names(self) -> set[str]:
        with self._lock:
            return {e["name"] for e in self._events}

    def attribution(self) -> dict:
        """``{compile_key: {compile_ms, exec_calls, exec_ms_total, ...}}``.

        ``compile_ms`` is the first-call duration (trace + compile + one
        run); ``exec_ms_min`` is the best steady-state execution — their
        ratio is the compile overhead a warm cache amortizes away.
        """
        with self._lock:
            out = {}
            for key, att in self._attribution.items():
                row = dict(att)
                if row["exec_ms_min"] == float("inf"):
                    row["exec_ms_min"] = None
                out[repr(key)] = row
            return out

    def to_chrome_trace(self) -> dict:
        """Chrome tracing JSON object format (Perfetto-loadable)."""
        events = self.events()
        meta = [{
            "name": "process_name",
            "ph": "M",
            "pid": os.getpid(),
            "args": {"name": "repro-ptmt"},
        }]
        return {
            "traceEvents": meta + events,
            "displayTimeUnit": "ms",
            "otherData": {
                "dropped_events": self.dropped,
                "attribution": self.attribution(),
            },
        }

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f, indent=1)


class _NullSpan:
    __slots__ = ()
    name, args = "", {}

    def set(self, **attrs):
        return self

    def sync(self, value):
        return self

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Disabled-mode tracer: every ``span()`` is the same shared no-op."""

    enabled = False
    dropped = 0

    def span(self, name, *, compile_key=None, **args):
        return _NULL_SPAN

    def events(self):
        return []

    def span_names(self):
        return set()

    def attribution(self):
        return {}

    def to_chrome_trace(self):
        return {"traceEvents": [], "displayTimeUnit": "ms", "otherData": {}}

    def write(self, path):
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)


NULL_TRACER = NullTracer()
