"""Jit'd wrappers exposing the Pallas zone-scan with the reference API.

This module is the "pallas" entry in :mod:`repro.core.backends`: the
registry's lazy loader resolves to :func:`scan_zones`.  The kernel's tile
sizes are the registry's ``PALLAS_BLOCK_DEFAULTS`` (a single source of
truth importable without Pallas) rather than being hard-coded at call
sites.
"""

from __future__ import annotations

import functools

import jax

from repro.core.backends import FUSED_BLK_DEFAULT
from repro.core.backends import PALLAS_BLOCK_DEFAULTS as DEFAULT_BLOCKS
from repro.core.expansion import ZoneResult
from repro.kernels.common import note_trace

from .zone_scan import fused_zone_scan_flat, zone_scan_pallas


@functools.partial(
    jax.jit,
    static_argnames=("delta", "l_max", "c_blk", "e_blk", "interpret",
                     "with_ts"),
)
def scan_zone(
    u, v, t, valid, *, delta: int, l_max: int,
    c_blk: int = DEFAULT_BLOCKS["c_blk"], e_blk: int = DEFAULT_BLOCKS["e_blk"],
    interpret: bool | None = None, with_ts: bool = False,
) -> ZoneResult:
    # runs at trace time (inside jit): counts kernel re-traces, not launches
    note_trace("zone_scan")
    out = zone_scan_pallas(
        u, v, t, valid, delta=delta, l_max=l_max, c_blk=c_blk, e_blk=e_blk,
        interpret=interpret, with_ts=with_ts,
    )
    if with_ts:
        code, length, ts = out
        return ZoneResult(code=code, length=length, ts=ts)
    code, length = out
    return ZoneResult(code=code, length=length)


def scan_zones(
    u, v, t, valid, *, delta: int, l_max: int,
    c_blk: int = DEFAULT_BLOCKS["c_blk"], e_blk: int = DEFAULT_BLOCKS["e_blk"],
    interpret: bool | None = None, with_ts: bool = False,
) -> ZoneResult:
    """vmap over a [Z, E] zone batch (same signature as the reference)."""
    fn = functools.partial(
        scan_zone, delta=delta, l_max=l_max, c_blk=c_blk, e_blk=e_blk,
        interpret=interpret, with_ts=with_ts,
    )
    return jax.vmap(fn)(u, v, t, valid)


def scan_flat(
    u, v, t, valid, zone_id, lo, hi, *, delta: int, l_max: int,
    blk: int = FUSED_BLK_DEFAULT, interpret: bool | None = None,
    with_ts: bool = False,
):
    """Single-launch fused scan over a concatenated flat slot stream.

    The "pallas" registry entry's ``fused_loader`` target.  Traceable (the
    executor jits it together with the on-device Phase-2 fold); returns
    raw ``(code int32[S, L], length int32[S])`` per candidate slot rather
    than a :class:`ZoneResult` — the flat stream has no zone axis.  With
    ``with_ts`` a third ``ts int32[S, l_max]`` array is appended.
    ``lo``/``hi`` are the layout's per-candidate-block sweep bounds.
    """
    note_trace("zone_scan_flat")
    return fused_zone_scan_flat(
        u, v, t, valid, zone_id, lo, hi, delta=delta, l_max=l_max, blk=blk,
        interpret=interpret, with_ts=with_ts,
    )
