"""Pallas TPU kernel for PTMT Phase-1 zone expansion.

Layout (all VMEM, lanes = candidates):

  grid = (n_cand_blocks, n_edge_blocks)   # both sequential on TPU
  scratch: candidate SoA for ONE candidate block —
      length/last_t/done/n_nodes  int32[1, C_BLK]
      nodes                       int32[K, C_BLK]   K = l_max + 1
      code                        int32[L, C_BLK]   L = n_limbs(l_max)
  inputs per cell: one edge block (u, v, t, valid as int32[1, E_BLK])
      plus the candidate block's seed times t_cand[1, C_BLK]
  outputs per candidate block: code int32[L, C_BLK], length int32[1, C_BLK]

With the candidate axis OUTER, each candidate block streams the whole edge
stream once and is flushed exactly once; scratch is a single block
(~(K+L+4) * C_BLK * 4 bytes ≈ 50 KB at C_BLK=1024, l_max=6 — far under VMEM).

**Live-window block skipping** (beyond-paper, the kernel's key optimization):
cell (c, e) is skipped when
  * every edge index in block e precedes every candidate in block c
    (those candidates are not yet seeded: extensions need edge_idx > seed), or
  * the e-block's first timestamp exceeds the c-block's last seed time by more
    than ``l_max * delta`` (every candidate's lifetime is over — Lemma 4.1's
    span bound).
Edges are time-sorted, so both tests are O(1) block-boundary reads.  A
candidate is live for ~``1/omega`` of its zone, so skipping turns the dense
O(E^2) sweep into O(E^2 / omega) — measured in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import encoding

DIGITS_PER_LIMB = encoding.DIGITS_PER_LIMB


def _kernel(
    t_cand_ref, u_ref, v_ref, t_ref, valid_ref,
    code_out_ref, len_out_ref,
    length_ref, last_t_ref, done_ref, nn_ref, nodes_ref, code_ref,
    *, delta: int, l_max: int, c_blk: int, e_blk: int, n_e_blocks: int,
):
    ci = pl.program_id(0)
    ei = pl.program_id(1)
    k = l_max + 1
    limbs = code_ref.shape[0]

    @pl.when(ei == 0)
    def _init():
        length_ref[...] = jnp.zeros_like(length_ref)
        last_t_ref[...] = jnp.zeros_like(last_t_ref)
        done_ref[...] = jnp.zeros_like(done_ref)
        nn_ref[...] = jnp.zeros_like(nn_ref)
        nodes_ref[...] = jnp.full_like(nodes_ref, -1)
        code_ref[...] = jnp.zeros_like(code_ref)

    c_base = ci * c_blk
    e_base = ei * e_blk
    # skip tests (see module docstring)
    index_live = e_base + e_blk - 1 >= c_base
    time_live = t_ref[0, 0] <= t_cand_ref[0, c_blk - 1] + l_max * delta

    @pl.when(index_live & time_live)
    def _sweep():
        iota_c = jax.lax.broadcasted_iota(jnp.int32, (1, c_blk), 1) + c_base
        iota_k = jax.lax.broadcasted_iota(jnp.int32, (k, c_blk), 0)

        def body(j, _):
            u = u_ref[0, j]
            v = v_ref[0, j]
            t = t_ref[0, j]
            valid = valid_ref[0, j] != 0

            length = length_ref[...]
            last_t = last_t_ref[...]
            done = done_ref[...] != 0
            n_nodes = nn_ref[...]
            nodes = nodes_ref[...]

            active = (length > 0) & ~done
            gap_ok = (t > last_t) & (t - last_t <= delta)
            timed_out = active & (t - last_t > delta) & valid

            u_hit = nodes == u
            v_hit = nodes == v
            u_in = u_hit.any(axis=0, keepdims=True)
            v_in = v_hit.any(axis=0, keepdims=True)
            extend = (
                active & ~timed_out & gap_ok & (length < l_max)
                & (u_in | v_in) & valid
            )

            u_pos = jnp.min(jnp.where(u_hit, iota_k, k), axis=0,
                            keepdims=True)
            v_pos = jnp.min(jnp.where(v_hit, iota_k, k), axis=0,
                            keepdims=True)
            label_u = jnp.where(u_in, u_pos, n_nodes)
            nn1 = n_nodes + (~u_in).astype(jnp.int32)
            same_uv = u == v
            label_v = jnp.where(same_uv, label_u,
                                jnp.where(v_in, v_pos, nn1))
            nn2 = jnp.where(same_uv, nn1, nn1 + (~v_in).astype(jnp.int32))

            put_u = extend & ~u_in
            put_v = extend & ~v_in & ~same_uv
            local_k = iota_k  # broadcast helper over node slots
            nodes = jnp.where(put_u & (local_k == n_nodes), u, nodes)
            nodes = jnp.where(put_v & (local_k == nn1), v, nodes)

            # append the two digits (label+1) at positions 2*len, 2*len+1
            code = code_ref[...]
            li_iota = jax.lax.broadcasted_iota(
                jnp.int32, (limbs, c_blk), 0
            )
            for which, label in ((0, label_u), (1, label_v)):
                pos = 2 * length + which
                limb_idx = pos // DIGITS_PER_LIMB
                shift = 4 * (DIGITS_PER_LIMB - 1 - pos % DIGITS_PER_LIMB)
                add = jnp.where(
                    extend, jnp.left_shift(label + 1, shift), 0
                )
                code = code + jnp.where(li_iota == limb_idx, add, 0)

            new_length = length + extend.astype(jnp.int32)
            new_last_t = jnp.where(extend, t, last_t)
            new_nn = jnp.where(extend, nn2, n_nodes)

            # seed the candidate owned by this edge
            seed = (iota_c == e_base + j) & valid
            new_length = jnp.where(seed, 1, new_length)
            new_last_t = jnp.where(seed, t, new_last_t)
            new_nn = jnp.where(seed, jnp.where(same_uv, 1, 2), new_nn)
            nodes = jnp.where(seed & (local_k == 0), u, nodes)
            nodes = jnp.where(seed & (local_k == 1) & ~same_uv, v, nodes)
            seed_digit0 = 1 << (4 * (DIGITS_PER_LIMB - 1))
            seed_digit1 = jnp.where(same_uv, 1, 2) << (
                4 * (DIGITS_PER_LIMB - 2)
            )
            seed_code = jnp.where(li_iota == 0, seed_digit0 + seed_digit1, 0)
            code = jnp.where(seed, seed_code, code)

            length_ref[...] = new_length
            last_t_ref[...] = new_last_t
            done_ref[...] = (done | timed_out).astype(jnp.int32)
            nn_ref[...] = new_nn
            nodes_ref[...] = nodes
            code_ref[...] = code
            return 0

        jax.lax.fori_loop(0, e_blk, body, 0)

    @pl.when(ei == n_e_blocks - 1)
    def _flush():
        code_out_ref[...] = code_ref[...]
        len_out_ref[...] = length_ref[...]


def zone_scan_pallas(
    u, v, t, valid, *, delta: int, l_max: int,
    c_blk: int = 512, e_blk: int = 256, interpret: bool | None = None,
):
    """Run the Pallas zone-scan over one padded zone.

    Args:
      u, v, t: int32[E]; valid: bool[E].  E is padded up to block multiples.
    Returns:
      (code int32[E, L], length int32[E]) per seed candidate.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    e = u.shape[0]
    limbs = encoding.n_limbs(l_max)
    k = l_max + 1

    blk = max(c_blk, e_blk)
    e_pad = -(-e // blk) * blk
    pad = e_pad - e
    valid_i = valid.astype(jnp.int32)
    if pad:
        u = jnp.pad(u, (0, pad))
        v = jnp.pad(v, (0, pad))
        t = jnp.pad(t, (0, pad))
        valid_i = jnp.pad(valid_i, (0, pad))
    # normalize padding timestamps (invalid slots) to the max valid time so
    # block skipping stays conservative; padded edges are semantically inert.
    t_fill = jnp.max(jnp.where(valid_i != 0, t, jnp.iinfo(jnp.int32).min))
    t = jnp.where(valid_i != 0, t, t_fill)

    n_c_blocks = e_pad // c_blk
    n_e_blocks = e_pad // e_blk
    row = lambda x: x.reshape(1, e_pad)
    u2, v2, t2, valid2 = row(u), row(v), row(t), row(valid_i)

    kernel = functools.partial(
        _kernel, delta=delta, l_max=l_max, c_blk=c_blk, e_blk=e_blk,
        n_e_blocks=n_e_blocks,
    )
    code, length = pl.pallas_call(
        kernel,
        grid=(n_c_blocks, n_e_blocks),
        in_specs=[
            pl.BlockSpec((1, c_blk), lambda ci, ei: (0, ci)),   # t_cand
            pl.BlockSpec((1, e_blk), lambda ci, ei: (0, ei)),   # u
            pl.BlockSpec((1, e_blk), lambda ci, ei: (0, ei)),   # v
            pl.BlockSpec((1, e_blk), lambda ci, ei: (0, ei)),   # t
            pl.BlockSpec((1, e_blk), lambda ci, ei: (0, ei)),   # valid
        ],
        out_specs=[
            pl.BlockSpec((limbs, c_blk), lambda ci, ei: (0, ci)),
            pl.BlockSpec((1, c_blk), lambda ci, ei: (0, ci)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((limbs, e_pad), jnp.int32),
            jax.ShapeDtypeStruct((1, e_pad), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, c_blk), jnp.int32),      # length
            pltpu.VMEM((1, c_blk), jnp.int32),      # last_t
            pltpu.VMEM((1, c_blk), jnp.int32),      # done
            pltpu.VMEM((1, c_blk), jnp.int32),      # n_nodes
            pltpu.VMEM((k, c_blk), jnp.int32),      # nodes
            pltpu.VMEM((limbs, c_blk), jnp.int32),  # code
        ],
        interpret=interpret,
    )(t2, u2, v2, t2, valid2)

    return code.T[:e], length[0, :e]
