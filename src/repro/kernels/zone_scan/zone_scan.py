"""Pallas kernels for PTMT Phase-1 zone expansion.

Two kernels share one edge-update rule (:func:`_edge_update` — the single
copy of the paper's Definition 2-5 transition semantics in Pallas land):

**Dense per-zone kernel** (:func:`zone_scan_pallas`) — the seed layout.

  Layout (all VMEM, lanes = candidates):

    grid = (n_cand_blocks, n_edge_blocks)   # both sequential on TPU
    scratch: candidate SoA for ONE candidate block —
        length/last_t/done/n_nodes  int32[1, C_BLK]
        nodes                       int32[K, C_BLK]   K = l_max + 1
        code                        int32[L, C_BLK]   L = n_limbs(l_max)
    inputs per cell: one edge block (u, v, t, valid as int32[1, E_BLK])
        plus the candidate block's seed times t_cand[1, C_BLK]
    outputs per candidate block: code int32[L, C_BLK], length int32[1, C_BLK]

  With the candidate axis OUTER, each candidate block streams the whole
  edge stream once and is flushed exactly once; scratch is a single block
  (~(K+L+4) * C_BLK * 4 bytes ≈ 50 KB at C_BLK=1024, l_max=6 — far under
  VMEM).  It is mined per zone (``vmap`` over a padded [Z, e_cap] batch),
  so a multi-bucket :class:`~repro.core.tzp.ZoneBatchLayout` costs one
  launch *per bucket*.

**Fused bucket-native kernel** (:func:`fused_zone_scan_flat`) — a single
launch whose 1-D grid spans *every* bucket of a layout at once.  The host
concatenates all buckets' padded zone rows into one flat slot stream
(``repro.core.tzp.concat_layout``); candidate blocks of ``blk`` lanes tile
the stream, and a per-block descriptor (``hi``) bounds each block's sweep
to the flat span of the zones its lanes belong to.  Blocks may straddle
zones and buckets: a per-slot ``zone_id`` gates every extension/seed/
time-out to same-zone edges, so inert padding rows and foreign zones are
masked rather than aligned away.  Candidate state lives in a pure
``fori_loop`` carry (no cross-grid-step scratch), which keeps the kernel
portable across the interpreter, Triton (GPU), and Mosaic.

**Live-window block skipping** (beyond-paper, both kernels' key
optimization): a (candidate-block x edge-chunk) cell is skipped when

  * every edge index in the chunk precedes every candidate in the block
    (those candidates are not yet seeded: extensions need edge_idx > seed
    — the fused kernel gets this for free by starting each block's sweep
    at its own base), or
  * the chunk's earliest timestamp exceeds the block's last seed time by
    more than ``l_max * delta`` (every candidate's lifetime is over —
    Lemma 4.1's span bound).  The dense kernel reads the chunk's first
    timestamp (edges are time-sorted within a zone); the fused kernel
    reduces a masked min over the chunk, which stays conservative even
    where the concatenated stream is not globally time-sorted.

Edges are time-sorted within each zone, so a candidate is live for
~``1/omega`` of its zone and skipping turns the dense O(E^2) sweep into
O(E^2 / omega) — measured in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import encoding
from repro.kernels.common import resolve_interpret

DIGITS_PER_LIMB = encoding.DIGITS_PER_LIMB

_I32_MIN = jnp.iinfo(jnp.int32).min
_I32_MAX = jnp.iinfo(jnp.int32).max


def _edge_update(state, *, u, v, t, seed, gate, delta, l_max, iota_k,
                 li_iota, iota_l=None):
    """Apply one edge to a candidate block's expansion state.

    The single copy of the Phase-1 transition rule shared by the dense and
    fused kernels.  ``state`` is ``(length, last_t, done, n_nodes, nodes,
    code)`` — int32 arrays of shape [1, C] (nodes [K, C], code [L, C]) —
    plus a trailing ``ts`` [l_max, C] absorption-timestamp block when
    ``iota_l`` (an int32[l_max, C] step iota) is given.

    Args:
      u, v, t: this edge's scalars (int32).
      seed: bool[1, C] — lanes seeded by this edge (its own slot; already
        gated on the edge being valid).
      gate: bool — per-lane eligibility of this edge for extension and
        time-out (edge validity, and for the fused kernel same-zone
        membership).  Scalar or [1, C]; broadcasting handles both.
      iota_l: step iota enabling per-step timestamp tracking (the config-
        lattice co-mining input); None keeps the 6-element state.
    """
    if iota_l is None:
        length, last_t, done, n_nodes, nodes, code = state
        ts = None
    else:
        length, last_t, done, n_nodes, nodes, code, ts = state
    k = iota_k.shape[0]

    active = (length > 0) & ~done
    gap_ok = (t > last_t) & (t - last_t <= delta)
    timed_out = active & (t - last_t > delta) & gate

    u_hit = nodes == u
    v_hit = nodes == v
    u_in = u_hit.any(axis=0, keepdims=True)
    v_in = v_hit.any(axis=0, keepdims=True)
    extend = (
        active & ~timed_out & gap_ok & (length < l_max)
        & (u_in | v_in) & gate
    )

    u_pos = jnp.min(jnp.where(u_hit, iota_k, k), axis=0, keepdims=True)
    v_pos = jnp.min(jnp.where(v_hit, iota_k, k), axis=0, keepdims=True)
    label_u = jnp.where(u_in, u_pos, n_nodes)
    nn1 = n_nodes + (~u_in).astype(jnp.int32)
    same_uv = u == v
    label_v = jnp.where(same_uv, label_u,
                        jnp.where(v_in, v_pos, nn1))
    nn2 = jnp.where(same_uv, nn1, nn1 + (~v_in).astype(jnp.int32))

    put_u = extend & ~u_in
    put_v = extend & ~v_in & ~same_uv
    nodes = jnp.where(put_u & (iota_k == n_nodes), u, nodes)
    nodes = jnp.where(put_v & (iota_k == nn1), v, nodes)

    # append the two digits (label+1) at positions 2*len, 2*len+1
    for which, label in ((0, label_u), (1, label_v)):
        pos = 2 * length + which
        limb_idx = pos // DIGITS_PER_LIMB
        shift = 4 * (DIGITS_PER_LIMB - 1 - pos % DIGITS_PER_LIMB)
        add = jnp.where(extend, jnp.left_shift(label + 1, shift), 0)
        code = code + jnp.where(li_iota == limb_idx, add, 0)

    new_length = length + extend.astype(jnp.int32)
    new_last_t = jnp.where(extend, t, last_t)
    new_nn = jnp.where(extend, nn2, n_nodes)

    # seed the candidate owned by this edge
    new_length = jnp.where(seed, 1, new_length)
    new_last_t = jnp.where(seed, t, new_last_t)
    new_nn = jnp.where(seed, jnp.where(same_uv, 1, 2), new_nn)
    nodes = jnp.where(seed & (iota_k == 0), u, nodes)
    nodes = jnp.where(seed & (iota_k == 1) & ~same_uv, v, nodes)
    seed_digit0 = 1 << (4 * (DIGITS_PER_LIMB - 1))
    seed_digit1 = jnp.where(same_uv, 1, 2) << (4 * (DIGITS_PER_LIMB - 2))
    seed_code = jnp.where(li_iota == 0, seed_digit0 + seed_digit1, 0)
    code = jnp.where(seed, seed_code, code)

    out = (new_length, new_last_t, done | timed_out, new_nn, nodes, code)
    if ts is None:
        return out
    # record this edge's timestamp at the step it was absorbed: row
    # `length` (pre-increment) for an extension, row 0 for a seed
    ts = jnp.where(extend & (iota_l == length), t, ts)
    ts = jnp.where(seed & (iota_l == 0), t, ts)
    return out + (ts,)


def _kernel(
    t_cand_ref, u_ref, v_ref, t_ref, valid_ref, *refs,
    delta: int, l_max: int, c_blk: int, e_blk: int, n_e_blocks: int,
    with_ts: bool,
):
    if with_ts:
        (code_out_ref, len_out_ref, ts_out_ref,
         length_ref, last_t_ref, done_ref, nn_ref, nodes_ref, code_ref,
         ts_ref) = refs
    else:
        (code_out_ref, len_out_ref,
         length_ref, last_t_ref, done_ref, nn_ref, nodes_ref,
         code_ref) = refs
        ts_out_ref = ts_ref = None
    ci = pl.program_id(0)
    ei = pl.program_id(1)
    k = l_max + 1
    limbs = code_ref.shape[0]

    @pl.when(ei == 0)
    def _init():
        length_ref[...] = jnp.zeros_like(length_ref)
        last_t_ref[...] = jnp.zeros_like(last_t_ref)
        done_ref[...] = jnp.zeros_like(done_ref)
        nn_ref[...] = jnp.zeros_like(nn_ref)
        nodes_ref[...] = jnp.full_like(nodes_ref, -1)
        code_ref[...] = jnp.zeros_like(code_ref)
        if ts_ref is not None:
            ts_ref[...] = jnp.zeros_like(ts_ref)

    c_base = ci * c_blk
    e_base = ei * e_blk
    # skip tests (see module docstring)
    index_live = e_base + e_blk - 1 >= c_base
    time_live = t_ref[0, 0] <= t_cand_ref[0, c_blk - 1] + l_max * delta

    @pl.when(index_live & time_live)
    def _sweep():
        iota_c = jax.lax.broadcasted_iota(jnp.int32, (1, c_blk), 1) + c_base
        iota_k = jax.lax.broadcasted_iota(jnp.int32, (k, c_blk), 0)
        li_iota = jax.lax.broadcasted_iota(jnp.int32, (limbs, c_blk), 0)
        iota_l = (jax.lax.broadcasted_iota(jnp.int32, (l_max, c_blk), 0)
                  if with_ts else None)

        def body(j, _):
            u = u_ref[0, j]
            v = v_ref[0, j]
            t = t_ref[0, j]
            valid = valid_ref[0, j] != 0

            state = (
                length_ref[...], last_t_ref[...], done_ref[...] != 0,
                nn_ref[...], nodes_ref[...], code_ref[...],
            )
            if with_ts:
                state = state + (ts_ref[...],)
            out = _edge_update(
                state, u=u, v=v, t=t,
                seed=(iota_c == e_base + j) & valid, gate=valid,
                delta=delta, l_max=l_max, iota_k=iota_k, li_iota=li_iota,
                iota_l=iota_l,
            )
            length, last_t, done, nn, nodes, code = out[:6]
            length_ref[...] = length
            last_t_ref[...] = last_t
            done_ref[...] = done.astype(jnp.int32)
            nn_ref[...] = nn
            nodes_ref[...] = nodes
            code_ref[...] = code
            if with_ts:
                ts_ref[...] = out[6]
            return 0

        jax.lax.fori_loop(0, e_blk, body, 0)

    @pl.when(ei == n_e_blocks - 1)
    def _flush():
        code_out_ref[...] = code_ref[...]
        len_out_ref[...] = length_ref[...]
        if ts_out_ref is not None:
            ts_out_ref[...] = ts_ref[...]


def zone_scan_pallas(
    u, v, t, valid, *, delta: int, l_max: int,
    c_blk: int = 512, e_blk: int = 256, interpret: bool | None = None,
    with_ts: bool = False,
):
    """Run the Pallas zone-scan over one padded zone.

    Args:
      u, v, t: int32[E]; valid: bool[E].  E is padded up to block multiples.
      with_ts: also return per-step absorption timestamps int32[E, l_max]
        (the config-lattice co-mining input).
    Returns:
      (code int32[E, L], length int32[E]) per seed candidate, plus
      ts int32[E, l_max] when ``with_ts``.
    """
    interpret = resolve_interpret(interpret)
    e = u.shape[0]
    limbs = encoding.n_limbs(l_max)
    k = l_max + 1

    blk = max(c_blk, e_blk)
    e_pad = -(-e // blk) * blk
    pad = e_pad - e
    valid_i = valid.astype(jnp.int32)
    if pad:
        u = jnp.pad(u, (0, pad))
        v = jnp.pad(v, (0, pad))
        t = jnp.pad(t, (0, pad))
        valid_i = jnp.pad(valid_i, (0, pad))
    # normalize padding timestamps (invalid slots) to the max valid time so
    # block skipping stays conservative; padded edges are semantically inert.
    t_fill = jnp.max(jnp.where(valid_i != 0, t, _I32_MIN))
    t = jnp.where(valid_i != 0, t, t_fill)

    n_c_blocks = e_pad // c_blk
    n_e_blocks = e_pad // e_blk
    row = lambda x: x.reshape(1, e_pad)
    u2, v2, t2, valid2 = row(u), row(v), row(t), row(valid_i)

    kernel = functools.partial(
        _kernel, delta=delta, l_max=l_max, c_blk=c_blk, e_blk=e_blk,
        n_e_blocks=n_e_blocks, with_ts=with_ts,
    )
    out_specs = [
        pl.BlockSpec((limbs, c_blk), lambda ci, ei: (0, ci)),
        pl.BlockSpec((1, c_blk), lambda ci, ei: (0, ci)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((limbs, e_pad), jnp.int32),
        jax.ShapeDtypeStruct((1, e_pad), jnp.int32),
    ]
    scratch_shapes = [
        pltpu.VMEM((1, c_blk), jnp.int32),      # length
        pltpu.VMEM((1, c_blk), jnp.int32),      # last_t
        pltpu.VMEM((1, c_blk), jnp.int32),      # done
        pltpu.VMEM((1, c_blk), jnp.int32),      # n_nodes
        pltpu.VMEM((k, c_blk), jnp.int32),      # nodes
        pltpu.VMEM((limbs, c_blk), jnp.int32),  # code
    ]
    if with_ts:
        out_specs.append(
            pl.BlockSpec((l_max, c_blk), lambda ci, ei: (0, ci)))
        out_shape.append(jax.ShapeDtypeStruct((l_max, e_pad), jnp.int32))
        scratch_shapes.append(pltpu.VMEM((l_max, c_blk), jnp.int32))  # ts
    outs = pl.pallas_call(
        kernel,
        grid=(n_c_blocks, n_e_blocks),
        in_specs=[
            pl.BlockSpec((1, c_blk), lambda ci, ei: (0, ci)),   # t_cand
            pl.BlockSpec((1, e_blk), lambda ci, ei: (0, ei)),   # u
            pl.BlockSpec((1, e_blk), lambda ci, ei: (0, ei)),   # v
            pl.BlockSpec((1, e_blk), lambda ci, ei: (0, ei)),   # t
            pl.BlockSpec((1, e_blk), lambda ci, ei: (0, ei)),   # valid
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch_shapes,
        interpret=interpret,
    )(t2, u2, v2, t2, valid2)

    code, length = outs[0], outs[1]
    if with_ts:
        return code.T[:e], length[0, :e], outs[2].T[:e]
    return code.T[:e], length[0, :e]


# ---------------------------------------------------------------------------
# Fused bucket-native kernel: one launch over a concatenated ragged layout.
# ---------------------------------------------------------------------------


def _fused_kernel(
    lo_ref, hi_ref, u_ref, v_ref, t_ref, valid_ref, zid_ref,
    lane_t_ref, lane_valid_ref, lane_zid_ref,
    code_out_ref, len_out_ref, *maybe_ts_out_ref,
    delta: int, l_max: int, blk: int, with_ts: bool,
):
    """One candidate block of the concatenated flat slot stream.

    Grid is 1-D over candidate blocks; the flat edge arrays arrive whole
    (constant index map) and are chunk-loaded with dynamic slices, so the
    host-planned sweep span ``[lo, hi)`` can differ per block — that is
    what makes the ragged layout a *single* launch.  ``lo`` is the block's
    own base for live blocks (the sweep must pass over each lane's own
    slot to seed it) and equals ``hi`` for dead blocks (no valid lanes:
    zero chunks, outputs stay the zero init).  Candidate state is a pure
    ``fori_loop`` carry: no scratch persists across grid steps, so the
    kernel has no sequential-grid requirement.
    """
    i = pl.program_id(0)
    base = i * blk
    limbs = code_out_ref.shape[0]
    k = l_max + 1

    lo = lo_ref[0, 0]                       # blk-aligned sweep start
    hi = hi_ref[0, 0]                       # blk-aligned sweep end
    lane_t = lane_t_ref[...]                # [1, blk] seed times
    lane_valid = lane_valid_ref[...] != 0
    lane_zid = lane_zid_ref[...]
    iota_lane = jax.lax.broadcasted_iota(jnp.int32, (1, blk), 1) + base
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (k, blk), 0)
    li_iota = jax.lax.broadcasted_iota(jnp.int32, (limbs, blk), 0)
    iota_l = (jax.lax.broadcasted_iota(jnp.int32, (l_max, blk), 0)
              if with_ts else None)

    # latest seed time among this block's real lanes: the Lemma-4.1 horizon
    t_seed_max = jnp.max(jnp.where(lane_valid, lane_t, _I32_MIN))

    state0 = (
        jnp.zeros((1, blk), jnp.int32),            # length
        jnp.zeros((1, blk), jnp.int32),            # last_t
        jnp.zeros((1, blk), bool),                 # done
        jnp.zeros((1, blk), jnp.int32),            # n_nodes
        jnp.full((k, blk), -1, jnp.int32),         # nodes
        jnp.zeros((limbs, blk), jnp.int32),        # code
    )
    if with_ts:
        state0 = state0 + (jnp.zeros((l_max, blk), jnp.int32),)  # ts

    def chunk_body(ci, state):
        off = lo + ci * blk
        cu = u_ref[0, pl.ds(off, blk)]
        cv = v_ref[0, pl.ds(off, blk)]
        ct = t_ref[0, pl.ds(off, blk)]
        cvalid = valid_ref[0, pl.ds(off, blk)]
        czid = zid_ref[0, pl.ds(off, blk)]

        # time skip: every valid edge in the chunk is beyond the horizon.
        # A masked min stays conservative on the (not globally time-sorted)
        # concatenated stream; the first chunk contains the lanes
        # themselves, so min <= t_seed_max there and seeds are never lost.
        min_t = jnp.min(jnp.where(cvalid != 0, ct, _I32_MAX))
        live = min_t <= t_seed_max + l_max * delta

        def sweep(st):
            def body(j, s):
                u = cu[j]
                v = cv[j]
                t = ct[j]
                evalid = cvalid[j] != 0
                return _edge_update(
                    s, u=u, v=v, t=t,
                    seed=(iota_lane == off + j) & evalid,
                    gate=evalid & (czid[j] == lane_zid),
                    delta=delta, l_max=l_max, iota_k=iota_k,
                    li_iota=li_iota, iota_l=iota_l,
                )
            return jax.lax.fori_loop(0, blk, body, st)

        return jax.lax.cond(live, sweep, lambda s: s, state)

    # index skip is structural: the sweep starts at this block's own base
    # (edges before a candidate's seed slot can never extend it — within a
    # zone they are not strictly later in time), and ends at the host-
    # planned ``hi`` (zone end, or the Lemma-4.1 horizon cut when the
    # layout carries compacted bounds).  Dead blocks have lo == hi.
    n_chunks = (hi - lo) // blk
    state = jax.lax.fori_loop(0, n_chunks, chunk_body, state0)
    code_out_ref[...] = state[5]
    len_out_ref[...] = state[0]
    if with_ts:
        maybe_ts_out_ref[0][...] = state[6]


def fused_zone_scan_flat(
    u, v, t, valid, zone_id, lo, hi, *, delta: int, l_max: int,
    blk: int = 512, interpret: bool | None = None, with_ts: bool = False,
):
    """Single-launch ragged zone scan over a concatenated flat slot stream.

    Args:
      u, v, t: int32[S] flat edge slots — every bucket's padded [Z_b,
        e_cap_b] rows flattened and concatenated (see
        ``repro.core.tzp.concat_layout``).  S must be a multiple of
        ``blk``.
      valid: int32/bool[S] — real-edge mask (padding slots are 0).
      zone_id: int32[S] — owning zone row per slot (-1 for stream pad);
        gates extensions/seeds/time-outs to same-zone edges.
      lo, hi: int32[S // blk] — per candidate block, the blk-aligned
        host-planned sweep window ``[lo, hi)``: ``lo`` is the block's own
        base (``lo == hi`` for dead blocks), ``hi`` one past the last
        slot that can still affect any lane — the end of the last zone a
        lane belongs to, optionally tightened to the Lemma-4.1 time
        horizon (``bounds="live"`` in ``concat_layout``).

    Returns:
      (code int32[S, L], length int32[S]) per seed candidate slot, plus
      ts int32[S, l_max] absorption timestamps when ``with_ts``.
    """
    interpret = resolve_interpret(interpret)
    s_pad = u.shape[0]
    if s_pad % blk:
        raise ValueError(
            f"flat slot count {s_pad} is not a multiple of blk {blk}")
    n_blocks = s_pad // blk
    if lo.shape[0] != n_blocks or hi.shape[0] != n_blocks:
        raise ValueError(
            f"descriptors (lo: {lo.shape[0]}, hi: {hi.shape[0]}) do not "
            f"match {n_blocks} candidate blocks")
    limbs = encoding.n_limbs(l_max)

    valid_i = valid.astype(jnp.int32)
    row = lambda x: x.reshape(1, s_pad)
    u2, v2, t2 = row(u), row(v), row(t)
    valid2, zid2 = row(valid_i), row(zone_id)
    lo2 = lo.reshape(1, n_blocks)
    hi2 = hi.reshape(1, n_blocks)

    whole = lambda shape: pl.BlockSpec(shape, lambda i: (0, 0))
    per_block = lambda rows: pl.BlockSpec((rows, blk), lambda i: (0, i))

    kernel = functools.partial(
        _fused_kernel, delta=delta, l_max=l_max, blk=blk, with_ts=with_ts,
    )
    out_specs = [per_block(limbs), per_block(1)]
    out_shape = [
        jax.ShapeDtypeStruct((limbs, s_pad), jnp.int32),
        jax.ShapeDtypeStruct((1, s_pad), jnp.int32),
    ]
    if with_ts:
        out_specs.append(per_block(l_max))
        out_shape.append(jax.ShapeDtypeStruct((l_max, s_pad), jnp.int32))
    outs = pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, i)),     # lo descriptor
            pl.BlockSpec((1, 1), lambda i: (0, i)),     # hi descriptor
            whole((1, s_pad)),                          # u (full stream)
            whole((1, s_pad)),                          # v
            whole((1, s_pad)),                          # t
            whole((1, s_pad)),                          # valid
            whole((1, s_pad)),                          # zone_id
            per_block(1),                               # lane seed times
            per_block(1),                               # lane validity
            per_block(1),                               # lane zone ids
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(lo2, hi2, u2, v2, t2, valid2, zid2, t2, valid2, zid2)

    code, length = outs[0], outs[1]
    if with_ts:
        return code.T, length[0], outs[2].T
    return code.T, length[0]
