"""Pure-jnp oracle for the zone-scan kernel.

The reference implementation lives in ``repro.core.expansion`` (it *is* the
paper's Phase-1 semantics and is validated against the brute-force Python
oracle in tests).  Kernel tests compare the Pallas kernel against this —
including the fused flat-stream kernel, via :func:`scan_flat_ref`.
"""

import numpy as np

from repro.core.expansion import ZoneResult, scan_zone, scan_zones

__all__ = ["ZoneResult", "scan_flat_ref", "scan_zone", "scan_zones"]


def scan_flat_ref(u, v, t, valid, zone_id, *, delta: int, l_max: int,
                  with_ts: bool = False):
    """Oracle for ``fused_zone_scan_flat``: reassemble each zone from the
    concatenated slot stream (slots of a zone are contiguous and
    time-ordered) and run the per-zone reference scan, scattering results
    back to flat slot positions.  Pad slots (``zone_id < 0``) keep
    length 0."""
    u, v, t = (np.asarray(a, np.int32) for a in (u, v, t))
    valid = np.asarray(valid) != 0
    zone_id = np.asarray(zone_id, np.int32)
    s = u.shape[0]
    code = None
    length = np.zeros(s, np.int32)
    ts = np.zeros((s, l_max), np.int32) if with_ts else None
    for z in np.unique(zone_id[zone_id >= 0]):
        idx = np.flatnonzero(zone_id == z)
        res = scan_zone(u[idx], v[idx], t[idx], valid[idx],
                        delta=delta, l_max=l_max, with_ts=with_ts)
        if code is None:
            code = np.zeros((s, res.code.shape[1]), np.int32)
        code[idx] = np.asarray(res.code)
        length[idx] = np.asarray(res.length)
        if ts is not None:
            ts[idx] = np.asarray(res.ts)
    if code is None:
        from repro.core import encoding

        code = np.zeros((s, encoding.n_limbs(l_max)), np.int32)
    return ZoneResult(code=code, length=length, ts=ts)
