"""Pure-jnp oracle for the zone-scan kernel.

The reference implementation lives in ``repro.core.expansion`` (it *is* the
paper's Phase-1 semantics and is validated against the brute-force Python
oracle in tests).  Kernel tests compare the Pallas kernel against this.
"""

from repro.core.expansion import ZoneResult, scan_zone, scan_zones

__all__ = ["ZoneResult", "scan_zone", "scan_zones"]
