"""Compiled XLA lowering of the fused flat zone scan.

The third lowering of the Phase-1 transition rule — :func:`_edge_update`
in :mod:`.zone_scan` stays the single copy of Definition 2-5 semantics,
now shared by the dense Pallas kernel, the fused Pallas kernel, and this
pure ``lax``-formulation.  It exists because every CPU CI box (and any
host without a Triton/Mosaic lowering) previously ran the fused kernel
through the Pallas *interpreter*, which is orders of magnitude slower
than what XLA compiles from the same arithmetic.  The "xla" backend in
:mod:`repro.core.backends` resolves its ``fused_loader`` here, and the
executor's fused auto-dispatch prefers it over interpret-mode Pallas.

The loop structure deliberately differs from the Pallas kernel's
block-grid.  Sweeping each ``blk``-lane block over its whole ``[lo, hi)``
window (the Pallas shape — VMEM-resident state, chunk-level skipping) is
the wrong shape for XLA on CPU: a lane can only be extended by later
slots of its OWN zone row, so a block window spanning many rows makes
every lane re-inspect every cohabiting row's edges, and the per-op
dispatch of a narrow sequential formulation eats whatever the chunk skip
saves (measured: barely faster than the interpreter).  Instead:

* each lane's row window ``[row_start, win_end)`` is derived ONCE from
  the sorted ``zone_id`` stream (a ``cummax`` for row starts, a reverse
  ``cummin`` for row ends — O(S) total), and ``win_end`` is clipped by
  the lane's block descriptor ``hi`` so the host-planned live bounds
  (Lemma 4.1 horizon cuts, ``bounds="live"``) directly shrink the trip
  count; edges past the cut could only set ``done``, which never feeds
  the outputs, so the clip is output-exact;
* lanes are processed in **cache-sized segments** (``lax.map`` —
  sequential, so one segment's state stays L2-resident instead of
  streaming the whole ``[rows, S]`` state through memory every step);
* within a segment every lane advances through its own row in
  **lockstep**: step ``j`` applies slot ``row_start + j`` of each lane's
  row as one wide ``_edge_update`` over the segment (per-lane edge
  vectors broadcast through the rule exactly like the Pallas kernels'
  scalars), for ``max(win_end - row_start)`` steps — the longest LIVE
  row in the segment, not the stream length.  The bucketed layout
  orders rows by capacity, so short-row segments take few steps instead
  of being padded to the global maximum.

The function is traceable; the executor jits it together with the
on-device Phase-2 fold (``_mine_fused_jit``), so the compiled path has
the identical launch/fold structure as the Pallas path — one executable,
only the bounded count table leaving the device.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import encoding
from repro.kernels.common import note_trace

from .zone_scan import _edge_update

#: target lanes per segment — state is ~(18..23) int32 rows x width, so
#: 4096 lanes keep a segment's working set under ~0.5 MB (comfortably
#: L2-resident; measured faster than 8192 on the 40k-edge sweep)
_SEG_TARGET = 4096


def _segment_width(n_blocks: int, blk: int) -> int:
    """Largest ``blk`` multiple that divides the stream and fits cache."""
    best = 1
    for c in range(1, n_blocks + 1):
        if n_blocks % c == 0 and c * blk <= max(_SEG_TARGET, blk):
            best = c
    return best * blk


def fused_zone_scan_xla(
    u, v, t, valid, zone_id, lo, hi, *, delta: int, l_max: int,
    blk: int = 512, with_ts: bool = False,
):
    """Compiled single-launch ragged zone scan (same contract as
    :func:`.zone_scan.fused_zone_scan_flat`, minus ``interpret``).

    Args and returns are identical to the Pallas fused kernel: flat
    ``int32[S]`` slot streams plus per-block ``[lo, hi)`` descriptors in,
    ``(code int32[S, L], length int32[S][, ts int32[S, l_max]])`` out.
    """
    s_pad = u.shape[0]
    if s_pad % blk:
        raise ValueError(
            f"flat slot count {s_pad} is not a multiple of blk {blk}")
    n_blocks = s_pad // blk
    if lo.shape[0] != n_blocks or hi.shape[0] != n_blocks:
        raise ValueError(
            f"descriptors (lo: {lo.shape[0]}, hi: {hi.shape[0]}) do not "
            f"match {n_blocks} candidate blocks")
    limbs = encoding.n_limbs(l_max)
    k = l_max + 1

    u_f = u.astype(jnp.int32)
    v_f = v.astype(jnp.int32)
    t_f = t.astype(jnp.int32)
    valid_f = valid.astype(jnp.int32)
    zid_f = zone_id.astype(jnp.int32)
    hi_b = hi.astype(jnp.int32)

    # per-lane row windows from the sorted zone_id stream: row_start via
    # cummax over start markers, row_end as the next row's start via a
    # reverse cummin; hi (blk-rounded >= every lane's horizon cut under
    # "live", >= every row end under "full") clips the sweep
    iota_s = jnp.arange(s_pad, dtype=jnp.int32)
    is_start = jnp.concatenate([
        jnp.ones(1, bool), zid_f[1:] != zid_f[:-1]])
    row_start = jax.lax.cummax(jnp.where(is_start, iota_s, 0))
    start_or_end = jnp.where(is_start, iota_s, s_pad)
    row_end = jnp.concatenate([
        jax.lax.cummin(start_or_end, reverse=True)[1:],
        jnp.full(1, s_pad, jnp.int32)])
    win_end = jnp.minimum(row_end, hi_b[iota_s // blk])

    seg = _segment_width(n_blocks, blk)
    n_seg = s_pad // seg
    per_seg = lambda x: x.reshape(n_seg, seg)

    iota_lane = jax.lax.broadcasted_iota(jnp.int32, (1, seg), 1)
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (k, seg), 0)
    li_iota = jax.lax.broadcasted_iota(jnp.int32, (limbs, seg), 0)
    iota_l = (jax.lax.broadcasted_iota(jnp.int32, (l_max, seg), 0)
              if with_ts else None)
    last_slot = jnp.int32(s_pad - 1)

    def segment_fn(args):
        base, l_zid, l_valid, l_rs, l_we = args
        lane_idx = (iota_lane + base).reshape(1, seg)
        rs = l_rs.reshape(1, seg)
        we = l_we.reshape(1, seg)
        zid_lane = l_zid.reshape(1, seg)

        state0 = (
            jnp.zeros((1, seg), jnp.int32),            # length
            jnp.zeros((1, seg), jnp.int32),            # last_t
            jnp.zeros((1, seg), bool),                 # done
            jnp.zeros((1, seg), jnp.int32),            # n_nodes
            jnp.full((k, seg), -1, jnp.int32),         # nodes
            jnp.zeros((limbs, seg), jnp.int32),        # code
        )
        if with_ts:
            state0 = state0 + (jnp.zeros((l_max, seg), jnp.int32),)  # ts

        def body(j, s):
            eidx = rs + j                              # [1, seg] per lane
            in_win = eidx < we
            safe = jnp.minimum(eidx, last_slot)[0]
            evalid = in_win & (valid_f[safe] != 0)
            return _edge_update(
                s, u=u_f[safe], v=v_f[safe], t=t_f[safe],
                seed=(lane_idx == eidx) & evalid,
                gate=evalid & (zid_f[safe] == zid_lane),
                delta=delta, l_max=l_max, iota_k=iota_k,
                li_iota=li_iota, iota_l=iota_l,
            )

        # only lanes that can seed (their own slot is valid) drive the
        # lockstep trip — pad rows would otherwise stretch it for pure
        # no-op steps
        trip = jnp.max(jnp.where(l_valid != 0,
                                 jnp.maximum(l_we - l_rs, 0), 0))
        state = jax.lax.fori_loop(0, trip, body, state0)
        out = (state[5], state[0])                      # code, length
        if with_ts:
            out = out + (state[6],)
        return out

    bases = jnp.arange(n_seg, dtype=jnp.int32) * seg
    outs = jax.lax.map(segment_fn, (
        bases, per_seg(zid_f), per_seg(valid_f), per_seg(row_start),
        per_seg(win_end),
    ))
    code = outs[0].transpose(0, 2, 1).reshape(s_pad, limbs)
    length = outs[1].reshape(s_pad)
    if with_ts:
        return code, length, outs[2].transpose(0, 2, 1).reshape(s_pad, l_max)
    return code, length


def scan_flat_xla(
    u, v, t, valid, zone_id, lo, hi, *, delta: int, l_max: int,
    blk: int = 512, with_ts: bool = False,
):
    """The "xla" registry entry's ``fused_loader`` target.

    Traceable (the executor jits it together with the on-device Phase-2
    fold); same return contract as the Pallas ``ops.scan_flat``.
    """
    note_trace("zone_scan_flat_xla")
    return fused_zone_scan_xla(
        u, v, t, valid, zone_id, lo, hi, delta=delta, l_max=l_max, blk=blk,
        with_ts=with_ts,
    )
