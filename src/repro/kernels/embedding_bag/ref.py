"""Pure-jnp oracle for the embedding-bag kernel."""

import jax.numpy as jnp


def embedding_bag(table, ids, weights):
    """Weighted sum-bag lookup.

    table: [V, D]; ids: int32[B, K]; weights: f32[B, K] -> [B, D].
    (JAX has no native EmbeddingBag — gather + weighted reduce is the
    reference semantics, matching ``torch.nn.EmbeddingBag(mode='sum')``
    with per-sample weights.)
    """
    rows = jnp.take(table, ids, axis=0)              # [B, K, D]
    return jnp.einsum("bkd,bk->bd", rows, weights.astype(rows.dtype))
