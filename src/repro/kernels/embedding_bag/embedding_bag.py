"""Pallas TPU kernel: embedding-bag (gather + weighted segment reduce).

The table stays in HBM/ANY memory (it is far larger than VMEM); each grid
cell handles one batch block, issuing per-id dynamic row loads and
accumulating ``w * row`` into a VMEM accumulator.  On real TPU hardware the
row loads lower to dynamic-slice DMAs; production kernels double-buffer them
(FBGEMM-TBE style) — the single-buffer form here keeps the reference simple
and is what we validate in interpret mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import resolve_interpret


def _kernel(ids_ref, w_ref, table_ref, out_ref, *, b_blk, bag):
    def body(i, _):
        b = i // bag
        k = i % bag
        idx = ids_ref[b, k]
        w = w_ref[b, k]
        row = pl.load(table_ref, (pl.dslice(idx, 1), slice(None)))
        cur = pl.load(out_ref, (pl.dslice(b, 1), slice(None)))
        pl.store(out_ref, (pl.dslice(b, 1), slice(None)),
                 cur + w * row.astype(jnp.float32))
        return 0

    out_ref[...] = jnp.zeros_like(out_ref)
    jax.lax.fori_loop(0, b_blk * bag, body, 0)


def embedding_bag_pallas(
    table, ids, weights, *, b_blk: int = 64, interpret: bool | None = None,
):
    """table [V, D], ids [B, K], weights [B, K] -> [B, D]."""
    interpret = resolve_interpret(interpret)
    b, bag = ids.shape
    v, d = table.shape
    b_pad = -(-b // b_blk) * b_blk
    if b_pad != b:
        ids = jnp.pad(ids, ((0, b_pad - b), (0, 0)))
        weights = jnp.pad(weights, ((0, b_pad - b), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, b_blk=b_blk, bag=bag),
        grid=(b_pad // b_blk,),
        in_specs=[
            pl.BlockSpec((b_blk, bag), lambda i: (i, 0)),
            pl.BlockSpec((b_blk, bag), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.MemorySpace.ANY),   # the table
        ],
        out_specs=pl.BlockSpec((b_blk, d), lambda i: (i, 0)),
        # fp32 accumulation regardless of table dtype
        out_shape=jax.ShapeDtypeStruct((b_pad, d), jnp.float32),
        interpret=interpret,
    )(ids, weights, table)
    return out[:b].astype(table.dtype)
