"""Jit'd wrapper for the Pallas embedding-bag kernel."""

from __future__ import annotations

import functools

import jax

from .embedding_bag import embedding_bag_pallas


@functools.partial(jax.jit, static_argnames=("b_blk", "interpret"))
def embedding_bag(table, ids, weights, *, b_blk: int = 64,
                  interpret: bool | None = None):
    return embedding_bag_pallas(
        table, ids, weights, b_blk=b_blk, interpret=interpret
    )
