"""Jit'd wrapper: unsorted scatter-sum via sort + the sorted Pallas kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .segment_spmm import scatter_sum_sorted_pallas


@functools.partial(
    jax.jit, static_argnames=("num_segments", "n_blk", "e_blk", "interpret")
)
def scatter_sum(
    values, segment_ids, num_segments: int, mask=None, *,
    n_blk: int = 128, e_blk: int = 256, interpret: bool | None = None,
):
    """Drop-in for ``jax.ops.segment_sum`` over 2-D values (+ mask)."""
    if mask is not None:
        values = jnp.where(mask[:, None], values, 0.0)
        segment_ids = jnp.where(mask, segment_ids, num_segments)
    order = jnp.argsort(segment_ids)
    return scatter_sum_sorted_pallas(
        jnp.take(values, order, axis=0),
        jnp.take(segment_ids, order),
        num_segments, n_blk=n_blk, e_blk=e_blk, interpret=interpret,
    )
