"""Pallas TPU kernel: sorted-segment scatter-sum as one-hot MXU matmuls.

The GNN aggregation ``out[dst] += msg`` is irregular; the TPU-native
formulation regularizes it:

  1. (wrapper) sort messages by destination — sorted order makes each output
     node block touch a *contiguous* edge range;
  2. grid = (node_blocks, edge_blocks), node-outer.  Each cell builds the
     one-hot matrix ``onehot[b, e] = (dst[e] == node_base + b)`` and issues
     ``acc += onehot @ values`` — an MXU matmul instead of a scatter;
  3. off-diagonal cells (edge block's dst range disjoint from the node
     block) are skipped via block-boundary tests on the sorted dst array —
     the same live-window trick as kernels/zone_scan, leaving O(E/B) cells.

The output block stays resident in VMEM across the inner edge loop and is
flushed once per node block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import resolve_interpret


def _kernel(dst_ref, values_ref, out_ref, *, n_blk, e_blk, n_e_blocks):
    ni = pl.program_id(0)
    ei = pl.program_id(1)
    node_base = ni * n_blk

    @pl.when(ei == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # skip: sorted dst => edge block range [first, last]
    first = dst_ref[0, 0]
    last = dst_ref[0, e_blk - 1]
    live = (last >= node_base) & (first < node_base + n_blk)

    @pl.when(live)
    def _accum():
        dst = dst_ref[0, :]                                  # [e_blk]
        rows = jax.lax.broadcasted_iota(jnp.int32, (n_blk, e_blk), 0)
        onehot = (dst[None, :] - node_base == rows).astype(
            values_ref.dtype
        )
        out_ref[...] += jax.lax.dot(
            onehot, values_ref[...],
            preferred_element_type=out_ref.dtype,
        )


def scatter_sum_sorted_pallas(
    values, dst_sorted, num_segments: int, *,
    n_blk: int = 128, e_blk: int = 256, interpret: bool | None = None,
):
    """values [E, D] already sorted by ``dst_sorted`` (invalid rows must be
    zeroed and their dst set to ``num_segments``-or-larger sentinel)."""
    interpret = resolve_interpret(interpret)
    e, d = values.shape
    e_pad = -(-e // e_blk) * e_blk
    n_pad = -(-num_segments // n_blk) * n_blk
    if e_pad != e:
        values = jnp.pad(values, ((0, e_pad - e), (0, 0)))
        dst_sorted = jnp.pad(
            dst_sorted, (0, e_pad - e), constant_values=n_pad
        )
    out = pl.pallas_call(
        functools.partial(
            _kernel, n_blk=n_blk, e_blk=e_blk,
            n_e_blocks=e_pad // e_blk,
        ),
        grid=(n_pad // n_blk, e_pad // e_blk),
        in_specs=[
            pl.BlockSpec((1, e_blk), lambda ni, ei: (0, ei)),   # dst
            pl.BlockSpec((e_blk, d), lambda ni, ei: (ei, 0)),   # values
        ],
        out_specs=pl.BlockSpec((n_blk, d), lambda ni, ei: (ni, 0)),
        # fp32 accumulation regardless of input dtype (MXU-native)
        out_shape=jax.ShapeDtypeStruct((n_pad, d), jnp.float32),
        interpret=interpret,
    )(dst_sorted.reshape(1, e_pad), values)
    return out[:num_segments].astype(values.dtype)
