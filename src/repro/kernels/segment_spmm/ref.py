"""Pure-jnp oracle for the segment-scatter SpMM kernel."""

import jax
import jax.numpy as jnp


def scatter_sum(values, segment_ids, num_segments: int, mask=None):
    """Sum rows of ``values`` into ``num_segments`` buckets.

    values: [E, D]; segment_ids: int32[E]; mask: bool[E] or None.
    This is the GNN message-aggregation primitive (SpMM with a one-hot
    adjacency), the exact semantics of ``jax.ops.segment_sum``.
    """
    if mask is not None:
        values = jnp.where(mask[:, None], values, 0.0)
    return jax.ops.segment_sum(values, segment_ids,
                               num_segments=num_segments)
