"""Shared utilities for the Pallas kernels.

One copy of the interpret-mode default: every kernel wrapper used to
inline ``interpret = jax.default_backend() == "cpu"``, which made it
impossible for CI or a benchmark to force a mode without threading an
argument through every call site.  :func:`resolve_interpret` adds a
``REPRO_PALLAS_INTERPRET`` environment override on top of the backend
heuristic, so a single env var flips the whole kernel suite:

  * ``REPRO_PALLAS_INTERPRET=1`` (or ``true``/``yes``/``on``) — force the
    Pallas interpreter everywhere (debugging a kernel on any device);
  * ``REPRO_PALLAS_INTERPRET=0`` (or ``false``/``no``/``off``) — force
    compiled lowering even on CPU (exercises the Triton/Mosaic pipeline);
  * unset or ``auto`` — interpret exactly when the default backend is CPU
    (the historical behavior: CPU has no Pallas lowering).

An explicit ``interpret=`` argument at a call site still beats the env
var — explicit beats derived everywhere in this codebase.

The *silent* arm of the heuristic (unset/``auto`` on CPU) is a perf
footgun: the interpreter is orders of magnitude slower than a compiled
lowering, and nothing used to say it was active.  The first silent
fallback per process now emits one ``RuntimeWarning`` plus a
``repro_kernel_interpret_fallbacks_total`` counter tick (every fallback
counts; only the warning is once-per-process).  Explicit requests —
``interpret=True`` or the env var — are intentional and never warn, and
test runs (``PYTEST_CURRENT_TEST`` set) stay quiet: differential tests
pin interpret mode on purpose.
"""

from __future__ import annotations

import os
import warnings

import jax

__all__ = ["INTERPRET_ENV", "note_trace", "resolve_interpret"]

INTERPRET_ENV = "REPRO_PALLAS_INTERPRET"

_TRUE = ("1", "true", "yes", "on")
_FALSE = ("0", "false", "no", "off")

_fallback_warned = False


def _note_interpret_fallback() -> None:
    global _fallback_warned
    from repro.obs import global_obs

    global_obs().metrics.counter(
        "repro_kernel_interpret_fallbacks_total").inc()
    if _fallback_warned or "PYTEST_CURRENT_TEST" in os.environ:
        return
    _fallback_warned = True
    warnings.warn(
        "no compiled Pallas lowering for this host (default backend is "
        "cpu): kernels will run in INTERPRET mode, which is orders of "
        "magnitude slower.  Use the compiled 'xla' fused backend "
        "(fused_backend='xla' / --fused-backend xla, the CPU auto-dispatch "
        f"default), or silence this by setting {INTERPRET_ENV}=1 "
        "explicitly.",
        RuntimeWarning, stacklevel=3,
    )


def resolve_interpret(interpret: bool | None = None, *,
                      quiet: bool = False) -> bool:
    """Resolve a kernel's interpret-mode flag (see module docstring).

    ``quiet=True`` suppresses the silent-fallback warning/counter — for
    *probes* (e.g. the executor's fused auto-dispatch asking "would Pallas
    interpret here?") that make a decision rather than run a kernel.
    """
    if interpret is not None:
        return bool(interpret)
    raw = os.environ.get(INTERPRET_ENV, "").strip().lower()
    if raw in _TRUE:
        return True
    if raw in _FALSE:
        return False
    if raw not in ("", "auto"):
        raise ValueError(
            f"{INTERPRET_ENV}={raw!r} is not a recognized mode; use one of "
            f"{_TRUE + _FALSE} or 'auto'")
    fallback = jax.default_backend() == "cpu"
    if fallback and not quiet:
        _note_interpret_fallback()
    return fallback


def note_trace(kernel: str) -> None:
    """Count one *trace* of a kernel wrapper in the process-global metrics.

    Kernel wrappers run at jax trace time, inside ``jit`` — once per new
    shape, not once per device launch — so the counter is named
    ``repro_kernel_traces_total``: it measures how often XLA had to rebuild
    a kernel, which is exactly the jit-cache-health signal (launch counts
    live in ``repro_mining_launches_total``, emitted host-side by the
    executor).  The import is lazy and the global default is a no-op
    bundle, so the disabled-mode cost is one function call per trace.
    """
    from repro.obs import global_obs

    global_obs().metrics.counter("repro_kernel_traces_total",
                                 kernel=kernel).inc()
