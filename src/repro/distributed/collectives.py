"""Collective helpers: compressed gradient all-reduce, hierarchical psum.

``compressed_psum_int8`` implements a chunked int8 stochastic-rounding codec
around ``jax.lax.psum`` — 4x less inter-pod traffic for gradient all-reduce at
the cost of quantization noise that stochastic rounding keeps unbiased.  It is
used by the training substrate when ``grad_compression="int8"`` is configured
(a distributed-optimization trick; the pod axis crosses DCN where bandwidth,
not FLOPs, dominates).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def shard_map_compat(f, *, mesh, in_specs, out_specs):
    """shard_map across jax versions (stable ``jax.shard_map`` vs the
    ``jax.experimental`` spelling), replication checking disabled — SPMD
    bodies here create carries inside the shard, which the checker cannot
    see through."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map

    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def compressed_psum_int8(x, axis_name, key):
    """All-reduce ``x`` over ``axis_name`` with int8 payload compression.

    All participants first agree on a shared scale (pmax of |x| — a scalar,
    negligible payload), quantize with stochastic rounding (unbiased), then
    accumulate the int8 payloads at int32 (exact).  The only error is the
    per-element quantization noise, which stochastic rounding keeps
    zero-mean across steps.
    """
    amax = jax.lax.pmax(jnp.maximum(jnp.max(jnp.abs(x)), 1e-12), axis_name)
    scale = amax / 127.0
    noise = jax.random.uniform(key, x.shape, x.dtype, -0.5, 0.5)
    q = jnp.clip(jnp.round(x / scale + noise), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(x.dtype) * scale


def hierarchical_psum(x, inner_axis, outer_axis):
    """Reduce over the fast (ICI) axis first, then the slow (DCN) axis.

    XLA usually does this automatically for a joint psum; making it explicit
    documents the intent and lets the outer reduction be compressed.
    """
    return jax.lax.psum(jax.lax.psum(x, inner_axis), outer_axis)


def psum_tree(tree, axis_name):
    return jax.tree_util.tree_map(lambda g: jax.lax.psum(g, axis_name), tree)
