from . import collectives, mining

__all__ = ["collectives", "mining"]
