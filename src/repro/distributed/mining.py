"""Distributed PTMT: zones sharded over the mesh (the paper's thread pool).

Phase-2 aggregation becomes a **two-level merge**:

  1. every device signed-counts its own zones (`aggregate_zones`) — unique
     codes compact to the front of the local table;
  2. only the first ``out_cap`` rows (a configurable unique-code budget) are
     ``all_gather``-ed and merged, shrinking the collective payload from
     O(zones_local * e_cap) to O(out_cap) per device.

Overflow of the unique-code budget is detected and surfaced (psum of a flag)
rather than silently truncated.  This replaces the paper's atomic global hash
merge with a deterministic, collective-friendly reduction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import aggregation, expansion
from repro.core.aggregation import CodeCounts


def _scan_chunked(u, v, t, valid, *, delta, l_max, backend, zone_chunk):
    if backend == "pallas":
        from repro.kernels.zone_scan import ops as zone_ops

        scan = zone_ops.scan_zones
    else:
        scan = expansion.scan_zones

    def chunk_fn(args):
        cu, cv, ct, cvalid = args
        res = scan(cu, cv, ct, cvalid, delta=delta, l_max=l_max)
        return res.code, res.length

    z = u.shape[0]
    if zone_chunk and zone_chunk < z:
        nchunk = z // zone_chunk
        reshape = lambda x: x.reshape(nchunk, zone_chunk, *x.shape[1:])
        codes, lengths = jax.lax.map(
            chunk_fn, (reshape(u), reshape(v), reshape(t), reshape(valid))
        )
        codes = codes.reshape(z, *codes.shape[2:])
        lengths = lengths.reshape(z, *lengths.shape[2:])
    else:
        codes, lengths = chunk_fn((u, v, t, valid))
    return codes, lengths


def make_mine_fn(
    mesh: jax.sharding.Mesh,
    axes: tuple[str, ...],
    *,
    delta: int,
    l_max: int,
    backend: str = "ref",
    zone_chunk: int = 0,
    out_cap: int = 65536,
    merge_mode: str = "flat",
):
    """Build the (unjitted) SPMD mining step for a zone batch.

    Returns ``fn(u, v, t, valid, signs) -> (CodeCounts, overflow)`` where the
    zone axis (leading) is sharded over ``axes`` and the result is replicated.

    merge_mode:
      "flat"         — one all_gather over every axis, then a single merge
                       (paper-faithful analog of the atomic global merge);
      "hierarchical" — gather+merge one mesh axis at a time (innermost
                       first).  Duplicate codes collapse at each stage, so
                       per-device traffic drops from O(n_devices * out_cap)
                       to O(sum(axis sizes) * out_cap) — the beyond-paper
                       collective optimization measured in EXPERIMENTS §Perf.
    """
    zone_spec = P(axes)
    scalar_spec = P(axes)

    def _compact(counts_: aggregation.CodeCounts, cap: int):
        send_codes = jnp.where(
            counts_.unique_mask[:cap, None], counts_.codes[:cap], 0)
        send_counts = jnp.where(
            counts_.unique_mask[:cap], counts_.counts[:cap], 0)
        overflow = (counts_.unique_mask.sum() > cap).astype(jnp.int32)
        return send_codes, send_counts, overflow

    def step(u, v, t, valid, signs):
        codes, lengths = _scan_chunked(
            u, v, t, valid, delta=delta, l_max=l_max, backend=backend,
            zone_chunk=zone_chunk,
        )
        local = aggregation.aggregate_zones(codes, lengths, signs)
        cap = min(out_cap, local.counts.shape[0])
        overflow = jnp.int32(0)
        if merge_mode == "hierarchical":
            merged = local
            for axis in reversed(axes):      # innermost (fastest) first
                send_codes, send_counts, ovf = _compact(merged, cap)
                overflow = overflow + ovf
                all_codes = jax.lax.all_gather(send_codes, axis, tiled=True)
                all_counts = jax.lax.all_gather(send_counts, axis,
                                                tiled=True)
                merged = aggregation.count_codes(all_codes, all_counts)
        else:
            send_codes, send_counts, ovf = _compact(local, cap)
            overflow = overflow + ovf
            all_codes = jax.lax.all_gather(send_codes, axes, tiled=True)
            all_counts = jax.lax.all_gather(send_counts, axes, tiled=True)
            merged = aggregation.count_codes(all_codes, all_counts)
        overflow = jax.lax.psum(overflow, axes)
        return merged, overflow

    return jax.shard_map(
        step,
        mesh=mesh,
        in_specs=(zone_spec, zone_spec, zone_spec, zone_spec, scalar_spec),
        out_specs=(CodeCounts(P(), P(), P()), P()),
        check_vma=False,  # scan carry is created inside the shard
    )


def make_mine_step(mesh, axes, **kw):
    """Jitted variant of :func:`make_mine_fn`."""
    return jax.jit(make_mine_fn(mesh, axes, **kw))


def mine_on_mesh(
    batch,
    mesh: jax.sharding.Mesh,
    axes: tuple[str, ...],
    *,
    delta: int,
    l_max: int,
    backend: str = "ref",
    zone_chunk: int | None = None,
    out_cap: int = 65536,
) -> CodeCounts:
    """Run distributed discovery over a host-built :class:`ZoneBatch`."""
    fn = make_mine_step(
        mesh, axes, delta=delta, l_max=l_max, backend=backend,
        zone_chunk=zone_chunk or 0, out_cap=out_cap,
    )
    counts, overflow = fn(
        jnp.asarray(batch.u), jnp.asarray(batch.v), jnp.asarray(batch.t),
        jnp.asarray(batch.valid), jnp.asarray(batch.sign),
    )
    if int(overflow) > 0:
        raise RuntimeError(
            f"{int(overflow)} device(s) overflowed the unique-code budget "
            f"(out_cap={out_cap}); re-run with a larger out_cap"
        )
    return counts


def input_specs(n_zones: int, e_cap: int):
    """ShapeDtypeStructs for the mining step (dry-run stand-ins)."""
    zs = jax.ShapeDtypeStruct((n_zones, e_cap), jnp.int32)
    return dict(
        u=zs, v=zs, t=zs,
        valid=jax.ShapeDtypeStruct((n_zones, e_cap), jnp.bool_),
        signs=jax.ShapeDtypeStruct((n_zones,), jnp.int32),
    )
