"""Distributed PTMT: zones sharded over the mesh (the paper's thread pool).

Per-device scan + signed aggregation is delegated to
:class:`repro.core.executor.MiningExecutor` (``scan_aggregate_partial`` is
traceable and runs inside the ``shard_map`` body); this module owns only the
collective merge.  Phase-2 aggregation becomes a **multi-level merge**:

  1. every device folds its own zones into a partial count table — when the
     executor is chunked this is the hierarchical bounded-carry fold
     (O(zone_chunk*C) peak instead of O(zones_local*C), see
     ``core/executor.py``), never one whole-shard flatten;
  2. only the first ``out_cap`` rows (a configurable unique-code budget) are
     ``all_gather``-ed and merged, shrinking the collective payload from
     O(zones_local * e_cap) to O(out_cap) per device.

Overflow of either budget — the collective ``out_cap`` or the hierarchical
``merge_cap`` carry — is detected and surfaced (psum of a flag) rather than
silently truncated.  This replaces the paper's atomic global hash merge with
a deterministic, collective-friendly reduction.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import aggregation
from repro.core.aggregation import CodeCounts
from repro.core.executor import MiningExecutor, merge_partial_counts

from .collectives import shard_map_compat


def _as_executor(
    executor: MiningExecutor | None,
    *,
    delta: int | None,
    l_max: int | None,
    backend: str,
    zone_chunk: int | None,
    agg: str = "auto",
    merge_cap: int | None = None,
    config=None,
) -> MiningExecutor:
    if executor is None and config is not None:
        executor = MiningExecutor.from_config(config)
    if executor is None:
        if delta is None or l_max is None:
            raise ValueError(
                "pass an executor, a MiningConfig, or delta+l_max")
        executor = MiningExecutor(delta=delta, l_max=l_max, backend=backend,
                                  zone_chunk=zone_chunk, agg=agg,
                                  merge_cap=merge_cap)
    if not executor.spec.jittable:
        raise ValueError(
            f"backend {executor.backend!r} is host-only and cannot be "
            f"sharded over a mesh; use a jittable backend"
        )
    return executor


def make_mine_fn(
    mesh: jax.sharding.Mesh,
    axes: tuple[str, ...],
    *,
    executor: MiningExecutor | None = None,
    config=None,
    delta: int | None = None,
    l_max: int | None = None,
    backend: str = "ref",
    zone_chunk: int = 0,
    agg: str = "auto",
    merge_cap: int | None = None,
    out_cap: int = 65536,
    merge_mode: str = "flat",
):
    """Build the (unjitted) SPMD mining step for a zone batch.

    Returns ``fn(u, v, t, valid, signs) -> (CodeCounts, overflow)`` where the
    zone axis (leading) is sharded over ``axes`` and the result is replicated.
    Pass a configured :class:`MiningExecutor`, a
    :class:`repro.core.config.MiningConfig`, or the legacy
    delta/l_max/backend/zone_chunk (+ agg/merge_cap) kwargs (an executor is
    built internally).  With a chunked executor the per-shard aggregation is
    the hierarchical bounded-carry fold; its merge-cap spills are folded
    into the returned overflow flag.

    merge_mode:
      "flat"         — one all_gather over every axis, then a single merge
                       (paper-faithful analog of the atomic global merge);
      "hierarchical" — gather+merge one mesh axis at a time (innermost
                       first).  Duplicate codes collapse at each stage, so
                       per-device traffic drops from O(n_devices * out_cap)
                       to O(sum(axis sizes) * out_cap) — the beyond-paper
                       collective optimization measured in EXPERIMENTS.md
                       §Perf.
    """
    executor = _as_executor(executor, delta=delta, l_max=l_max,
                            backend=backend, zone_chunk=zone_chunk,
                            agg=agg, merge_cap=merge_cap, config=config)
    zone_spec = P(axes)
    scalar_spec = P(axes)

    def _compact(counts_: aggregation.CodeCounts, cap: int):
        send_codes = jnp.where(
            counts_.unique_mask[:cap, None], counts_.codes[:cap], 0)
        send_counts = jnp.where(
            counts_.unique_mask[:cap], counts_.counts[:cap], 0)
        overflow = (counts_.unique_mask.sum() > cap).astype(jnp.int32)
        return send_codes, send_counts, overflow

    def step(u, v, t, valid, signs):
        local, merge_spill = executor.scan_aggregate_partial(
            u, v, t, valid, signs)
        cap = min(out_cap, local.counts.shape[0])
        overflow = merge_spill
        if merge_mode == "hierarchical":
            merged = local
            for axis in reversed(axes):      # innermost (fastest) first
                send_codes, send_counts, ovf = _compact(merged, cap)
                overflow = overflow + ovf
                all_codes = jax.lax.all_gather(send_codes, axis, tiled=True)
                all_counts = jax.lax.all_gather(send_counts, axis,
                                                tiled=True)
                merged = aggregation.count_codes(all_codes, all_counts)
        else:
            send_codes, send_counts, ovf = _compact(local, cap)
            overflow = overflow + ovf
            all_codes = jax.lax.all_gather(send_codes, axes, tiled=True)
            all_counts = jax.lax.all_gather(send_counts, axes, tiled=True)
            merged = aggregation.count_codes(all_codes, all_counts)
        overflow = jax.lax.psum(overflow, axes)
        return merged, overflow

    return shard_map_compat(
        step,
        mesh=mesh,
        in_specs=(zone_spec, zone_spec, zone_spec, zone_spec, scalar_spec),
        out_specs=(CodeCounts(P(), P(), P()), P()),
    )


def make_mine_step(mesh, axes, **kw):
    """Jitted variant of :func:`make_mine_fn`."""
    return jax.jit(make_mine_fn(mesh, axes, **kw))


def run_mine_fn(fn, batch, *, out_cap: int = 65536) -> CodeCounts:
    """Drive a built mining step over a host :class:`ZoneBatch`.

    The single copy of the device-transfer + overflow-surfacing policy:
    :func:`mine_on_mesh` (one-shot) and
    :meth:`repro.core.engine.PTMTEngine.sharded` (cached step) both call
    it.  A positive psum'd overflow flag — collective ``out_cap`` exceeded
    or a hierarchical ``merge_cap`` carry spill — raises instead of
    silently truncating.
    """
    counts, overflow = fn(
        jnp.asarray(batch.u), jnp.asarray(batch.v), jnp.asarray(batch.t),
        jnp.asarray(batch.valid), jnp.asarray(batch.sign),
    )
    if int(overflow) > 0:
        raise RuntimeError(
            f"unique-code budget overflow on the mesh (psum flag "
            f"{int(overflow)}): either a device exceeded out_cap="
            f"{out_cap} at the collective merge or its hierarchical "
            f"merge_cap carry spilled; re-run with a larger out_cap / "
            f"merge_cap"
        )
    return counts


def run_mine_layout(fn, layout, *, out_cap: int = 65536,
                    merge_cap: int | None = None,
                    on_bucket=None) -> CodeCounts:
    """Drive a built SPMD step over every bucket of a layout and merge.

    The single copy of the per-bucket shard policy: each bucket runs
    through :func:`run_mine_fn` (``jax.jit`` re-specializes per bucket
    shape and caches), then the replicated partial tables fold through the
    bounded signed carry.  ``on_bucket(bucket)`` is invoked after each
    bucket's run — :meth:`repro.core.engine.PTMTEngine.sharded` uses it to
    record per-bucket execution keys.  Callers enforce the overflow policy
    (``MiningExecutor.check_layout_overflow``) before building device
    batches.
    """
    parts = []
    for bucket in layout.buckets:
        parts.append(run_mine_fn(fn, bucket, out_cap=out_cap))
        if on_bucket is not None:
            on_bucket(bucket)
    return merge_partial_counts(parts, merge_cap=merge_cap,
                                warn_label="sharded bucket")


def mine_layout_on_mesh(
    layout,
    mesh: jax.sharding.Mesh,
    axes: tuple[str, ...],
    *,
    executor: MiningExecutor | None = None,
    config=None,
    delta: int | None = None,
    l_max: int | None = None,
    backend: str = "ref",
    zone_chunk: int | None = None,
    agg: str = "auto",
    merge_cap: int | None = None,
    out_cap: int = 65536,
    merge_mode: str = "flat",
    allow_overflow: bool = False,
) -> CodeCounts:
    """Distributed discovery over a host-built ``ZoneBatchLayout``.

    Sharding is **per bucket**: each size bucket's zone axis is sharded
    over the mesh independently (its zones were round-robined across the
    shard lanes at build time, so the static load balance holds within
    every capacity class), one SPMD step serves every bucket (``jax.jit``
    re-specializes per bucket shape and caches — recurring bucket
    geometries reuse executables), and the replicated per-bucket count
    tables fold through the bounded signed carry
    (:func:`repro.core.executor.merge_partial_counts`) host-side.  Build
    the layout with ``n_shards = prod(mesh axis sizes)`` so every bucket's
    zone count divides the shard count.  Layouts that dropped edges raise
    :class:`~repro.core.executor.ZoneOverflowError` (same policy as the
    local ``run_layout``) unless ``allow_overflow=True``.
    """
    ex = _as_executor(executor, delta=delta, l_max=l_max, backend=backend,
                      zone_chunk=zone_chunk, agg=agg, merge_cap=merge_cap,
                      config=config)
    MiningExecutor.check_layout_overflow(layout,
                                         allow_overflow=allow_overflow)
    fn = make_mine_step(mesh, axes, executor=ex, out_cap=out_cap,
                        merge_mode=merge_mode)
    return run_mine_layout(fn, layout, out_cap=out_cap,
                           merge_cap=ex.merge_cap)


def mine_on_mesh(
    batch,
    mesh: jax.sharding.Mesh,
    axes: tuple[str, ...],
    *,
    executor: MiningExecutor | None = None,
    config=None,
    delta: int | None = None,
    l_max: int | None = None,
    backend: str = "ref",
    zone_chunk: int | None = None,
    agg: str = "auto",
    merge_cap: int | None = None,
    out_cap: int = 65536,
) -> CodeCounts:
    """Run distributed discovery over a host-built :class:`ZoneBatch`.

    One-shot: builds (and jits) the step per call.  For repeated sharded
    runs use :meth:`repro.core.engine.PTMTEngine.sharded`, which caches the
    compiled step per mesh geometry.
    """
    fn = make_mine_step(
        mesh, axes, executor=executor, config=config, delta=delta,
        l_max=l_max, backend=backend, zone_chunk=zone_chunk or 0, agg=agg,
        merge_cap=merge_cap, out_cap=out_cap,
    )
    return run_mine_fn(fn, batch, out_cap=out_cap)


def input_specs(n_zones: int, e_cap: int):
    """ShapeDtypeStructs for the mining step (dry-run stand-ins)."""
    zs = jax.ShapeDtypeStruct((n_zones, e_cap), jnp.int32)
    return dict(
        u=zs, v=zs, t=zs,
        valid=jax.ShapeDtypeStruct((n_zones, e_cap), jnp.bool_),
        signs=jax.ShapeDtypeStruct((n_zones,), jnp.int32),
    )
