"""gat-cora — graph attention network [arXiv:1710.10903].
2L, 8 heads x 8 features (d_hidden = 64 total), attn aggregator."""

from repro.models.gnn import GNNConfig

from .common import ArchDef
from .gnn_common import GNN_SHAPES, gnn_workload

CONFIG = GNNConfig(
    name="gat-cora",
    kind="gat",
    n_layers=2,
    d_in=1433,          # overridden per shape
    d_hidden=64,        # 8 heads x 8 per-head features
    n_heads=8,
    n_classes=7,
)

SMOKE = GNNConfig(
    name="gat-cora-smoke",
    kind="gat",
    n_layers=2,
    d_in=16,
    d_hidden=16,
    n_heads=4,
    n_classes=4,
)

ARCH = ArchDef(
    name="gat-cora", family="gnn", config=CONFIG, smoke_config=SMOKE,
    shapes=GNN_SHAPES, workload_fn=gnn_workload,
)
