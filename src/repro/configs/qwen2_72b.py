"""qwen2-72b — dense LM, GQA kv=8, QKV bias [arXiv:2407.10671]."""

import jax.numpy as jnp

from repro.models.transformer import TransformerConfig

from .common import LM_SHAPES, ArchDef, lm_workload

CONFIG = TransformerConfig(
    name="qwen2-72b",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=29568,
    vocab=152064,
    rope_theta=1_000_000.0,
    qkv_bias=True,
    dtype=jnp.bfloat16,
    remat="full",
)

SMOKE = TransformerConfig(
    name="qwen2-72b-smoke",
    n_layers=3,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_head=8,
    d_ff=160,
    vocab=512,
    rope_theta=1_000_000.0,
    qkv_bias=True,
    dtype=jnp.float32,
    remat="none",
    q_chunk=16,
)

ARCH = ArchDef(
    name="qwen2-72b", family="lm", config=CONFIG, smoke_config=SMOKE,
    shapes=LM_SHAPES, workload_fn=lm_workload,
)
