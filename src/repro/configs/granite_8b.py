"""granite-8b — dense code LM, llama-arch, GQA [arXiv:2405.04324; hf]."""

import jax.numpy as jnp

from repro.models.transformer import TransformerConfig

from .common import LM_SHAPES, ArchDef, lm_workload

CONFIG = TransformerConfig(
    name="granite-8b",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab=49152,
    rope_theta=10_000_000.0,
    tie_embeddings=True,
    dtype=jnp.bfloat16,
    remat="full",
)

SMOKE = TransformerConfig(
    name="granite-8b-smoke",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab=256,
    rope_theta=10_000_000.0,
    tie_embeddings=True,
    dtype=jnp.float32,
    remat="none",
    q_chunk=16,
)

ARCH = ArchDef(
    name="granite-8b", family="lm", config=CONFIG, smoke_config=SMOKE,
    shapes=LM_SHAPES, workload_fn=lm_workload,
)
