"""gemma3-1b — dense LM, 5:1 local:global sliding window, GQA kv=1
[hf:google/gemma-3-1b-pt]."""

import jax.numpy as jnp

from repro.models.transformer import TransformerConfig

from .common import LM_SHAPES, ArchDef, lm_workload

CONFIG = TransformerConfig(
    name="gemma3-1b",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    d_head=256,
    d_ff=6912,
    vocab=262144,
    rope_theta=1_000_000.0,       # global layers
    rope_theta_local=10_000.0,    # local layers
    window=512,
    pattern_local=5,
    pattern_global=1,
    tie_embeddings=True,
    embed_scale=True,
    dtype=jnp.bfloat16,
    remat="full",
)

SMOKE = TransformerConfig(
    name="gemma3-1b-smoke",
    n_layers=6,                   # one full 5:1 local/global period
    d_model=48,
    n_heads=2,
    n_kv_heads=1,
    d_head=24,
    d_ff=96,
    vocab=256,
    rope_theta=1_000_000.0,
    rope_theta_local=10_000.0,
    window=8,
    pattern_local=5,
    pattern_global=1,
    tie_embeddings=True,
    embed_scale=True,
    dtype=jnp.float32,
    remat="none",
    q_chunk=16,
)

ARCH = ArchDef(
    name="gemma3-1b", family="lm", config=CONFIG, smoke_config=SMOKE,
    shapes=LM_SHAPES, workload_fn=lm_workload,
)
