"""Architecture registry: ``--arch <id>`` resolves here.

10 assigned architectures (5 LM, 4 GNN, 1 recsys) + the paper's own PTMT
mining configuration.  Each entry is an :class:`common.ArchDef` with a full
config (dry-run only), a reduced smoke config (CPU tests) and its shape set.
"""

from __future__ import annotations

from .common import ArchDef, Workload  # noqa: F401


def _registry() -> dict:
    from . import (  # local import: keep module import light
        arctic_480b,
        dcn_v2,
        equiformer_v2,
        gat_cora,
        gatedgcn,
        gemma3_1b,
        gin_tu,
        granite_8b,
        moonshot_v1_16b_a3b,
        ptmt,
        qwen2_72b,
    )

    archs = [
        granite_8b.ARCH,
        gemma3_1b.ARCH,
        qwen2_72b.ARCH,
        moonshot_v1_16b_a3b.ARCH,
        arctic_480b.ARCH,
        equiformer_v2.ARCH,
        gatedgcn.ARCH,
        gin_tu.ARCH,
        gat_cora.ARCH,
        dcn_v2.ARCH,
        ptmt.ARCH,       # the paper's own workload (mining)
    ]
    return {a.name: a for a in archs}


_CACHE: dict | None = None


def registry() -> dict:
    global _CACHE
    if _CACHE is None:
        _CACHE = _registry()
    return _CACHE


def get_arch(name: str) -> ArchDef:
    reg = registry()
    if name not in reg:
        raise KeyError(f"unknown arch {name!r}; have {sorted(reg)}")
    return reg[name]


def arch_names() -> list[str]:
    return sorted(registry())


def lm_arch_names() -> list[str]:
    return sorted(a.name for a in registry().values() if a.family == "lm")


def all_cells(include_mining: bool = True) -> list[tuple[str, str]]:
    """Every (arch, shape) dry-run cell — 40 assigned + 4 mining."""
    out = []
    for arch in registry().values():
        if arch.family == "mining" and not include_mining:
            continue
        for shape in arch.shapes:
            out.append((arch.name, shape.name))
    return sorted(out)
