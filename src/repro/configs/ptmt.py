"""ptmt-mining — the paper's own workload as a first-class arch config.

Shapes are zone-batch geometries (zones x per-zone edge capacity); the step
is the full distributed discovery: per-device zone expansion + two-level
signed merge.  Paper defaults: delta=600s, l_max=6, omega=20.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.distributed import mining

from .common import ArchDef, Workload


@dataclasses.dataclass(frozen=True)
class MiningConfig:
    name: str
    delta: int = 600
    l_max: int = 6
    omega: int = 20
    backend: str = "ref"
    out_cap: int = 65536
    merge_mode: str = "flat"   # "hierarchical": staged per-axis merge


CONFIG = MiningConfig(name="ptmt-mining")
SMOKE = MiningConfig(name="ptmt-mining-smoke", delta=30, l_max=3,
                     out_cap=1024)


@dataclasses.dataclass(frozen=True)
class MiningShape:
    name: str
    n_zones: int
    e_cap: int


MINING_SHAPES = (
    MiningShape("mine_1m", 2_048, 2_048),      # ~4M edge slots
    MiningShape("mine_dense", 1_024, 8_192),   # bursty regime (few big zones)
    MiningShape("mine_wide", 8_192, 1_024),    # sparse regime (many zones)
    MiningShape("mine_xl", 8_192, 4_096),      # ~34M edge slots
)


def mining_workload(cfg: MiningConfig, shape: MiningShape, mesh) -> Workload:
    axes = tuple(mesh.axis_names)
    fn = mining.make_mine_fn(
        mesh, axes, delta=cfg.delta, l_max=cfg.l_max,
        backend=cfg.backend, out_cap=cfg.out_cap,
        merge_mode=cfg.merge_mode,
    )
    sds = mining.input_specs(shape.n_zones, shape.e_cap)
    in_sds = (sds["u"], sds["v"], sds["t"], sds["valid"], sds["signs"])
    # The expansion sweep is integer VPU work, not MXU flops: count the
    # per-(edge x candidate) vector ops as the useful-work yardstick.
    per_pair_ops = (cfg.l_max + 1) + 10
    vpu_ops = float(shape.n_zones) * shape.e_cap * shape.e_cap * per_pair_ops
    return Workload(
        name=f"{cfg.name}/{shape.name}", kind="mine", fn=fn,
        in_sds=in_sds, in_shardings=None,   # shard_map carries the specs
        model_flops=vpu_ops,
    )


def analytic_mining_terms(cfg: MiningConfig, shape: MiningShape,
                          n_chips: int) -> dict:
    """Roofline inputs for the mining sweep (integer VPU workload).

    Per zone the expansion does E steps, each a dense vector pass over the
    C = E candidate table (~(l_max+1)+10 int ops per pair).  On TPU the
    candidate table lives in VMEM (zone_scan kernel), so HBM traffic is the
    edge stream in + final codes out + one table spill per zone, not the
    per-step table traffic.
    """
    import repro.core.encoding as enc

    z_local = max(shape.n_zones // n_chips, 1)
    per_pair = (cfg.l_max + 1) + 10
    ops = float(z_local) * shape.e_cap * shape.e_cap * per_pair
    limbs = enc.n_limbs(cfg.l_max)
    state_bytes = (limbs + cfg.l_max + 1 + 4) * 4
    hbm = float(z_local) * (
        shape.e_cap * 16                      # u, v, t, valid in
        + shape.e_cap * (limbs + 1) * 4       # codes + lengths out
        + shape.e_cap * state_bytes           # one table spill
    )
    return {"ops_per_chip": ops, "hbm_bytes_per_chip": hbm}


ARCH = ArchDef(
    name="ptmt-mining", family="mining", config=CONFIG, smoke_config=SMOKE,
    shapes=MINING_SHAPES, workload_fn=mining_workload,
)
