"""gatedgcn — edge-gated graph convnet [arXiv:2003.00982]. 16L d=70."""

from repro.models.gnn import GNNConfig

from .common import ArchDef
from .gnn_common import GNN_SHAPES, gnn_workload

CONFIG = GNNConfig(
    name="gatedgcn",
    kind="gatedgcn",
    n_layers=16,
    d_in=1433,          # overridden per shape
    d_hidden=70,
    n_classes=7,
)

SMOKE = GNNConfig(
    name="gatedgcn-smoke",
    kind="gatedgcn",
    n_layers=3,
    d_in=16,
    d_hidden=16,
    n_classes=4,
)

ARCH = ArchDef(
    name="gatedgcn", family="gnn", config=CONFIG, smoke_config=SMOKE,
    shapes=GNN_SHAPES, workload_fn=gnn_workload,
)
