"""equiformer-v2 — SO(2)-eSCN equivariant graph attention
[arXiv:2306.12059].  12L d_hidden=128 l_max=6 m_max=2 8H.

Non-geometric shapes (cora / reddit-like / ogb_products) have no atomic
coordinates; input_specs synthesize unit-norm positions (stub noted in
DESIGN.md §Arch-applicability)."""

from repro.models.equiformer import EquiformerConfig

from .common import ArchDef
from .gnn_common import GNN_SHAPES, gnn_workload

CONFIG = EquiformerConfig(
    name="equiformer-v2",
    n_layers=12,
    d_hidden=128,
    l_max=6,
    m_max=2,
    n_heads=8,
    n_radial=32,
)

SMOKE = EquiformerConfig(
    name="equiformer-v2-smoke",
    n_layers=2,
    d_hidden=16,
    l_max=3,
    m_max=2,
    n_heads=4,
    n_radial=8,
)

ARCH = ArchDef(
    name="equiformer-v2", family="gnn", config=CONFIG, smoke_config=SMOKE,
    shapes=GNN_SHAPES, workload_fn=gnn_workload,
)
