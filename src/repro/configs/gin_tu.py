"""gin-tu — Graph Isomorphism Network [arXiv:1810.00826].
5L d=64, sum aggregator, learnable eps."""

from repro.models.gnn import GNNConfig

from .common import ArchDef
from .gnn_common import GNN_SHAPES, gnn_workload

CONFIG = GNNConfig(
    name="gin-tu",
    kind="gin",
    n_layers=5,
    d_in=1433,          # overridden per shape
    d_hidden=64,
    n_classes=7,
    eps_learnable=True,
)

SMOKE = GNNConfig(
    name="gin-tu-smoke",
    kind="gin",
    n_layers=2,
    d_in=16,
    d_hidden=16,
    n_classes=4,
)

ARCH = ArchDef(
    name="gin-tu", family="gnn", config=CONFIG, smoke_config=SMOKE,
    shapes=GNN_SHAPES, workload_fn=gnn_workload,
)
