"""arctic-480b — 128-expert top-2 MoE with a dense residual MLP in every
layer [hf:Snowflake/snowflake-arctic-base]."""

import jax.numpy as jnp

from repro.models.transformer import TransformerConfig

from .common import LM_SHAPES, ArchDef, lm_workload

CONFIG = TransformerConfig(
    name="arctic-480b",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_head=128,
    d_ff=4864,                 # dense residual MLP
    vocab=32000,
    rope_theta=10_000.0,
    moe=True,
    n_experts=128,
    top_k=2,
    d_ff_expert=4864,
    dense_residual=True,
    dtype=jnp.bfloat16,
    remat="full",
)

SMOKE = TransformerConfig(
    name="arctic-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=96,
    vocab=256,
    rope_theta=10_000.0,
    moe=True,
    n_experts=4,
    top_k=2,
    d_ff_expert=96,
    dense_residual=True,
    capacity_factor=8.0,
    dtype=jnp.float32,
    remat="none",
    q_chunk=16,
)

ARCH = ArchDef(
    name="arctic-480b", family="lm", config=CONFIG, smoke_config=SMOKE,
    shapes=LM_SHAPES, workload_fn=lm_workload,
)
