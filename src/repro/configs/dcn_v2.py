"""dcn-v2 — deep & cross network v2 ranking [arXiv:2008.13535].

13 dense + 26 sparse fields, embed_dim=16, 3 cross layers, MLP 1024-1024-512.
Shapes: train_batch 65k, serve_p99 512, serve_bulk 262k, retrieval_cand 1x1M.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import params as prm, recsys, sharding as shd
from repro.training import optimizer

from .common import ArchDef, Workload

CONFIG = recsys.DCNConfig(name="dcn-v2")

SMOKE = recsys.DCNConfig(
    name="dcn-v2-smoke",
    n_dense=4,
    n_sparse=6,
    embed_dim=8,
    n_cross_layers=2,
    mlp=(32, 16),
    vocab_sizes=(100, 100, 50, 50, 20, 20),
    bag_size=2,
    d_retrieval=8,
    n_items=1000,
)


@dataclasses.dataclass(frozen=True)
class RecsysShape:
    name: str
    batch: int
    kind: str                 # train | serve | retrieval
    n_candidates: int = 0


RECSYS_SHAPES = (
    RecsysShape("train_batch", 65_536, "train"),
    RecsysShape("serve_p99", 512, "serve"),
    RecsysShape("serve_bulk", 262_144, "serve"),
    RecsysShape("retrieval_cand", 1, "retrieval", n_candidates=1_000_000),
)


def _batch_specs(cfg, b, mesh, with_labels):
    sds = {
        "dense": jax.ShapeDtypeStruct((b, cfg.n_dense), jnp.float32),
        "sparse_ids": jax.ShapeDtypeStruct(
            (b, cfg.n_sparse, cfg.bag_size), jnp.int32),
        "sparse_weights": jax.ShapeDtypeStruct(
            (b, cfg.n_sparse, cfg.bag_size), jnp.float32),
    }
    if with_labels:
        sds["labels"] = jax.ShapeDtypeStruct((b,), jnp.float32)
    shards = {
        k: shd.named_sharding(mesh, (shd.BATCH,) + (None,) * (len(v.shape) - 1),
                              v.shape)
        for k, v in sds.items()
    }
    return sds, shards


def recsys_workload(cfg, shape: RecsysShape, mesh,
                    opt_cfg: optimizer.AdamWConfig | None = None) -> Workload:
    specs = recsys.dcn_param_specs(cfg)
    p_sds = prm.tree_sds(specs)
    p_shd = prm.tree_shardings(mesh, specs)
    d = cfg.d_interact
    mlp_flops = d * cfg.mlp[0] + sum(
        a * b for a, b in zip(cfg.mlp[:-1], cfg.mlp[1:])
    )
    fwd_flops = 2.0 * shape.batch * (
        cfg.n_cross_layers * d * d + mlp_flops
    )

    if shape.kind == "train":
        opt_cfg = opt_cfg or optimizer.AdamWConfig(weight_decay=0.0)
        o_sds = optimizer.AdamWState(
            step=jax.ShapeDtypeStruct((), jnp.int32), mu=p_sds, nu=p_sds)
        rep = shd.named_sharding(mesh, (), ())
        o_shd = optimizer.AdamWState(step=rep, mu=p_shd, nu=p_shd)
        b_sds, b_shd = _batch_specs(cfg, shape.batch, mesh, True)

        def step(params, opt_state, batch):
            l, grads = jax.value_and_grad(recsys.loss_fn)(
                params, batch, cfg, mesh
            )
            new_p, new_o, metrics = optimizer.apply_updates(
                opt_cfg, params, grads, opt_state
            )
            metrics["loss"] = l
            return new_p, new_o, metrics

        return Workload(
            name=f"{cfg.name}/{shape.name}", kind="train", fn=step,
            in_sds=(p_sds, o_sds, b_sds), in_shardings=(p_shd, o_shd, b_shd),
            out_shardings=(p_shd, o_shd, None), model_flops=3.0 * fwd_flops,
        )

    if shape.kind == "serve":
        b_sds, b_shd = _batch_specs(cfg, shape.batch, mesh, False)

        def serve(params, batch):
            return recsys.forward(params, batch, cfg, mesh)

        return Workload(
            name=f"{cfg.name}/{shape.name}", kind="serve", fn=serve,
            in_sds=(p_sds, b_sds), in_shardings=(p_shd, b_shd),
            model_flops=fwd_flops,
        )

    # retrieval: one query vs n_candidates batched dot
    b_sds, b_shd = _batch_specs(cfg, shape.batch, mesh, False)
    cand_sds = jax.ShapeDtypeStruct((shape.n_candidates,), jnp.int32)
    cand_shd = shd.named_sharding(
        mesh, (shd.MODEL,), (shape.n_candidates,))

    def retrieve(params, batch, candidate_ids):
        return recsys.retrieval_step(params, batch, candidate_ids, cfg, mesh)

    return Workload(
        name=f"{cfg.name}/{shape.name}", kind="serve", fn=retrieve,
        in_sds=(p_sds, b_sds, cand_sds),
        in_shardings=(p_shd, b_shd, cand_shd),
        model_flops=fwd_flops
        + 2.0 * shape.batch * shape.n_candidates * cfg.d_retrieval,
    )


ARCH = ArchDef(
    name="dcn-v2", family="recsys", config=CONFIG, smoke_config=SMOKE,
    shapes=RECSYS_SHAPES, workload_fn=recsys_workload,
)
