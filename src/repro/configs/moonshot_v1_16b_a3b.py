"""moonshot-v1-16b-a3b — DeepSeek-style MoE (64 experts, top-6, shared
experts) [hf:moonshotai/Moonlight-16B-A3B]."""

import jax.numpy as jnp

from repro.models.transformer import TransformerConfig

from .common import LM_SHAPES, ArchDef, lm_workload

CONFIG = TransformerConfig(
    name="moonshot-v1-16b-a3b",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=0,                    # all layers MoE (no dense MLP)
    vocab=163840,
    rope_theta=50_000.0,
    moe=True,
    n_experts=64,
    top_k=6,
    d_ff_expert=1408,
    n_shared_experts=2,
    dtype=jnp.bfloat16,
    remat="full",
)

SMOKE = TransformerConfig(
    name="moonshot-smoke",
    n_layers=2,
    d_model=48,
    n_heads=4,
    n_kv_heads=4,
    d_head=12,
    d_ff=0,
    vocab=256,
    rope_theta=50_000.0,
    moe=True,
    n_experts=8,
    top_k=2,
    d_ff_expert=32,
    n_shared_experts=1,
    capacity_factor=8.0,
    dtype=jnp.float32,
    remat="none",
    q_chunk=16,
)

ARCH = ArchDef(
    name="moonshot-v1-16b-a3b", family="lm", config=CONFIG,
    smoke_config=SMOKE, shapes=LM_SHAPES, workload_fn=lm_workload,
)
