"""Workload plumbing shared by all architecture configs.

Every (arch × input-shape) cell resolves to a :class:`Workload`: a step
function plus ShapeDtypeStruct stand-ins and NamedShardings for its inputs.
``launch/dryrun.py`` lowers+compiles these on the production meshes; smoke
tests run reduced configs eagerly on CPU.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import params as prm, sharding as shd, transformer
from repro.training import optimizer


@dataclasses.dataclass
class Workload:
    """One dry-run cell: ``fn(*args)`` with arg stand-ins and shardings."""

    name: str                 # e.g. "granite-8b/train_4k"
    kind: str                 # train | prefill | decode
    fn: Callable
    in_sds: tuple
    in_shardings: tuple
    out_shardings: Any = None
    model_flops: float = 0.0  # 6*N*D (dense) or 6*N_active*D (MoE)


@dataclasses.dataclass(frozen=True)
class LMShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode


LM_SHAPES = (
    LMShape("train_4k", 4_096, 256, "train"),
    LMShape("prefill_32k", 32_768, 32, "prefill"),
    LMShape("decode_32k", 32_768, 128, "decode"),
    LMShape("long_500k", 524_288, 1, "decode"),
)


def _replicated(mesh):
    return NamedSharding(mesh, P())


def lm_active_params(cfg: transformer.TransformerConfig) -> int:
    """Active parameter count (MoE: top_k + shared experts only)."""
    total = prm.count_params(transformer.param_specs(cfg))
    if not cfg.moe:
        return total
    expert = 3 * cfg.d_model * cfg.d_ff_expert
    inactive = cfg.n_layers * (cfg.n_experts - cfg.top_k) * expert
    return total - inactive


def _batch_shards(mesh, b: int) -> int:
    """How many ways the batch dim actually shards on this mesh."""
    import math

    spec = shd.resolve((shd.BATCH,), (b,), mesh)
    axes = spec[0]
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return math.prod(mesh.shape[a] for a in axes)


def choose_microbatches(cfg, shape: LMShape, mesh,
                        carry_budget: float = 2.5e9) -> int:
    """Gradient-accumulation factor bounding scan-carry activation memory.

    The layer scan saves one [b_local/k, S, D] bf16 carry per layer for the
    backward pass; pick the smallest power-of-two k (dividing the per-shard
    batch) that fits them in ``carry_budget`` bytes per device.
    """
    if getattr(cfg, "microbatch_override", 0):
        return cfg.microbatch_override
    b_local = shape.global_batch // _batch_shards(mesh, shape.global_batch)
    k = 1
    while k < b_local:
        carry = (cfg.n_layers * (b_local / k) * shape.seq_len
                 * cfg.d_model * 2)
        if carry <= carry_budget:
            break
        k *= 2
    return k


def lm_train_workload(cfg, shape: LMShape, mesh,
                      opt_cfg: optimizer.AdamWConfig | None = None,
                      microbatches: int | None = None):
    opt_cfg = opt_cfg or optimizer.AdamWConfig()
    specs = transformer.param_specs(cfg)
    p_sds = prm.tree_sds(specs)
    p_shd = prm.tree_shardings(mesh, specs)
    o_sds = optimizer.AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32), mu=p_sds, nu=p_sds)
    o_shd = optimizer.AdamWState(step=_replicated(mesh), mu=p_shd, nu=p_shd)
    b, s = shape.global_batch, shape.seq_len
    tok_sds = jax.ShapeDtypeStruct((b, s), jnp.int32)
    tok_shd = shd.named_sharding(mesh, (shd.BATCH, None), (b, s))
    batch_sds = {"tokens": tok_sds, "targets": tok_sds}
    batch_shd = {"tokens": tok_shd, "targets": tok_shd}
    k = microbatches or choose_microbatches(cfg, shape, mesh)

    def step(params, opt_state, batch):
        if k == 1:
            loss, grads = jax.value_and_grad(transformer.loss_fn)(
                params, batch, cfg, mesh
            )
        else:
            # gradient accumulation over k microbatches (memory bound)
            def mb(carry, mbatch):
                loss_acc, g_acc = carry
                l, g = jax.value_and_grad(transformer.loss_fn)(
                    params, mbatch, cfg, mesh
                )
                g_acc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), g_acc, g)
                return (loss_acc + l, g_acc), None

            split = jax.tree.map(
                lambda x: x.reshape(k, b // k, *x.shape[1:]), batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                mb, (0.0, zeros), split, unroll=cfg.unroll_scans)
            loss = loss / k
            grads = jax.tree.map(lambda g: g / k, grads)
        new_p, new_o, metrics = optimizer.apply_updates(
            opt_cfg, params, grads, opt_state
        )
        metrics["loss"] = loss
        return new_p, new_o, metrics

    tokens = b * s
    return Workload(
        name=f"{cfg.name}/{shape.name}", kind="train", fn=step,
        in_sds=(p_sds, o_sds, batch_sds),
        in_shardings=(p_shd, o_shd, batch_shd),
        out_shardings=(p_shd, o_shd, None),
        model_flops=6.0 * lm_active_params(cfg) * tokens,
    )


def _serve_param_specs(cfg):
    """Inference-time parameters: stored (and gathered) at compute dtype."""
    return jax.tree.map(
        lambda s: s._replace(dtype=cfg.dtype),
        transformer.param_specs(cfg), is_leaf=prm.is_spec,
    )


def lm_prefill_workload(cfg, shape: LMShape, mesh):
    specs = _serve_param_specs(cfg)
    p_sds = prm.tree_sds(specs)
    p_shd = prm.tree_shardings(mesh, specs)
    b, s = shape.global_batch, shape.seq_len
    tok_sds = jax.ShapeDtypeStruct((b, s), jnp.int32)
    tok_shd = shd.named_sharding(mesh, (shd.BATCH, None), (b, s))

    def step(params, tokens):
        logits, _ = transformer.forward(params, tokens, cfg, mesh)
        return logits

    return Workload(
        name=f"{cfg.name}/{shape.name}", kind="prefill", fn=step,
        in_sds=(p_sds, tok_sds), in_shardings=(p_shd, tok_shd),
        model_flops=2.0 * lm_active_params(cfg) * b * s,
    )


def lm_decode_workload(cfg, shape: LMShape, mesh):
    specs = _serve_param_specs(cfg)
    p_sds = prm.tree_sds(specs)
    p_shd = prm.tree_shardings(mesh, specs)
    b, s = shape.global_batch, shape.seq_len
    c_specs = transformer.cache_specs(cfg, b, s)
    c_sds = prm.tree_sds(c_specs)
    c_shd = prm.tree_shardings(mesh, c_specs)
    tok_sds = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    tok_shd = shd.named_sharding(mesh, (shd.BATCH, None), (b, 1))
    len_sds = jax.ShapeDtypeStruct((), jnp.int32)

    def step(params, cache, tokens, cache_len):
        return transformer.serve_step(
            params, cache, tokens, cache_len, cfg, mesh
        )

    return Workload(
        name=f"{cfg.name}/{shape.name}", kind="decode", fn=step,
        in_sds=(p_sds, c_sds, tok_sds, len_sds),
        in_shardings=(p_shd, c_shd, tok_shd, _replicated(mesh)),
        out_shardings=(None, c_shd),
        model_flops=2.0 * lm_active_params(cfg) * b,
    )


def lm_workload(cfg, shape: LMShape, mesh, **kw):
    if shape.kind == "train":
        return lm_train_workload(cfg, shape, mesh, **kw)
    if shape.kind == "prefill":
        return lm_prefill_workload(cfg, shape, mesh)
    return lm_decode_workload(cfg, shape, mesh)


@dataclasses.dataclass
class ArchDef:
    """Registry entry: full config + reduced smoke config + shape table."""

    name: str
    family: str                       # lm | gnn | recsys | mining
    config: Any
    smoke_config: Any
    shapes: tuple
    workload_fn: Callable             # (config, shape, mesh) -> Workload

    def _shape(self, shape_name: str):
        return next(s for s in self.shapes if s.name == shape_name)

    def workload(self, shape_name: str, mesh) -> Workload:
        return self.workload_fn(self.config, self._shape(shape_name), mesh)

    def smoke_workload(self, shape_name: str, mesh) -> Workload:
        return self.workload_fn(
            self.smoke_config, self._shape(shape_name), mesh)

    def workload_with_depth(self, shape_name: str, mesh,
                            n_layers: int) -> Workload | None:
        """Reduced-depth variant for scan-flop calibration (see dryrun).

        Keeps shape-dependent choices (e.g. microbatch count) pinned to the
        full-depth config so the per-layer delta is comparable.
        """
        if not hasattr(self.config, "n_layers"):
            return None
        shape = self._shape(shape_name)
        repl = {"n_layers": n_layers, "unroll_scans": True}
        if hasattr(self.config, "edge_chunk"):
            repl["edge_chunk"] = 0      # count per-edge work in one body
        cfg = dataclasses.replace(self.config, **repl)
        kw = {}
        if self.family == "lm" and getattr(shape, "kind", "") == "train":
            kw["microbatches"] = choose_microbatches(
                self.config, shape, mesh)
        return self.workload_fn(cfg, shape, mesh, **kw)
