"""GNN workload plumbing: shapes, input specs, train-step builders."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import equiformer, gnn, params as prm, sharding as shd
from repro.training import optimizer

from .common import Workload


@dataclasses.dataclass(frozen=True)
class GNNShape:
    name: str
    n_nodes: int
    n_edges: int
    d_feat: int
    n_classes: int
    n_graphs: int = 0            # >0: batched small graphs, graph readout
    note: str = ""


# assigned shape set (4 cells per GNN arch)
GNN_SHAPES = (
    GNNShape("full_graph_sm", 2_708, 10_556, 1_433, 7,
             note="cora full-batch"),
    # 1024 seeds, fanout 15-10 two-hop sample of the 233k-node graph
    GNNShape("minibatch_lg", 169_984, 168_960, 602, 41,
             note="reddit-like sampled subgraph"),
    GNNShape("ogb_products", 2_449_029, 61_859_140, 100, 47,
             note="full-batch-large"),
    GNNShape("molecule", 30 * 128, 64 * 128, 16, 1, n_graphs=128,
             note="batch=128 small molecules (regression)"),
)


def _round_up(x, m):
    return -(-x // m) * m


def graph_input_specs(shape: GNNShape, *, with_positions: bool,
                      edge_mult: int = 1):
    """ShapeDtypeStruct stand-ins for a padded graph batch."""
    n = _round_up(shape.n_nodes, 8)
    e = _round_up(shape.n_edges, max(edge_mult, 512))
    g = {
        "node_feat": jax.ShapeDtypeStruct((n, shape.d_feat), jnp.float32),
        "edge_src": jax.ShapeDtypeStruct((e,), jnp.int32),
        "edge_dst": jax.ShapeDtypeStruct((e,), jnp.int32),
        "node_mask": jax.ShapeDtypeStruct((n,), jnp.bool_),
        "edge_mask": jax.ShapeDtypeStruct((e,), jnp.bool_),
    }
    if with_positions:
        g["positions"] = jax.ShapeDtypeStruct((n, 3), jnp.float32)
    if shape.n_graphs:
        g["graph_ids"] = jax.ShapeDtypeStruct((n,), jnp.int32)
        if shape.n_classes == 1:
            g["targets"] = jax.ShapeDtypeStruct(
                (shape.n_graphs,), jnp.float32)
        else:
            g["labels"] = jax.ShapeDtypeStruct((shape.n_graphs,), jnp.int32)
    else:
        g["labels"] = jax.ShapeDtypeStruct((n,), jnp.int32)
    return g


def graph_shardings(mesh, sds_tree):
    """Edge arrays use the whole mesh on big graphs; (pod, data) otherwise
    (512-way shards of a 10k-edge graph are pure collective overhead)."""
    e_len = sds_tree["edge_src"].shape[0]
    edge_spec = shd.EDGE if e_len > 1_000_000 else shd.BATCH

    def shard(sds):
        lead = edge_spec if sds.shape[0] == e_len else shd.BATCH
        spec = (lead,) + (None,) * (len(sds.shape) - 1)
        return shd.named_sharding(mesh, spec, sds.shape)

    return jax.tree.map(shard, sds_tree)


def _specialize(cfg, shape: GNNShape):
    """Adapt an arch config to a shape's feature/class/readout layout."""
    if isinstance(cfg, equiformer.EquiformerConfig):
        chunk = 262_144 if shape.n_edges > 1_000_000 else 0
        if cfg.unroll_scans:
            chunk = 0    # calibration variants count edges in one body
        return dataclasses.replace(
            cfg, d_node_in=shape.d_feat, n_classes=shape.n_classes,
            readout="graph" if shape.n_graphs else "node",
            n_graphs=shape.n_graphs,
            edge_chunk=chunk,
        )
    return dataclasses.replace(
        cfg, d_in=shape.d_feat, n_classes=shape.n_classes,
        readout="graph" if shape.n_graphs else "node",
        n_graphs=shape.n_graphs,
        # remat pays recompute to bound memory — only worth it at scale
        remat=shape.n_edges > 1_000_000,
    )


def gnn_workload(cfg, shape: GNNShape, mesh,
                 opt_cfg: optimizer.AdamWConfig | None = None) -> Workload:
    opt_cfg = opt_cfg or optimizer.AdamWConfig(weight_decay=0.0)
    is_eq = isinstance(cfg, equiformer.EquiformerConfig)
    cfg = _specialize(cfg, shape)
    if is_eq:
        specs = equiformer.equiformer_param_specs(cfg)
        loss = equiformer.loss_fn
        edge_mult = cfg.edge_chunk or 1
    else:
        specs = gnn.gnn_param_specs(cfg)
        loss = gnn.loss_fn
        edge_mult = 1

    p_sds = prm.tree_sds(specs)
    p_shd = prm.tree_shardings(mesh, specs)
    o_sds = optimizer.AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32), mu=p_sds, nu=p_sds)
    rep = shd.named_sharding(mesh, (), ())
    o_shd = optimizer.AdamWState(step=rep, mu=p_shd, nu=p_shd)
    g_sds = graph_input_specs(shape, with_positions=is_eq,
                              edge_mult=edge_mult)
    g_shd = graph_shardings(mesh, g_sds)

    def step(params, opt_state, batch):
        l, grads = jax.value_and_grad(loss)(params, batch, cfg, mesh)
        new_p, new_o, metrics = optimizer.apply_updates(
            opt_cfg, params, grads, opt_state
        )
        metrics["loss"] = l
        return new_p, new_o, metrics

    # message-passing "model flops": 2 * E * d_hidden^2 matmul-dominated per
    # layer (+ irrep factor for equiformer) — the useful-work yardstick.
    d = cfg.d_hidden
    if is_eq:
        per_edge = sum(
            2 * ((cfg.l_max + 1 - m) * d) ** 2 * (2 if m else 1)
            for m in range(cfg.m_max + 1)
        )
        flops = cfg.n_layers * shape.n_edges * per_edge
    else:
        flops = cfg.n_layers * (2 * shape.n_edges * d
                                + 2 * shape.n_nodes * d * d)
    return Workload(
        name=f"{cfg.name}/{shape.name}", kind="train", fn=step,
        in_sds=(p_sds, o_sds, g_sds), in_shardings=(p_shd, o_shd, g_shd),
        out_shardings=(p_shd, o_shd, None),
        model_flops=3.0 * flops,   # fwd + bwd ~ 3x forward
    )
