"""repro — PTMT (parallel motif-transition discovery) on TPU/JAX.

Subpackages:
  core         the paper's algorithm (TZP + expansion + signed aggregation)
  kernels      Pallas TPU kernels (zone_scan, segment_spmm, embedding_bag)
  models       transformer / gnn / equiformer / recsys substrates
  distributed  shard_map mining, compressed collectives
  training     AdamW, checkpointing, fault-tolerant loop, elastic re-mesh
  serving      KV-cache decode engine
  configs      10 assigned architectures + the paper's mining config
  launch       production meshes, 512-device dry-run, train/mine CLIs
"""

__version__ = "1.0.0"
