from . import lm_pipeline, recsys_pipeline, synthetic_graphs

__all__ = ["lm_pipeline", "recsys_pipeline", "synthetic_graphs"]
