"""Synthetic temporal-graph generators standing in for the paper's datasets.

The 10 real datasets (Email-Eu ... Soc-bitcoin) are not available offline, so
benchmarks use generators that reproduce their salient statistics: power-law
degree, bursty inter-event times (the paper's "long-tailed event
distributions"), and controllable density relative to ``delta``.
"""

from __future__ import annotations

import numpy as np

from repro.core.temporal_graph import TemporalGraph, from_edges


def poisson_stream(
    n_edges: int, n_nodes: int, *, rate: float = 1.0, seed: int = 0
) -> TemporalGraph:
    """Uniform-random endpoints, exponential inter-arrival times."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, n_edges)
    t = np.cumsum(gaps).astype(np.int64)
    u = rng.integers(0, n_nodes, n_edges)
    v = rng.integers(0, n_nodes, n_edges)
    return from_edges(u, v, t)


def powerlaw_stream(
    n_edges: int,
    n_nodes: int,
    *,
    alpha: float = 1.5,
    rate: float = 1.0,
    seed: int = 0,
) -> TemporalGraph:
    """Power-law node popularity (social-network-like hubs)."""
    rng = np.random.default_rng(seed)
    weights = (np.arange(1, n_nodes + 1, dtype=np.float64)) ** (-alpha)
    p = weights / weights.sum()
    u = rng.choice(n_nodes, n_edges, p=p)
    v = rng.choice(n_nodes, n_edges, p=p)
    gaps = rng.exponential(1.0 / rate, n_edges)
    t = np.cumsum(gaps).astype(np.int64)
    return from_edges(u, v, t)


def bursty_stream(
    n_edges: int,
    n_nodes: int,
    *,
    burst_size: int = 20,
    burst_span: int = 60,
    gap_span: int = 3600,
    seed: int = 0,
) -> TemporalGraph:
    """Bursts of correlated activity separated by quiet gaps.

    Reproduces the paper's "rapid burst chains" (Section 5.6) — groups of
    edges among few nodes inside a short window, then a long pause.  This is
    the regime where TZP's adaptive zoning matters (dense zones shrink).
    """
    rng = np.random.default_rng(seed)
    us, vs, ts = [], [], []
    t = 0
    remaining = n_edges
    while remaining > 0:
        k = min(int(rng.integers(1, burst_size + 1)), remaining)
        group = rng.integers(0, n_nodes, size=max(2, k // 3 + 2))
        for _ in range(k):
            a, b = rng.choice(group, 2, replace=True)
            us.append(a)
            vs.append(b)
            ts.append(t + int(rng.integers(0, burst_span)))
        t += gap_span + int(rng.integers(0, gap_span))
        remaining -= k
    return from_edges(np.array(us), np.array(vs), np.array(ts))


def triadic_stream(
    n_edges: int, n_nodes: int, *, window: int = 300, p_close: float = 0.4,
    seed: int = 0,
) -> TemporalGraph:
    """Triadic-closure-biased stream (WikiTalk-like transition profile).

    With probability ``p_close`` a new edge closes an open wedge from the
    recent window, yielding the triangle-heavy transition trees the paper's
    case study reports.
    """
    rng = np.random.default_rng(seed)
    us, vs, ts = [], [], []
    t = 0
    recent: list[tuple[int, int]] = []
    for _ in range(n_edges):
        t += int(rng.integers(1, window // 4 + 1))
        if recent and rng.random() < p_close and len(recent) >= 2:
            a, b = recent[int(rng.integers(0, len(recent)))]
            c = int(rng.integers(0, n_nodes))
            u, v = b, c
            if rng.random() < 0.5:
                u, v = (a, b) if rng.random() < 0.5 else (c, a)
        else:
            u = int(rng.integers(0, n_nodes))
            v = int(rng.integers(0, n_nodes))
        us.append(u)
        vs.append(v)
        ts.append(t)
        recent.append((u, v))
        if len(recent) > 64:
            recent.pop(0)
    return from_edges(np.array(us), np.array(vs), np.array(ts))


DATASET_ANALOGS = {
    # name -> (generator, kwargs) sized as CPU-scale analogs of Table 1
    "collegemsg-like": (poisson_stream, dict(n_edges=20_000, n_nodes=1_899)),
    "email-eu-like": (powerlaw_stream, dict(n_edges=33_000, n_nodes=986)),
    "sms-a-like": (bursty_stream, dict(n_edges=54_000, n_nodes=4_409)),
    "wikitalk-like": (triadic_stream, dict(n_edges=78_000, n_nodes=11_401)),
}


def make(name: str, seed: int = 0) -> TemporalGraph:
    gen, kwargs = DATASET_ANALOGS[name]
    return gen(seed=seed, **kwargs)
