"""Synthetic graph batches matching the GNN shape specs (+ real loaders
would slot in here; offline we generate deterministic stand-ins)."""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp


def random_graph_batch(
    *, n_nodes: int, n_edges: int, d_feat: int, n_classes: int,
    n_graphs: int = 0, with_positions: bool = False, seed: int = 0,
    pad_nodes: int = 0, pad_edges: int = 0,
):
    """Padded, fixed-shape graph batch dict (numpy -> jnp on use)."""
    rng = np.random.default_rng(seed)
    n = max(pad_nodes, n_nodes)
    e = max(pad_edges, n_edges)
    g = {
        "node_feat": np.zeros((n, d_feat), np.float32),
        "edge_src": np.zeros(e, np.int32),
        "edge_dst": np.zeros(e, np.int32),
        "node_mask": np.zeros(n, bool),
        "edge_mask": np.zeros(e, bool),
    }
    g["node_feat"][:n_nodes] = rng.standard_normal(
        (n_nodes, d_feat)).astype(np.float32)
    g["edge_src"][:n_edges] = rng.integers(0, n_nodes, n_edges)
    g["edge_dst"][:n_edges] = rng.integers(0, n_nodes, n_edges)
    g["node_mask"][:n_nodes] = True
    g["edge_mask"][:n_edges] = True
    if with_positions:
        g["positions"] = np.zeros((n, 3), np.float32)
        g["positions"][:n_nodes] = rng.standard_normal(
            (n_nodes, 3)).astype(np.float32)
    if n_graphs:
        per = n_nodes // n_graphs
        gid = np.zeros(n, np.int32)
        gid[:n_nodes] = np.minimum(
            np.arange(n_nodes) // max(per, 1), n_graphs - 1)
        g["graph_ids"] = gid
        if n_classes == 1:
            g["targets"] = rng.standard_normal(n_graphs).astype(np.float32)
        else:
            g["labels"] = rng.integers(
                0, n_classes, n_graphs).astype(np.int32)
    else:
        g["labels"] = rng.integers(0, n_classes, n).astype(np.int32)
    return {k: jnp.asarray(v) for k, v in g.items()}


def make_csr(n_nodes: int, edge_src, edge_dst):
    """CSR adjacency (by destination's incoming? by source's outgoing)."""
    order = np.argsort(edge_src, kind="stable")
    sorted_src = np.asarray(edge_src)[order]
    sorted_dst = np.asarray(edge_dst)[order]
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.add.at(indptr, sorted_src + 1, 1)
    indptr = np.cumsum(indptr)
    return indptr, sorted_dst
