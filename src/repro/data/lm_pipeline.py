"""Synthetic LM token pipeline (fleshed out with the training substrate)."""

from __future__ import annotations

import numpy as np


def synthetic_lm_batch(
    rng: np.random.Generator, *, batch: int, seq_len: int, vocab: int
):
    """One (tokens, targets) pair of int32[batch, seq_len]."""
    tokens = rng.integers(0, vocab, (batch, seq_len), dtype=np.int32)
    targets = np.roll(tokens, -1, axis=1)
    return tokens, targets


def batches(seed: int, *, batch: int, seq_len: int, vocab: int):
    rng = np.random.default_rng(seed)
    while True:
        yield synthetic_lm_batch(rng, batch=batch, seq_len=seq_len,
                                 vocab=vocab)
