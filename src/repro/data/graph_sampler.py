"""Uniform fanout neighbor sampler (GraphSAGE-style) for minibatch_lg.

Host-side numpy: given a CSR adjacency, sample a two-hop (fanout 15-10)
subgraph around a seed batch and emit a padded fixed-shape graph batch whose
layout matches ``configs.gnn_common.graph_input_specs`` — this is the real
sampled-training data path, not a stub.
"""

from __future__ import annotations

import numpy as np


def sample_subgraph(
    indptr, indices, seeds, *, fanouts=(15, 10), rng=None,
    pad_nodes: int | None = None, pad_edges: int | None = None,
):
    """Sample a k-hop subgraph.

    Returns dict with local edge lists (src/dst index into `nodes`),
    `nodes` (global ids, seeds first), and padded masks.
    """
    rng = rng or np.random.default_rng(0)
    seeds = np.asarray(seeds, np.int64)
    node_ids = [seeds]
    edge_src_g, edge_dst_g = [], []
    frontier = seeds
    for fanout in fanouts:
        nxt = []
        for u in frontier:
            lo, hi = int(indptr[u]), int(indptr[u + 1])
            deg = hi - lo
            if deg == 0:
                continue
            take = min(fanout, deg)
            sel = rng.choice(deg, size=take, replace=False)
            nbrs = indices[lo + sel]
            nxt.append(nbrs)
            edge_src_g.append(nbrs)
            edge_dst_g.append(np.full(take, u, np.int64))
        frontier = np.concatenate(nxt) if nxt else np.zeros(0, np.int64)
        node_ids.append(frontier)

    # relabel in first-occurrence order (seeds come first)
    all_ids = np.concatenate(node_ids)
    _, first_pos = np.unique(all_ids, return_index=True)
    nodes = all_ids[np.sort(first_pos)]
    lookup = {int(g): i for i, g in enumerate(nodes)}
    src = np.asarray(
        [lookup[int(g)] for g in np.concatenate(edge_src_g)]
        if edge_src_g else [], np.int32)
    dst = np.asarray(
        [lookup[int(g)] for g in np.concatenate(edge_dst_g)]
        if edge_dst_g else [], np.int32)

    n = pad_nodes or len(nodes)
    e = pad_edges or len(src)
    out = {
        "nodes": np.zeros(n, np.int64),
        "edge_src": np.zeros(e, np.int32),
        "edge_dst": np.zeros(e, np.int32),
        "node_mask": np.zeros(n, bool),
        "edge_mask": np.zeros(e, bool),
        "n_real_nodes": len(nodes),
        "n_real_edges": len(src),
        "n_seeds": len(seeds),
    }
    k_n = min(len(nodes), n)
    k_e = min(len(src), e)
    out["nodes"][:k_n] = nodes[:k_n]
    out["edge_src"][:k_e] = src[:k_e]
    out["edge_dst"][:k_e] = dst[:k_e]
    out["node_mask"][:k_n] = True
    out["edge_mask"][:k_e] = True
    return out
