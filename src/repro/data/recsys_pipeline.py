"""Synthetic recsys (Criteo-like) batch generator for DCN-v2."""

from __future__ import annotations

import numpy as np


def synthetic_recsys_batch(
    rng: np.random.Generator,
    *,
    batch: int,
    n_dense: int,
    n_sparse: int,
    vocab_sizes,
):
    dense = rng.standard_normal((batch, n_dense)).astype(np.float32)
    sparse = np.stack(
        [rng.integers(0, v, batch, dtype=np.int32) for v in vocab_sizes],
        axis=1,
    )
    # CTR-ish label correlated with a few dense features
    logits = dense[:, :3].sum(axis=1) * 0.5
    label = (logits + rng.standard_normal(batch) > 0.5).astype(np.float32)
    return dense, sparse, label
