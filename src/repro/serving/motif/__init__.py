"""Motif analytics service — multi-tenant serving over streaming discovery.

Layers (bottom up):

* :mod:`.query`   — :class:`QueryEngine`: analytics over one immutable
  snapshot (top-k, Table-6 transition probabilities, O(log n) prefix counts
  via the limb encoding's integer-lexicographic order, level histogram).
* :mod:`.cache`   — :class:`EpochCache`: snapshot cache keyed on the
  miner's closed-prefix epoch; invalidation is exact, never TTL-based.
* :mod:`.session` — :class:`MotifSession`: one tenant's StreamingMiner
  behind batched-ingest admission and the cache.
* :mod:`.manager` — :class:`SessionManager`: named multi-tenant registry.
* :mod:`.service` — :class:`MotifService`: dataclass request/response
  protocol; the surface transports and drivers talk to.
"""

from .cache import EpochCache
from .manager import SessionManager
from .query import QueryEngine, TransitionRow
from .service import (
    QUERY_OPS,
    IngestAck,
    MotifService,
    QueryRequest,
    QueryResponse,
)
from .session import MotifSession

__all__ = [
    "EpochCache",
    "IngestAck",
    "MotifService",
    "MotifSession",
    "QUERY_OPS",
    "QueryEngine",
    "QueryRequest",
    "QueryResponse",
    "SessionManager",
    "TransitionRow",
]
