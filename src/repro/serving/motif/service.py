"""Service front: a dataclass request/response protocol over the manager.

:class:`MotifService` is the single surface a transport (HTTP handler, RPC
stub, the replay driver in ``launch/serve_motifs.py``) talks to.  Requests
and responses are plain frozen dataclasses so they serialize trivially and
the protocol is testable without any network layer.  Every response carries
the snapshot ``epoch`` it was answered at — the consistency token a client
can use to correlate answers across queries — plus the server-side latency.
"""

from __future__ import annotations

import dataclasses
import threading
import time

import numpy as np

from repro.obs import get_obs

from .manager import SessionManager
from .query import QueryEngine

#: Query operations understood by :meth:`MotifService.query`.
QUERY_OPS = ("top_k", "transition_probs", "prefix_count", "level_histogram",
             "total")


@dataclasses.dataclass(frozen=True)
class QueryRequest:
    """One analytics query against a tenant session."""

    session: str
    op: str                      # one of QUERY_OPS
    code: str = ""               # motif code for transition/prefix ops
    level: int | None = None     # level filter for top_k
    k: int = 10                  # result bound for top_k


@dataclasses.dataclass(frozen=True)
class QueryResponse:
    session: str
    op: str
    epoch: int                   # snapshot epoch the answer reflects
    latency_s: float
    payload: object
    #: True when this was the first query of its (session, op) pair —
    #: ``latency_s`` then includes one-time costs (JAX trace + compile,
    #: lazy index builds) that steady-state percentiles must exclude.
    first_call: bool = False


@dataclasses.dataclass(frozen=True)
class IngestAck:
    session: str
    accepted: int                # edges buffered by this call
    flushed: bool                # did this call trigger a batch admission
    epoch: int                   # session epoch after the call


class MotifService:
    """Multi-tenant motif analytics over streaming discovery.

    ``manager_kwargs`` flow into :class:`SessionManager` as session
    defaults — ``MotifService(engine=PTMTEngine(cfg), ingest_batch=8192)``
    is the standard deployment: every tenant session mines through the one
    shared engine (one resolved backend, one warm compile cache).
    """

    def __init__(self, manager: SessionManager | None = None, *,
                 obs=None, **manager_kwargs):
        if manager is not None and manager_kwargs:
            raise ValueError("pass either a manager or manager kwargs")
        # the bundle is both the service's own sink (query latency
        # histograms) and the default for every tenant session (it rides
        # the manager's session_defaults into MotifSession(obs=...))
        self.obs = get_obs(obs)
        if manager is None and self.obs.enabled:
            manager_kwargs.setdefault("obs", self.obs)
        self.manager = manager or SessionManager(**manager_kwargs)
        # (session, op) pairs that have answered at least one query — the
        # first query pays one-time compile/index cost and is reported as
        # first_call instead of polluting steady-state latency
        self._warm: set[tuple[str, str]] = set()
        self._warm_lock = threading.Lock()

    # -- tenant lifecycle ---------------------------------------------------

    def create_session(self, name: str, **params):
        return self.manager.create(name, **params)

    def drop_session(self, name: str):
        session = self.manager.drop(name)
        # a re-created tenant starts cold again: forget its warm pairs
        with self._warm_lock:
            self._warm = {k for k in self._warm if k[0] != name}
        return session

    def sessions(self) -> list[str]:
        return self.manager.names()

    # -- ingest -------------------------------------------------------------

    def ingest(self, session: str, u, v, t) -> IngestAck:
        sess = self.manager.get(session)
        # count after the same normalization the session applies, so acks
        # agree with session stats for scalars and multi-dim chunks alike
        n = int(np.asarray(t).size)
        flushed = sess.ingest(u, v, t)
        return IngestAck(session=session, accepted=n, flushed=flushed,
                         epoch=sess.epoch)

    def flush(self, session: str) -> IngestAck:
        sess = self.manager.get(session)
        n = sess.flush()
        return IngestAck(session=session, accepted=n, flushed=n > 0,
                         epoch=sess.epoch)

    def flush_all(self) -> list[IngestAck]:
        acks = []
        for name in self.manager.names():
            try:
                acks.append(self.flush(name))
            except KeyError:       # tenant dropped concurrently — skip it
                continue
        return acks

    def discard_pending(self, session: str) -> int:
        """Drop a session's not-yet-admitted window (rejected-flush recovery)."""
        return self.manager.get(session).discard_pending()

    # -- cross-tenant co-mining ---------------------------------------------

    def comine(self, graph, sessions: list[str] | None = None) -> dict:
        """Batch-mine one graph under every (or the named) tenants' configs.

        Thin delegate to :meth:`SessionManager.comine`: tenant configs that
        differ only in ``delta``/``l_max``/``omega`` share one Phase-1 sweep
        via ``PTMTEngine.discover_many``.  Returns
        ``{tenant_name: DiscoveryResult}`` with counts byte-identical to
        per-tenant independent mining.
        """
        with self.obs.tracer.span("serve.comine",
                                  tenants=len(sessions or self.sessions())):
            return self.manager.comine(graph, sessions)

    # -- query --------------------------------------------------------------

    def query(self, request: QueryRequest) -> QueryResponse:
        if request.op not in QUERY_OPS:
            raise ValueError(
                f"unknown op {request.op!r}; expected one of {QUERY_OPS}"
            )
        sess = self.manager.get(request.session)
        with self._warm_lock:
            first_call = (request.session, request.op) not in self._warm
            if first_call:
                self._warm.add((request.session, request.op))
        with self.obs.tracer.span("serve.query", tenant=request.session,
                                  op=request.op):
            t0 = time.perf_counter()
            # engine() holds the session lock for the cache lookup (and, on
            # the first query of an epoch, the snapshot mine — see
            # MotifSession.engine); dispatch then runs lock-free against
            # the immutable snapshot, so query evaluation itself never
            # blocks ingest
            engine = sess.engine()
            payload = self._dispatch(engine, request)
            latency_s = time.perf_counter() - t0
        # first calls carry one-time trace/compile/index cost; route them
        # to their own histogram so the steady-state series stays honest
        name = ("repro_serving_query_first_call_ms" if first_call
                else "repro_serving_query_latency_ms")
        self.obs.metrics.histogram(
            name, tenant=request.session, op=request.op,
        ).observe(latency_s * 1e3)
        return QueryResponse(
            session=request.session, op=request.op, epoch=engine.epoch,
            latency_s=latency_s, payload=payload, first_call=first_call,
        )

    @staticmethod
    def _dispatch(engine: QueryEngine, request: QueryRequest):
        if request.op == "top_k":
            return engine.top_k_motifs(level=request.level, k=request.k)
        if request.op == "transition_probs":
            return engine.transition_probs(request.code)
        if request.op == "prefix_count":
            return engine.prefix_count(request.code)
        if request.op == "level_histogram":
            return engine.level_histogram()
        return engine.total_processes()            # "total"

    # -- reporting ----------------------------------------------------------

    def stats(self) -> dict:
        return self.manager.stats()
