"""Multi-tenant session registry.

The manager owns the name -> :class:`MotifSession` mapping and nothing else:
per-session concurrency lives on each session's lock, so tenants never
contend with each other on the hot ingest/query paths — the manager lock is
held only for registry mutations and listings.
"""

from __future__ import annotations

import threading

from .session import MotifSession


class SessionManager:
    """Hosts many named tenant sessions with a bounded session count."""

    def __init__(self, *, max_sessions: int = 64, **session_defaults):
        """``session_defaults`` seed every :meth:`create` call — typically
        ``engine=`` (one shared :class:`repro.core.engine.PTMTEngine`, the
        multi-tenant deployment shape: each session's miner shares the
        engine's warm executor) or ``config=`` plus serving knobs like
        ``ingest_batch``; per-tenant ``create(**params)`` overrides win.
        ``obs=`` (an :class:`repro.obs.Observability` bundle) is a valid
        default too — every tenant session then emits into one registry,
        with per-tenant series split by the ``tenant`` label."""
        if max_sessions < 1:
            raise ValueError("max_sessions must be >= 1")
        self.max_sessions = int(max_sessions)
        self.session_defaults = dict(session_defaults)
        self._sessions: dict[str, MotifSession] = {}
        self._lock = threading.Lock()

    def __len__(self) -> int:
        return len(self._sessions)

    def create(self, name: str, **params) -> MotifSession:
        """Create a tenant session; defaults fill any unspecified params."""
        merged = {**self.session_defaults, **params}
        with self._lock:
            if name in self._sessions:
                raise ValueError(f"session {name!r} already exists")
            if len(self._sessions) >= self.max_sessions:
                raise RuntimeError(
                    f"session limit reached ({self.max_sessions}); "
                    f"drop a tenant before creating {name!r}"
                )
            session = MotifSession(name, **merged)
            self._sessions[name] = session
            return session

    def get(self, name: str) -> MotifSession:
        with self._lock:
            try:
                return self._sessions[name]
            except KeyError:
                raise KeyError(f"unknown session {name!r}") from None

    def drop(self, name: str) -> MotifSession:
        """Remove and return a session (its miner state stays usable)."""
        with self._lock:
            try:
                return self._sessions.pop(name)
            except KeyError:
                raise KeyError(f"unknown session {name!r}") from None

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._sessions)

    def stats(self) -> dict:
        with self._lock:
            sessions = list(self._sessions.values())
        per_session = [s.stats() for s in sessions]
        return {
            "n_sessions": len(per_session),
            "max_sessions": self.max_sessions,
            "edges_accepted": sum(s["edges_accepted"] for s in per_session),
            "queries": sum(s["queries"] for s in per_session),
            "snapshots_mined": sum(s["snapshots_mined"] for s in per_session),
            "cache_hits": sum(s["cache"]["hits"] for s in per_session),
            "cache_misses": sum(s["cache"]["misses"] for s in per_session),
            "sessions": per_session,
        }
