"""Multi-tenant session registry.

The manager owns the name -> :class:`MotifSession` mapping and nothing else:
per-session concurrency lives on each session's lock, so tenants never
contend with each other on the hot ingest/query paths — the manager lock is
held only for registry mutations and listings.  Session *construction* is
deliberately outside the lock: building a :class:`MotifSession` can resolve
a backend, validate a config, and touch jit state, and one slow (or
failing) tenant must not stall every other tenant's ``create``/``get``.
The name is reserved under the lock first, so concurrent creates of the
same name still race safely.
"""

from __future__ import annotations

import threading

from .session import MotifSession

#: Placeholder parked in the registry while a session is being constructed
#: outside the manager lock.  Reserved names count toward ``max_sessions``
#: and reject duplicate ``create`` calls, but are invisible to ``get`` /
#: ``drop`` / ``names`` / ``stats`` until construction commits.
_RESERVED = object()


class SessionManager:
    """Hosts many named tenant sessions with a bounded session count."""

    def __init__(self, *, max_sessions: int = 64, **session_defaults):
        """``session_defaults`` seed every :meth:`create` call — typically
        ``engine=`` (one shared :class:`repro.core.engine.PTMTEngine`, the
        multi-tenant deployment shape: each session's miner shares the
        engine's warm executor) or ``config=`` plus serving knobs like
        ``ingest_batch``; per-tenant ``create(**params)`` overrides win.
        ``obs=`` (an :class:`repro.obs.Observability` bundle) is a valid
        default too — every tenant session then emits into one registry,
        with per-tenant series split by the ``tenant`` label."""
        if max_sessions < 1:
            raise ValueError("max_sessions must be >= 1")
        self.max_sessions = int(max_sessions)
        self.session_defaults = dict(session_defaults)
        self._sessions: dict[str, MotifSession] = {}
        self._lock = threading.Lock()
        # lazily-built fallback engine for comine() when tenants don't
        # share a mining engine (config/kwargs-built sessions)
        self._comine_engine = None

    def __len__(self) -> int:
        with self._lock:
            return len(self._sessions)

    def create(self, name: str, **params) -> MotifSession:
        """Create a tenant session; defaults fill any unspecified params.

        The name is reserved under the manager lock, then the session is
        constructed with the lock *released* — a slow or failing construct
        never blocks other tenants.  On any construction failure the
        reservation is rolled back, so the name is immediately reusable.
        """
        merged = {**self.session_defaults, **params}
        return self._admit(name, lambda: MotifSession(name, **merged))

    def restore(self, state: dict, **params) -> MotifSession:
        """Rebuild a tenant session from a checkpointed state capture.

        ``state`` is a :meth:`MotifSession.checkpoint_state` dict (the
        cluster layer hands over the decoded payload of a
        :class:`~repro.serving.cluster.checkpoint.SessionCheckpoint`).
        A fresh session is built for the checkpointed config — when the
        manager's defaults carry a shared ``engine=``, the checkpointed
        config is expressed as per-tenant overrides of the engine's config
        so the warm executor is still shared whenever the configs agree —
        and the captured miner + admission state is installed before the
        session becomes visible to ``get``/``names``.  Restored counts are
        byte-identical to a session that never stopped (asserted in
        ``tests/test_cluster.py``).
        """
        from repro.core.config import MiningConfig

        name = state["name"]
        cfg = MiningConfig.from_json(state["miner"]["config"])
        merged = {**self.session_defaults, **params}
        if merged.get("engine") is not None:
            # per-tenant overrides of the shared engine's config; empty
            # when they agree, so the warm executor is shared
            eng_cfg = merged["engine"].config
            merged.update({
                k: v for k, v in cfg.to_dict().items()
                if getattr(eng_cfg, k) != v
            })
        else:
            merged.pop("config", None)
            merged["config"] = cfg

        def build() -> MotifSession:
            session = MotifSession(name, **merged)
            session.restore_state(state)
            return session

        return self._admit(name, build)

    def _admit(self, name: str, build) -> MotifSession:
        """Reserve ``name``, run ``build()`` outside the lock, publish."""
        with self._lock:
            if name in self._sessions:
                raise ValueError(f"session {name!r} already exists")
            if len(self._sessions) >= self.max_sessions:
                raise RuntimeError(
                    f"session limit reached ({self.max_sessions}); "
                    f"drop a tenant before creating {name!r}"
                )
            self._sessions[name] = _RESERVED
        try:
            session = build()
        except BaseException:
            with self._lock:
                if self._sessions.get(name) is _RESERVED:
                    del self._sessions[name]
            raise
        with self._lock:
            self._sessions[name] = session
        return session

    def get(self, name: str) -> MotifSession:
        with self._lock:
            session = self._sessions.get(name)
        if session is None or session is _RESERVED:
            raise KeyError(f"unknown session {name!r}")
        return session

    def drop(self, name: str) -> MotifSession:
        """Remove and return a session (its miner state stays usable)."""
        with self._lock:
            session = self._sessions.get(name)
            if session is None or session is _RESERVED:
                # a reservation is an in-flight create, not a droppable
                # session — callers see it only once construction commits
                raise KeyError(f"unknown session {name!r}")
            del self._sessions[name]
            return session

    def names(self) -> list[str]:
        with self._lock:
            return sorted(n for n, s in self._sessions.items()
                          if s is not _RESERVED)

    def _snapshot(self) -> list[MotifSession]:
        with self._lock:
            return [s for s in self._sessions.values() if s is not _RESERVED]

    def stats(self) -> dict:
        per_session = [s.stats() for s in self._snapshot()]
        return {
            "n_sessions": len(per_session),
            "max_sessions": self.max_sessions,
            "edges_accepted": sum(s["edges_accepted"] for s in per_session),
            "queries": sum(s["queries"] for s in per_session),
            "snapshots_mined": sum(s["snapshots_mined"] for s in per_session),
            "cache_hits": sum(s["cache"]["hits"] for s in per_session),
            "cache_misses": sum(s["cache"]["misses"] for s in per_session),
            "sessions": per_session,
        }

    # -- cross-tenant co-mining ---------------------------------------------

    def comine(self, graph, names: list[str] | None = None) -> dict:
        """Mine one graph under every selected tenant's config, co-scheduled.

        The tenants' :class:`~repro.core.config.MiningConfig`\\ s are handed
        to ``PTMTEngine.discover_many``, which groups configs differing only
        in ``delta``/``l_max``/``omega`` into lattices and runs ONE shared
        Phase-1 sweep per lattice instead of one per tenant.  Counts are
        identical to per-tenant ``engine.discover`` calls.

        Returns ``{tenant_name: DiscoveryResult}``.  When every selected
        session was built from the same shared engine (the standard
        deployment), that engine runs the sweep — its compile caches stay
        warm; otherwise a manager-level engine is built lazily from the
        first tenant's config.

        A tenant dropped between auto-selection (``names=None``) and the
        mine is silently skipped — the registry moved on and the caller
        asked for "everyone current", not a fixed set.  Explicitly named
        tenants are a fixed set: a missing one raises ``KeyError``.
        """
        explicit = names is not None
        selected = list(names) if explicit else self.names()
        sessions, kept = [], []
        for n in selected:
            try:
                sessions.append(self.get(n))
            except KeyError:
                if explicit:
                    raise
                continue        # dropped mid-call under auto-selection
            kept.append(n)
        selected = kept
        if not sessions:
            return {}
        engines = {id(s.mining_engine): s.mining_engine
                   for s in sessions if s.mining_engine is not None}
        if len(engines) == 1:
            engine = next(iter(engines.values()))
        else:
            with self._lock:
                if self._comine_engine is None:
                    from repro.core.engine import PTMTEngine

                    self._comine_engine = PTMTEngine(sessions[0].config)
                engine = self._comine_engine
        results = engine.discover_many(graph, [s.config for s in sessions])
        return dict(zip(selected, results))
