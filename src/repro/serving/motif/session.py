"""One tenant session: a StreamingMiner behind admission + snapshot cache.

A session decouples the tenant-facing arrival rate from the miner's frontier
advance.  Arriving edges land in a cheap **admission buffer** and are flushed
to :meth:`StreamingMiner.ingest` only when ``ingest_batch`` edges have
accumulated (or on an explicit :meth:`flush`) — one sorted `ingest()` per
batch amortizes the per-call Python and device-dispatch overhead that
dominates small-chunk streaming.  The admission window also stable-sorts by
timestamp, so arrivals that are slightly out of order *within* one window
are repaired for free; ordering across windows is still enforced by the
miner.

Queries are served from an epoch-keyed :class:`EpochCache` of
:class:`QueryEngine` objects built over ``miner.snapshot()``.  Because the
miner's ``epoch`` bumps exactly when the closed prefix changes, repeated
queries between finalizations reuse the cached engine (no re-mine) and
invalidation is exact — never time-based.

Consistency model: query answers reflect the **closed prefix of admitted
edges** — everything with ``t < t_head - L_b`` where ``t_head`` is the
newest admitted timestamp (exact by Lemma 4.2, see ``core/streaming.py``).
Edges still in the admission buffer become visible at the next flush.
"""

from __future__ import annotations

import threading

import numpy as np

from repro.core.config import MiningConfig
from repro.core.streaming import StreamingMiner, validate_edge_chunk
from repro.obs import get_obs

from .cache import EpochCache
from .query import QueryEngine


class MotifSession:
    """A named tenant stream with its own miner, buffer, and cache.

    Mining parameters come in one of three equivalent ways (most to least
    preferred): ``engine=`` — a :class:`repro.core.engine.PTMTEngine`
    whose config *and* warm executor the session's miner shares (the
    serving deployment shape: many tenants, one engine; individual kwargs
    alongside it are per-tenant overrides of the engine's config, routed
    through ``engine.stream(**overrides)``); ``config=`` — a validated
    :class:`~repro.core.config.MiningConfig`; or the legacy individual
    kwargs alone (a config is built and validated internally, ``delta`` and
    ``l_max`` required).  ``engine`` and ``config`` together are ambiguous
    and rejected.  ``ingest_batch`` / ``cache_capacity`` are serving-side
    knobs and stay per-session.
    """

    def __init__(
        self,
        name: str,
        *,
        engine=None,
        config: MiningConfig | None = None,
        delta: int | None = None,
        l_max: int | None = None,
        omega: int | None = None,
        e_cap: int | None = None,
        backend: str | None = None,
        zone_chunk: int | None = None,
        agg: str | None = None,
        merge_cap: int | None = None,
        memory_budget_mb: float | None = None,
        ingest_batch: int = 4096,
        cache_capacity: int = 2,
        obs=None,
    ):
        if ingest_batch < 1:
            raise ValueError("ingest_batch must be >= 1")
        self.name = name
        self.ingest_batch = int(ingest_batch)
        legacy = {k: v for k, v in dict(
            delta=delta, l_max=l_max, omega=omega, e_cap=e_cap,
            backend=backend, zone_chunk=zone_chunk, agg=agg,
            merge_cap=merge_cap, memory_budget_mb=memory_budget_mb,
        ).items() if v is not None}
        if engine is not None:
            if config is not None:
                raise ValueError(
                    "pass either an engine or a config, not both")
            self.miner = engine.stream(**legacy)
        else:
            self.miner = StreamingMiner(config=config, **legacy)
        # NB: distinct from the .engine() *method*, which returns the
        # per-epoch QueryEngine — mining_engine is the PTMTEngine this
        # session was built from (None on the config/kwargs paths)
        self.mining_engine = engine
        # obs resolution: explicit bundle > shared engine's > miner's own
        # (NULL unless the miner was given one).  When the session's bundle
        # is live and the miner's is not, adopt it on the miner too so
        # stream.* spans and gauges land in the same export.
        if obs is not None:
            self.obs = get_obs(obs)
        elif engine is not None:
            self.obs = engine.obs
        else:
            self.obs = self.miner.obs
        if self.obs.enabled and not self.miner.obs.enabled:
            self.miner.obs = self.obs
        # tag the miner's metric series with the tenant name
        self.miner.obs_label = name
        self.config = self.miner.config
        self.cache = EpochCache(cache_capacity)
        self.lock = threading.RLock()
        self._pend_u: list[np.ndarray] = []
        self._pend_v: list[np.ndarray] = []
        self._pend_t: list[np.ndarray] = []
        self._pending = 0
        self.edges_accepted = 0
        self.edges_discarded = 0
        self.flushes = 0
        self.snapshots_mined = 0
        self.queries = 0

    # -- state --------------------------------------------------------------

    @property
    def epoch(self) -> int:
        return self.miner.epoch

    @property
    def closed_time(self) -> int | None:
        return self.miner.closed_time

    @property
    def pending_edges(self) -> int:
        return self._pending

    # -- ingest path --------------------------------------------------------

    def ingest(self, u, v, t) -> bool:
        """Buffer one edge chunk; returns True if it triggered a flush.

        Chunks are validated *before* buffering (integer dtypes, values in
        int32/int64 range — see :func:`validate_edge_chunk`); a bad chunk
        raises ``ValueError`` and leaves the admission window untouched.
        """
        u, v, t = validate_edge_chunk(u, v, t)
        with self.lock:
            if t.size:
                self._pend_u.append(u)
                self._pend_v.append(v)
                self._pend_t.append(t)
                self._pending += int(t.size)
                self.edges_accepted += int(t.size)
            if self._pending >= self.ingest_batch:
                self._flush_locked()
                return True
            self._note_pending()
            return False

    def flush(self) -> int:
        """Admit everything buffered; returns the number of edges admitted."""
        with self.lock:
            return self._flush_locked()

    def discard_pending(self) -> int:
        """Drop the not-yet-admitted window; returns the edges discarded.

        The recovery path after a rejected flush (an edge older than the
        stream head): without it the bad window would poison every later
        flush.  Admitted state is untouched.
        """
        with self.lock:
            n = self._pending
            self._pend_u, self._pend_v, self._pend_t = [], [], []
            self._pending = 0
            self.edges_discarded += n
            self._note_pending()
            return n

    def _note_pending(self) -> None:
        if self.obs.enabled:
            self.obs.metrics.gauge("repro_serving_pending_edges",
                                   tenant=self.name).set(self._pending)

    def _flush_locked(self) -> int:
        n = self._pending
        if n == 0:
            return 0
        u = np.concatenate(self._pend_u)
        v = np.concatenate(self._pend_v)
        t = np.concatenate(self._pend_t)
        order = np.argsort(t, kind="stable")
        # the miner validates ordering before mutating any state, so on a
        # rejected window (e.g. an edge older than the stream head) the
        # buffer is kept intact for the caller to inspect or drop — edges
        # are never silently lost
        with self.obs.tracer.span("serve.flush", tenant=self.name, edges=n):
            self.miner.ingest(u[order], v[order], t[order])
        self._pend_u, self._pend_v, self._pend_t = [], [], []
        self._pending = 0
        self.flushes += 1
        self._note_pending()
        return n

    # -- query path ---------------------------------------------------------

    def engine(self) -> QueryEngine:
        """Engine for the current epoch; mines a snapshot only on cache miss.

        The miss path mines **outside** the session lock: the lock is held
        only to freeze an immutable :class:`~repro.core.streaming.
        SnapshotView` of the closed prefix (O(#codes), no device work) and
        again to compare-and-swap the mined engine into the cache — so the
        first query of an epoch no longer stalls concurrent ``ingest`` for
        the duration of the mine (the historical stall, regression-tested
        in ``tests/test_motif_service.py``).  If two queries race the same
        cold epoch both mine, but only the first CAS wins and both return
        the winning engine; equal epochs guarantee equal snapshots, so the
        loser's work is redundant, never wrong.  The returned engine is
        immutable and stamped with its epoch, so everything after the
        fetch (query evaluation, lazy index builds) also runs lock-free.
        """
        with self.lock:
            self.queries += 1
            epoch = self.miner.epoch
            engine = self.cache.get(epoch)
            if engine is not None:
                self.obs.metrics.counter(
                    "repro_serving_snapshot_cache_hits_total",
                    tenant=self.name).inc()
                return engine
            view = self.miner.freeze()
        # device mining happens here, with the lock RELEASED — ingest
        # proceeds concurrently against the buffers the view froze
        with self.obs.tracer.span("serve.snapshot",
                                  tenant=self.name, epoch=epoch):
            result, tail = self.miner.mine_view(view)
        engine = QueryEngine(result, epoch=epoch)
        with self.lock:
            self.miner.adopt_tail(view, tail)
            self.snapshots_mined += 1
            self.obs.metrics.counter(
                "repro_serving_snapshot_cache_misses_total",
                tenant=self.name).inc()
            current = self.cache.peek(epoch)
            if current is None:
                self.cache.put(epoch, engine)
            else:
                engine = current     # a racing query won the CAS; serve its
            return engine            # engine (identical counts by epoch)

    # -- checkpoint/restore --------------------------------------------------

    def checkpoint_state(self) -> dict:
        """Consistent durable capture of this tenant's state.

        Taken under the session lock, so the miner state and the admission
        window are from one instant: the miner's
        :meth:`~repro.core.streaming.StreamingMiner.state_dict` plus the
        not-yet-admitted pending edges and the ingest-side counters.
        Query-side state (snapshot cache, query counters) is deliberately
        *not* durable — it is a pure re-derivable function of the miner
        state and rebuilds on first use after restore.
        """
        with self.lock:
            if self._pending:
                pend_u = np.concatenate(self._pend_u)
                pend_v = np.concatenate(self._pend_v)
                pend_t = np.concatenate(self._pend_t)
            else:
                pend_u = np.zeros(0, np.int32)
                pend_v = np.zeros(0, np.int32)
                pend_t = np.zeros(0, np.int64)
            return {
                "name": self.name,
                "miner": self.miner.state_dict(),
                "pend_u": pend_u, "pend_v": pend_v, "pend_t": pend_t,
                "edges_accepted": self.edges_accepted,
                "edges_discarded": self.edges_discarded,
                "flushes": self.flushes,
            }

    def restore_state(self, state: dict) -> None:
        """Install a :meth:`checkpoint_state` capture into this session.

        Restore into a **freshly built** session for the same tenant name
        (the manager's ``restore`` does this): the miner validates that
        its config and tail-layout signature match the checkpointed ones,
        the admission window is re-buffered, and ingest-side counters
        resume.  Continuing the same edge stream afterwards yields state
        byte-identical to a session that never stopped.
        """
        if state["name"] != self.name:
            raise ValueError(
                f"checkpoint is for tenant {state['name']!r}, "
                f"not {self.name!r}")
        u, v, t = validate_edge_chunk(
            state["pend_u"], state["pend_v"], state["pend_t"])
        with self.lock:
            self.miner.restore_state(state["miner"])
            self._pend_u = [u] if t.size else []
            self._pend_v = [v] if t.size else []
            self._pend_t = [t] if t.size else []
            self._pending = int(t.size)
            self.edges_accepted = int(state["edges_accepted"])
            self.edges_discarded = int(state["edges_discarded"])
            self.flushes = int(state["flushes"])
            self._note_pending()

    # -- reporting ----------------------------------------------------------

    def stats(self) -> dict:
        with self.lock:
            return {
                "name": self.name,
                "epoch": self.miner.epoch,
                "edges_accepted": self.edges_accepted,
                "edges_discarded": self.edges_discarded,
                "edges_admitted": self.miner.n_edges_ingested,
                "pending_edges": self._pending,
                "flushes": self.flushes,
                "zones_finalized": self.miner.n_zones_finalized,
                "edges_retired": self.miner.n_edges_retired,
                "buffered_edges": self.miner.buffered_edges,
                "queries": self.queries,
                "snapshots_mined": self.snapshots_mined,
                "cache": self.cache.stats(),
                # miner-level reuse of finalized partial counts + the
                # open-tail mine (exact, epoch-keyed — even when this
                # session's engine cache evicted the epoch, a re-snapshot
                # within the same epoch does no device mining)
                "tail_cache_hits": self.miner.tail_cache_hits,
                "tail_cache_misses": self.miner.tail_cache_misses,
            }
