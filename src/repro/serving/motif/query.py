"""Query engine over one immutable :class:`DiscoveryResult` snapshot.

Final-code counts are sufficient statistics for every query the service
answers (see :mod:`repro.core.transitions`), so an engine is built once per
snapshot epoch and all derived indexes — the transition tree and the
integer-lexicographic code index — are materialized lazily and then shared
by every query against that epoch.

``prefix_count`` exploits the limb encoding's ordering guarantee
(:func:`repro.core.encoding.prefix_range_np`): codes sharing a transition
prefix form one contiguous range in integer-lexicographic limb order, so the
count of processes that *reached* a motif is two binary searches over a
sorted byte-key index plus one prefix-sum subtraction — O(log n) per query
instead of a scan over all motif types.
"""

from __future__ import annotations

import bisect
import dataclasses

import numpy as np

from repro.core import encoding, transitions
from repro.core.api import DiscoveryResult


@dataclasses.dataclass(frozen=True)
class TransitionRow:
    """One Table-6 row: an observed next step from a motif and its share."""

    code: str      # child motif code (one edge longer)
    count: int     # processes that reached the child
    share: float   # fraction of the parent's evolved processes


#: Labels are first-occurrence node indices, at most ``l_max`` (paper cap
#: 14), so valid code digits are exactly the hex characters 0..e.
_CODE_ALPHABET = frozenset("0123456789abcde")


def _check_code(code: str, l_max: int) -> bool:
    """Validate structure; return whether the code is observable at all.

    Odd length is a malformed request (two digits per edge) and raises;
    codes outside the label alphabet or longer than ``l_max`` edges are
    well-formed but unobservable — no process can ever carry them — so
    callers treat those as cheap misses (count 0, no rows), not errors.
    """
    if len(code) % 2 != 0:
        raise ValueError(
            f"motif code {code!r} has odd length; transition prefixes "
            "carry two digits per edge"
        )
    return (len(code) <= 2 * l_max
            and all(c in _CODE_ALPHABET for c in code))


class QueryEngine:
    """Read-only analytics over one snapshot; safe to share across readers.

    ``epoch`` is the session epoch the snapshot was mined at — the
    consistency token responses carry.  Lazy index builds race benignly
    under concurrent readers: every build derives the same immutable data
    from the same immutable snapshot.
    """

    def __init__(self, result: DiscoveryResult, epoch: int = 0):
        self.result = result
        self.epoch = epoch
        self._tree: transitions.TransitionTree | None = None
        # assigned as one tuple so concurrent lazy builds stay atomic
        self._index: tuple[list[bytes], np.ndarray] | None = None

    # -- lazily built indexes ----------------------------------------------

    @property
    def tree(self) -> transitions.TransitionTree:
        if self._tree is None:
            self._tree = transitions.build_tree(self.result.counts)
        return self._tree

    def _code_index(self) -> tuple[list[bytes], np.ndarray]:
        index = self._index
        if index is None:
            l_max = self.result.l_max
            rows = sorted(
                (encoding.code_key_np(
                    encoding.encode_label_string_np(code, l_max)), cnt)
                for code, cnt in self.result.counts.items()
            )
            index = ([k for k, _ in rows],
                     np.cumsum([c for _, c in rows], dtype=np.int64))
            self._index = index
        return index

    # -- queries ------------------------------------------------------------

    def top_k_motifs(self, level: int | None = None,
                     k: int = 10) -> list[tuple[str, int]]:
        """Most frequent final motifs, optionally restricted to one level."""
        if k < 1:
            raise ValueError("k must be >= 1")
        items = (
            (code, cnt) for code, cnt in self.result.counts.items()
            if level is None or len(code) // 2 == level
        )
        return sorted(items, key=lambda kv: (-kv[1], kv[0]))[:k]

    def transition_probs(self, code: str = "") -> list[TransitionRow]:
        """Observed next steps from ``code`` (Table 6 as predictions).

        Shares sum to 1 over the rows whenever any process evolved past
        ``code``; an unobserved code yields no rows rather than an error so
        speculative lookups stay cheap for callers.
        """
        if not _check_code(code, self.result.l_max):
            return []
        try:
            node = self.tree.node(code) if code else self.tree.root
        except KeyError:
            return []
        return [TransitionRow(code=c, count=n, share=s)
                for c, n, s in node.transition_rows()]

    def prefix_count(self, code: str = "") -> int:
        """Processes whose transition process passed through ``code``."""
        if not _check_code(code, self.result.l_max):
            return 0
        keys, cum = self._code_index()
        if not keys:
            return 0
        if not code:
            return int(cum[-1])
        lo, hi = encoding.prefix_range_np(code, self.result.l_max)
        i = bisect.bisect_left(keys, encoding.code_key_np(lo))
        j = bisect.bisect_right(keys, encoding.code_key_np(hi))
        if j <= i:
            return 0
        return int(cum[j - 1] - (cum[i - 1] if i else 0))

    def level_histogram(self) -> dict[int, int]:
        return self.result.level_histogram()

    def total_processes(self) -> int:
        return self.result.total_processes()
