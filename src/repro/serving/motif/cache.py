"""Epoch-keyed snapshot cache — exact invalidation, no TTLs.

The streaming miner's ``epoch`` counter (see
:attr:`repro.core.streaming.StreamingMiner.epoch`) bumps exactly when the
closed prefix — and therefore the snapshot — can change.  Caching query
state keyed on that epoch makes repeated queries between finalizations free
(no re-mine) while staying provably fresh: a stale entry cannot be served
because the key itself is the consistency token.

The cache is deliberately tiny: sessions only ever query the newest epoch,
so ``capacity`` is a small LRU bound that tolerates a reader briefly holding
an older engine, not a memory pool.
"""

from __future__ import annotations

from collections import OrderedDict


class EpochCache:
    """Small LRU mapping ``epoch -> value`` with hit/miss accounting."""

    def __init__(self, capacity: int = 2):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self._entries: OrderedDict[int, object] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, epoch: int):
        """Return the cached value for ``epoch`` or ``None`` (and count it)."""
        try:
            value = self._entries[epoch]
        except KeyError:
            self.misses += 1
            return None
        self._entries.move_to_end(epoch)
        self.hits += 1
        return value

    def peek(self, epoch: int):
        """Uncounted lookup (no hit/miss, no LRU bump).

        The compare-and-swap re-check after a lock-free snapshot mine:
        the racing reader already paid (and recorded) its miss, so the
        re-check must not double-count or reorder the LRU.
        """
        return self._entries.get(epoch)

    def put(self, epoch: int, value) -> None:
        self._entries[epoch] = value
        self._entries.move_to_end(epoch)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.evictions += 1

    def stats(self) -> dict[str, int]:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "entries": len(self._entries),
        }
