"""Minimal batched serving engine over transformer.serve_step.

Continuous-batching-lite: a fixed slot pool; finished sequences free their
slot, queued requests claim it and prefill token-by-token (correct if not
maximally fast on CPU; the decode path is the same jitted ``serve_step``
the dry-run lowers at scale).
"""

from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.models import transformer


@dataclasses.dataclass
class Request:
    prompt: list
    max_new_tokens: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, cfg, params, *, slots: int = 4, max_len: int = 256):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.cache = transformer.init_cache(cfg, slots, max_len)
        self.positions = np.zeros(slots, np.int64)
        self.active: list[Request | None] = [None] * slots
        self._step = jax.jit(
            lambda p, c, t, i: transformer.serve_step(
                p, c, t, i, cfg, None
            )
        )

    def _feed_token(self, slot: int, token: int) -> int:
        """Insert one token at the slot's position, return argmax token."""
        toks = np.zeros((self.slots, 1), np.int32)
        toks[slot, 0] = token
        logits, self.cache = self._step(
            self.params, self.cache, jnp.asarray(toks),
            jnp.asarray(int(self.positions[slot]), jnp.int32),
        )
        self.positions[slot] += 1
        return int(jnp.argmax(logits[slot]))

    def run(self, requests: list[Request]) -> list[Request]:
        queue = list(requests)
        while queue or any(r is not None for r in self.active):
            # admit
            for s in range(self.slots):
                if self.active[s] is None and queue:
                    req = queue.pop(0)
                    self.active[s] = req
                    self.positions[s] = 0
                    # prefill (token by token through the decode path)
                    nxt = 0
                    for tok in req.prompt:
                        nxt = self._feed_token(s, tok)
                    req.out.append(nxt)
            # decode one token for every active slot
            for s in range(self.slots):
                req = self.active[s]
                if req is None:
                    continue
                if (len(req.out) >= req.max_new_tokens
                        or self.positions[s] >= self.max_len - 1):
                    req.done = True
                    self.active[s] = None
                    continue
                req.out.append(self._feed_token(s, req.out[-1]))
        return requests
