"""Durable tenant checkpoints — versioned, atomic, CRC-verified.

The paper's TZP decomposition (Lemma 4.2) makes a streaming session's
durable state *small and exact*: the frozen :class:`~repro.core.config.
MiningConfig`, the finalized closed-prefix counts plus the epoch/closure
signature, and the still-open tail buffer.  Everything else — snapshot
caches, query engines, compiled executables — is a pure re-derivable
function of that state and is deliberately excluded, so a checkpoint is a
few counts and one tail window, not a dump of device memory.  Restoring
replays only the open tail; the byte-identity guarantee is asserted in
``tests/test_cluster.py`` and by the CI kill/restart smoke.

On-disk format (one JSON document per tenant)::

    {"format": "repro.session-checkpoint", "version": 1, "crc32": <int>,
     "tenant": <name>, "meta": {...}, "payload": {...}}

``payload`` is the :meth:`MotifSession.checkpoint_state` capture with
numpy arrays base64-encoded; ``meta`` is caller-owned replay bookkeeping
(the harness stores per-tenant stream offsets so a restart knows where to
resume the feed).  ``crc32`` covers the canonical JSON encoding of
``{tenant, meta, payload}`` — a truncated or bit-flipped file fails loudly
with :class:`CheckpointError` instead of restoring silently-wrong counts.
Writes go through a temp file + ``os.replace`` so a crash mid-write leaves
the previous checkpoint intact: the store never holds a torn file.
"""

from __future__ import annotations

import base64
import dataclasses
import json
import os
import re
import tempfile
import zlib

import numpy as np

FORMAT_NAME = "repro.session-checkpoint"
FORMAT_VERSION = 1

_NDARRAY_KEY = "__ndarray__"


class CheckpointError(RuntimeError):
    """A checkpoint file is missing, corrupt, or from an unknown format."""


def _encode(obj):
    """JSON-safe encoding of a checkpoint payload (numpy-aware)."""
    if isinstance(obj, np.ndarray):
        return {
            _NDARRAY_KEY: base64.b64encode(
                np.ascontiguousarray(obj).tobytes()).decode("ascii"),
            "dtype": str(obj.dtype),
            "shape": list(obj.shape),
        }
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, np.floating):
        return float(obj)
    if isinstance(obj, dict):
        return {str(k): _encode(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_encode(v) for v in obj]
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    raise TypeError(f"cannot checkpoint value of type {type(obj).__name__}")


def _decode(obj):
    if isinstance(obj, dict):
        if _NDARRAY_KEY in obj:
            raw = base64.b64decode(obj[_NDARRAY_KEY])
            return np.frombuffer(raw, dtype=np.dtype(obj["dtype"])).reshape(
                obj["shape"]).copy()
        return {k: _decode(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_decode(v) for v in obj]
    return obj


def _canonical_bytes(doc: dict) -> bytes:
    return json.dumps(doc, sort_keys=True, separators=(",", ":")).encode()


@dataclasses.dataclass(frozen=True)
class SessionCheckpoint:
    """One tenant's durable state plus caller-owned replay metadata."""

    tenant: str
    payload: dict          # MotifSession.checkpoint_state() capture
    meta: dict             # replay bookkeeping (e.g. stream offsets)
    version: int = FORMAT_VERSION

    @classmethod
    def capture(cls, session, meta: dict | None = None) -> "SessionCheckpoint":
        """Snapshot a live :class:`~repro.serving.motif.MotifSession`."""
        state = session.checkpoint_state()
        return cls(tenant=state["name"], payload=state,
                   meta=dict(meta or {}))

    # -- wire format ---------------------------------------------------------

    def to_json(self) -> str:
        body = {
            "tenant": self.tenant,
            "meta": _encode(self.meta),
            "payload": _encode(self.payload),
        }
        doc = {
            "format": FORMAT_NAME,
            "version": self.version,
            "crc32": zlib.crc32(_canonical_bytes(body)),
            **body,
        }
        return json.dumps(doc, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "SessionCheckpoint":
        try:
            doc = json.loads(text)
        except json.JSONDecodeError as e:
            raise CheckpointError(f"checkpoint is not valid JSON: {e}") from e
        if not isinstance(doc, dict) or doc.get("format") != FORMAT_NAME:
            raise CheckpointError(
                f"not a {FORMAT_NAME} document "
                f"(format={doc.get('format')!r})"
                if isinstance(doc, dict) else
                f"not a {FORMAT_NAME} document")
        version = doc.get("version")
        if version != FORMAT_VERSION:
            raise CheckpointError(
                f"unsupported checkpoint version {version!r} "
                f"(this build reads version {FORMAT_VERSION})")
        body = {k: doc.get(k) for k in ("tenant", "meta", "payload")}
        crc = zlib.crc32(_canonical_bytes(body))
        if crc != doc.get("crc32"):
            raise CheckpointError(
                f"checkpoint CRC mismatch for tenant {body['tenant']!r}: "
                f"stored {doc.get('crc32')}, computed {crc} — the file is "
                f"corrupt; refusing to restore")
        return cls(tenant=body["tenant"], payload=_decode(body["payload"]),
                   meta=_decode(body["meta"]) or {}, version=version)

    # -- file I/O ------------------------------------------------------------

    def save(self, path: str) -> str:
        """Atomically write this checkpoint to ``path`` (tmp + replace)."""
        directory = os.path.dirname(os.path.abspath(path)) or "."
        os.makedirs(directory, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=directory, prefix=".ckpt-",
                                   suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                f.write(self.to_json())
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        return path

    @classmethod
    def load(cls, path: str) -> "SessionCheckpoint":
        try:
            with open(path) as f:
                text = f.read()
        except OSError as e:
            raise CheckpointError(f"cannot read checkpoint {path}: {e}") from e
        return cls.from_json(text)


def _filename(tenant: str) -> str:
    """Collision-free filename for an arbitrary tenant name."""
    slug = re.sub(r"[^A-Za-z0-9._-]", "_", tenant)[:48]
    tag = zlib.crc32(tenant.encode()) & 0xFFFFFFFF
    return f"{slug}-{tag:08x}.ckpt.json"


class CheckpointStore:
    """One directory of per-tenant checkpoint files.

    Each tenant owns exactly one file, overwritten atomically on every
    :meth:`save` — the store always holds each tenant's *latest complete*
    checkpoint, never a torn one (a kill mid-write leaves the previous
    file).  The tenant name lives inside the document; the filename is a
    sanitized slug + CRC tag purely so arbitrary names map to legal paths.
    """

    def __init__(self, root: str):
        self.root = os.path.abspath(root)
        os.makedirs(self.root, exist_ok=True)

    def path_for(self, tenant: str) -> str:
        return os.path.join(self.root, _filename(tenant))

    def save(self, checkpoint: SessionCheckpoint) -> str:
        return checkpoint.save(self.path_for(checkpoint.tenant))

    def load(self, tenant: str) -> SessionCheckpoint:
        path = self.path_for(tenant)
        if not os.path.exists(path):
            raise CheckpointError(
                f"no checkpoint for tenant {tenant!r} under {self.root}")
        ckpt = SessionCheckpoint.load(path)
        if ckpt.tenant != tenant:
            raise CheckpointError(
                f"checkpoint file {path} is for tenant {ckpt.tenant!r}, "
                f"not {tenant!r}")
        return ckpt

    def tenants(self) -> list[str]:
        names = []
        for fname in os.listdir(self.root):
            if not fname.endswith(".ckpt.json"):
                continue
            names.append(
                SessionCheckpoint.load(os.path.join(self.root, fname)).tenant)
        return sorted(names)

    def load_all(self) -> dict[str, SessionCheckpoint]:
        return {t: self.load(t) for t in self.tenants()}

    def delete(self, tenant: str) -> bool:
        try:
            os.unlink(self.path_for(tenant))
            return True
        except FileNotFoundError:
            return False
