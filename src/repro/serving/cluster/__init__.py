"""Cluster availability layer over the motif serving stack.

Modules (bottom up):

* :mod:`.checkpoint` — :class:`SessionCheckpoint` / :class:`CheckpointStore`:
  versioned, CRC-verified, atomically-written per-tenant durability;
  restore replays only the open tail and is byte-identical.
* :mod:`.placement`  — rendezvous hashing: deterministic tenant → worker
  ownership with minimal movement on membership change.
* :mod:`.admission`  — :class:`AdmissionController`: per-tenant + global
  pending-edge budgets surfacing an explicit throttle signal.
* :mod:`.coordinator` — :class:`ClusterWorker` / :class:`ClusterCoordinator`:
  N disjoint serving stacks behind one routing surface, with
  checkpoint-driven failover and restart.
"""

from .admission import AdmissionController, AdmissionDecision
from .checkpoint import (
    FORMAT_VERSION,
    CheckpointError,
    CheckpointStore,
    SessionCheckpoint,
)
from .coordinator import ClusterAck, ClusterCoordinator, ClusterWorker, WorkerDown
from .placement import place, rendezvous_owner

__all__ = [
    "AdmissionController",
    "AdmissionDecision",
    "CheckpointError",
    "CheckpointStore",
    "ClusterAck",
    "ClusterCoordinator",
    "ClusterWorker",
    "FORMAT_VERSION",
    "SessionCheckpoint",
    "WorkerDown",
    "place",
    "rendezvous_owner",
]
