"""Admission control — pending-edge budgets with an explicit throttle signal.

Ingest can outrun mining: admitted edges wait in per-session admission
windows (and, behind them, the miner's open-tail buffer) whose memory is
bounded only by arrival rate.  The controller enforces two budgets over the
*pending* (buffered, not yet flushed to the miner) edge count — one per
tenant, one global across the worker — and turns overflow into an explicit
**throttle decision** instead of unbounded buffering: the caller (the
replay harness, a transport) gets ``admitted=False`` with the binding
budget named, defers the chunk, and retries after draining.  Nothing is
dropped by the controller itself; shedding is a *caller* choice recorded
via :meth:`AdmissionController.shed`.

Deferred and shed volumes are exported through the ``obs`` registry
(``repro_cluster_deferred_edges_total`` / ``repro_cluster_shed_edges_total``,
labelled per tenant) so backpressure is visible in the same place as
latency and throughput.
"""

from __future__ import annotations

import dataclasses
import threading

from repro.obs import get_obs


@dataclasses.dataclass(frozen=True)
class AdmissionDecision:
    """Outcome of offering one edge chunk to the controller."""

    admitted: bool
    reason: str                # "ok" | "tenant_budget" | "global_budget"
    tenant_pending: int        # tenant's tracked pending AFTER this decision
    global_pending: int        # worker-wide pending AFTER this decision

    def __bool__(self) -> bool:
        return self.admitted


class AdmissionController:
    """Tracks pending-edge debt per tenant and grants or defers chunks.

    ``offer(tenant, n)`` charges the chunk against both budgets and
    answers; callers must mirror reality back with :meth:`settle` after
    the ingest (the session reports its true ``pending_edges`` — flushes
    inside the ingest call repay debt immediately, so the controller
    never over-throttles on stale accounting).  A budget of ``None``
    disables that check.
    """

    def __init__(self, *, tenant_budget: int | None = 65536,
                 global_budget: int | None = None, obs=None):
        if tenant_budget is not None and tenant_budget < 1:
            raise ValueError("tenant_budget must be >= 1 (or None)")
        if global_budget is not None and global_budget < 1:
            raise ValueError("global_budget must be >= 1 (or None)")
        self.tenant_budget = tenant_budget
        self.global_budget = global_budget
        self.obs = get_obs(obs)
        self._lock = threading.Lock()
        self._pending: dict[str, int] = {}
        self._global = 0
        self.deferrals = 0
        self.deferred_edges = 0
        self.shed_edges = 0

    # -- decisions -----------------------------------------------------------

    def offer(self, tenant: str, n: int) -> AdmissionDecision:
        """Charge ``n`` arriving edges; admitted unless a budget binds."""
        n = int(n)
        with self._lock:
            tenant_pending = self._pending.get(tenant, 0)
            reason = "ok"
            if (self.tenant_budget is not None
                    and tenant_pending + n > self.tenant_budget):
                reason = "tenant_budget"
            elif (self.global_budget is not None
                    and self._global + n > self.global_budget):
                reason = "global_budget"
            if reason != "ok":
                self.deferrals += 1
                self.deferred_edges += n
                if self.obs.enabled:
                    self.obs.metrics.counter(
                        "repro_cluster_deferred_edges_total",
                        tenant=tenant, reason=reason).inc(n)
                return AdmissionDecision(False, reason, tenant_pending,
                                         self._global)
            self._pending[tenant] = tenant_pending + n
            self._global += n
            return AdmissionDecision(True, "ok", tenant_pending + n,
                                     self._global)

    def settle(self, tenant: str, pending: int) -> None:
        """Reconcile to the session's true pending count after an ingest."""
        pending = int(pending)
        with self._lock:
            old = self._pending.get(tenant, 0)
            self._pending[tenant] = pending
            self._global += pending - old

    def shed(self, tenant: str, n: int) -> None:
        """Record ``n`` edges the *caller* chose to drop under pressure."""
        n = int(n)
        with self._lock:
            self.shed_edges += n
        if self.obs.enabled:
            self.obs.metrics.counter(
                "repro_cluster_shed_edges_total", tenant=tenant).inc(n)

    def forget(self, tenant: str) -> None:
        """Release a tenant's debt (dropped or migrated away)."""
        with self._lock:
            self._global -= self._pending.pop(tenant, 0)

    # -- reporting -----------------------------------------------------------

    def pending(self, tenant: str | None = None) -> int:
        with self._lock:
            if tenant is None:
                return self._global
            return self._pending.get(tenant, 0)

    def stats(self) -> dict:
        with self._lock:
            return {
                "tenant_budget": self.tenant_budget,
                "global_budget": self.global_budget,
                "global_pending": self._global,
                "deferrals": self.deferrals,
                "deferred_edges": self.deferred_edges,
                "shed_edges": self.shed_edges,
                "pending": dict(self._pending),
            }
