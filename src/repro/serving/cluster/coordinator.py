"""Cluster layer: N workers, rendezvous placement, checkpoint failover.

Topology: a :class:`ClusterCoordinator` owns N :class:`ClusterWorker`\\ s.
Each worker is a full serving stack — its own
:class:`~repro.core.engine.PTMTEngine` (own warm executor),
:class:`~repro.serving.motif.MotifService`, and
:class:`~repro.serving.cluster.admission.AdmissionController` — so worker
state is genuinely disjoint: killing one loses exactly its tenants'
in-memory state and nothing else, which is what makes the failover test
meaningful.  Workers here are thread-hosted service instances behind one
routing surface; the worker API (create/restore/ingest/query/checkpoint)
is the process boundary a transport would serialize over, and the
restart harness exercises the real-process version of the same story
(kill -9, new process, restore from disk).

Routing: tenant → worker by rendezvous hashing
(:mod:`~repro.serving.cluster.placement`) over the *live* worker set.
On worker death only the dead worker's tenants re-home; each is restored
on its new owner from its latest on-disk checkpoint
(:class:`~repro.serving.cluster.checkpoint.CheckpointStore`) and the
caller gets back each tenant's checkpoint ``meta`` (the harness stores
stream offsets there) so the feed can rewind to exactly the durable
point.  Counts after replay are byte-identical to an undisturbed run —
TZP finalization is deterministic, and the checkpoint captures every
input the remaining stream suffix will interact with.

Backpressure: every ingest is offered to the owning worker's admission
controller first; over-budget chunks come back ``throttled=True`` in the
:class:`ClusterAck` without buffering anything, and the caller defers.
"""

from __future__ import annotations

import dataclasses
import threading

import numpy as np

from repro.obs import get_obs
from repro.serving.motif import MotifService

from .admission import AdmissionController
from .checkpoint import CheckpointError, CheckpointStore, SessionCheckpoint
from .placement import rendezvous_owner


class WorkerDown(RuntimeError):
    """The routed-to worker has been killed."""


@dataclasses.dataclass(frozen=True)
class ClusterAck:
    """Result of one cluster-routed ingest offer."""

    tenant: str
    worker: str
    accepted: int              # edges buffered (0 when throttled)
    flushed: bool              # did this call trigger a batch admission
    epoch: int                 # tenant epoch after the call
    throttled: bool = False
    reason: str = "ok"         # binding budget when throttled
    pending: int = 0           # tenant's pending edges after the call


class ClusterWorker:
    """One worker: engine + service + admission, with a liveness flag.

    ``kill()`` flips ``alive`` and every later call raises
    :class:`WorkerDown` — the in-memory sessions still exist as Python
    objects but are unreachable through the API, modelling a crashed
    process whose state is recoverable only from checkpoints.
    """

    def __init__(self, worker_id: str, *, engine=None, config=None,
                 tenant_budget: int | None = 65536,
                 global_budget: int | None = None,
                 mesh=None, mesh_axes=None, obs=None, **session_defaults):
        if engine is None and config is not None:
            from repro.core.engine import PTMTEngine

            engine = PTMTEngine(config, obs=obs)
        self.worker_id = worker_id
        self.engine = engine
        self.obs = get_obs(obs)
        self.mesh = mesh
        self.mesh_axes = mesh_axes
        kwargs = dict(session_defaults)
        if engine is not None:
            kwargs["engine"] = engine
        self.service = MotifService(obs=obs, **kwargs)
        self.admission = AdmissionController(
            tenant_budget=tenant_budget, global_budget=global_budget,
            obs=obs)
        self.alive = True

    def _check(self) -> None:
        if not self.alive:
            raise WorkerDown(f"worker {self.worker_id!r} is down")

    def kill(self) -> None:
        self.alive = False

    # -- tenant lifecycle ----------------------------------------------------

    def create_session(self, tenant: str, **params):
        self._check()
        return self.service.create_session(tenant, **params)

    def restore_session(self, state: dict, **params):
        self._check()
        session = self.service.manager.restore(state, **params)
        self.admission.settle(state["name"], session.pending_edges)
        return session

    def drop(self, tenant: str):
        self._check()
        session = self.service.drop_session(tenant)
        self.admission.forget(tenant)
        return session

    def tenants(self) -> list[str]:
        self._check()
        return self.service.sessions()

    # -- data path -----------------------------------------------------------

    def ingest(self, tenant: str, u, v, t) -> ClusterAck:
        self._check()
        n = int(np.asarray(t).size)
        decision = self.admission.offer(tenant, n)
        session = self.service.manager.get(tenant)
        if not decision:
            return ClusterAck(
                tenant=tenant, worker=self.worker_id, accepted=0,
                flushed=False, epoch=session.epoch, throttled=True,
                reason=decision.reason, pending=session.pending_edges)
        ack = self.service.ingest(tenant, u, v, t)
        pending = session.pending_edges
        # flushes inside the call repay debt immediately — reconcile to
        # the session's true window so throttling never runs on stale debt
        self.admission.settle(tenant, pending)
        return ClusterAck(
            tenant=tenant, worker=self.worker_id, accepted=ack.accepted,
            flushed=ack.flushed, epoch=ack.epoch, pending=pending)

    def flush(self, tenant: str):
        self._check()
        ack = self.service.flush(tenant)
        self.admission.settle(tenant, 0)
        return ack

    def query(self, request):
        self._check()
        return self.service.query(request)

    def comine(self, graph, tenants: list[str] | None = None) -> dict:
        self._check()
        return self.service.comine(graph, tenants)

    def sharded_mine(self, graph, **kw):
        """Batch mine on this worker's device mesh (intra-worker sharding).

        With a mesh configured this is ``engine.sharded`` — zones sharded
        over the worker's devices via the ``distributed/`` SPMD step —
        and a plain warm ``engine.discover`` otherwise.  Counts are
        identical either way (asserted in ``tests/test_cluster.py``).
        """
        self._check()
        if self.engine is None:
            raise RuntimeError(
                f"worker {self.worker_id!r} has no engine; batch mining "
                f"needs an engine= or config= at construction")
        if self.mesh is not None:
            return self.engine.sharded(graph, self.mesh, self.mesh_axes,
                                       **kw)
        return self.engine.discover(graph)

    # -- durability ----------------------------------------------------------

    def checkpoint(self, tenant: str,
                   meta: dict | None = None) -> SessionCheckpoint:
        self._check()
        return SessionCheckpoint.capture(
            self.service.manager.get(tenant), meta)

    def stats(self) -> dict:
        return {
            "worker": self.worker_id,
            "alive": self.alive,
            "service": self.service.stats() if self.alive else None,
            "admission": self.admission.stats(),
        }


class ClusterCoordinator:
    """Routes tenants across workers; rebalances from checkpoints on death."""

    def __init__(self, n_workers: int = 2, *, config=None,
                 store: CheckpointStore | None = None,
                 checkpoint_dir: str | None = None,
                 tenant_budget: int | None = 65536,
                 global_budget: int | None = None,
                 mesh=None, mesh_axes=None, obs=None, **session_defaults):
        if n_workers < 1:
            raise ValueError("n_workers must be >= 1")
        if store is not None and checkpoint_dir is not None:
            raise ValueError("pass either store or checkpoint_dir, not both")
        self.obs = get_obs(obs)
        self.store = store or (
            CheckpointStore(checkpoint_dir) if checkpoint_dir else None)
        self.workers: dict[str, ClusterWorker] = {
            f"w{i}": ClusterWorker(
                f"w{i}", config=config, tenant_budget=tenant_budget,
                global_budget=global_budget, mesh=mesh, mesh_axes=mesh_axes,
                obs=obs, **session_defaults)
            for i in range(n_workers)
        }
        self._placement: dict[str, str] = {}
        self._lock = threading.Lock()
        self.failovers = 0
        self.tenants_lost = 0

    # -- membership ----------------------------------------------------------

    def live_workers(self) -> list[str]:
        return sorted(w for w, obj in self.workers.items() if obj.alive)

    def owner_of(self, tenant: str) -> str:
        with self._lock:
            try:
                return self._placement[tenant]
            except KeyError:
                raise KeyError(f"unknown tenant {tenant!r}") from None

    def _worker_for(self, tenant: str) -> ClusterWorker:
        return self.workers[self.owner_of(tenant)]

    def tenants(self) -> list[str]:
        with self._lock:
            return sorted(self._placement)

    # -- tenant lifecycle ----------------------------------------------------

    def create_tenant(self, tenant: str, **params):
        with self._lock:
            if tenant in self._placement:
                raise ValueError(f"tenant {tenant!r} already placed")
            owner = rendezvous_owner(tenant, self.live_workers())
            self._placement[tenant] = owner
        try:
            return self.workers[owner].create_session(tenant, **params)
        except BaseException:
            with self._lock:
                if self._placement.get(tenant) == owner:
                    del self._placement[tenant]
            raise

    def drop_tenant(self, tenant: str):
        worker = self._worker_for(tenant)
        session = worker.drop(tenant)
        with self._lock:
            self._placement.pop(tenant, None)
        if self.store is not None:
            self.store.delete(tenant)
        return session

    # -- data path -----------------------------------------------------------

    def ingest(self, tenant: str, u, v, t) -> ClusterAck:
        return self._worker_for(tenant).ingest(tenant, u, v, t)

    def flush(self, tenant: str):
        return self._worker_for(tenant).flush(tenant)

    def flush_all(self) -> None:
        for tenant in self.tenants():
            try:
                self.flush(tenant)
            except KeyError:
                continue

    def query(self, request):
        return self._worker_for(request.session).query(request)

    def comine(self, graph, tenants: list[str] | None = None) -> dict:
        """Co-mine one graph per tenant config, grouped by owning worker.

        Tenants co-located on a worker share that worker's lattice sweep
        (``PTMTEngine.discover_many``); groups on different workers are
        independent mines.  Returns ``{tenant: DiscoveryResult}``.
        """
        selected = self.tenants() if tenants is None else list(tenants)
        by_worker: dict[str, list[str]] = {}
        for tenant in selected:
            by_worker.setdefault(self.owner_of(tenant), []).append(tenant)
        out: dict = {}
        for worker_id, group in by_worker.items():
            out.update(self.workers[worker_id].comine(graph, group))
        return out

    # -- durability & failover -----------------------------------------------

    def _require_store(self) -> CheckpointStore:
        if self.store is None:
            raise CheckpointError(
                "no checkpoint store configured (pass store= or "
                "checkpoint_dir= to ClusterCoordinator)")
        return self.store

    def checkpoint(self, tenant: str, meta: dict | None = None) -> str:
        store = self._require_store()
        ckpt = self._worker_for(tenant).checkpoint(tenant, meta)
        return store.save(ckpt)

    def checkpoint_all(
            self, metas: dict[str, dict] | None = None) -> dict[str, str]:
        """Checkpoint every tenant; ``metas[tenant]`` rides along if given."""
        metas = metas or {}
        return {tenant: self.checkpoint(tenant, metas.get(tenant))
                for tenant in self.tenants()}

    def kill_worker(self, worker_id: str) -> dict[str, dict | None]:
        """Kill a worker and fail its tenants over from their checkpoints.

        Each victim tenant re-homes to its rendezvous runner-up among the
        surviving workers and is restored from its latest on-disk
        checkpoint.  Returns ``{tenant: checkpoint_meta}`` so the caller
        can rewind each tenant's feed to the durable point (the harness
        stores stream offsets in ``meta``).  A tenant with no checkpoint
        on disk is *lost* — mapped to ``None`` and removed — because a
        crashed worker's memory is by definition unrecoverable.
        """
        worker = self.workers[worker_id]
        if not worker.alive:
            raise WorkerDown(f"worker {worker_id!r} is already down")
        worker.kill()
        with self._lock:
            victims = sorted(t for t, w in self._placement.items()
                             if w == worker_id)
        live = self.live_workers()
        if victims and not live:
            raise RuntimeError("no surviving workers to fail over to")
        recovered: dict[str, dict | None] = {}
        for tenant in victims:
            try:
                ckpt = self._require_store().load(tenant)
            except CheckpointError:
                with self._lock:
                    del self._placement[tenant]
                self.tenants_lost += 1
                if self.obs.enabled:
                    self.obs.metrics.counter(
                        "repro_cluster_tenants_lost_total").inc()
                recovered[tenant] = None
                continue
            new_owner = rendezvous_owner(tenant, live)
            self.workers[new_owner].restore_session(ckpt.payload)
            with self._lock:
                self._placement[tenant] = new_owner
            self.failovers += 1
            if self.obs.enabled:
                self.obs.metrics.counter(
                    "repro_cluster_failovers_total",
                    src=worker_id, dst=new_owner).inc()
            recovered[tenant] = ckpt.meta
        return recovered

    def restore_all(self) -> dict[str, dict]:
        """Cold-start path: place + restore every checkpointed tenant.

        A fresh coordinator pointed at an existing checkpoint directory
        rebuilds the whole tenant set (the restart harness after a kill
        -9).  Returns ``{tenant: checkpoint_meta}`` for feed rewind.
        """
        store = self._require_store()
        live = self.live_workers()
        recovered: dict[str, dict] = {}
        for tenant in store.tenants():
            ckpt = store.load(tenant)
            owner = rendezvous_owner(tenant, live)
            self.workers[owner].restore_session(ckpt.payload)
            with self._lock:
                self._placement[tenant] = owner
            recovered[tenant] = ckpt.meta
        return recovered

    # -- reporting -----------------------------------------------------------

    def placement(self) -> dict[str, str]:
        with self._lock:
            return dict(self._placement)

    def stats(self) -> dict:
        per_worker = {w: obj.stats() for w, obj in self.workers.items()}
        return {
            "n_workers": len(self.workers),
            "live_workers": self.live_workers(),
            "placement": self.placement(),
            "failovers": self.failovers,
            "tenants_lost": self.tenants_lost,
            "workers": per_worker,
        }
