"""Tenant → worker placement by rendezvous (highest-random-weight) hashing.

Every participant can compute the owner of any tenant locally from just
the live worker set — no placement table to replicate, no coordination
round.  The property the cluster layer actually relies on is *minimal
movement*: when a worker dies, only the tenants it owned re-home (each to
its runner-up worker); every other tenant's placement is untouched, so a
failover restores exactly the dead worker's checkpoints and nothing else.

Scores are derived from ``blake2b`` digests, **not** Python's builtin
``hash`` — placement must be identical across processes and restarts
(``PYTHONHASHSEED`` randomizes ``hash``), because a restarted coordinator
recomputes ownership from the checkpoint directory alone.
"""

from __future__ import annotations

import hashlib


def score(worker: str, tenant: str) -> int:
    """Deterministic rendezvous weight of ``worker`` for ``tenant``."""
    digest = hashlib.blake2b(
        f"{worker}\x00{tenant}".encode(), digest_size=8).digest()
    return int.from_bytes(digest, "big")


def rendezvous_owner(tenant: str, workers) -> str:
    """The worker owning ``tenant`` among ``workers`` (highest weight).

    Ties break on the worker id itself so the choice is total and
    deterministic even in the astronomically unlikely digest collision.
    """
    pool = list(workers)
    if not pool:
        raise ValueError(f"no live workers to place tenant {tenant!r}")
    return max(pool, key=lambda w: (score(w, tenant), w))


def place(tenants, workers) -> dict[str, str]:
    """Full placement map ``{tenant: owner}`` for the given worker set."""
    pool = list(workers)
    return {t: rendezvous_owner(t, pool) for t in tenants}
