from . import cluster, engine, motif

__all__ = ["cluster", "engine", "motif"]
