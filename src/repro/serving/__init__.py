from . import engine, motif

__all__ = ["engine", "motif"]
