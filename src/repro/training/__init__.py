from . import checkpoint, elastic, optimizer, train_loop

__all__ = ["checkpoint", "elastic", "optimizer", "train_loop"]
