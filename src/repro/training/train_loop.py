"""Fault-tolerant training loop: periodic checkpoints, crash resume,
preemption handling, gradient compression hook.

The loop is deliberately framework-grade rather than demo-grade:
  * resumes from the latest intact checkpoint (atomic manifests mean a
    mid-save crash falls back to the previous step);
  * catches SIGTERM/SIGINT (preemption notice) and checkpoints before exit;
  * step function is built once and reused — recompilation only on restart;
  * metrics stream to a JSONL file for post-hoc analysis (no TB offline).
"""

from __future__ import annotations

import dataclasses
import json
import os
import signal
import time
from typing import Callable, Iterator

import jax

from . import checkpoint, optimizer


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int
    ckpt_dir: str
    ckpt_every: int = 100
    keep_ckpts: int = 3
    log_every: int = 10
    metrics_path: str | None = None


class _PreemptionGuard:
    """Flips a flag on SIGTERM/SIGINT so the loop can checkpoint and exit."""

    def __init__(self):
        self.requested = False
        self._old = {}

    def __enter__(self):
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                self._old[sig] = signal.signal(sig, self._handler)
            except ValueError:   # not on main thread (tests)
                pass
        return self

    def _handler(self, signum, frame):
        self.requested = True

    def __exit__(self, *exc):
        for sig, old in self._old.items():
            signal.signal(sig, old)


def run(
    *,
    step_fn: Callable,
    params,
    opt_state: optimizer.AdamWState,
    batches: Iterator,
    loop_cfg: TrainLoopConfig,
    shardings=None,
) -> tuple:
    """Run (or resume) training. Returns (params, opt_state, history)."""
    os.makedirs(loop_cfg.ckpt_dir, exist_ok=True)
    start_step = 0
    state_tree = {"params": params, "opt": opt_state}
    if checkpoint.latest_step(loop_cfg.ckpt_dir) is not None:
        state_tree, start_step = checkpoint.restore(
            loop_cfg.ckpt_dir, state_tree, shardings=shardings
        )
        params, opt_state = state_tree["params"], state_tree["opt"]

    metrics_f = None
    if loop_cfg.metrics_path:
        metrics_f = open(loop_cfg.metrics_path, "a")

    history = []
    with _PreemptionGuard() as guard:
        step = start_step
        for step in range(start_step + 1, loop_cfg.total_steps + 1):
            batch = next(batches)
            t0 = time.perf_counter()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            metrics = {
                k: float(v) for k, v in metrics.items()
            }
            metrics["step"] = step
            metrics["step_time_s"] = time.perf_counter() - t0
            history.append(metrics)
            if metrics_f and step % loop_cfg.log_every == 0:
                metrics_f.write(json.dumps(metrics) + "\n")
                metrics_f.flush()
            if step % loop_cfg.ckpt_every == 0 or guard.requested:
                checkpoint.save(
                    loop_cfg.ckpt_dir, step,
                    {"params": params, "opt": opt_state},
                    keep=loop_cfg.keep_ckpts,
                )
            if guard.requested:
                break
        else:
            step = loop_cfg.total_steps
        # final checkpoint
        checkpoint.save(
            loop_cfg.ckpt_dir, step,
            {"params": params, "opt": opt_state}, keep=loop_cfg.keep_ckpts,
        )
    if metrics_f:
        metrics_f.close()
    return params, opt_state, history
