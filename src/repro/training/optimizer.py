"""AdamW + cosine schedule, implemented from scratch (no optax offline).

States are plain pytrees matching the parameter tree, so they inherit the
parameter shardings (first/second moments shard exactly like their params —
the ZeRO-style memory layout falls out of the FSDP param specs for free).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any = None
    nu: Any = None


def init_state(params) -> AdamWState:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.zeros_like, params))


def schedule(cfg: AdamWConfig, step):
    """Linear warmup then cosine decay to ``min_lr_ratio * lr``."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    progress = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * progress))
    decay = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * decay


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def apply_updates(cfg: AdamWConfig, params, grads, state: AdamWState):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.grad_clip:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    new = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [n[0] for n in new])
    new_m = jax.tree.unflatten(tdef, [n[1] for n in new])
    new_v = jax.tree.unflatten(tdef, [n[2] for n in new])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v), metrics
