"""Elastic scaling: restart the same job on a different mesh shape.

Checkpoints store *global* arrays (see training.checkpoint), so elasticity
reduces to (1) picking a new mesh from the surviving device set, and
(2) re-deriving shardings for that mesh from the models' *logical* specs —
``models.sharding.resolve`` already drops axes that no longer divide.  This
module provides the mesh-selection policy and a resharding helper; the
multi-pod dry-run exercises both mesh shapes end to end.
"""

from __future__ import annotations

import numpy as np

import jax


def choose_mesh_shape(n_devices: int, *, model_parallel: int = 16,
                      pod_size: int = 256) -> tuple[tuple, tuple]:
    """Pick (shape, axis_names) for a possibly-degraded device count.

    Policy: keep the ``model`` axis fixed (TP degree is a property of the
    architecture), give whole pods a ``pod`` axis, and absorb stragglers by
    shrinking ``data`` — the largest (pods * data * model) <= n_devices.
    """
    model = min(model_parallel, n_devices)
    while n_devices % model:
        model //= 2
    rest = n_devices // model
    if rest * model >= 2 * pod_size and rest % (pod_size // model) == 0:
        pods = rest // (pod_size // model)
        data = pod_size // model
        return (pods, data, model), ("pod", "data", "model")
    return (rest, model), ("data", "model")


def make_mesh_for(n_devices: int, **kw) -> jax.sharding.Mesh:
    shape, names = choose_mesh_shape(n_devices, **kw)
    devs = np.asarray(jax.devices()[: int(np.prod(shape))]).reshape(shape)
    return jax.sharding.Mesh(devs, names)


def reshard(tree, shardings):
    """Move a (restored) global tree onto new shardings."""
    return jax.tree.map(jax.device_put, tree, shardings)
