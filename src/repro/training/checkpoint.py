"""Fault-tolerant checkpointing (no orbax offline — built from scratch).

Layout per step::

    <dir>/step_000123/
        shard_00000.npz        # flat {index -> array} for this host's slice
        MANIFEST.json          # tree structure, shapes, dtypes, step
    <dir>/LATEST               # atomic pointer file (write-tmp + rename)

Properties needed at cluster scale:
  * **atomic**: MANIFEST + LATEST are written last via os.replace — a crash
    mid-save never corrupts the restore point;
  * **mesh-shape agnostic**: arrays are saved as *global* arrays (gathered
    per host from addressable shards) and re-sharded on restore against
    whatever mesh the restart uses — elastic restarts on a different pod
    count re-shard transparently;
  * **self-describing**: the manifest stores the flattened tree paths, so
    restore does not need the defining code to run first.

On multi-host deployments each process saves only its addressable shards
(process-local npz) — here (single-host CPU) that degenerates to one shard.
"""

from __future__ import annotations

import json
import os
import shutil

import numpy as np

import jax

SHARD_FILE = "shard_{idx:05d}.npz"
MANIFEST = "MANIFEST.json"
LATEST = "LATEST"


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save(directory: str, step: int, tree, *, keep: int = 3) -> str:
    """Write a checkpoint; returns its path. Atomic via rename."""
    step_dir = os.path.join(directory, f"step_{step:09d}")
    tmp_dir = step_dir + ".tmp"
    os.makedirs(tmp_dir, exist_ok=True)

    paths, leaves, _ = _flatten_with_paths(tree)
    arrays = {}
    for i, leaf in enumerate(leaves):
        arrays[str(i)] = np.asarray(jax.device_get(leaf))
    np.savez(os.path.join(tmp_dir, SHARD_FILE.format(idx=0)), **arrays)

    manifest = {
        "step": step,
        "paths": paths,
        "dtypes": [str(a.dtype) for a in arrays.values()],
        "shapes": [list(a.shape) for a in arrays.values()],
        "n_shards": 1,
    }
    with open(os.path.join(tmp_dir, MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(step_dir):
        shutil.rmtree(step_dir)
    os.replace(tmp_dir, step_dir)

    latest_tmp = os.path.join(directory, LATEST + ".tmp")
    with open(latest_tmp, "w") as f:
        f.write(os.path.basename(step_dir))
    os.replace(latest_tmp, os.path.join(directory, LATEST))

    _gc(directory, keep)
    return step_dir


def _gc(directory: str, keep: int):
    steps = sorted(
        d for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> int | None:
    pointer = os.path.join(directory, LATEST)
    if not os.path.exists(pointer):
        return None
    with open(pointer) as f:
        name = f.read().strip()
    if not os.path.exists(os.path.join(directory, name, MANIFEST)):
        return None
    return int(name.split("_")[1])


def restore(directory: str, tree_like, *, step: int | None = None,
            shardings=None):
    """Restore into the structure of ``tree_like``.

    ``shardings``: optional matching pytree of NamedShardings — arrays are
    placed (re-sharded) onto the current mesh, which is how elastic
    restarts onto a different mesh shape work.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    step_dir = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(step_dir, MANIFEST)) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(step_dir, SHARD_FILE.format(idx=0)))
    leaves = [data[str(i)] for i in range(len(manifest["paths"]))]

    ref_paths, ref_leaves, treedef = _flatten_with_paths(tree_like)
    if ref_paths != manifest["paths"]:
        raise ValueError(
            "checkpoint tree mismatch: "
            f"{set(ref_paths) ^ set(manifest['paths'])}"
        )
    if shardings is not None:
        _, shard_leaves, _ = _flatten_with_paths(shardings)
        leaves = [
            jax.device_put(leaf, s)
            for leaf, s in zip(leaves, shard_leaves)
        ]
    else:
        leaves = [jax.numpy.asarray(leaf) for leaf in leaves]
    return jax.tree_util.tree_unflatten(treedef, leaves), step
