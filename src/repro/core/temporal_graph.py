"""Temporal graph container used by the PTMT pipeline.

A temporal graph is a time-ordered stream of directed edges ``(u, v, t)``
(Definition 1 of the paper).  We keep it as three parallel arrays sorted by
``(t, arrival index)``.  Timestamps are normalized to ``int32`` offsets from
``t_min`` — every dataset in the paper spans < 2^31 seconds.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TemporalGraph:
    """Sorted temporal edge stream.

    Attributes:
      u: int32[n] source node ids (>= 0).
      v: int32[n] destination node ids (>= 0).
      t: int32[n] timestamps, non-decreasing, offset so ``t[0] >= 0``.
      n_nodes: number of distinct nodes (max id + 1).
    """

    u: np.ndarray
    v: np.ndarray
    t: np.ndarray
    n_nodes: int

    @property
    def n_edges(self) -> int:
        return int(self.u.shape[0])

    @property
    def time_span(self) -> int:
        if self.n_edges == 0:
            return 0
        return int(self.t[-1] - self.t[0])

    def __post_init__(self):
        if not (self.u.shape == self.v.shape == self.t.shape):
            raise ValueError("u, v, t must have identical shapes")
        if self.t.size and np.any(np.diff(self.t) < 0):
            raise ValueError("timestamps must be non-decreasing")


def from_edges(u, v, t, *, stable: bool = True) -> TemporalGraph:
    """Build a :class:`TemporalGraph` from unsorted edge triples.

    Ties in ``t`` keep arrival order (stable sort) so that the discovery
    semantics are deterministic, matching the paper's stream model.
    """
    u = np.asarray(u)
    v = np.asarray(v)
    t = np.asarray(t)
    if u.ndim != 1:
        raise ValueError("edges must be 1-D arrays")
    if not (u.shape == v.shape == t.shape):
        raise ValueError("u, v, t must have identical shapes")
    order = np.argsort(t, kind="stable" if stable else "quicksort")
    u, v, t = u[order], v[order], t[order]
    if t.size:
        t = t - t.min()
    n_nodes = int(max(u.max(initial=-1), v.max(initial=-1)) + 1) if u.size else 0
    return TemporalGraph(
        u=u.astype(np.int32), v=v.astype(np.int32), t=t.astype(np.int32),
        n_nodes=n_nodes,
    )
