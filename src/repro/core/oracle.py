"""Brute-force motif-transition-process oracle (host-side, pure Python).

Independent of every JAX code path; used by tests and benchmarks as ground
truth for the paper's semantics (Definitions 2-4):

* each edge seeds one 1-edge process (processes never fork — Definition 3's
  "no earlier valid transition" rule makes the successor unique);
* a process with last edge at ``t_l`` absorbs the first later edge ``(u,v,t)``
  with ``t > t_l``, ``t - t_l <= delta`` and ``{u,v}`` intersecting its node
  set, until it has ``l_max`` edges or the window ``(t_l, t_l + delta]``
  passes with no eligible edge.

Complexity O(n^2 l_max) — fine for the <= few-thousand-edge graphs tests use.
"""

from __future__ import annotations

from collections import Counter

import numpy as np

from .encoding import decode_code_np, encode_process_np


def enumerate_processes(u, v, t, delta: int, l_max: int) -> list[list[int]]:
    """Return, per seed edge, the list of edge indices of its process."""
    u = np.asarray(u)
    v = np.asarray(v)
    t = np.asarray(t)
    n = len(u)
    processes = []
    for seed in range(n):
        edges = [seed]
        nodes = {int(u[seed]), int(v[seed])}
        last_t = int(t[seed])
        j = seed + 1
        while len(edges) < l_max:
            extended = False
            while j < n and int(t[j]) <= last_t + delta:
                tj = int(t[j])
                if tj > last_t and (int(u[j]) in nodes or int(v[j]) in nodes):
                    edges.append(j)
                    nodes.add(int(u[j]))
                    nodes.add(int(v[j]))
                    last_t = tj
                    extended = True
                    j += 1
                    break
                j += 1
            if not extended:
                break
        # NB: the inner cursor j only moves forward; restart scanning for the
        # *next* extension right after the edge just absorbed.
        processes.append(edges)
    return processes


def count_codes(u, v, t, delta: int, l_max: int) -> Counter:
    """Counter mapping paper-style code strings -> process counts."""
    counts: Counter = Counter()
    for edges in enumerate_processes(u, v, t, delta, l_max):
        code = encode_process_np(
            [(int(u[e]), int(v[e])) for e in edges], l_max
        )
        counts[decode_code_np(code)] += 1
    return counts


def transition_counts(final_counts: Counter) -> Counter:
    """Per-level transition statistics from final-code counts.

    A process stopping at code ``c`` passed through every even-length prefix
    of ``c``; the through-count of prefix ``p`` is the paper's transition
    count into ``p``.
    """
    through: Counter = Counter()
    for code, cnt in final_counts.items():
        for level in range(2, len(code) + 1, 2):
            through[code[:level]] += cnt
    return through
