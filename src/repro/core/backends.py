"""Pluggable zone-scan backend registry (the executor's dispatch layer).

Every Phase-1 implementation (growth-zone candidate expansion) is published
here as a :class:`BackendSpec` carrying the scan callable plus capability
metadata the executor needs to drive it correctly:

* ``jittable``  — whether the scan is JAX-traceable (can live inside
  ``jax.jit`` / ``shard_map``).  The pure-NumPy oracle backend is host-side
  and runs outside the jit boundary.
* ``grade``     — "reference" (vectorized jnp, exact), "accelerator"
  (Pallas TPU kernel, exact, fast), or "oracle" (brute-force host walk,
  the ground-truth semantics tests cross-check against).
* ``block_defaults`` — kernel tile sizes (e.g. Pallas ``c_blk``/``e_blk``)
  owned by the backend, not by call sites.
* ``default_zone_chunk`` / ``max_recommended_e_cap`` — scheduling hints.
* ``mem_model`` / ``default_merge_cap`` — memory hints for the capacity
  planner (:mod:`repro.core.planner`): ``mem_model(e_cap, l_max)`` is the
  backend's per-zone scan footprint in bytes (the Pallas kernel pads the
  edge axis up to block multiples, so its zones cost more than the
  reference model says), and ``default_merge_cap`` bounds the hierarchical
  aggregation carry when the executor is not given an explicit cap.

Backends self-describe; the executor, the distributed mining step, and the
CLI all resolve scans through :func:`get_backend` instead of hand-rolled
``if backend == ...`` chains.  Registration is lazy: the loader imports the
implementation on first use, so importing this module never pulls in Pallas.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

__all__ = [
    "BackendSpec",
    "available_backends",
    "get_backend",
    "register_backend",
]


@dataclasses.dataclass
class BackendSpec:
    """One registered zone-scan implementation plus its capabilities.

    ``scan`` has the reference signature
    ``scan(u, v, t, valid, *, delta, l_max) -> ZoneResult`` over a
    ``[Z, E]`` zone batch (arrays are jnp for jittable backends, numpy
    for host backends).
    """

    name: str
    loader: Callable[[], Callable]
    jittable: bool = True
    grade: str = "reference"
    description: str = ""
    block_defaults: dict | None = None
    default_zone_chunk: int | None = None
    max_recommended_e_cap: int | None = None
    mem_model: Callable[[int, int], int] | None = None
    default_merge_cap: int | None = None
    fused_loader: Callable[[], Callable] | None = None
    #: Whether ``scan`` (and ``fused_scan``, if any) accept a ``with_ts``
    #: keyword returning per-step absorption timestamps — the input the
    #: executor's config-lattice co-mining fold needs to derive smaller
    #: configs' counts from one dominating sweep.
    supports_comine: bool = False
    _scan: Callable | None = dataclasses.field(
        default=None, repr=False, compare=False)
    _fused_scan: Callable | None = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def scan(self) -> Callable:
        """Resolve (and cache) the scan callable."""
        if self._scan is None:
            self._scan = self.loader()
        return self._scan

    @property
    def supports_fused(self) -> bool:
        """Whether this backend publishes a flat single-launch scan."""
        return self.fused_loader is not None

    @property
    def fused_scan(self) -> Callable:
        """Resolve (and cache) the fused flat-stream scan callable.

        Signature: ``fused_scan(u, v, t, valid, zone_id, lo, hi, *, delta,
        l_max, blk) -> (code int32[S, L], length int32[S])`` over a
        concatenated :class:`repro.core.tzp.FusedZoneLayout` slot stream,
        where ``lo``/``hi`` are the layout's per-candidate-block sweep
        bounds (host-planned compaction).
        """
        if self.fused_loader is None:
            raise ValueError(
                f"backend {self.name!r} has no fused single-launch scan "
                f"(fused paths need a bucket-native kernel; use the "
                f"per-bucket layout path instead)")
        if self._fused_scan is None:
            self._fused_scan = self.fused_loader()
        return self._fused_scan


_REGISTRY: dict[str, BackendSpec] = {}


def register_backend(
    name: str,
    loader: Callable[[], Callable],
    *,
    jittable: bool = True,
    grade: str = "reference",
    description: str = "",
    block_defaults: dict | None = None,
    default_zone_chunk: int | None = None,
    max_recommended_e_cap: int | None = None,
    mem_model: Callable[[int, int], int] | None = None,
    default_merge_cap: int | None = None,
    fused_loader: Callable[[], Callable] | None = None,
    supports_comine: bool = False,
    overwrite: bool = False,
) -> BackendSpec:
    """Publish a zone-scan backend under ``name``.

    ``loader`` is a zero-arg callable returning the scan function; it runs
    at most once, on first :func:`get_backend` resolution.
    ``fused_loader`` (optional) resolves the backend's single-launch flat
    scan over a concatenated ragged layout — see ``BackendSpec.fused_scan``.
    """
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {name!r} already registered "
                         f"(pass overwrite=True to replace)")
    spec = BackendSpec(
        name=name, loader=loader, jittable=jittable, grade=grade,
        description=description, block_defaults=block_defaults,
        default_zone_chunk=default_zone_chunk,
        max_recommended_e_cap=max_recommended_e_cap,
        mem_model=mem_model, default_merge_cap=default_merge_cap,
        fused_loader=fused_loader, supports_comine=supports_comine,
    )
    _REGISTRY[name] = spec
    return spec


def get_backend(name: str) -> BackendSpec:
    """Look up a backend; error lists what is available."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; available: {available_backends()}"
        ) from None


def available_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# Built-in backends.
# ---------------------------------------------------------------------------


def _load_ref():
    from repro.core import expansion

    return expansion.scan_zones


# Pallas zone-scan tile sizes (candidates x edges per VMEM block).  Defined
# here — importable without pulling in Pallas — and consumed by
# kernels/zone_scan/ops.py as its call defaults, so registry metadata and
# kernel defaults cannot drift.
PALLAS_BLOCK_DEFAULTS = {"c_blk": 512, "e_blk": 256}

#: Candidate-block width of the fused single-launch flat kernel.  Matches
#: ``c_blk`` so a fused candidate block does the same lane-width work as a
#: dense candidate block — but sweeps only its own zones' flat span instead
#: of a whole padded bucket.
FUSED_BLK_DEFAULT = 512


def _load_pallas():
    from repro.kernels.zone_scan import ops as zone_ops

    return zone_ops.scan_zones


def _load_pallas_fused():
    from repro.kernels.zone_scan import ops as zone_ops

    return zone_ops.scan_flat


def _load_xla_fused():
    from repro.kernels.zone_scan import xla as zone_xla

    return zone_xla.scan_flat_xla


def _load_numpy():
    from repro.core import scan_numpy

    return scan_numpy.scan_zones


def _ref_mem_model(e_cap: int, l_max: int) -> int:
    from repro.core import planner

    return planner.ref_zone_bytes(e_cap, l_max)


def _pallas_mem_model(e_cap: int, l_max: int) -> int:
    from repro.core import planner

    return planner.pallas_zone_bytes(e_cap, l_max, **PALLAS_BLOCK_DEFAULTS)


register_backend(
    "ref", _load_ref,
    jittable=True, grade="reference",
    description="vectorized jnp lax.scan expansion (exact, any device)",
    mem_model=_ref_mem_model,
    supports_comine=True,
)

register_backend(
    "pallas", _load_pallas,
    jittable=True, grade="accelerator",
    description="Pallas TPU kernel with live-window block skipping",
    block_defaults=PALLAS_BLOCK_DEFAULTS,
    mem_model=_pallas_mem_model,
    fused_loader=_load_pallas_fused,
    supports_comine=True,
)

register_backend(
    "xla", _load_ref,
    jittable=True, grade="reference",
    description=("compiled XLA lowering: reference dense scan plus a pure "
                 "lax fused flat scan (fast on CPU, no interpreter)"),
    mem_model=_ref_mem_model,
    fused_loader=_load_xla_fused,
    supports_comine=True,
)

register_backend(
    "numpy", _load_numpy,
    jittable=False, grade="oracle",
    description="pure-NumPy brute-force walk (ground truth, small inputs)",
    max_recommended_e_cap=4096,
    mem_model=_ref_mem_model,
    default_merge_cap=4096,
    supports_comine=True,
)
