# The paper's primary contribution: PTMT — parallel motif-transition-process
# discovery with Temporal Zone Partitioning, adapted TPU-native (see DESIGN.md).
from . import aggregation, encoding, expansion, oracle, transitions, tzp
from .api import DiscoveryResult, discover, discover_sequential
from .temporal_graph import TemporalGraph, from_edges

__all__ = [
    "DiscoveryResult",
    "TemporalGraph",
    "aggregation",
    "discover",
    "discover_sequential",
    "encoding",
    "expansion",
    "from_edges",
    "oracle",
    "transitions",
    "tzp",
]
