# The paper's primary contribution: PTMT — parallel motif-transition-process
# discovery with Temporal Zone Partitioning, adapted TPU-native (see DESIGN.md).
from . import (
    aggregation,
    backends,
    encoding,
    expansion,
    oracle,
    planner,
    transitions,
    tzp,
)
from .api import DiscoveryResult, discover, discover_sequential
from .backends import available_backends, get_backend, register_backend
from .config import MiningConfig
from .engine import EngineStats, PTMTEngine
from .executor import MiningExecutor, ZoneChunkError, ZoneOverflowError
from .streaming import StreamingMiner
from .temporal_graph import TemporalGraph, from_edges

__all__ = [
    "DiscoveryResult",
    "EngineStats",
    "MiningConfig",
    "MiningExecutor",
    "PTMTEngine",
    "StreamingMiner",
    "TemporalGraph",
    "ZoneChunkError",
    "ZoneOverflowError",
    "aggregation",
    "available_backends",
    "backends",
    "config",
    "discover",
    "discover_sequential",
    "encoding",
    "engine",
    "expansion",
    "from_edges",
    "get_backend",
    "oracle",
    "planner",
    "register_backend",
    "transitions",
    "tzp",
]
