"""Incremental streaming discovery on top of the unified executor.

Real temporal-graph workloads arrive as unbounded, time-ordered streams.
TZP's signed growth/boundary decomposition (Lemma 4.2) is naturally
incremental: counts are a signed sum over zones, and the identity holds for
*any* partition whose consecutive zones overlap by exactly ``L_b = delta *
l_max`` and are each at least ``2 * L_b`` long.  A growth/boundary zone pair
``(G_i = [s_i, e_i), B_i = [e_i - L_b, e_i))`` is **final** once the stream
head has moved past ``e_i + L_b``: no future edge can extend any process
seeded before ``e_i`` (the per-step gap bound is ``delta <= L_b``), so the
pair can be mined immediately and merged into the running totals, and every
edge older than ``s_{i+1} = e_i - L_b`` can be discarded.

:class:`StreamingMiner` therefore keeps only a sliding buffer of
not-yet-finalized edges.  ``snapshot()`` mines the still-open tail of the
**closed prefix** (edges with ``t < t_head - L_b``) as a fresh mini zone
plan and merges it with the finalized totals — by Lemma 4.2 the result
equals batch ``discover()`` run on that prefix, exactly, per code (tested in
``tests/test_streaming.py``), whenever batch discovery itself is exact
(``overflow == 0``).  The streaming miner never drops edges: with a small
``e_cap`` on bursty data, batch ``discover`` may overflow zone capacity and
undercount, while snapshots stay oracle-exact — cross-checks against a
batch run must first confirm its ``overflow`` is zero.  Finalized-pair
contributions never change as
more data arrives; like batch discovery on a truncated stream, processes
seeded within ``L_b`` of the prefix end are reported as currently observed
and may still grow in later snapshots.

All mining goes through :class:`repro.core.executor.MiningExecutor` — the
streaming layer owns frontier bookkeeping only.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.obs import get_obs
from repro.obs.timing import Stopwatch

from . import transitions, tzp
from .api import DiscoveryResult
from .config import MiningConfig
from .executor import MiningExecutor
from .temporal_graph import TemporalGraph


def validate_edge_chunk(u, v, t) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Validate and coerce one edge chunk to ``(int32 u, int32 v, int64 t)``.

    ``np.asarray(x, np.int32)`` silently wraps out-of-range node ids and
    truncates float timestamps — a tenant sending ids >= 2**31 would get
    corrupted motif counts with no error.  This is the single ingestion
    guard (:class:`StreamingMiner` and the serving ``MotifSession`` both
    route through it): non-integer dtypes and values outside the target
    dtype's range raise ``ValueError`` before anything is buffered.
    """
    out = []
    for name, x, dtype in (("u", u, np.int32), ("v", v, np.int32),
                           ("t", t, np.int64)):
        arr = np.asarray(x)
        if arr.dtype.kind not in "iu":
            raise ValueError(
                f"edge chunk field {name!r} must be integer-typed, got "
                f"dtype {arr.dtype} (floats would be silently truncated)")
        info = np.iinfo(dtype)
        if arr.size and (int(arr.min()) < info.min
                         or int(arr.max()) > info.max):
            raise ValueError(
                f"edge chunk field {name!r} has values outside "
                f"{np.dtype(dtype).name} range [{info.min}, {info.max}]; "
                f"they would silently wrap and corrupt motif counts")
        out.append(arr.astype(dtype, copy=False).ravel())
    u, v, t = out
    if not (u.shape == v.shape == t.shape):
        raise ValueError("u, v, t must have identical shapes")
    return u, v, t


def _merge_into(total: dict[str, int], part: dict[str, int]) -> None:
    for code, cnt in part.items():
        new = total.get(code, 0) + cnt
        if new:
            total[code] = new
        else:
            total.pop(code, None)


def replay_stream(miner: "StreamingMiner", graph, chunk_edges: int):
    """Feed ``graph`` through ``miner`` in chunks; measure ingest latency.

    Shared by the CLI ``--stream`` mode and ``benchmarks/bench_streaming``
    so both report the same metric.  Returns ``(latencies, total_seconds)``
    with one latency per ingested chunk.
    """
    if chunk_edges < 1:
        raise ValueError("chunk_edges must be >= 1")
    latencies = []
    with Stopwatch() as total:
        for i in range(0, graph.n_edges, chunk_edges):
            with Stopwatch() as sw:
                miner.ingest(graph.u[i:i + chunk_edges],
                             graph.v[i:i + chunk_edges],
                             graph.t[i:i + chunk_edges])
            latencies.append(sw.seconds)
    return latencies, total.seconds


@dataclasses.dataclass(frozen=True)
class SnapshotView:
    """Immutable capture of everything a non-final ``snapshot()`` reads.

    Produced by :meth:`StreamingMiner.freeze` under the caller's ingest
    synchronization; mined by :meth:`StreamingMiner.mine_view` **without**
    that synchronization (the serving layer's first-query-of-an-epoch mine
    no longer stalls concurrent ingest).  The buffer arrays are captured by
    reference — ``ingest`` replaces them wholesale and never writes in
    place, so a view stays internally consistent while new edges arrive;
    the finalized-counts dict *is* mutated in place by finalization and is
    therefore copied at freeze time.
    """

    epoch: int
    sig: tuple                    # tail-layout signature at freeze time
    counts: dict                  # finalized-pair counts (copy)
    n_zones_finalized: int
    u: np.ndarray
    v: np.ndarray
    t: np.ndarray
    cut: int                      # buffered edges inside the closed prefix
    cached_tail: tuple | None     # (tail_counts, tail_zones, tail_cap)


class StreamingMiner:
    """Ingests time-ordered edge chunks; maintains running exact counts.

    Parameters come in as one validated
    :class:`~repro.core.config.MiningConfig` (``config=``), or as the
    legacy individual kwargs (``delta=, l_max=, ...`` — a config is built
    internally), but never both.  ``executor=`` optionally shares an
    already-built :class:`MiningExecutor` (the
    :class:`repro.core.engine.PTMTEngine` path — one warm backend across
    batch and stream modes); it must agree with the config.

    Usage::

        miner = StreamingMiner(delta=600, l_max=6)
        for u, v, t in chunks:           # t non-decreasing across chunks
            miner.ingest(u, v, t)
        result = miner.snapshot()        # exact counts on the closed prefix
        final = miner.snapshot(final=True)   # treat the stream as ended
    """

    def __init__(
        self,
        *,
        config: MiningConfig | None = None,
        executor: MiningExecutor | None = None,
        delta: int | None = None,
        l_max: int | None = None,
        omega: int | None = None,
        e_cap: int | None = None,
        backend: str | None = None,
        zone_chunk: int | None = None,
        agg: str | None = None,
        merge_cap: int | None = None,
        memory_budget_mb: float | None = None,
        obs=None,
    ):
        legacy = {k: v for k, v in dict(
            delta=delta, l_max=l_max, omega=omega, e_cap=e_cap,
            backend=backend, zone_chunk=zone_chunk, agg=agg,
            merge_cap=merge_cap, memory_budget_mb=memory_budget_mb,
        ).items() if v is not None}
        if config is None:
            # delta/l_max have no safe fallback here: silently mining with
            # the config defaults would return plausible-but-wrong counts
            if delta is None or l_max is None:
                raise ValueError(
                    "delta and l_max are required (or pass config=)")
            config = MiningConfig(**legacy)     # validates
        elif legacy:
            raise ValueError(
                f"pass either a MiningConfig or individual parameters, "
                f"not both (got config plus {sorted(legacy)})")
        if executor is not None:
            # self.config is exposed as the source of truth for execution
            # parameters (the serving layer reports it), so a shared
            # executor must match on every field from_config would set
            ref = MiningExecutor.from_config(config)
            mismatch = [
                f for f in ("delta", "l_max", "backend", "zone_chunk",
                            "agg", "merge_cap", "memory_budget_mb")
                if getattr(executor, f) != getattr(ref, f)
            ]
            if mismatch:
                raise ValueError(
                    f"executor disagrees with config on {mismatch} — "
                    f"mining would not run with the parameters "
                    f"self.config reports")
        self.config = config
        self.delta = config.delta
        self.l_max = config.l_max
        self.omega = config.omega
        self.e_cap = config.e_cap
        self.l_b = config.l_b
        self.l_g = self.omega * self.l_b
        # obs resolution: an explicit bundle wins, else inherit the shared
        # executor's (the engine.stream() path — one bundle across batch
        # and stream modes), else the no-op default
        self.obs = get_obs(obs) if obs is not None else (
            executor.obs if executor is not None else get_obs(None))
        self.executor = executor if executor is not None \
            else MiningExecutor.from_config(config, obs=self.obs)

        self._u = np.zeros(0, np.int32)     # sliding buffer: edges >= s
        self._v = np.zeros(0, np.int32)
        self._t = np.zeros(0, np.int64)
        self._s: int | None = None          # next zone start time
        self._t_head: int | None = None     # newest ingested timestamp
        self._counts: dict[str, int] = {}   # merged finalized-pair counts
        self.n_edges_ingested = 0
        self.n_edges_retired = 0            # dropped from the buffer
        self.n_zones_finalized = 0
        self._epoch = 0
        self._closed_sig: tuple = (None, 0)
        # cache of the open-tail mining result, keyed by (epoch, layout
        # signature): (epoch, sig, tail_counts, tail_zones, tail_cap).
        # snapshot() is a pure function of the closed prefix and the
        # epoch bumps exactly when that prefix changes, so reuse is exact
        # — the finalized partial counts in self._counts are never
        # re-mined, and between finalizations the tail is not either.
        # The signature covers every setting that shapes the tail's zone
        # layout (layout kind, e_cap, chunking), so a bucket-affecting
        # change invalidates the cached mine instead of serving a result
        # computed under a different layout.
        self._tail_cache: tuple | None = None
        self.tail_cache_hits = 0
        self.tail_cache_misses = 0
        self.last_tail_layout: dict | None = None
        # metric-label tag for multi-miner processes (the serving layer
        # sets this to the tenant name); empty means unlabeled series
        self.obs_label = ""

    def _obs_labels(self) -> dict:
        return {"miner": self.obs_label} if self.obs_label else {}

    # -- stream state -------------------------------------------------------

    @property
    def t_head(self) -> int | None:
        return self._t_head

    @property
    def closed_time(self) -> int | None:
        """Exclusive upper bound of the closed (final) prefix."""
        if self._t_head is None:
            return None
        return int(self._t_head) - self.l_b

    @property
    def buffered_edges(self) -> int:
        return int(self._t.shape[0])

    @property
    def epoch(self) -> int:
        """Monotone counter that bumps exactly when the closed prefix changes.

        ``snapshot()`` (non-final) is a pure function of the closed prefix:
        the merged finalized-pair counts plus the buffered edges with ``t <
        closed_time``.  Both can only change when ``closed_time`` advances or
        a pair finalizes — newly ingested edges always satisfy ``t >=
        t_head_old > closed_time_old`` and so never land inside an unchanged
        closed prefix.  Equal epochs therefore guarantee equal snapshots,
        which makes epoch-keyed snapshot caches (the serving layer) exact:
        invalidation happens precisely when the answer could differ, never on
        a clock.
        """
        return self._epoch

    # -- ingestion ----------------------------------------------------------

    def ingest(self, u, v, t) -> None:
        """Append one time-ordered edge chunk and advance the frontier.

        Raises ``ValueError`` on non-integer or out-of-range input (see
        :func:`validate_edge_chunk`) — nothing is buffered on rejection.
        """
        u, v, t = validate_edge_chunk(u, v, t)
        if t.size == 0:
            return
        if np.any(np.diff(t) < 0):
            raise ValueError("chunk timestamps must be non-decreasing")
        if self._t_head is not None and int(t[0]) < self._t_head:
            raise ValueError(
                f"chunk starts at t={int(t[0])} before the stream head "
                f"{self._t_head}; edges must arrive time-ordered"
            )
        with self.obs.tracer.span("stream.ingest", edges=int(t.size)):
            self._u = np.concatenate([self._u, u])
            self._v = np.concatenate([self._v, v])
            self._t = np.concatenate([self._t, t])
            self._t_head = int(t[-1])
            if self._s is None:
                self._s = int(self._t[0])
            self.n_edges_ingested += int(t.size)
            self._advance()
            sig = (self.closed_time, self.n_zones_finalized)
            if sig != self._closed_sig:
                self._closed_sig = sig
                self._epoch += 1
        if self.obs.enabled:
            labels = self._obs_labels()
            m = self.obs.metrics
            m.gauge("repro_streaming_epoch", **labels).set(self._epoch)
            m.gauge("repro_streaming_buffered_edges",
                    **labels).set(self.buffered_edges)

    def _advance(self) -> None:
        """Finalize every growth/boundary pair fully behind the frontier."""
        while True:
            if self._t.size == 0:
                return
            limit = self._t_head - self.l_b
            # quiet-gap skip: no edges exist in [s, t0), so jumping the zone
            # start to the next buffered edge leaves the signed cover exact
            # (empty zones contribute nothing) and keeps ingest O(zones with
            # edges) instead of one iteration per empty l_g-window.
            t0 = int(self._t[0])
            if t0 > self._s:
                self._s = t0
            s = self._s
            e = s + self.l_g
            if e > limit:
                return
            # adaptive shrink, same rule as the batch planner (all edges in
            # [s, e) have arrived because e <= limit < t_head)
            lo = int(np.searchsorted(self._t, s, side="left"))
            e = tzp.adaptive_zone_end(self._t, s, e, e_cap=self.e_cap,
                                      l_b=self.l_b)
            self._finalize_pair(s, e, lo)
            new_s = e - self.l_b
            keep = int(np.searchsorted(self._t, new_s, side="left"))
            self.n_edges_retired += keep
            self._u = self._u[keep:]
            self._v = self._v[keep:]
            self._t = self._t[keep:]
            self._s = new_s

    def _finalize_pair(self, s: int, e: int, lo: int) -> None:
        """Mine G = [s, e) with sign +1 and B = [e - l_b, e) with sign -1.

        The pair goes through the same :func:`tzp.build_zone_layout` →
        :meth:`MiningExecutor.run_layout` pipeline as batch discovery — a
        two-zone plan over the pair's edge slice — but always as the
        **dense** layout: a 2-row batch has almost nothing to bucket,
        while splitting G and B into separate capacity buckets doubles
        the per-pair dispatches, adds a host-synced cross-bucket merge,
        and multiplies the distinct jit shapes on the ingest hot path
        (measured ~1.6× slower warm, far worse cold).  The multi-zone
        tail mine is where the configured layout pays off.
        """
        hi = int(np.searchsorted(self._t, e, side="left"))
        b_lo = int(np.searchsorted(self._t, e - self.l_b, side="left"))
        g_cnt = hi - lo
        b_cnt = hi - b_lo
        if g_cnt == 0:
            self.n_zones_finalized += 2
            return
        # rebase timestamps to the pair start so the int32 device batch
        # never overflows (counts are shift-invariant, only gaps matter)
        t_base = int(self._t[lo])
        pair = TemporalGraph(
            u=self._u[lo:hi], v=self._v[lo:hi],
            t=(self._t[lo:hi] - t_base).astype(np.int32),
            n_nodes=int(max(self._u[lo:hi].max(initial=-1),
                            self._v[lo:hi].max(initial=-1)) + 1),
        )
        plan = tzp.ZonePlan(
            lo=np.asarray([0, b_lo - lo], np.int64),
            count=np.asarray([g_cnt, b_cnt], np.int64),
            sign=np.asarray([1, -1], np.int32),
            t_start=np.asarray([s - t_base, e - self.l_b - t_base],
                               np.int64),
            t_end=np.asarray([e - t_base, e - t_base], np.int64),
            l_b=self.l_b,
        )
        # cap at a power of two so jit shapes stabilize across pairs
        with self.obs.tracer.span("stream.finalize", edges=g_cnt):
            layout = tzp.build_zone_layout(
                pair, plan, layout="dense",
                e_cap=tzp.next_pow2(max(g_cnt, 8)),
            )
            counts = self.executor.run_layout(layout).counts
            _merge_into(self._counts,
                        transitions.device_counts_to_dict(counts))
        self.n_zones_finalized += 2

    # -- results ------------------------------------------------------------

    def snapshot(self, *, final: bool = False) -> DiscoveryResult:
        """Exact counts over the closed prefix (``t < t_head - L_b``).

        With ``final=True`` the stream is treated as ended and every
        buffered edge is mined (the result then equals batch ``discover``
        over everything ingested).  ``snapshot`` never mutates miner state
        (only the epoch-keyed tail cache); it can be called at any time,
        repeatedly — repeated calls within one epoch reuse both the
        finalized partial counts and the cached open-tail mine, so only the
        first snapshot of an epoch pays for device work.
        """
        if final:
            counts = dict(self._counts)
            tail_counts, tail_zones, tail_cap = self._mine_tail_arrays(
                self._u, self._v, self._t, int(self._t.size), final=True)
            _merge_into(counts, tail_counts)
            return DiscoveryResult(
                counts=counts, n_zones=self.n_zones_finalized + tail_zones,
                e_cap=tail_cap, overflow=0, delta=self.delta,
                l_max=self.l_max,
            )
        view = self.freeze()
        result, tail = self.mine_view(view)
        self.adopt_tail(view, tail)
        return result

    # -- lock-free snapshot protocol ----------------------------------------

    def freeze(self) -> SnapshotView:
        """Capture a :class:`SnapshotView` of the current closed prefix.

        Call under the same synchronization as ``ingest`` (the serving
        session holds its lock).  The capture is O(#finalized codes): array
        references plus one dict copy — no mining happens here.
        """
        if self._t.size == 0:
            cut = 0
        else:
            cut = int(np.searchsorted(self._t, self.closed_time,
                                      side="left"))
        sig = self._tail_sig()
        cached = None
        if self._tail_cache is not None \
                and self._tail_cache[:2] == (self._epoch, sig):
            cached = self._tail_cache[2:]
        return SnapshotView(
            epoch=self._epoch, sig=sig, counts=dict(self._counts),
            n_zones_finalized=self.n_zones_finalized,
            u=self._u, v=self._v, t=self._t, cut=cut, cached_tail=cached,
        )

    def mine_view(self, view: SnapshotView):
        """Mine a frozen view into ``(DiscoveryResult, tail_tuple)``.

        Safe to call *outside* the ingest synchronization: it reads only
        the view (immutable by construction) and the executor, whose
        concurrent runs are supported (per-run stats travel in the
        ``RunOutcome``).  Pass the tail tuple back through
        :meth:`adopt_tail` (under the lock again) to publish the mine into
        the epoch-keyed tail cache.
        """
        if view.cached_tail is not None:
            tail = view.cached_tail
        else:
            tail = self._mine_tail_arrays(view.u, view.v, view.t, view.cut,
                                          final=False)
        counts = dict(view.counts)
        _merge_into(counts, tail[0])
        result = DiscoveryResult(
            counts=counts, n_zones=view.n_zones_finalized + tail[1],
            e_cap=tail[2], overflow=0, delta=self.delta, l_max=self.l_max,
        )
        return result, tail

    def adopt_tail(self, view: SnapshotView, tail: tuple) -> None:
        """Publish a mined view's tail into the cache (CAS semantics).

        Call under the same synchronization as ``ingest``.  A stale
        publish — the epoch moved on while the mine ran — is discarded:
        the cache only ever holds a tail computed for the *current* epoch,
        so exactness is preserved no matter how the mine raced ingest.
        """
        if view.cached_tail is not None:
            self.tail_cache_hits += 1
            self.obs.metrics.counter("repro_streaming_tail_cache_hits_total",
                                     **self._obs_labels()).inc()
            return
        self.tail_cache_misses += 1
        self.obs.metrics.counter("repro_streaming_tail_cache_misses_total",
                                 **self._obs_labels()).inc()
        if self._epoch == view.epoch:
            self._tail_cache = (view.epoch, view.sig) + tuple(tail)

    def _tail_sig(self) -> tuple:
        """Settings that shape the tail's zone layout (cache invalidation).

        Defensive: every component is fixed at construction today (the
        config is frozen), so within one miner the signature only restates
        the epoch key.  It exists to pin the contract — the cached tail
        mine is only valid for the layout settings it was computed under —
        so a future mutable setting (or a subclass) cannot silently serve
        a mine computed under a different bucket decomposition.
        """
        return (self.config.zone_layout, self.e_cap,
                self.executor.zone_chunk)

    def _mine_tail_arrays(self, u: np.ndarray, v: np.ndarray,
                          t: np.ndarray, cut: int,
                          final: bool) -> tuple[dict[str, int], int, int]:
        """Mine the first ``cut`` buffered edges of ``(u, v, t)``; returns
        ``(counts, n_zones, e_cap)``.

        The tail flows through the same plan → :func:`tzp.
        build_zone_layout` → :meth:`MiningExecutor.run_layout` pipeline as
        batch discovery, so streaming inherits the size-bucketed layout
        (``self.last_tail_layout`` records the decomposition used).  The
        arrays come in explicitly (not read off ``self``) so a frozen
        :class:`SnapshotView` can be mined concurrently with ingest.
        """
        if t.size == 0 or cut == 0:
            return {}, 0, 0
        with self.obs.tracer.span("stream.tail_mine", edges=cut,
                                  final=final) as sp:
            # rebase to the tail start: int32-safe, shift-invariant
            tail = TemporalGraph(
                u=u[:cut], v=v[:cut],
                t=(t[:cut] - t[0]).astype(np.int32),
                n_nodes=int(max(u[:cut].max(initial=-1),
                                v[:cut].max(initial=-1)) + 1),
            )
            plan = tzp.plan_zones(
                tail, delta=self.delta, l_max=self.l_max,
                omega=self.omega, e_cap=self.e_cap,
            )
            layout = tzp.build_zone_layout(
                tail, plan, layout=self.config.zone_layout,
                pad_zones_to=self.executor.zone_chunk or 1,
                pad_edges_to=64,
            )
            sp.set(n_zones=plan.n_zones)
            tail_counts = self.executor.run_layout(layout).counts
            self.last_tail_layout = layout.summary()
        return (transitions.device_counts_to_dict(tail_counts),
                plan.n_zones, layout.e_cap)

    # -- checkpoint state round-trip -----------------------------------------

    def state_dict(self) -> dict:
        """Exact capture of the miner's durable state (checkpointing).

        Call under the same synchronization as ``ingest``.  The dict holds
        the frozen config, the finalized closed-prefix counts, the epoch
        and its closure signature, the frontier cursors, the monotone
        counters, the open-tail edge buffer (copies — a checkpoint must
        not alias the live buffer), and the tail-layout signature.  A
        miner restored from it and fed the remainder of the stream is
        **byte-identical** to one that never stopped: every field that
        influences future finalization or snapshots is included, and the
        epoch-keyed tail cache — a pure re-derivable function of the rest
        — is deliberately excluded (the first snapshot after restore
        replays only the open tail).
        """
        return {
            "config": self.config.to_dict(),
            "epoch": self._epoch,
            "closed_sig": list(self._closed_sig),
            "counts": dict(self._counts),
            "zone_start": self._s,
            "t_head": self._t_head,
            "n_edges_ingested": self.n_edges_ingested,
            "n_edges_retired": self.n_edges_retired,
            "n_zones_finalized": self.n_zones_finalized,
            "tail_u": self._u.copy(),
            "tail_v": self._v.copy(),
            "tail_t": self._t.copy(),
            "tail_sig": list(self._tail_sig()),
        }

    def restore_state(self, state: dict) -> None:
        """Install a :meth:`state_dict` capture into this (fresh) miner.

        The miner must have been constructed with the *same* config the
        state was captured under, and its executor must resolve the same
        tail-layout signature — a restored session that would silently
        mine under different layout settings is rejected instead, because
        the byte-identity guarantee only holds when the restored pipeline
        is the checkpointed one.
        """
        cfg = state["config"]
        if cfg != self.config.to_dict():
            theirs = MiningConfig.from_json(cfg)
            raise ValueError(
                f"checkpointed config {theirs.to_json()} does not match "
                f"this miner's {self.config.to_json()}; restore into a "
                f"miner built from the checkpointed config")
        sig = list(self._tail_sig())
        if list(state.get("tail_sig", sig)) != sig:
            raise ValueError(
                f"checkpointed tail-layout signature {state['tail_sig']} "
                f"does not match this miner's {sig}; the executor's "
                f"layout settings differ from the checkpointed ones")
        u, v, t = validate_edge_chunk(
            state["tail_u"], state["tail_v"], state["tail_t"])
        self._u, self._v, self._t = u, v, t
        self._s = None if state["zone_start"] is None \
            else int(state["zone_start"])
        self._t_head = None if state["t_head"] is None \
            else int(state["t_head"])
        self._counts = {str(c): int(n) for c, n in state["counts"].items()}
        self.n_edges_ingested = int(state["n_edges_ingested"])
        self.n_edges_retired = int(state["n_edges_retired"])
        self.n_zones_finalized = int(state["n_zones_finalized"])
        self._epoch = int(state["epoch"])
        self._closed_sig = tuple(state["closed_sig"])
        # re-derivable: the first snapshot after restore re-mines the tail
        self._tail_cache = None
