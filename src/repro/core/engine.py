"""PTMT session engine — one object that owns config + compilation state.

The paper's pipeline is one fixed lifecycle — plan zones (TZP), expand in
parallel, aggregate, encode — but the entry points had diverged into
per-call parameter bundles that re-resolved backends, capacity plans, and
jit state on every invocation.  :class:`PTMTEngine` is the single factory:

* ``engine.discover(graph)``    — batch PTMT discovery;
* ``engine.sequential(graph)``  — the TMC-analog baseline (one zone, built
  through :func:`repro.core.tzp.single_zone_plan` — no hand-rolled pad);
* ``engine.stream()``           — a :class:`repro.core.streaming.
  StreamingMiner` sharing this engine's executor;
* ``engine.sharded(graph, mesh, axes)`` — the mesh path, with the jitted
  SPMD mining step cached per ``(mesh, axes, out_cap, merge_mode)`` so
  repeated sharded calls skip re-building (and re-jitting) the step;
* serving sessions take the engine whole: ``MotifSession(name,
  engine=engine)``.

The engine resolves the backend **once** (at construction, via the
executor), owns the capacity planner (budget-derived plans are memoized per
batch geometry), and tracks the compiled-executable reuse that the
module-level jit caches provide: every run's
:meth:`~repro.core.executor.MiningExecutor.execution_key` is recorded, and
a key seen before is a **compile-cache hit** — the call dispatches straight
to an existing executable with no re-trace.  ``engine.stats`` exposes the
counters; ``benchmarks/bench_perf_mining.py`` asserts the warm-call
speedup and CI re-checks it on every push.

The legacy ``discover(...)``/``discover_sequential(...)`` kwargs functions
in :mod:`repro.core.api` remain as thin deprecated shims that construct a
one-shot engine.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from . import tzp
from .api import DiscoveryResult, counts_to_result
from .config import MiningConfig
from .executor import MiningExecutor
from .streaming import StreamingMiner
from .temporal_graph import TemporalGraph

__all__ = ["EngineStats", "PTMTEngine"]


@dataclasses.dataclass
class EngineStats:
    """Observable engine counters (mutated in place, cheap to read)."""

    discover_calls: int = 0
    sequential_calls: int = 0
    sharded_calls: int = 0
    stream_sessions: int = 0
    compile_cache_hits: int = 0     # runs whose execution key was seen before
    compile_cache_misses: int = 0   # runs that had to trace + compile
    zones_mined: int = 0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class PTMTEngine:
    """Session object for PTMT discovery: validated config + warm jit state.

    Construct from a :class:`~repro.core.config.MiningConfig` (or field
    overrides — ``PTMTEngine(delta=600, l_max=6)`` builds one), then call
    any mode repeatedly.  Same-shaped workloads reuse compiled executables:
    the backend is resolved once, capacity plans are memoized, and the
    mesh-path SPMD step is cached per mesh geometry.

    Thread-safety matches the underlying executor: concurrent ``discover``
    calls are safe (state is append-only caches and counters); the stats
    are best-effort under races.
    """

    def __init__(self, config: MiningConfig | None = None, **overrides):
        if config is None:
            config = MiningConfig(**overrides)
        elif overrides:
            config = config.with_updates(**overrides)
        self.config = config
        self.executor = MiningExecutor.from_config(config)
        self.stats = EngineStats()
        self._seen_keys: set[tuple] = set()
        self._mesh_steps: dict[tuple, object] = {}

    @property
    def backend(self) -> str:
        return self.executor.backend

    def __repr__(self) -> str:
        return (f"PTMTEngine(backend={self.backend!r}, "
                f"delta={self.config.delta}, l_max={self.config.l_max}, "
                f"compiled_plans={len(self._seen_keys)})")

    # -- compilation-state bookkeeping --------------------------------------

    def _note_execution(self, key: tuple, n_zones: int) -> None:
        """Record a *successful* run's execution key (call after the run —
        a raised overflow/out_cap error compiles nothing and must not
        poison the reuse counters the bench and CI assert on)."""
        if key in self._seen_keys:
            self.stats.compile_cache_hits += 1
        else:
            self._seen_keys.add(key)
            self.stats.compile_cache_misses += 1
        self.stats.zones_mined += n_zones

    def capacity_plan(self, n_zones: int, e_cap: int):
        """Budget-derived capacity plan (None without a budget).

        Delegates to the engine-held executor, which memoizes per batch
        geometry — repeated same-shaped runs never re-derive the plan.
        """
        return self.executor.capacity_plan(n_zones, e_cap)

    # -- batch discovery ----------------------------------------------------

    def _plan_and_batch(self, graph: TemporalGraph, n_shards: int = 1):
        cfg = self.config
        plan = tzp.plan_zones(graph, delta=cfg.delta, l_max=cfg.l_max,
                              omega=cfg.omega, e_cap=cfg.e_cap)
        pad_zones = (self.executor.zone_chunk or 1) * n_shards
        batch = tzp.build_zone_batch(graph, plan, e_cap=cfg.e_cap,
                                     pad_zones_to=pad_zones,
                                     n_shards=n_shards)
        return plan, batch

    def discover(self, graph: TemporalGraph) -> DiscoveryResult:
        """PTMT parallel discovery (plan zones → expand → aggregate).

        Repeated calls on same-shaped workloads dispatch to cached
        executables (``stats.compile_cache_hits``).
        """
        self.stats.discover_calls += 1
        plan, batch = self._plan_and_batch(graph)
        key = self.executor.execution_key(batch.n_zones, batch.e_cap)
        counts = self.executor.run(
            batch, allow_overflow=self.config.allow_overflow)
        self._note_execution(key, batch.n_zones)
        return counts_to_result(
            counts, n_zones=plan.n_zones, e_cap=batch.e_cap,
            overflow=batch.overflow, delta=self.config.delta,
            l_max=self.config.l_max,
        )

    def sequential(self, graph: TemporalGraph) -> DiscoveryResult:
        """TMC-analog baseline: one zone spanning the whole stream (no TZP).

        The single-zone batch goes through the same
        :func:`~repro.core.tzp.build_zone_batch` padding policy as every
        other mode.
        """
        self.stats.sequential_calls += 1
        plan = tzp.single_zone_plan(graph, l_b=self.config.l_b)
        batch = tzp.build_zone_batch(graph, plan)
        key = self.executor.execution_key(batch.n_zones, batch.e_cap)
        counts = self.executor.run(batch)
        self._note_execution(key, batch.n_zones)
        return counts_to_result(
            counts, n_zones=1, e_cap=batch.e_cap, overflow=batch.overflow,
            delta=self.config.delta, l_max=self.config.l_max,
        )

    # -- streaming ----------------------------------------------------------

    def stream(self, **overrides) -> StreamingMiner:
        """A fresh :class:`StreamingMiner` bound to this engine's config.

        Without overrides the miner shares this engine's executor (and so
        its warm jit state); with overrides a derived config (and executor)
        is built for the miner alone.
        """
        self.stats.stream_sessions += 1
        if overrides:
            return StreamingMiner(config=self.config.with_updates(
                **overrides))
        return StreamingMiner(config=self.config, executor=self.executor)

    # -- mesh path ----------------------------------------------------------

    def sharded(
        self,
        graph: TemporalGraph,
        mesh,
        axes: tuple[str, ...] | None = None,
        *,
        out_cap: int = 65536,
        merge_mode: str = "flat",
    ) -> DiscoveryResult:
        """Distributed discovery with zones sharded over ``mesh``.

        The jitted SPMD mining step is cached per ``(mesh, axes, out_cap,
        merge_mode)`` — the previous per-call ``mine_on_mesh`` rebuilt (and
        re-jitted) the step every invocation.
        """
        from repro.distributed import mining as dist_mining

        self.stats.sharded_calls += 1
        axes = tuple(axes or mesh.axis_names)
        n_shards = int(np.prod([mesh.shape[a] for a in axes]))
        plan, batch = self._plan_and_batch(graph, n_shards=n_shards)
        MiningExecutor.check_batch_overflow(
            batch, allow_overflow=self.config.allow_overflow)

        step_key = (mesh, axes, out_cap, merge_mode)
        fn = self._mesh_steps.get(step_key)
        if fn is None:
            fn = dist_mining.make_mine_step(
                mesh, axes, executor=self.executor, out_cap=out_cap,
                merge_mode=merge_mode,
            )
            self._mesh_steps[step_key] = fn
        # sharded executables are per SPMD step, not shared with the local
        # jit cache — key on the step too, or a first sharded call after a
        # same-shaped discover would misreport as a cache hit
        key = (step_key,
               self.executor.execution_key(batch.n_zones, batch.e_cap))
        counts = dist_mining.run_mine_fn(fn, batch, out_cap=out_cap)
        self._note_execution(key, batch.n_zones)
        return counts_to_result(
            counts, n_zones=plan.n_zones, e_cap=batch.e_cap,
            overflow=batch.overflow, delta=self.config.delta,
            l_max=self.config.l_max,
        )
