"""PTMT session engine — one object that owns config + compilation state.

The paper's pipeline is one fixed lifecycle — plan zones (TZP), expand in
parallel, aggregate, encode — but the entry points had diverged into
per-call parameter bundles that re-resolved backends, capacity plans, and
jit state on every invocation.  :class:`PTMTEngine` is the single factory:

* ``engine.discover(graph)``    — batch PTMT discovery;
* ``engine.sequential(graph)``  — the TMC-analog baseline (one zone, built
  through :func:`repro.core.tzp.single_zone_plan` — no hand-rolled pad);
* ``engine.stream()``           — a :class:`repro.core.streaming.
  StreamingMiner` sharing this engine's executor;
* ``engine.sharded(graph, mesh, axes)`` — the mesh path, with the jitted
  SPMD mining step cached per ``(mesh, axes, out_cap, merge_mode)`` so
  repeated sharded calls skip re-building (and re-jitting) the step;
* serving sessions take the engine whole: ``MotifSession(name,
  engine=engine)``.

The engine resolves the backend **once** (at construction, via the
executor), owns the capacity planner (budget-derived plans are memoized per
batch geometry), and tracks the compiled-executable reuse that the
module-level jit caches provide: every run's
:meth:`~repro.core.executor.MiningExecutor.execution_key` is recorded, and
a key seen before is a **compile-cache hit** — the call dispatches straight
to an existing executable with no re-trace.  ``engine.stats`` exposes the
counters; ``benchmarks/bench_perf_mining.py`` asserts the warm-call
speedup and CI re-checks it on every push.

The legacy ``discover(...)``/``discover_sequential(...)`` kwargs functions
in :mod:`repro.core.api` finished their deprecation cycle and now raise
with a pointer back here.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.obs import get_obs

from . import planner, tzp
from .api import DiscoveryResult, counts_to_result
from .config import MiningConfig
from .executor import MiningExecutor
from .streaming import StreamingMiner
from .temporal_graph import TemporalGraph

__all__ = ["EngineStats", "PTMTEngine"]


@dataclasses.dataclass
class EngineStats:
    """Observable engine counters (mutated in place, cheap to read).

    This dataclass is the stable, zero-dependency *view* of the engine's
    execution history — its fields and meanings are unchanged by the
    observability layer.  When the engine is built with a live
    :class:`repro.obs.Observability` bundle, every increment here is
    mirrored into the bundle's metrics registry
    (``repro_mining_compile_cache_hits_total`` etc.), so Prometheus
    exports and ``EngineStats`` always agree."""

    discover_calls: int = 0
    discover_many_calls: int = 0    # co-mined multi-config discover calls
    comined_configs: int = 0        # member configs served by shared sweeps
    sequential_calls: int = 0
    sharded_calls: int = 0
    stream_sessions: int = 0
    compile_cache_hits: int = 0     # bucket runs whose execution key was seen
    compile_cache_misses: int = 0   # bucket runs that had to trace + compile
    plan_cache_hits: int = 0        # discover calls that skipped plan_zones
    plan_cache_misses: int = 0      # discover calls that ran Algorithm 1
    zones_mined: int = 0
    launches: int = 0               # scan dispatches (fused layout run = 1)
    fused_runs: int = 0             # discover calls served by the fused path
    padding_ratio: float = 0.0      # last layout's padded-slot waste
    bucket_occupancy: dict = dataclasses.field(default_factory=dict)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class PTMTEngine:
    """Session object for PTMT discovery: validated config + warm jit state.

    Construct from a :class:`~repro.core.config.MiningConfig` (or field
    overrides — ``PTMTEngine(delta=600, l_max=6)`` builds one), then call
    any mode repeatedly.  Same-shaped workloads reuse compiled executables:
    the backend is resolved once, capacity plans are memoized, and the
    mesh-path SPMD step is cached per mesh geometry.

    Thread-safety matches the underlying executor: concurrent ``discover``
    calls are safe (state is append-only caches and counters); the stats
    are best-effort under races.
    """

    def __init__(self, config: MiningConfig | None = None, *, obs=None,
                 **overrides):
        if config is None:
            config = MiningConfig(**overrides)
        elif overrides:
            config = config.with_updates(**overrides)
        self.config = config
        # obs is deliberately NOT a MiningConfig field: the config is a
        # frozen hashable value object, an Observability bundle is live
        # mutable state.  It rides alongside instead, threaded into the
        # executor (and from there into streaming miners and layouts).
        self.obs = get_obs(obs)
        self.executor = MiningExecutor.from_config(config, obs=self.obs)
        self.stats = EngineStats()
        self._seen_keys: set[tuple] = set()
        self._mesh_steps: dict[tuple, object] = {}
        # host-side zone-plan cache: (graph fingerprint, delta, l_max,
        # omega, e_cap) -> ZonePlan.  Repeated discover on the same graph
        # skips Algorithm 1's O(n) scan entirely (stats.plan_cache_hits).
        # LRU-bounded: plans hold O(n_zones) arrays, and a long-lived
        # engine iterating many distinct graphs must not grow without
        # bound.
        self._zone_plans: dict[tuple, tzp.ZonePlan] = {}
        self._zone_plan_cap = 64
        # lattice-keyed executor cache: dominating MiningConfig -> warm
        # MiningExecutor for that sweep shape.  discover_many over the
        # same tenant mix reuses one executor (and its jit state) per
        # lattice; the engine's own executor serves lattices whose
        # dominating config IS the engine config.  LRU-bounded like the
        # zone-plan cache.
        self._lattice_executors: dict[MiningConfig, MiningExecutor] = {}
        self._lattice_executor_cap = 16

    @property
    def backend(self) -> str:
        return self.executor.backend

    def __repr__(self) -> str:
        return (f"PTMTEngine(backend={self.backend!r}, "
                f"delta={self.config.delta}, l_max={self.config.l_max}, "
                f"compiled_plans={len(self._seen_keys)})")

    # -- compilation-state bookkeeping --------------------------------------

    def _note_execution(self, key: tuple, n_zones: int) -> None:
        """Record a *successful* run's execution key (call after the run —
        a raised overflow/out_cap error compiles nothing and must not
        poison the reuse counters the bench and CI assert on)."""
        if key in self._seen_keys:
            self.stats.compile_cache_hits += 1
            self.obs.metrics.counter(
                "repro_mining_compile_cache_hits_total").inc()
        else:
            self._seen_keys.add(key)
            self.stats.compile_cache_misses += 1
            self.obs.metrics.counter(
                "repro_mining_compile_cache_misses_total").inc()
        self.stats.zones_mined += n_zones

    def capacity_plan(self, n_zones: int, e_cap: int):
        """Budget-derived capacity plan (None without a budget).

        Delegates to the engine-held executor, which memoizes per batch
        geometry — repeated same-shaped runs never re-derive the plan.
        """
        return self.executor.capacity_plan(n_zones, e_cap)

    # -- batch discovery ----------------------------------------------------

    def plan_zones(self, graph: TemporalGraph,
                   config: MiningConfig | None = None) -> tzp.ZonePlan:
        """Zone plan for ``graph``, memoized by graph fingerprint.

        The cache key is ``(graph_fingerprint, delta, l_max, omega,
        e_cap)`` — exactly the inputs Algorithm 1 depends on — so repeated
        ``discover`` on the same stream skips host-side planning entirely.
        ``ZonePlan.to_json``/``from_json`` round-trip exactly, so a plan
        can also be persisted and re-attached out of process.  ``config``
        plans for a non-engine config (the co-mine path plans at a
        lattice's dominating config) through the same cache.
        """
        cfg = config or self.config
        key = (tzp.graph_fingerprint(graph), cfg.delta, cfg.l_max,
               cfg.omega, cfg.e_cap)
        plan = self._zone_plans.get(key)
        if plan is not None:
            self.stats.plan_cache_hits += 1
            self.obs.metrics.counter(
                "repro_mining_plan_cache_hits_total").inc()
            self._zone_plans[key] = self._zone_plans.pop(key)  # LRU bump
            return plan
        with self.obs.tracer.span("engine.plan", n_edges=graph.n_edges):
            plan = tzp.plan_zones(graph, delta=cfg.delta, l_max=cfg.l_max,
                                  omega=cfg.omega, e_cap=cfg.e_cap)
        self._zone_plans[key] = plan
        while len(self._zone_plans) > self._zone_plan_cap:
            self._zone_plans.pop(next(iter(self._zone_plans)))
        self.stats.plan_cache_misses += 1
        self.obs.metrics.counter("repro_mining_plan_cache_misses_total").inc()
        return plan

    def _plan_and_layout(self, graph: TemporalGraph, n_shards: int = 1, *,
                         config: MiningConfig | None = None,
                         executor: MiningExecutor | None = None):
        cfg = config or self.config
        executor = executor or self.executor
        plan = self.plan_zones(graph, config=cfg)
        pad_zones = (executor.zone_chunk or 1) * n_shards
        with self.obs.tracer.span("engine.layout", n_zones=plan.n_zones):
            layout = tzp.build_zone_layout(graph, plan,
                                           layout=cfg.zone_layout,
                                           e_cap=cfg.e_cap,
                                           pad_zones_to=pad_zones,
                                           n_shards=n_shards)
        return plan, layout

    def _note_layout(self, layout: tzp.ZoneBatchLayout) -> None:
        self.stats.padding_ratio = layout.padding_ratio
        self.stats.bucket_occupancy = {
            b.label or "dense": b.occupancy for b in layout.buckets}

    def discover(self, graph: TemporalGraph) -> DiscoveryResult:
        """PTMT parallel discovery (plan zones → expand → aggregate).

        The zone batch is laid out per ``config.zone_layout`` (size
        buckets by default when zone sizes are skewed); repeated calls on
        recurring bucket shapes dispatch to cached executables
        (``stats.compile_cache_hits``) and repeated calls on the same
        graph skip planning (``stats.plan_cache_hits``).
        """
        self.stats.discover_calls += 1
        with self.obs.tracer.span("engine.discover",
                                  n_edges=graph.n_edges) as sp:
            plan, layout = self._plan_and_layout(graph)
            keys = self.executor.layout_execution_keys(layout)
            counts, run_stats = self.executor.run_layout(
                layout, allow_overflow=self.config.allow_overflow)
            sp.set(n_zones=plan.n_zones, path=run_stats.get("path"))
        if str(run_stats.get("path", "")).startswith("fused"):
            # one launch, one executable: the whole layout resolves to a
            # single fused execution key ("fused" or "fused_<backend>"
            # when dispatch rerouted the kernel, e.g. "fused_xla" on CPU)
            self._note_execution(keys[0], layout.n_zones)
            self.stats.fused_runs += 1
        else:
            for key, bucket in zip(keys, layout.buckets):
                self._note_execution(key, bucket.n_zones)
        self.stats.launches += int(run_stats.get("launches", 0))
        self._note_layout(layout)
        return counts_to_result(
            counts, n_zones=plan.n_zones, e_cap=layout.e_cap,
            overflow=layout.overflow, delta=self.config.delta,
            l_max=self.config.l_max,
            layout={**layout.summary(), "execution": dict(run_stats)},
        )

    # -- config-lattice co-mining --------------------------------------------

    def _lattice_executor(self, dominating: MiningConfig) -> MiningExecutor:
        """Warm executor for a lattice's dominating sweep config."""
        if dominating == self.config:
            return self.executor
        ex = self._lattice_executors.get(dominating)
        if ex is not None:
            self._lattice_executors[dominating] = \
                self._lattice_executors.pop(dominating)   # LRU bump
            return ex
        ex = MiningExecutor.from_config(dominating, obs=self.obs)
        self._lattice_executors[dominating] = ex
        while len(self._lattice_executors) > self._lattice_executor_cap:
            self._lattice_executors.pop(next(iter(self._lattice_executors)))
        return ex

    def discover_many(self, graph: TemporalGraph,
                      configs) -> list[DiscoveryResult]:
        """Co-mine N tenant configs from shared dominating Phase-1 sweeps.

        ``configs`` is a sequence of :class:`MiningConfig`s over the SAME
        graph.  Configs differing only in ``delta``/``l_max``/``omega``
        group into one lattice (:func:`repro.core.planner.
        build_config_lattices`) and share ONE Phase-1 expansion planned at
        the dominating ``(max delta, max l_max, max omega)``; each
        member's count table is split out during the Phase-2 fold by
        prefix-truncating candidates on per-edge absorption timestamps.
        Results are byte-identical to per-config :meth:`discover` calls
        (the differential tests assert it), returned in input order.
        """
        configs = list(configs)
        if not configs:
            return []
        self.stats.discover_many_calls += 1
        self.stats.comined_configs += len(configs)
        results: list[DiscoveryResult | None] = [None] * len(configs)
        lattices = planner.build_config_lattices(configs)
        with self.obs.tracer.span("engine.discover_many",
                                  n_edges=graph.n_edges,
                                  n_configs=len(configs),
                                  n_lattices=len(lattices)):
            for lat in lattices:
                self._discover_lattice(graph, lat, results)
        return results

    def _discover_lattice(self, graph: TemporalGraph,
                          lat: planner.ConfigLattice, results: list) -> None:
        """Mine one lattice's shared sweep and scatter member results."""
        dom = lat.dominating
        ex = self._lattice_executor(dom)
        plan, layout = self._plan_and_layout(graph, config=dom, executor=ex)
        params = lat.params
        # compile-cache accounting: a multi-config fold compiles its own
        # executable per (sweep key, member params) — distinct from the
        # single-config executable the same layout would use
        keys = tuple(k + (("multi",) + params,)
                     for k in ex.layout_execution_keys(layout))
        counts_tuple, run_stats = ex.run_layout_multi(
            layout, params, allow_overflow=dom.allow_overflow)
        if str(run_stats.get("path", "")).startswith("fused"):
            self._note_execution(keys[0], layout.n_zones)
            self.stats.fused_runs += 1
        else:
            for key, bucket in zip(keys, layout.buckets):
                self._note_execution(key, bucket.n_zones)
        self.stats.launches += int(run_stats.get("launches", 0))
        self._note_layout(layout)
        layout_summary = {**layout.summary(), "execution": dict(run_stats)}
        for member, idx, counts in zip(lat.members, lat.indices,
                                       counts_tuple):
            results[idx] = counts_to_result(
                counts, n_zones=plan.n_zones, e_cap=layout.e_cap,
                overflow=layout.overflow, delta=member.delta,
                l_max=member.l_max, layout=layout_summary,
            )

    def sequential(self, graph: TemporalGraph) -> DiscoveryResult:
        """TMC-analog baseline: one zone spanning the whole stream (no TZP).

        Always the dense layout (a single zone has nothing to bucket) —
        the one-zone batch goes through the same
        :func:`~repro.core.tzp.build_zone_batch` padding policy as every
        other mode.
        """
        self.stats.sequential_calls += 1
        plan = tzp.single_zone_plan(graph, l_b=self.config.l_b)
        layout = tzp.build_zone_layout(graph, plan, layout="dense")
        batch = layout.buckets[0]
        key = self.executor.execution_key(batch.n_zones, batch.e_cap)
        counts = self.executor.run(batch)
        self._note_execution(key, batch.n_zones)
        return counts_to_result(
            counts, n_zones=1, e_cap=batch.e_cap, overflow=batch.overflow,
            delta=self.config.delta, l_max=self.config.l_max,
            layout=layout.summary(),
        )

    # -- streaming ----------------------------------------------------------

    def stream(self, **overrides) -> StreamingMiner:
        """A fresh :class:`StreamingMiner` bound to this engine's config.

        Without overrides the miner shares this engine's executor (and so
        its warm jit state); with overrides a derived config (and executor)
        is built for the miner alone.
        """
        self.stats.stream_sessions += 1
        if overrides:
            return StreamingMiner(config=self.config.with_updates(
                **overrides), obs=self.obs)
        return StreamingMiner(config=self.config, executor=self.executor,
                              obs=self.obs)

    # -- mesh path ----------------------------------------------------------

    def sharded(
        self,
        graph: TemporalGraph,
        mesh,
        axes: tuple[str, ...] | None = None,
        *,
        out_cap: int = 65536,
        merge_mode: str = "flat",
    ) -> DiscoveryResult:
        """Distributed discovery with zones sharded over ``mesh``.

        The jitted SPMD mining step is cached per ``(mesh, axes, out_cap,
        merge_mode)``; with a bucketed layout each bucket is sharded over
        the mesh independently (its zones were round-robined across the
        shard lanes at build time) and the replicated per-bucket tables
        merge host-side through the same bounded carry as the local path.
        """
        from repro.distributed import mining as dist_mining

        self.stats.sharded_calls += 1
        axes = tuple(axes or mesh.axis_names)
        n_shards = int(np.prod([mesh.shape[a] for a in axes]))
        plan, layout = self._plan_and_layout(graph, n_shards=n_shards)
        MiningExecutor.check_layout_overflow(
            layout, allow_overflow=self.config.allow_overflow)

        step_key = (mesh, axes, out_cap, merge_mode)
        fn = self._mesh_steps.get(step_key)
        if fn is None:
            fn = dist_mining.make_mine_step(
                mesh, axes, executor=self.executor, out_cap=out_cap,
                merge_mode=merge_mode,
            )
            self._mesh_steps[step_key] = fn
        # sharded executables are per SPMD step, not shared with the local
        # jit cache — key on the step too, or a first sharded call after a
        # same-shaped discover would misreport as a cache hit
        def note(bucket):
            key = (step_key,
                   self.executor.execution_key(bucket.n_zones, bucket.e_cap))
            self._note_execution(key, bucket.n_zones)

        counts = dist_mining.run_mine_layout(
            fn, layout, out_cap=out_cap,
            merge_cap=self.executor.merge_cap, on_bucket=note)
        self._note_layout(layout)
        return counts_to_result(
            counts, n_zones=plan.n_zones, e_cap=layout.e_cap,
            overflow=layout.overflow, delta=self.config.delta,
            l_max=self.config.l_max, layout=layout.summary(),
        )
