"""Phase 2 — overlap-aware signed aggregation (sort + segment-sum).

The paper deduplicates boundary-zone candidates with hash sets and an atomic
global merge.  On TPU we instead exploit Lemma 4.2 directly: count every zone
independently and give growth zones weight +1, boundary zones weight -1.  The
signed sum over identical codes *is* the inclusion-exclusion reconciliation
``|G| = sum|G_i| - sum|B_i|`` — no hashing, no atomics, fully vectorized:

  1. flatten (zone, candidate) -> one stream of (code limbs, weight);
  2. lexicographic sort by limbs (``lax.sort`` with num_keys = n_limbs);
  3. group boundaries by adjacent-difference; segment-sum the weights.

Everything is static-shape; invalid slots carry the all-zero code (sorts
first) with weight 0 and are dropped by the caller via the validity mask.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


class CodeCounts(NamedTuple):
    """Sorted unique codes with (possibly signed-cancelled) counts.

    ``codes`` int32[N, L] — row i is meaningful where ``unique_mask[i]``;
    ``counts`` int32[N]   — aligned with codes;
    ``unique_mask`` bool[N].
    The all-zero padding code, if present, is masked out.
    """

    codes: jax.Array
    counts: jax.Array
    unique_mask: jax.Array


def empty_counts(capacity: int, limbs: int) -> CodeCounts:
    """An all-padding count table (the identity element of merging)."""
    return CodeCounts(
        codes=jnp.zeros((capacity, limbs), jnp.int32),
        counts=jnp.zeros((capacity,), jnp.int32),
        unique_mask=jnp.zeros((capacity,), bool),
    )


@jax.jit
def count_codes(codes, weights) -> CodeCounts:
    """Signed counting of code rows.

    Args:
      codes:   int32[N, L] limb codes (all-zero rows = padding).
      weights: int32[N] signed weights (0 for padding).
    """
    n, limbs = codes.shape
    if n == 0:
        return empty_counts(0, limbs)
    operands = tuple(codes[:, i] for i in range(limbs)) + (weights,)
    sorted_ops = jax.lax.sort(operands, num_keys=limbs)
    sorted_codes = jnp.stack(sorted_ops[:limbs], axis=1)
    sorted_w = sorted_ops[limbs]

    prev = jnp.roll(sorted_codes, 1, axis=0)
    boundary = jnp.any(sorted_codes != prev, axis=1)
    boundary = boundary.at[0].set(True)
    gid = jnp.cumsum(boundary.astype(jnp.int32)) - 1

    counts = jax.ops.segment_sum(sorted_w, gid, num_segments=n)
    unique_codes = jnp.zeros_like(sorted_codes).at[gid].set(sorted_codes)
    n_unique = gid[-1] + 1
    idx = jnp.arange(n)
    unique_mask = (idx < n_unique) & jnp.any(unique_codes != 0, axis=1)
    return CodeCounts(codes=unique_codes, counts=counts,
                      unique_mask=unique_mask)


@functools.partial(jax.jit, donate_argnums=())
def aggregate_zones(zone_codes, zone_lengths, zone_signs) -> CodeCounts:
    """Flatten a [Z, C, L] zone-result batch and signed-count it.

    Args:
      zone_codes:   int32[Z, C, L] final candidate codes.
      zone_lengths: int32[Z, C] process lengths (0 = padding slot).
      zone_signs:   int32[Z] +1 growth / -1 boundary / 0 padded zone row.
    """
    z, c, limbs = zone_codes.shape
    flat_codes = zone_codes.reshape(z * c, limbs)
    w = (zone_lengths > 0).astype(jnp.int32) * zone_signs[:, None]
    flat_w = w.reshape(z * c)
    flat_codes = jnp.where(flat_w[:, None] != 0, flat_codes, 0)
    return count_codes(flat_codes, flat_w)


@jax.jit
def merge_counts(a: CodeCounts, b: CodeCounts) -> CodeCounts:
    """Merge two (e.g. per-device) count maps into one."""
    codes = jnp.concatenate([
        jnp.where(a.unique_mask[:, None], a.codes, 0),
        jnp.where(b.unique_mask[:, None], b.codes, 0),
    ])
    counts = jnp.concatenate([
        jnp.where(a.unique_mask, a.counts, 0),
        jnp.where(b.unique_mask, b.counts, 0),
    ])
    return count_codes(codes, counts)


def live_rows(c: CodeCounts):
    """(codes, counts) with dead rows zeroed.

    A row is live when it is a unique code whose signed count has not fully
    cancelled.  Cancelled rows (count 0) are semantically absent but still
    occupy table slots after :func:`count_codes`; zeroing their codes lets
    the next merge reclaim the capacity — they collapse into the all-zero
    padding group instead of holding a bounded-width carry slot forever.
    """
    live = c.unique_mask & (c.counts != 0)
    return jnp.where(live[:, None], c.codes, 0), jnp.where(live, c.counts, 0)


@functools.partial(jax.jit, static_argnames=("cap",))
def merge_bounded(a: CodeCounts, b: CodeCounts, *, cap: int):
    """Merge ``b`` into ``a``, bounding the result to ``cap`` rows.

    The carry primitive of hierarchical aggregation: fold partial per-chunk
    count tables through a fixed-capacity table so the merge tree has
    bounded width (peak memory O(cap + len(b)) instead of O(total
    candidates)).  Unique codes compact to the front sorted, so truncating
    to ``cap`` rows is exact whenever the live-unique population fits.

    Returns ``(merged, spilled)`` where ``spilled`` is the number of live
    unique codes that did NOT fit in ``cap`` rows.  ``spilled > 0`` means
    the result is inexact and the caller must re-run with a larger cap
    (the executor's spill policy doubles ``merge_cap`` and retries — exact
    overflow detection makes the retry loop lossless).
    """
    a_codes, a_counts = live_rows(a)
    b_codes, b_counts = live_rows(b)
    merged = count_codes(jnp.concatenate([a_codes, b_codes]),
                         jnp.concatenate([a_counts, b_counts]))
    live = merged.unique_mask & (merged.counts != 0)
    spilled = live[cap:].sum(dtype=jnp.int32)
    total = merged.counts.shape[0]
    if total >= cap:
        out = CodeCounts(codes=merged.codes[:cap], counts=merged.counts[:cap],
                         unique_mask=merged.unique_mask[:cap])
    else:
        pad = cap - total
        out = CodeCounts(
            codes=jnp.pad(merged.codes, ((0, pad), (0, 0))),
            counts=jnp.pad(merged.counts, (0, pad)),
            unique_mask=jnp.pad(merged.unique_mask, (0, pad)),
        )
    return out, spilled
