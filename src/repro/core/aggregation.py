"""Phase 2 — overlap-aware signed aggregation (sort + segment-sum).

The paper deduplicates boundary-zone candidates with hash sets and an atomic
global merge.  On TPU we instead exploit Lemma 4.2 directly: count every zone
independently and give growth zones weight +1, boundary zones weight -1.  The
signed sum over identical codes *is* the inclusion-exclusion reconciliation
``|G| = sum|G_i| - sum|B_i|`` — no hashing, no atomics, fully vectorized:

  1. flatten (zone, candidate) -> one stream of (code limbs, weight);
  2. lexicographic sort by limbs (``lax.sort`` with num_keys = n_limbs);
  3. group boundaries by adjacent-difference; segment-sum the weights.

Everything is static-shape; invalid slots carry the all-zero code (sorts
first) with weight 0 and are dropped by the caller via the validity mask.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


class CodeCounts(NamedTuple):
    """Sorted unique codes with (possibly signed-cancelled) counts.

    ``codes`` int32[N, L] — row i is meaningful where ``unique_mask[i]``;
    ``counts`` int32[N]   — aligned with codes;
    ``unique_mask`` bool[N].
    The all-zero padding code, if present, is masked out.
    """

    codes: jax.Array
    counts: jax.Array
    unique_mask: jax.Array


@jax.jit
def count_codes(codes, weights) -> CodeCounts:
    """Signed counting of code rows.

    Args:
      codes:   int32[N, L] limb codes (all-zero rows = padding).
      weights: int32[N] signed weights (0 for padding).
    """
    n, limbs = codes.shape
    operands = tuple(codes[:, i] for i in range(limbs)) + (weights,)
    sorted_ops = jax.lax.sort(operands, num_keys=limbs)
    sorted_codes = jnp.stack(sorted_ops[:limbs], axis=1)
    sorted_w = sorted_ops[limbs]

    prev = jnp.roll(sorted_codes, 1, axis=0)
    boundary = jnp.any(sorted_codes != prev, axis=1)
    boundary = boundary.at[0].set(True)
    gid = jnp.cumsum(boundary.astype(jnp.int32)) - 1

    counts = jax.ops.segment_sum(sorted_w, gid, num_segments=n)
    unique_codes = jnp.zeros_like(sorted_codes).at[gid].set(sorted_codes)
    n_unique = gid[-1] + 1
    idx = jnp.arange(n)
    unique_mask = (idx < n_unique) & jnp.any(unique_codes != 0, axis=1)
    return CodeCounts(codes=unique_codes, counts=counts,
                      unique_mask=unique_mask)


@functools.partial(jax.jit, donate_argnums=())
def aggregate_zones(zone_codes, zone_lengths, zone_signs) -> CodeCounts:
    """Flatten a [Z, C, L] zone-result batch and signed-count it.

    Args:
      zone_codes:   int32[Z, C, L] final candidate codes.
      zone_lengths: int32[Z, C] process lengths (0 = padding slot).
      zone_signs:   int32[Z] +1 growth / -1 boundary / 0 padded zone row.
    """
    z, c, limbs = zone_codes.shape
    flat_codes = zone_codes.reshape(z * c, limbs)
    w = (zone_lengths > 0).astype(jnp.int32) * zone_signs[:, None]
    flat_w = w.reshape(z * c)
    flat_codes = jnp.where(flat_w[:, None] != 0, flat_codes, 0)
    return count_codes(flat_codes, flat_w)


@jax.jit
def merge_counts(a: CodeCounts, b: CodeCounts) -> CodeCounts:
    """Merge two (e.g. per-device) count maps into one."""
    codes = jnp.concatenate([
        jnp.where(a.unique_mask[:, None], a.codes, 0),
        jnp.where(b.unique_mask[:, None], b.codes, 0),
    ])
    counts = jnp.concatenate([
        jnp.where(a.unique_mask, a.counts, 0),
        jnp.where(b.unique_mask, b.counts, 0),
    ])
    return count_codes(codes, counts)
