"""Public PTMT API — result rendering.

The parameter surface lives in :class:`repro.core.config.MiningConfig` and
the lifecycle in :class:`repro.core.engine.PTMTEngine`; use them directly::

    engine = PTMTEngine(MiningConfig(delta=600, l_max=6))
    result = engine.discover(graph)          # warm calls reuse executables
    baseline = engine.sequential(graph)

The old one-shot ``discover`` / ``discover_sequential`` kwargs functions
went through a deprecation cycle and are now **removed**; the names remain
importable but raise immediately with a pointer at the engine API, so a
stale call site fails with instructions instead of an ``ImportError``
three frames away.
"""

from __future__ import annotations

import dataclasses

from . import transitions

_REMOVED = (
    "repro.core.{name}(...) was removed after its deprecation cycle; "
    "build a PTMTEngine from a MiningConfig — "
    "PTMTEngine(MiningConfig(delta=..., l_max=...)).{method}(graph) — "
    "which reuses compiled executables across calls.  Mesh-sharded "
    "mining is engine.sharded(graph, mesh, axes)."
)


@dataclasses.dataclass
class DiscoveryResult:
    counts: dict[str, int]          # final-code string -> exact count
    n_zones: int
    e_cap: int
    overflow: int                   # edges dropped by zone capacity (0 = exact)
    delta: int
    l_max: int
    #: device zone-batch layout summary (``ZoneBatchLayout.summary()``):
    #: kind, padding_ratio, per-bucket occupancy.  None for paths that do
    #: not build a layout (e.g. streaming snapshots' merged totals).
    layout: dict | None = None

    def tree(self) -> transitions.TransitionTree:
        return transitions.build_tree(self.counts)

    def total_processes(self) -> int:
        return sum(self.counts.values())

    def level_histogram(self) -> dict[int, int]:
        return transitions.level_histogram(self.counts)


def counts_to_result(counts, *, n_zones, e_cap, overflow, delta,
                     l_max, layout=None) -> DiscoveryResult:
    """Render a device :class:`CodeCounts` into a :class:`DiscoveryResult`."""
    count_dict = transitions.device_counts_to_dict(counts)
    return DiscoveryResult(
        counts=count_dict, n_zones=n_zones, e_cap=e_cap, overflow=overflow,
        delta=delta, l_max=l_max, layout=layout,
    )


def discover(*args, **kwargs):
    """REMOVED — use :meth:`repro.core.engine.PTMTEngine.discover`."""
    raise RuntimeError(_REMOVED.format(name="discover", method="discover"))


def discover_sequential(*args, **kwargs):
    """REMOVED — use :meth:`repro.core.engine.PTMTEngine.sequential`."""
    raise RuntimeError(
        _REMOVED.format(name="discover_sequential", method="sequential"))
