"""Public PTMT API — zone planning, parallel expansion, signed aggregation.

``discover``            TZP-partitioned parallel discovery (the paper's PTMT).
``discover_sequential`` single-zone stream scan — the TMC-analog baseline the
                        paper compares against (identical semantics, no
                        partitioning, O(n^2) candidate sweep).

Both return a :class:`DiscoveryResult` whose counts are *exact* (validated
against the brute-force oracle and each other in tests — the paper's Fig. 7).

The actual scan+aggregate work happens in :class:`repro.core.executor.
MiningExecutor`; this module only plans zones, builds the padded batch, and
renders the result.  Backends are resolved through
:mod:`repro.core.backends`.
"""

from __future__ import annotations

import dataclasses

import numpy as np

import jax

from . import transitions, tzp
from .executor import MiningExecutor
from .temporal_graph import TemporalGraph


@dataclasses.dataclass
class DiscoveryResult:
    counts: dict[str, int]          # final-code string -> exact count
    n_zones: int
    e_cap: int
    overflow: int                   # edges dropped by zone capacity (0 = exact)
    delta: int
    l_max: int

    def tree(self) -> transitions.TransitionTree:
        return transitions.build_tree(self.counts)

    def total_processes(self) -> int:
        return sum(self.counts.values())

    def level_histogram(self) -> dict[int, int]:
        return transitions.level_histogram(self.counts)


def counts_to_result(counts, *, n_zones, e_cap, overflow, delta,
                     l_max) -> DiscoveryResult:
    """Render a device :class:`CodeCounts` into a :class:`DiscoveryResult`."""
    count_dict = transitions.device_counts_to_dict(counts)
    return DiscoveryResult(
        counts=count_dict, n_zones=n_zones, e_cap=e_cap, overflow=overflow,
        delta=delta, l_max=l_max,
    )


def discover(
    graph: TemporalGraph,
    *,
    delta: int,
    l_max: int,
    omega: int = 20,
    e_cap: int | None = None,
    backend: str = "ref",
    zone_chunk: int | None = None,
    agg: str = "auto",
    merge_cap: int | None = None,
    memory_budget_mb: float | None = None,
    allow_overflow: bool = False,
    mesh: jax.sharding.Mesh | None = None,
    zone_axes: tuple[str, ...] | None = None,
) -> DiscoveryResult:
    """PTMT parallel motif-transition-process discovery.

    Args:
      graph: time-sorted temporal edge stream.
      delta, l_max, omega: paper parameters (Definitions 2-5).
      e_cap: per-zone edge capacity; zones denser than this are adaptively
        shrunk by the planner (never below the correctness floor ``2*L_b``).
      backend: any registered zone-scan backend ("ref", "pallas", "numpy");
        see :func:`repro.core.backends.available_backends`.
      zone_chunk: process zones in chunks of this many to bound memory.
      agg: Phase-2 aggregation mode ("auto" | "legacy" | "hierarchical" |
        "pipelined") — see :class:`repro.core.executor.MiningExecutor`.
      merge_cap: hierarchical bounded-merge carry width (None = derived).
      memory_budget_mb: derive ``zone_chunk``/``merge_cap`` from a device
        memory budget (:mod:`repro.core.planner`) when ``zone_chunk`` is
        not given explicitly.
      allow_overflow: mine even if the zone batch dropped edges beyond
        ``e_cap`` (the counts then undercount); default is to raise
        :class:`repro.core.executor.ZoneOverflowError`.
      mesh/zone_axes: optional mesh to shard the zone axis over (data
        parallelism across devices — the paper's thread pool).
    """
    executor = MiningExecutor(
        delta=delta, l_max=l_max, backend=backend, zone_chunk=zone_chunk,
        agg=agg, merge_cap=merge_cap, memory_budget_mb=memory_budget_mb,
    )
    plan = tzp.plan_zones(graph, delta=delta, l_max=l_max, omega=omega,
                          e_cap=e_cap)
    n_shards = 1
    if mesh is not None:
        axes = zone_axes or tuple(mesh.axis_names)
        n_shards = int(np.prod([mesh.shape[a] for a in axes]))
    pad_zones = (executor.zone_chunk or 1) * n_shards
    batch = tzp.build_zone_batch(
        graph, plan, e_cap=e_cap, pad_zones_to=pad_zones, n_shards=n_shards
    )

    if mesh is not None:
        from repro.distributed import mining as dist_mining

        MiningExecutor.check_batch_overflow(batch,
                                            allow_overflow=allow_overflow)
        counts = dist_mining.mine_on_mesh(
            batch, mesh, axes, executor=executor,
        )
    else:
        counts = executor.run(batch, allow_overflow=allow_overflow)

    return counts_to_result(
        counts, n_zones=plan.n_zones, e_cap=batch.e_cap,
        overflow=batch.overflow, delta=delta, l_max=l_max,
    )


def discover_sequential(
    graph: TemporalGraph, *, delta: int, l_max: int, backend: str = "ref"
) -> DiscoveryResult:
    """TMC-analog baseline: one zone spanning the whole stream (no TZP)."""
    n = max(graph.n_edges, 8)
    u = np.zeros((1, n), np.int32)
    v = np.zeros((1, n), np.int32)
    t = np.zeros((1, n), np.int32)
    valid = np.zeros((1, n), bool)
    tzp.fill_zone_row(u[0], v[0], t[0], valid[0], graph.u, graph.v, graph.t)
    executor = MiningExecutor(delta=delta, l_max=l_max, backend=backend,
                              zone_chunk=0)
    counts = executor.run_arrays(u, v, t, valid, np.ones(1, np.int32))
    return counts_to_result(
        counts, n_zones=1, e_cap=n, overflow=0, delta=delta, l_max=l_max,
    )
