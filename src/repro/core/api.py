"""Public PTMT API — zone planning, parallel expansion, signed aggregation.

``discover``            TZP-partitioned parallel discovery (the paper's PTMT).
``discover_sequential`` single-zone stream scan — the TMC-analog baseline the
                        paper compares against (identical semantics, no
                        partitioning, O(n^2) candidate sweep).

Both return a :class:`DiscoveryResult` whose counts are *exact* (validated
against the brute-force oracle and each other in tests — the paper's Fig. 7).
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

import jax
import jax.numpy as jnp

from . import aggregation, expansion, transitions, tzp
from .temporal_graph import TemporalGraph


@dataclasses.dataclass
class DiscoveryResult:
    counts: dict[str, int]          # final-code string -> exact count
    n_zones: int
    e_cap: int
    overflow: int                   # edges dropped by zone capacity (0 = exact)
    delta: int
    l_max: int

    def tree(self) -> transitions.TransitionTree:
        return transitions.build_tree(self.counts)

    def total_processes(self) -> int:
        return sum(self.counts.values())

    def level_histogram(self) -> dict[int, int]:
        return transitions.level_histogram(self.counts)


def _backend_scan(backend: str):
    if backend == "ref":
        return expansion.scan_zones
    if backend == "pallas":
        from repro.kernels.zone_scan import ops as zone_ops

        return zone_ops.scan_zones
    raise ValueError(f"unknown backend {backend!r}")


@functools.partial(
    jax.jit, static_argnames=("delta", "l_max", "backend", "zone_chunk")
)
def _mine_batch(u, v, t, valid, signs, *, delta, l_max, backend, zone_chunk):
    """Jitted zone sweep + signed aggregation over a padded zone batch."""
    scan = _backend_scan(backend)

    def chunk_fn(args):
        cu, cv, ct, cvalid = args
        res = scan(cu, cv, ct, cvalid, delta=delta, l_max=l_max)
        return res.code, res.length

    z = u.shape[0]
    if zone_chunk and zone_chunk < z:
        # bound peak memory: process zones in chunks of `zone_chunk`
        nchunk = z // zone_chunk
        reshape = lambda x: x.reshape(nchunk, zone_chunk, *x.shape[1:])
        codes, lengths = jax.lax.map(
            chunk_fn, (reshape(u), reshape(v), reshape(t), reshape(valid))
        )
        codes = codes.reshape(z, *codes.shape[2:])
        lengths = lengths.reshape(z, *lengths.shape[2:])
    else:
        codes, lengths = chunk_fn((u, v, t, valid))
    return aggregation.aggregate_zones(codes, lengths, signs)


def discover(
    graph: TemporalGraph,
    *,
    delta: int,
    l_max: int,
    omega: int = 20,
    e_cap: int | None = None,
    backend: str = "ref",
    zone_chunk: int | None = None,
    mesh: jax.sharding.Mesh | None = None,
    zone_axes: tuple[str, ...] | None = None,
) -> DiscoveryResult:
    """PTMT parallel motif-transition-process discovery.

    Args:
      graph: time-sorted temporal edge stream.
      delta, l_max, omega: paper parameters (Definitions 2-5).
      e_cap: per-zone edge capacity; zones denser than this are adaptively
        shrunk by the planner (never below the correctness floor ``2*L_b``).
      backend: "ref" (pure jnp lax.scan) or "pallas" (TPU kernel).
      zone_chunk: process zones in chunks of this many to bound memory.
      mesh/zone_axes: optional mesh to shard the zone axis over (data
        parallelism across devices — the paper's thread pool).
    """
    plan = tzp.plan_zones(graph, delta=delta, l_max=l_max, omega=omega,
                          e_cap=e_cap)
    n_shards = 1
    if mesh is not None:
        axes = zone_axes or tuple(mesh.axis_names)
        n_shards = int(np.prod([mesh.shape[a] for a in axes]))
    pad_zones = (zone_chunk or 1) * n_shards
    batch = tzp.build_zone_batch(
        graph, plan, e_cap=e_cap, pad_zones_to=pad_zones, n_shards=n_shards
    )

    if mesh is not None:
        from repro.distributed import mining as dist_mining

        counts = dist_mining.mine_on_mesh(
            batch, mesh, axes, delta=delta, l_max=l_max, backend=backend,
            zone_chunk=zone_chunk,
        )
    else:
        counts = _mine_batch(
            jnp.asarray(batch.u), jnp.asarray(batch.v), jnp.asarray(batch.t),
            jnp.asarray(batch.valid), jnp.asarray(batch.sign),
            delta=delta, l_max=l_max, backend=backend,
            zone_chunk=zone_chunk or 0,
        )

    count_dict = transitions.counts_to_dict(
        np.asarray(counts.codes), np.asarray(counts.counts),
        np.asarray(counts.unique_mask),
    )
    return DiscoveryResult(
        counts=count_dict, n_zones=plan.n_zones, e_cap=batch.e_cap,
        overflow=batch.overflow, delta=delta, l_max=l_max,
    )


def discover_sequential(
    graph: TemporalGraph, *, delta: int, l_max: int, backend: str = "ref"
) -> DiscoveryResult:
    """TMC-analog baseline: one zone spanning the whole stream (no TZP)."""
    n = max(graph.n_edges, 8)
    u = jnp.zeros((1, n), jnp.int32).at[0, : graph.n_edges].set(graph.u)
    v = jnp.zeros((1, n), jnp.int32).at[0, : graph.n_edges].set(graph.v)
    t = jnp.zeros((1, n), jnp.int32).at[0, : graph.n_edges].set(graph.t)
    valid = (
        jnp.zeros((1, n), bool).at[0, : graph.n_edges].set(True)
    )
    counts = _mine_batch(
        u, v, t, valid, jnp.ones(1, jnp.int32),
        delta=delta, l_max=l_max, backend=backend, zone_chunk=0,
    )
    count_dict = transitions.counts_to_dict(
        np.asarray(counts.codes), np.asarray(counts.counts),
        np.asarray(counts.unique_mask),
    )
    return DiscoveryResult(
        counts=count_dict, n_zones=1, e_cap=n, overflow=0,
        delta=delta, l_max=l_max,
    )
