"""Public PTMT API — result rendering + deprecated one-shot shims.

The parameter surface lives in :class:`repro.core.config.MiningConfig` and
the lifecycle in :class:`repro.core.engine.PTMTEngine`; new code should
use them directly::

    engine = PTMTEngine(MiningConfig(delta=600, l_max=6))
    result = engine.discover(graph)          # warm calls reuse executables
    baseline = engine.sequential(graph)

``discover`` / ``discover_sequential`` below are kept as thin back-compat
shims: each constructs a one-shot engine from its kwargs and emits a
``DeprecationWarning``.  Both return a :class:`DiscoveryResult` whose
counts are *exact* (validated against the brute-force oracle and each
other in tests — the paper's Fig. 7).
"""

from __future__ import annotations

import dataclasses
import warnings

import jax

from . import transitions

_DEPRECATION = (
    "repro.core.{name}(...) is deprecated; build a PTMTEngine from a "
    "MiningConfig (repro.core.engine / repro.core.config) and call "
    "engine.{method}(graph) — the engine reuses compiled executables "
    "across calls"
)


@dataclasses.dataclass
class DiscoveryResult:
    counts: dict[str, int]          # final-code string -> exact count
    n_zones: int
    e_cap: int
    overflow: int                   # edges dropped by zone capacity (0 = exact)
    delta: int
    l_max: int
    #: device zone-batch layout summary (``ZoneBatchLayout.summary()``):
    #: kind, padding_ratio, per-bucket occupancy.  None for paths that do
    #: not build a layout (e.g. streaming snapshots' merged totals).
    layout: dict | None = None

    def tree(self) -> transitions.TransitionTree:
        return transitions.build_tree(self.counts)

    def total_processes(self) -> int:
        return sum(self.counts.values())

    def level_histogram(self) -> dict[int, int]:
        return transitions.level_histogram(self.counts)


def counts_to_result(counts, *, n_zones, e_cap, overflow, delta,
                     l_max, layout=None) -> DiscoveryResult:
    """Render a device :class:`CodeCounts` into a :class:`DiscoveryResult`."""
    count_dict = transitions.device_counts_to_dict(counts)
    return DiscoveryResult(
        counts=count_dict, n_zones=n_zones, e_cap=e_cap, overflow=overflow,
        delta=delta, l_max=l_max, layout=layout,
    )


def discover(
    graph,
    *,
    delta: int,
    l_max: int,
    omega: int = 20,
    e_cap: int | None = None,
    backend: str = "ref",
    zone_chunk: int | None = None,
    agg: str = "auto",
    merge_cap: int | None = None,
    memory_budget_mb: float | None = None,
    allow_overflow: bool = False,
    mesh: jax.sharding.Mesh | None = None,
    zone_axes: tuple[str, ...] | None = None,
) -> DiscoveryResult:
    """Deprecated shim for :meth:`repro.core.engine.PTMTEngine.discover`.

    Builds a one-shot engine from the kwargs (see
    :class:`repro.core.config.MiningConfig` for their meaning) and runs a
    single discovery — the mesh kwargs route through ``engine.sharded``.
    Compiled executables are NOT reused across calls to this shim beyond
    the process-wide jit caches; hold a :class:`PTMTEngine` instead.
    """
    warnings.warn(
        _DEPRECATION.format(name="discover", method="discover"),
        DeprecationWarning, stacklevel=2,
    )
    from .config import MiningConfig
    from .engine import PTMTEngine

    engine = PTMTEngine(MiningConfig(
        delta=delta, l_max=l_max, omega=omega, e_cap=e_cap, backend=backend,
        zone_chunk=zone_chunk, agg=agg, merge_cap=merge_cap,
        memory_budget_mb=memory_budget_mb, allow_overflow=allow_overflow,
    ))
    if mesh is not None:
        return engine.sharded(graph, mesh, zone_axes)
    return engine.discover(graph)


def discover_sequential(
    graph, *, delta: int, l_max: int, backend: str = "ref"
) -> DiscoveryResult:
    """Deprecated shim for :meth:`repro.core.engine.PTMTEngine.sequential`.

    The TMC-analog baseline: one zone spanning the whole stream (no TZP).
    """
    warnings.warn(
        _DEPRECATION.format(name="discover_sequential", method="sequential"),
        DeprecationWarning, stacklevel=2,
    )
    from .config import MiningConfig
    from .engine import PTMTEngine

    return PTMTEngine(MiningConfig(
        delta=delta, l_max=l_max, backend=backend, zone_chunk=0,
    )).sequential(graph)
