"""Deterministic relabeling encoding (PTMT Phase 3), TPU-native form.

The paper encodes a motif transition process as the concatenation of
first-occurrence node labels of its edges, e.g. ``(A,B),(B,C),(A,C)`` becomes
the string ``"010212"``.  Strings and hash maps do not vectorize on TPU, so we
store codes as fixed-width **multi-limb int32 words**:

* each digit is ``label + 1`` in 4 bits (0 is reserved for padding, which makes
  codes self-delimiting: the number of non-zero digits is exactly ``2 * l``);
* 7 big-endian digits per limb (28 bits, the int32 sign bit stays clear);
* ``n_limbs = ceil(2 * l_max / 7)`` limbs per code.

Because digits are big-endian and padding is 0, integer-lexicographic order on
the limb tuple groups every process under its transition prefix — the property
Phase 3's string encoding provides, preserved for radix-style TPU sorting.

A connected ``l``-edge motif has at most ``l + 1`` nodes, so labels fit in
``[0, l_max]`` and 4-bit digits support ``l_max <= 14`` (the paper sweeps to 12).
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

DIGIT_BITS = 4
DIGITS_PER_LIMB = 7
_LIMB_MASK = (1 << (DIGIT_BITS * DIGITS_PER_LIMB)) - 1


def n_limbs(l_max: int) -> int:
    """Number of int32 limbs needed for ``2 * l_max`` digits."""
    if l_max > 14:
        raise ValueError(f"l_max={l_max} > 14 exceeds 4-bit label digits")
    return -(-2 * l_max // DIGITS_PER_LIMB)


def digit_shift(pos):
    """Bit shift of digit position ``pos`` *within its limb* (big-endian)."""
    return DIGIT_BITS * (DIGITS_PER_LIMB - 1 - pos % DIGITS_PER_LIMB)


def append_digit(code, pos, digit):
    """Add ``digit`` at global digit position ``pos`` into ``code[..., L]``.

    Vectorized over leading axes; ``pos``/``digit`` broadcast against
    ``code[..., 0]``.  The target slot must currently be zero.
    """
    limbs = code.shape[-1]
    limb_idx = pos // DIGITS_PER_LIMB
    add = jnp.left_shift(digit.astype(jnp.int32), digit_shift(pos))
    onehot = (
        jnp.arange(limbs, dtype=jnp.int32) == limb_idx[..., None]
    ).astype(jnp.int32)
    return code + onehot * add[..., None]


def empty_code(shape, l_max: int):
    return jnp.zeros((*shape, n_limbs(l_max)), dtype=jnp.int32)


def truncate_codes(code, lengths):
    """Truncate limb codes to their first ``lengths`` edges (vectorized).

    The jnp analog of :func:`prefix_code_np` with a per-row level: keeps
    the first ``2 * lengths[...]`` digits of ``code[..., L]`` and zeroes
    the rest.  Because label assignment is first-occurrence over the edge
    sequence, a truncated code equals the code of the prefix process — the
    property the config-lattice co-mining fold relies on to split one
    dominating sweep into per-config count tables.
    """
    limbs = code.shape[-1]
    keep = 2 * lengths.astype(jnp.int32)
    limb_iota = jnp.arange(limbs, dtype=jnp.int32)
    n_keep = jnp.clip(keep[..., None] - limb_iota * DIGITS_PER_LIMB,
                      0, DIGITS_PER_LIMB)
    mask = jnp.bitwise_xor(
        jnp.right_shift(_LIMB_MASK, DIGIT_BITS * n_keep), _LIMB_MASK)
    return code & mask


# ---------------------------------------------------------------------------
# Host-side (numpy) helpers for reporting / tests.
# ---------------------------------------------------------------------------


def encode_digits_np(digits, l_max: int) -> np.ndarray:
    """Pack a python list of digit values (label+1, 1-based) into limbs."""
    limbs = np.zeros(n_limbs(l_max), dtype=np.int32)
    for pos, d in enumerate(digits):
        if not 1 <= d <= 15:
            raise ValueError(f"digit {d} out of 4-bit 1-based range")
        limbs[pos // DIGITS_PER_LIMB] |= d << digit_shift(pos)
    return limbs


def encode_label_string_np(s: str, l_max: int) -> np.ndarray:
    """Encode a paper-style label string (e.g. ``"0101"``) into limbs."""
    return encode_digits_np([int(c, 16) + 1 for c in s], l_max)


def decode_code_np(limbs) -> str:
    """Limb code → paper-style label string (e.g. ``"010212"``)."""
    out = []
    for limb in np.asarray(limbs).tolist():
        for pos in range(DIGITS_PER_LIMB):
            d = (limb >> (DIGIT_BITS * (DIGITS_PER_LIMB - 1 - pos))) & 0xF
            if d == 0:
                continue
            out.append(format(d - 1, "x"))
    return "".join(out)


def code_length_np(limbs) -> int:
    """Number of edges encoded in a limb code."""
    return len(decode_code_np(limbs)) // 2


def encode_process_np(edges, l_max: int) -> np.ndarray:
    """Encode an explicit edge sequence ``[(u, v), ...]`` (host-side oracle)."""
    labels: dict[int, int] = {}
    digits = []
    for u, v in edges:
        for node in (u, v):
            if node not in labels:
                labels[node] = len(labels)
        digits.append(labels[u] + 1)
        digits.append(labels[v] + 1)
    return encode_digits_np(digits, l_max)


def prefix_range_np(s: str, l_max: int) -> tuple[np.ndarray, np.ndarray]:
    """Inclusive limb-code bounds of every code extending prefix ``s``.

    Because digits are big-endian within fixed-width limbs and padding is 0,
    the codes whose label string starts with ``s`` are exactly the codes
    ``c`` with ``lo <= c <= hi`` in integer-lexicographic limb order, where
    ``lo`` is ``s`` followed by zero digits and ``hi`` is ``s`` followed by
    all-0xF digits.  This is what lets the serving layer answer
    ``prefix_count`` with two binary searches over a sorted code index
    instead of a full scan.
    """
    lo = encode_label_string_np(s, l_max)
    hi = lo.copy()
    for pos in range(len(s), n_limbs(l_max) * DIGITS_PER_LIMB):
        hi[pos // DIGITS_PER_LIMB] |= 0xF << digit_shift(pos)
    return lo, hi


def code_key_np(limbs) -> bytes:
    """Limb code → big-endian byte key; bytewise order == integer-lex order.

    Each int32 limb is non-negative (28 data bits), so serializing limbs as
    big-endian uint32 and concatenating preserves the integer-lexicographic
    order on limb tuples under plain ``bytes`` comparison.
    """
    return np.ascontiguousarray(np.asarray(limbs), dtype=">u4").tobytes()


def prefix_code_np(limbs, level: int) -> np.ndarray:
    """Truncate a limb code to its first ``level`` edges (2*level digits)."""
    limbs = np.asarray(limbs).copy()
    keep_digits = 2 * level
    for m in range(limbs.shape[-1]):
        lo = m * DIGITS_PER_LIMB
        n_keep = int(np.clip(keep_digits - lo, 0, DIGITS_PER_LIMB))
        mask = (_LIMB_MASK >> (DIGIT_BITS * n_keep)) ^ _LIMB_MASK if n_keep else 0
        limbs[..., m] &= mask
    return limbs
