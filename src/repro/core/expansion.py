"""Phase 1 — growth-zone candidate expansion (vectorized reference).

The paper's ``try_to_transit`` loop, re-thought for SIMD/TPU execution:

* Definition 3 makes the successor of a motif unique ("no earlier valid
  transition"), so processes never fork.  Candidate *i* is therefore exactly
  the process seeded by edge *i* — a static, allocator-free table.
* Edges are consumed with ``lax.scan`` in stream order; each step does one
  dense vector sweep over the candidate table (extension test + relabeling
  encode), which is the inner loop the Pallas kernel tiles into VMEM.

State (structure-of-arrays over candidates):
  ``length``  int32[C]  edges absorbed so far (0 = not yet seeded)
  ``last_t``  int32[C]  timestamp of the newest edge
  ``done``    bool[C]   timed out (frozen forever)
  ``n_nodes`` int32[C]  node-table population
  ``nodes``   int32[C,K] first-occurrence node table, K = l_max + 1, -1 = empty
  ``code``    int32[C,L] multi-limb relabeling code (see core.encoding)
  ``ts``      int32[C,l_max] per-step absorption timestamps (``with_ts``
              only; ``ts[:, k]`` is the timestamp of the k-th absorbed
              edge, ``ts[:, 0]`` the seed time).  The config-lattice
              co-mining path derives every smaller ``(delta, l_max)``
              config's counts from one dominating sweep by prefix-
              truncating candidates on these timestamps
              (:func:`derive_lengths`).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import encoding


class ZoneState(NamedTuple):
    length: jax.Array
    last_t: jax.Array
    done: jax.Array
    n_nodes: jax.Array
    nodes: jax.Array
    code: jax.Array
    ts: jax.Array | None = None


class ZoneResult(NamedTuple):
    """Final per-candidate codes of one zone (candidate i = seed edge i)."""

    code: jax.Array     # int32[C, L]
    length: jax.Array   # int32[C] (0 for padding slots)
    ts: jax.Array | None = None   # int32[C, l_max] absorption timestamps


def init_state(e_cap: int, l_max: int, *, with_ts: bool = False) -> ZoneState:
    k = l_max + 1
    return ZoneState(
        length=jnp.zeros(e_cap, jnp.int32),
        last_t=jnp.zeros(e_cap, jnp.int32),
        done=jnp.zeros(e_cap, bool),
        n_nodes=jnp.zeros(e_cap, jnp.int32),
        nodes=jnp.full((e_cap, k), -1, jnp.int32),
        code=encoding.empty_code((e_cap,), l_max),
        ts=jnp.zeros((e_cap, l_max), jnp.int32) if with_ts else None,
    )


def step(state: ZoneState, edge, *, delta: int, l_max: int) -> ZoneState:
    """Absorb one edge: time-outs, extensions, then seed the new candidate."""
    u, v, t, valid, slot = edge
    c = state.length.shape[0]

    active = (state.length > 0) & ~state.done
    gap_ok = (t > state.last_t) & (t - state.last_t <= delta)
    timed_out = active & (t - state.last_t > delta) & valid
    done = state.done | timed_out

    u_hit = state.nodes == u
    v_hit = state.nodes == v
    u_in = u_hit.any(axis=1)
    v_in = v_hit.any(axis=1)
    extend = (
        active & ~timed_out & gap_ok & (state.length < l_max)
        & (u_in | v_in) & valid
    )

    # first-occurrence relabeling (Phase 3 encoding, fused into the sweep)
    k_iota = jnp.arange(state.nodes.shape[1], dtype=jnp.int32)[None, :]
    label_u = jnp.where(u_in, jnp.argmax(u_hit, axis=1), state.n_nodes)
    nn1 = state.n_nodes + (~u_in).astype(jnp.int32)
    same_uv = u == v
    label_v = jnp.where(
        same_uv, label_u, jnp.where(v_in, jnp.argmax(v_hit, axis=1), nn1)
    )
    nn2 = jnp.where(same_uv, nn1, nn1 + (~v_in).astype(jnp.int32))

    put_u = extend & ~u_in
    put_v = extend & ~v_in & ~same_uv
    nodes = jnp.where(
        (put_u[:, None] & (k_iota == state.n_nodes[:, None])), u, state.nodes
    )
    nodes = jnp.where(
        (put_v[:, None] & (k_iota == nn1[:, None])), v, nodes
    )

    pos = 2 * state.length
    code = encoding.append_digit(
        state.code, pos, jnp.where(extend, label_u + 1, 0)
    )
    code = encoding.append_digit(
        code, pos + 1, jnp.where(extend, label_v + 1, 0)
    )

    length = state.length + extend.astype(jnp.int32)
    last_t = jnp.where(extend, t, state.last_t)
    n_nodes = jnp.where(extend, nn2, state.n_nodes)

    # seed the candidate owned by this edge (slot == stream index)
    seed = (jnp.arange(c, dtype=jnp.int32) == slot) & valid
    length = jnp.where(seed, 1, length)
    last_t = jnp.where(seed, t, last_t)
    n_nodes = jnp.where(seed, jnp.where(same_uv, 1, 2), n_nodes)
    nodes = jnp.where((seed[:, None] & (k_iota == 0)), u, nodes)
    nodes = jnp.where(
        (seed[:, None] & (k_iota == 1) & ~same_uv), v, nodes
    )
    seed_code = encoding.append_digit(
        encoding.empty_code((c,), l_max),
        jnp.zeros(c, jnp.int32),
        jnp.ones(c, jnp.int32),
    )
    seed_code = encoding.append_digit(
        seed_code,
        jnp.ones(c, jnp.int32),
        jnp.where(same_uv, 1, 2) * jnp.ones(c, jnp.int32),
    )
    code = jnp.where(seed[:, None], seed_code, code)

    ts = state.ts
    if ts is not None:
        # record this edge's timestamp at the step it was absorbed: slot
        # state.length for an extension (pre-increment), slot 0 for a seed
        step_iota = jnp.arange(ts.shape[1], dtype=jnp.int32)[None, :]
        ts = jnp.where(extend[:, None] & (step_iota == state.length[:, None]),
                       t, ts)
        ts = jnp.where(seed[:, None] & (step_iota == 0), t, ts)

    return ZoneState(length=length, last_t=last_t, done=done,
                     n_nodes=n_nodes, nodes=nodes, code=code, ts=ts)


@functools.partial(jax.jit, static_argnames=("delta", "l_max", "with_ts"))
def scan_zone(u, v, t, valid, *, delta: int, l_max: int,
              with_ts: bool = False) -> ZoneResult:
    """Run the full expansion over one zone's padded edge stream.

    Args:
      u, v, t: int32[E] padded edge stream (time-ordered within the zone).
      valid:   bool[E] real-edge mask.
      with_ts: also return per-step absorption timestamps (the co-mining
        path's input; the single-config path pays nothing for the flag).
    Returns:
      ZoneResult with per-seed final codes; padding slots have length 0.
    """
    e_cap = u.shape[0]
    state = init_state(e_cap, l_max, with_ts=with_ts)

    def body(state, edge):
        return step(state, edge, delta=delta, l_max=l_max), None

    slots = jnp.arange(e_cap, dtype=jnp.int32)
    state, _ = jax.lax.scan(body, state, (u, v, t, valid, slots))
    return ZoneResult(code=state.code, length=state.length, ts=state.ts)


def scan_zones(u, v, t, valid, *, delta: int, l_max: int,
               with_ts: bool = False) -> ZoneResult:
    """vmap of :func:`scan_zone` over a [Z, E] zone batch."""
    fn = functools.partial(scan_zone, delta=delta, l_max=l_max,
                           with_ts=with_ts)
    return jax.vmap(fn)(u, v, t, valid)


def derive_lengths(length, ts, *, delta: int, l_max: int):
    """Prefix length of each dominating-sweep candidate under a smaller config.

    The config-lattice co-mining lemma: zone streams are time-sorted, so
    for ``delta <= delta_dom`` and ``l_max <= l_max_dom`` the process a
    smaller config would have mined for a candidate is exactly the longest
    prefix of the dominating config's absorbed edge sequence in which every
    consecutive absorption gap ``ts[k] - ts[k-1]`` is ``<= delta``, capped
    at ``l_max`` edges.  (While the two configs agree on a prefix they make
    identical extension decisions — extension needs a node overlap, a
    strictly increasing timestamp, and a gap ``<= delta``; the first
    dominating absorption whose gap exceeds the smaller ``delta`` also
    proves an intervening stream edge timed the smaller config out, because
    any in-between edge ``t'`` satisfies ``ts[k-1] <= t' <= ts[k]``.)

    Args:
      length: int32[...] dominating-sweep process lengths.
      ts:     int32[..., l_max_dom] absorption timestamps (``with_ts``).
    Returns:
      int32[...] prefix lengths under ``(delta, l_max)``; 0 stays 0.
    """
    l_dom = ts.shape[-1]
    if l_dom > 1:
        steps = jnp.arange(1, l_dom, dtype=jnp.int32)
        gaps = ts[..., 1:] - ts[..., :-1]
        ok = (steps < length[..., None]) & (gaps <= delta)
        run = jnp.cumprod(ok.astype(jnp.int32), axis=-1).sum(axis=-1)
    else:
        run = jnp.zeros_like(length)
    out = jnp.minimum(1 + run, l_max).astype(jnp.int32)
    return jnp.where(length > 0, out, 0)
