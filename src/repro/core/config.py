"""Unified mining configuration — ONE validated parameter surface.

Every discovery entry point (batch, sequential baseline, streaming,
serving sessions, the mesh path, and both CLIs) historically re-declared an
overlapping subset of ``delta / l_max / omega / e_cap / backend /
zone_chunk / agg / merge_cap / memory_budget_mb / allow_overflow`` and
re-validated (or forgot to validate) it independently.  :class:`MiningConfig`
is the single source of truth:

* **frozen + hashable** — a config is a value; it can key caches (the
  engine's compiled-plan cache, serving-session defaults) and be shared
  across threads without defensive copies;
* **validated on construction** — ``__post_init__`` runs :meth:`validate`,
  so an invalid config cannot exist; ``with_updates`` re-validates;
* **serializable** — ``to_json``/``from_json`` round-trip exactly (the
  serving layer persists tenant configs, benchmarks embed them in
  ``BENCH_*.json`` payloads);
* **owns the CLI surface** — :meth:`add_cli_args` declares the shared
  mining flags once (defaults come from the dataclass fields, backend /
  agg choices from the live registries) and :meth:`from_cli_args` parses
  them back, so ``launch/mine.py`` and ``launch/serve_motifs.py`` cannot
  drift apart.

Precedence rule (the one genuine conflict in the surface): an explicit
``zone_chunk`` always beats a ``memory_budget_mb``-derived one — explicit
beats derived everywhere in this codebase — and setting both warns so the
silently-ignored budget is visible.
"""

from __future__ import annotations

import dataclasses
import json
import warnings
from typing import Any

from . import backends
from .executor import AGG_MODES, FUSED_MODES
from .tzp import ZONE_LAYOUTS

__all__ = ["MiningConfig"]

#: argparse flag -> (help text,) for the shared mining surface; the flag
#: names are the dataclass field names with ``_`` -> ``-``.
_CLI_HELP = {
    "delta": "max gap between consecutive process steps (Definition 2)",
    "l_max": "max process length (Definition 4)",
    "omega": "growth-zone length in boundary units (Algorithm 1)",
    "e_cap": "per-zone edge capacity; denser zones are adaptively shrunk",
    "backend": "zone-scan backend",
    "zone_chunk": "process zones in chunks of this many to bound memory "
                  "(explicit value beats --memory-budget-mb)",
    "agg": "Phase-2 aggregation: hierarchical/pipelined bound peak memory "
           "to O(zone_chunk) instead of O(zones)",
    "merge_cap": "hierarchical bounded-merge carry width (default: derived)",
    "memory_budget_mb": "derive zone_chunk/merge_cap from this device "
                        "memory budget (core.planner) instead of hints",
    "allow_overflow": "mine even if the zone batch dropped edges beyond "
                      "e_cap (counts then undercount; default: error)",
    "zone_layout": "device zone-batch layout: 'bucketed' groups zones into "
                   "power-of-two e_cap buckets (less padding sweep work on "
                   "skewed zone sizes), 'dense' pads every zone to the "
                   "global max, 'auto' buckets only when sizes span more "
                   "than one bucket",
    "fused": "single-launch layout dispatch: 'auto' mines the whole layout "
             "in one bucket-native kernel launch (Phase-2 fold on-device) "
             "whenever the backend has a flat kernel, 'on' requires one, "
             "'off' keeps one launch per bucket",
    "fused_backend": "which backend's flat kernel serves fused runs: "
                     "'auto' keeps --backend except where Pallas would "
                     "interpret (CPU), where the compiled 'xla' lowering "
                     "takes over; an explicit name pins the lowering "
                     "(must publish a fused kernel)",
}


@dataclasses.dataclass(frozen=True)
class MiningConfig:
    """The full PTMT parameter surface: paper params + execution params.

    Paper parameters (Definitions 2-5, Algorithm 1):
      delta, l_max, omega, e_cap — as in ``PTMTEngine.discover``.

    Execution parameters (see :class:`repro.core.executor.MiningExecutor`):
      backend, zone_chunk, agg, merge_cap, memory_budget_mb,
      allow_overflow.

    Instances are frozen, hashable, and validated on construction.
    """

    delta: int = 600
    l_max: int = 6
    omega: int = 20
    e_cap: int | None = None
    backend: str = "ref"
    zone_chunk: int | None = None
    agg: str = "auto"
    merge_cap: int | None = None
    memory_budget_mb: float | None = None
    allow_overflow: bool = False
    zone_layout: str = "auto"
    fused: str = "auto"
    fused_backend: str = "auto"

    def __post_init__(self):
        # frozen dataclass: normalize via object.__setattr__ before the
        # value escapes, then validate — an invalid config never exists.
        # Non-integral values for integer fields are rejected, not
        # truncated: MiningConfig(delta=599.9) silently mining with
        # delta=599 would be a parameter the caller never asked for.
        for f in ("delta", "l_max", "omega", "e_cap", "zone_chunk",
                  "merge_cap"):
            val = getattr(self, f)
            if val is None:
                continue
            if int(val) != val:
                raise ValueError(
                    f"{f} must be an integer, got {val!r}")
            object.__setattr__(self, f, int(val))
        if self.memory_budget_mb is not None:
            object.__setattr__(self, "memory_budget_mb",
                               float(self.memory_budget_mb))
        object.__setattr__(self, "allow_overflow", bool(self.allow_overflow))
        self.validate()

    # -- validation ---------------------------------------------------------

    def validate(self) -> "MiningConfig":
        """Raise ``ValueError`` on any invalid field; returns self.

        Error messages keep the historical phrasings ("delta and l_max
        must be >= 1", "omega must be >= 2") that callers and tests match
        against.
        """
        if self.delta < 1 or self.l_max < 1:
            raise ValueError("delta and l_max must be >= 1")
        if self.omega < 2:
            raise ValueError(
                "omega must be >= 2 (growth zone >= 2 boundary zones)")
        if self.e_cap is not None and self.e_cap < 1:
            raise ValueError(f"e_cap must be >= 1, got {self.e_cap}")
        if self.zone_chunk is not None and self.zone_chunk < 0:
            raise ValueError(
                f"zone_chunk must be >= 0, got {self.zone_chunk}")
        if self.merge_cap is not None and self.merge_cap < 1:
            raise ValueError(
                f"merge_cap must be >= 1, got {self.merge_cap}")
        if self.memory_budget_mb is not None and self.memory_budget_mb <= 0:
            raise ValueError("memory_budget_mb must be > 0")
        if self.agg not in AGG_MODES:
            raise ValueError(
                f"unknown agg mode {self.agg!r}; one of {AGG_MODES}")
        if self.zone_layout not in ZONE_LAYOUTS:
            raise ValueError(
                f"unknown zone layout {self.zone_layout!r}; one of "
                f"{ZONE_LAYOUTS}")
        if self.fused not in FUSED_MODES:
            raise ValueError(
                f"unknown fused mode {self.fused!r}; one of {FUSED_MODES}")
        # resolves through the live registry so plugin backends validate
        # too; unknown names raise ValueError listing what is available
        backends.get_backend(self.backend)
        if self.fused_backend != "auto" and \
                not backends.get_backend(self.fused_backend).supports_fused:
            raise ValueError(
                f"fused_backend {self.fused_backend!r} has no fused "
                f"single-launch scan; pick one that publishes a flat "
                f"kernel (or leave it 'auto')")
        if self.zone_chunk is not None and self.memory_budget_mb is not None:
            # includes zone_chunk=0 ("explicitly unchunked") — any explicit
            # value beats the budget-derived chunk, so the budget is inert
            warnings.warn(
                f"both zone_chunk={self.zone_chunk} and memory_budget_mb="
                f"{self.memory_budget_mb} are set; the explicit zone_chunk "
                f"takes precedence and the budget-derived chunk is ignored",
                RuntimeWarning, stacklevel=3,
            )
        return self

    # -- derived ------------------------------------------------------------

    @property
    def l_b(self) -> int:
        """Boundary length ``delta * l_max`` (max process time span)."""
        return self.delta * self.l_max

    def with_updates(self, **updates: Any) -> "MiningConfig":
        """A new validated config with ``updates`` applied."""
        return dataclasses.replace(self, **updates)

    # -- serialization ------------------------------------------------------

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, data: str | bytes | dict) -> "MiningConfig":
        """Inverse of :meth:`to_json`; also accepts an already-parsed dict.

        Unknown keys raise (a config round-trip must be exact, not lossy).
        """
        if not isinstance(data, dict):
            data = json.loads(data)
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(data) - known)
        if unknown:
            raise ValueError(
                f"unknown MiningConfig field(s) {unknown}; known: "
                f"{sorted(known)}")
        return cls(**data)

    # -- CLI surface --------------------------------------------------------

    @classmethod
    def add_cli_args(cls, parser) -> None:
        """Declare the shared mining flags on an argparse parser.

        Flag defaults are the dataclass field defaults and choice lists
        come from the live registries, so the CLIs can never drift from
        the config.  ``from_cli_args`` parses the result back.
        """
        defaults = {f.name: f.default for f in dataclasses.fields(cls)}
        parser.add_argument("--delta", type=int, default=defaults["delta"],
                            help=_CLI_HELP["delta"])
        parser.add_argument("--l-max", type=int, default=defaults["l_max"],
                            help=_CLI_HELP["l_max"])
        parser.add_argument("--omega", type=int, default=defaults["omega"],
                            help=_CLI_HELP["omega"])
        parser.add_argument("--e-cap", type=int, default=defaults["e_cap"],
                            help=_CLI_HELP["e_cap"])
        parser.add_argument("--backend", default=defaults["backend"],
                            choices=list(backends.available_backends()),
                            help=_CLI_HELP["backend"])
        parser.add_argument("--zone-chunk", type=int,
                            default=defaults["zone_chunk"],
                            help=_CLI_HELP["zone_chunk"])
        parser.add_argument("--agg", default=defaults["agg"],
                            choices=list(AGG_MODES), help=_CLI_HELP["agg"])
        parser.add_argument("--merge-cap", type=int,
                            default=defaults["merge_cap"],
                            help=_CLI_HELP["merge_cap"])
        parser.add_argument("--memory-budget-mb", type=float,
                            default=defaults["memory_budget_mb"],
                            help=_CLI_HELP["memory_budget_mb"])
        parser.add_argument("--allow-overflow", action="store_true",
                            default=defaults["allow_overflow"],
                            help=_CLI_HELP["allow_overflow"])
        parser.add_argument("--zone-layout", default=defaults["zone_layout"],
                            choices=list(ZONE_LAYOUTS),
                            help=_CLI_HELP["zone_layout"])
        parser.add_argument("--fused", default=defaults["fused"],
                            choices=list(FUSED_MODES),
                            help=_CLI_HELP["fused"])
        parser.add_argument("--fused-backend",
                            default=defaults["fused_backend"],
                            choices=["auto",
                                     *backends.available_backends()],
                            help=_CLI_HELP["fused_backend"])

    @classmethod
    def from_cli_args(cls, args) -> "MiningConfig":
        """Build a validated config from a parsed argparse namespace."""
        return cls(**{f.name: getattr(args, f.name)
                      for f in dataclasses.fields(cls)})
