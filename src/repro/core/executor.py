"""Unified mining executor — ONE chunked scan+aggregate engine.

Every discovery entry point (batch ``discover``, the sequential baseline,
``distributed.mining.mine_on_mesh`` and the streaming miner) routes through
:class:`MiningExecutor` instead of carrying its own copy of the zone sweep:

* backend dispatch goes through :mod:`repro.core.backends` (capability-aware,
  pluggable);
* zone chunking (chunks of ``zone_chunk`` zones to bound peak memory) is
  implemented once, with an explicit policy for zone counts that do not
  divide ``zone_chunk`` — **pad** (default: append inert zero-sign rows) or
  **raise** — never the silent remainder drop the pre-refactor
  ``_mine_batch`` had;
* Phase-2 aggregation has three modes (``agg``):

  - ``"legacy"``      — materialize every chunk's candidate codes, then one
                        whole-batch flatten-and-sort (peak O(Z*C));
  - ``"hierarchical"``— fold each chunk through ``count_codes`` immediately
                        and tree-merge the partial tables inside the
                        ``lax.scan`` carry via
                        :func:`repro.core.aggregation.merge_bounded` — a
                        bounded-width merge whose capacity is ``merge_cap``.
                        Peak memory is O(zone_chunk*C + merge_cap),
                        independent of the zone count.  Spills (more live
                        unique codes than ``merge_cap``) are detected
                        exactly and retried host-side with a doubled cap,
                        so results are always exact;
  - ``"pipelined"``   — same fold, driven by a host loop that double-buffers
                        chunk dispatch: the next zone-chunk's host->device
                        transfer is issued while the current chunk computes,
                        and the carry buffers are donated to the jitted step
                        so XLA reuses them in place.
  - ``"auto"`` (default) resolves to ``"hierarchical"`` when chunking is
    active and ``"legacy"`` otherwise (identical numerics either way —
    enforced by ``tests/test_differential.py``);

* ``zone_chunk`` itself no longer has to be a hardcoded hint: pass
  ``memory_budget_mb`` and the executor derives the chunk (and
  ``merge_cap``) from the backend's memory model via
  :mod:`repro.core.planner`;
* jit compilation is cached per ``(backend, delta, l_max, zone_chunk,
  merge_cap, batch shape)`` via module-level jitted functions shared by
  every executor instance;
* host-only backends (``jittable=False``, e.g. the NumPy oracle) run their
  scan outside the jit boundary and only the signed aggregation is jitted —
  including a chunked host loop so even the oracle honors the hierarchical
  memory bound.

``scan_aggregate``/``scan_aggregate_partial`` are the traceable cores
(usable inside ``shard_map``); ``run`` is the host-level entry that applies
batching policy first and refuses to mis-report overflowed (edge-dropping)
zone batches as exact counts.
"""

from __future__ import annotations

import functools
import warnings
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.obs import get_obs

from . import aggregation, backends, encoding, expansion, planner
from .aggregation import CodeCounts
from .tzp import (FUSED_BOUNDS, ZoneBatch, ZoneBatchLayout, concat_layout,
                  pad_zone_arrays)

AGG_MODES = ("auto", "legacy", "hierarchical", "pipelined")


class RunOutcome(NamedTuple):
    """A layout run's result plus the stats of the dispatch that made it.

    ``stats`` travels with the counts instead of being read back off the
    executor, so concurrent runs through one shared executor can no longer
    misattribute each other's ``path``/``launches``/``spill_retries``.
    """

    counts: CodeCounts
    stats: dict


class MultiRunOutcome(NamedTuple):
    """A co-mined layout run: one count table per lattice member config."""

    counts: tuple          # tuple[CodeCounts, ...], aligned with params
    stats: dict

#: Fused single-launch dispatch policy for ``run_layout``: "auto" fuses
#: whenever the backend publishes a bucket-native flat kernel, "on"
#: requires one (erroring otherwise), "off" keeps the per-bucket path.
FUSED_MODES = ("auto", "on", "off")


def merge_partial_counts(
    parts,
    *,
    merge_cap: int | None = None,
    warn_label: str = "partial",
    obs=None,
) -> CodeCounts:
    """Fold per-bucket (or per-shard) count tables through ``merge_bounded``.

    The cross-bucket analog of the hierarchical chunk fold: partial tables
    stream through one bounded-width carry instead of a single unbounded
    concat-and-sort, so the resident merge state is O(cap) regardless of
    how many buckets a layout produced.  ``merge_cap`` seeds the carry
    width; a spill (more live unique codes than rows) is detected exactly
    and retried with a doubled cap, capped at the provably-sufficient
    ceiling (total live rows + 1 slot for the all-zero padding group), so
    the result is always exact.
    """
    obs = get_obs(obs)
    parts = list(parts)
    if not parts:
        raise ValueError("merge_partial_counts needs at least one table")
    if len(parts) == 1:
        return parts[0]
    limbs = int(parts[0].codes.shape[1])
    ceiling = sum(int(p.unique_mask.sum()) for p in parts) + 1
    cap = min(int(merge_cap), ceiling) if merge_cap else ceiling
    cap = max(cap, 8)
    with obs.tracer.span("mine.fold", parts=len(parts)) as sp:
        while True:
            carry = aggregation.empty_counts(cap, limbs)
            spilled = jnp.zeros((), jnp.int32)
            for part in parts:
                carry, spill = aggregation.merge_bounded(carry, part, cap=cap)
                spilled = spilled + spill
            n_spilled = int(spilled)
            if n_spilled == 0:
                sp.set(merge_cap=cap).sync(carry)
                return carry
            need = max(2 * cap, cap + n_spilled, 8)
            new_cap = min(1 << (need - 1).bit_length(), ceiling)
            warnings.warn(
                f"{warn_label} merge spilled {n_spilled} unique code(s) at "
                f"merge_cap={cap}; retrying with merge_cap={new_cap}",
                RuntimeWarning, stacklevel=3,
            )
            obs.metrics.counter("repro_mining_spill_retries_total",
                                path="fold").inc()
            cap = new_cap


class ZoneChunkError(ValueError):
    """Zone count does not divide ``zone_chunk`` under pad_policy='raise'."""


class ZoneOverflowError(RuntimeError):
    """The zone batch dropped edges (``ZoneBatch.overflow > 0``).

    Counts mined from such a batch undercount silently; the executor
    refuses to run unless the caller opts in with ``allow_overflow=True``
    (which still warns).  Raise-by-default is the regression guard for the
    bug where ``build_zone_batch`` tallied dropped edges but every consumer
    ignored the tally.
    """


def _chunked_scan(scan, u, v, t, valid, *, delta, l_max, zone_chunk):
    """Sweep a [Z, E] zone batch, optionally in chunks of ``zone_chunk``.

    Traceable; shapes are static here, so divisibility is checked at trace
    time (the executor's host path pads beforehand under pad_policy='pad').
    """

    def chunk_fn(args):
        cu, cv, ct, cvalid = args
        res = scan(cu, cv, ct, cvalid, delta=delta, l_max=l_max)
        return res.code, res.length

    z = u.shape[0]
    if zone_chunk and zone_chunk < z:
        nchunk = _n_chunks(z, zone_chunk)
        reshape = lambda x: x.reshape(nchunk, zone_chunk, *x.shape[1:])
        codes, lengths = jax.lax.map(
            chunk_fn, (reshape(u), reshape(v), reshape(t), reshape(valid))
        )
        codes = codes.reshape(z, *codes.shape[2:])
        lengths = lengths.reshape(z, *lengths.shape[2:])
    else:
        codes, lengths = chunk_fn((u, v, t, valid))
    return codes, lengths


def _n_chunks(z: int, zone_chunk: int) -> int:
    if z % zone_chunk != 0:
        raise ZoneChunkError(
            f"zone count {z} is not divisible by zone_chunk "
            f"{zone_chunk}; pad the batch (pad_policy='pad') or pick a "
            f"divisor — remainder zones would otherwise be dropped"
        )
    return z // zone_chunk


def _hier_fold(scan, u, v, t, valid, signs, *, delta, l_max, zone_chunk,
               merge_cap):
    """Hierarchical streaming aggregation (traceable).

    Each zone-chunk is scanned and immediately signed-counted
    (``aggregate_zones``); the partial tables fold through a bounded-width
    carry (``merge_bounded``) inside ``lax.scan``, so at no point do all
    Z*C candidate codes coexist.  Returns ``(CodeCounts[merge_cap],
    spilled)`` — ``spilled > 0`` means ``merge_cap`` was too small and the
    result is inexact (the host retries with a doubled cap).
    """
    z = u.shape[0]
    zc = zone_chunk if (zone_chunk and zone_chunk < z) else z
    nchunk = _n_chunks(z, zc)
    limbs = encoding.n_limbs(l_max)
    reshape = lambda x: x.reshape(nchunk, zc, *x.shape[1:])
    xs = (reshape(u), reshape(v), reshape(t), reshape(valid),
          signs.reshape(nchunk, zc))

    def body(carry, chunk):
        counts, spilled = carry
        cu, cv, ct, cvalid, csigns = chunk
        res = scan(cu, cv, ct, cvalid, delta=delta, l_max=l_max)
        part = aggregation.aggregate_zones(res.code, res.length, csigns)
        merged, spill = aggregation.merge_bounded(counts, part,
                                                 cap=merge_cap)
        return (merged, spilled + spill), None

    init = (aggregation.empty_counts(merge_cap, limbs), jnp.int32(0))
    (counts, spilled), _ = jax.lax.scan(body, init, xs)
    return counts, spilled


@functools.partial(
    jax.jit, static_argnames=("delta", "l_max", "scan", "zone_chunk")
)
def _mine_jit(u, v, t, valid, signs, *, delta, l_max, scan, zone_chunk):
    """Jitted legacy path: full zone sweep, then one whole-batch aggregation.

    jax.jit keys its cache on the static args plus input shapes, so every
    executor instance with the same (scan fn, delta, l_max, zone_chunk,
    batch shape) reuses one executable.  The cache is keyed on the resolved
    scan *callable*, not the backend name, so re-registering a backend
    (``overwrite=True``) cannot serve a stale executable.
    """
    codes, lengths = _chunked_scan(
        scan, u, v, t, valid, delta=delta, l_max=l_max, zone_chunk=zone_chunk
    )
    return aggregation.aggregate_zones(codes, lengths, signs)


@functools.partial(
    jax.jit,
    static_argnames=("delta", "l_max", "scan", "zone_chunk", "merge_cap"),
)
def _mine_jit_hier(u, v, t, valid, signs, *, delta, l_max, scan, zone_chunk,
                   merge_cap):
    """Jitted hierarchical fold (shared compile cache, as ``_mine_jit``)."""
    return _hier_fold(scan, u, v, t, valid, signs, delta=delta, l_max=l_max,
                      zone_chunk=zone_chunk, merge_cap=merge_cap)


@functools.partial(
    jax.jit,
    static_argnames=("delta", "l_max", "scan", "merge_cap"),
    donate_argnums=(0, 1),
)
def _pipeline_step(carry, spilled, u, v, t, valid, signs, *, delta, l_max,
                   scan, merge_cap):
    """One pipelined chunk: scan + partial count + bounded merge.

    The carry (and spill counter) are donated — XLA reuses their buffers in
    place, so the resident aggregation state stays a single ``merge_cap``
    table no matter how many chunks stream through.
    """
    res = scan(u, v, t, valid, delta=delta, l_max=l_max)
    part = aggregation.aggregate_zones(res.code, res.length, signs)
    merged, spill = aggregation.merge_bounded(carry, part, cap=merge_cap)
    return merged, spilled + spill


@functools.partial(
    jax.jit,
    static_argnames=("delta", "l_max", "scan", "blk", "fold_chunk",
                     "merge_cap"),
)
def _mine_fused_jit(u, v, t, valid, zone_id, sign, lo, hi, *, delta, l_max,
                    scan, blk, fold_chunk, merge_cap):
    """Jitted fused path: single-launch flat scan + on-device Phase-2 fold.

    One executable does the whole mine: the bucket-native kernel sweeps
    every zone of the concatenated layout in a single launch, and the
    candidate codes fold straight through ``count_codes`` +
    ``merge_bounded`` in ``fold_chunk``-row slices inside the same jit —
    only the bounded ``CodeCounts`` table and the spill counter leave the
    device.  The [S, L] code block never round-trips to host.  ``scan``
    is a static arg, so the Pallas and XLA lowerings compile separately.
    """
    code, length = scan(u, v, t, valid, zone_id, lo, hi,
                        delta=delta, l_max=l_max, blk=blk)
    s, limbs = code.shape
    w = (length > 0).astype(jnp.int32) * sign
    codes = jnp.where(w[:, None] != 0, code, 0)
    nchunk = s // fold_chunk
    xs = (codes.reshape(nchunk, fold_chunk, limbs),
          w.reshape(nchunk, fold_chunk))

    def body(carry, chunk):
        counts, spilled = carry
        chunk_codes, chunk_w = chunk
        part = aggregation.count_codes(chunk_codes, chunk_w)
        merged, spill = aggregation.merge_bounded(counts, part,
                                                  cap=merge_cap)
        return (merged, spilled + spill), None

    init = (aggregation.empty_counts(merge_cap, limbs), jnp.int32(0))
    (counts, spilled), _ = jax.lax.scan(body, init, xs)
    return counts, spilled


@functools.partial(
    jax.jit, static_argnames=("merge_cap",), donate_argnums=(0, 1)
)
def _merge_chunk_jit(carry, spilled, codes, lengths, signs, *, merge_cap):
    """Bounded merge of one host-scanned chunk (host-only backends)."""
    part = aggregation.aggregate_zones(codes, lengths, signs)
    merged, spill = aggregation.merge_bounded(carry, part, cap=merge_cap)
    return merged, spilled + spill


# ---------------------------------------------------------------------------
# Config-lattice co-mining: derive every member config's Phase-2 tables from
# ONE dominating Phase-1 sweep (see planner.ConfigLattice).
# ---------------------------------------------------------------------------


def _derive_member(code, length, ts, *, d_i, l_i, delta, l_max):
    """A member config's (code, length) view of dominating sweep output.

    The dominating member is the sweep itself; every smaller ``(delta,
    l_max)`` is the timestamp-gap prefix truncation
    (:func:`repro.core.expansion.derive_lengths` +
    :func:`repro.core.encoding.truncate_codes`) — lossless because zone
    streams are time-sorted, so prefix processes of the dominating sweep
    are exactly what the smaller config would have mined.
    """
    if (d_i, l_i) == (delta, l_max):
        return code, length
    len_i = expansion.derive_lengths(length, ts, delta=d_i, l_max=l_i)
    return encoding.truncate_codes(code, len_i), len_i


@functools.partial(
    jax.jit,
    static_argnames=("delta", "l_max", "scan", "zone_chunk", "params",
                     "merge_caps"),
)
def _mine_multi_jit(u, v, t, valid, signs, *, delta, l_max, scan, zone_chunk,
                    params, merge_caps):
    """Jitted multi-config hierarchical fold over a [Z, E] zone batch.

    ONE ``with_ts`` dominating scan per chunk; each member of ``params``
    (a tuple of ``(delta_i, l_max_i)``) folds its derived candidate view
    through its own bounded merge carry.  Returns a tuple of
    ``(CodeCounts, spilled)`` pairs aligned with ``params``.
    """
    z = u.shape[0]
    zc = zone_chunk if (zone_chunk and zone_chunk < z) else z
    nchunk = _n_chunks(z, zc)
    limbs = encoding.n_limbs(l_max)
    reshape = lambda x: x.reshape(nchunk, zc, *x.shape[1:])
    xs = (reshape(u), reshape(v), reshape(t), reshape(valid),
          signs.reshape(nchunk, zc))

    def body(carry, chunk):
        cu, cv, ct, cvalid, csigns = chunk
        res = scan(cu, cv, ct, cvalid, delta=delta, l_max=l_max,
                   with_ts=True)
        new_carry = []
        for (d_i, l_i), (counts, spilled), cap in zip(params, carry,
                                                      merge_caps):
            code_i, len_i = _derive_member(
                res.code, res.length, res.ts,
                d_i=d_i, l_i=l_i, delta=delta, l_max=l_max)
            part = aggregation.aggregate_zones(code_i, len_i, csigns)
            merged, spill = aggregation.merge_bounded(counts, part, cap=cap)
            new_carry.append((merged, spilled + spill))
        return tuple(new_carry), None

    init = tuple(
        (aggregation.empty_counts(cap, limbs), jnp.int32(0))
        for cap in merge_caps)
    out, _ = jax.lax.scan(body, init, xs)
    return out


@functools.partial(
    jax.jit,
    static_argnames=("delta", "l_max", "scan", "blk", "fold_chunk",
                     "params", "merge_caps"),
)
def _mine_fused_multi_jit(u, v, t, valid, zone_id, sign, lo, hi, *, delta,
                          l_max, scan, blk, fold_chunk, params, merge_caps):
    """Jitted fused co-mine: ONE flat kernel launch, N on-device folds.

    The single-launch analog of :func:`_mine_multi_jit`: the dominating
    sweep runs once over the concatenated layout (with per-step absorption
    timestamps), then every member config's derived candidate view streams
    through its own ``count_codes`` + ``merge_bounded`` fold inside the
    same executable.
    """
    code, length, ts = scan(u, v, t, valid, zone_id, lo, hi,
                            delta=delta, l_max=l_max, blk=blk, with_ts=True)
    s, limbs = code.shape
    nchunk = s // fold_chunk
    xs = (code.reshape(nchunk, fold_chunk, limbs),
          length.reshape(nchunk, fold_chunk),
          ts.reshape(nchunk, fold_chunk, ts.shape[-1]),
          sign.reshape(nchunk, fold_chunk))

    def body(carry, chunk):
        c_code, c_len, c_ts, c_sign = chunk
        new_carry = []
        for (d_i, l_i), (counts, spilled), cap in zip(params, carry,
                                                      merge_caps):
            code_i, len_i = _derive_member(
                c_code, c_len, c_ts,
                d_i=d_i, l_i=l_i, delta=delta, l_max=l_max)
            w = (len_i > 0).astype(jnp.int32) * c_sign
            codes_m = jnp.where(w[:, None] != 0, code_i, 0)
            part = aggregation.count_codes(codes_m, w)
            merged, spill = aggregation.merge_bounded(counts, part, cap=cap)
            new_carry.append((merged, spilled + spill))
        return tuple(new_carry), None

    init = tuple(
        (aggregation.empty_counts(cap, limbs), jnp.int32(0))
        for cap in merge_caps)
    out, _ = jax.lax.scan(body, init, xs)
    return out


@functools.partial(
    jax.jit, static_argnames=("d_i", "l_i", "delta", "l_max", "merge_cap")
)
def _derive_merge_chunk_jit(carry, spilled, codes, lengths, ts, signs, *,
                            d_i, l_i, delta, l_max, merge_cap):
    """One member config's bounded merge of a host-scanned chunk."""
    code_i, len_i = _derive_member(codes, lengths, ts, d_i=d_i, l_i=l_i,
                                   delta=delta, l_max=l_max)
    part = aggregation.aggregate_zones(code_i, len_i, signs)
    merged, spill = aggregation.merge_bounded(carry, part, cap=merge_cap)
    return merged, spilled + spill


class MiningExecutor:
    """Chunked scan+aggregate engine over padded zone batches.

    Args:
      delta, l_max: paper parameters (Definitions 2-5).
      backend: registry name ("ref", "pallas", "numpy", or plugin).
      zone_chunk: process zones in chunks of this many to bound peak memory
        (None/0 = whole batch at once); defaults to the backend's hint.
      pad_policy: "pad" appends inert zero-sign zone rows when the zone
        count does not divide ``zone_chunk``; "raise" errors instead.
      agg: Phase-2 aggregation mode — "auto", "legacy", "hierarchical" or
        "pipelined" (see module docstring).
      merge_cap: bounded-merge carry width for the hierarchical modes
        (None = backend hint, else one chunk's candidate rows).  Spills
        are detected exactly and retried with a doubled cap.
      memory_budget_mb: derive ``zone_chunk``/``merge_cap`` from this
        device-memory budget via :mod:`repro.core.planner` whenever
        ``zone_chunk`` was not given explicitly.
      fused: single-launch dispatch policy for :meth:`run_layout` —
        "auto" (default) fuses whenever the resolved fused backend
        publishes a bucket-native flat kernel, "on" requires one, "off"
        keeps the per-bucket path.  A per-call ``run_layout(fused=...)``
        override beats the policy.
      fused_backend: which backend's flat kernel serves fused runs —
        "auto" (default) keeps this executor's backend except on hosts
        where the Pallas kernel would run in *interpret* mode (CPU), where
        the compiled ``xla`` lowering takes over; an explicit registry
        name pins the lowering (e.g. ``"pallas"`` for the differential
        oracle, ``"xla"`` to force the compiled path from any backend).
      fused_bounds: sweep-bound planning for the fused flat stream —
        "live" (default) tightens each candidate block's ``[lo, hi)``
        window to the Lemma-4.1 horizon cut (see
        :func:`repro.core.tzp.concat_layout`), "full" sweeps to each
        block's zone end.  Output-identical; "live" is strictly less
        dispatched work.

    :meth:`run_layout`/:meth:`run_fused` return a :class:`RunOutcome`
    whose ``stats`` describes the dispatch that produced the counts:
    ``path`` ("fused" — suffixed ``fused_<name>`` when the fused kernel
    came from a different backend than the executor's, e.g. "fused_xla" —
    "per-bucket", and their ``-multi`` co-mine variants), ``launches``
    (scan dispatches in the final successful attempt — 1 for fused, one
    per bucket otherwise) and ``spill_retries`` (merge-cap doublings,
    each re-running the launch).  The old ``last_run_stats`` attribute —
    shared mutable state that misattributed under concurrent runs — is
    removed; stats travel only on the returned outcome.
    """

    def __init__(
        self,
        *,
        delta: int,
        l_max: int,
        backend: str = "ref",
        zone_chunk: int | None = None,
        pad_policy: str = "pad",
        agg: str = "auto",
        merge_cap: int | None = None,
        memory_budget_mb: float | None = None,
        fused: str = "auto",
        fused_backend: str = "auto",
        fused_bounds: str = "live",
        obs=None,
    ):
        if pad_policy not in ("pad", "raise"):
            raise ValueError(f"unknown pad_policy {pad_policy!r}")
        if agg not in AGG_MODES:
            raise ValueError(f"unknown agg mode {agg!r}; one of {AGG_MODES}")
        if fused not in FUSED_MODES:
            raise ValueError(
                f"unknown fused mode {fused!r}; one of {FUSED_MODES}")
        if fused_bounds not in FUSED_BOUNDS:
            raise ValueError(
                f"unknown fused bounds {fused_bounds!r}; one of "
                f"{FUSED_BOUNDS}")
        if fused_backend != "auto" and \
                not backends.get_backend(fused_backend).supports_fused:
            raise ValueError(
                f"fused_backend {fused_backend!r} has no fused "
                f"single-launch scan; pick one that publishes a flat "
                f"kernel (or leave it 'auto')")
        self.delta = int(delta)
        self.l_max = int(l_max)
        self.spec = backends.get_backend(backend)
        # an explicit zone_chunk=0 means "unchunked, full batch" (the
        # sequential baseline's contract) and must beat a budget-derived
        # chunk, exactly like any other explicit value; only None falls
        # through to the backend hint / capacity planner
        self._zone_chunk_explicit = zone_chunk is not None
        if zone_chunk is None:
            zone_chunk = self.spec.default_zone_chunk
        self.zone_chunk = int(zone_chunk or 0)
        self.pad_policy = pad_policy
        self.agg = agg
        self.merge_cap = int(merge_cap) if merge_cap else None
        self.memory_budget_mb = memory_budget_mb
        self.fused = fused
        self.fused_backend = fused_backend
        self.fused_bounds = fused_bounds
        self.fused_blk = backends.FUSED_BLK_DEFAULT
        self._plan_cache: dict[tuple, object] = {}
        # spill-adapted fused merge caps, keyed by fold_chunk: once a
        # fused run spills and retries at a larger cap, later runs with
        # the same fold geometry start from that cap directly instead of
        # re-paying the spilled launch (and its recompile) every call.
        # Only consulted when no explicit merge_cap pins the table size;
        # like _plan_cache, a racy lost update under concurrent use is
        # benign (one extra adaptive retry, never a wrong count).
        self._fused_cap_adapt: dict[int, int] = {}
        # observability bundle: NULL_OBS by default (shared no-op
        # singletons), so the hot paths below emit unconditionally
        self.obs = get_obs(obs)

    @classmethod
    def from_config(cls, config, *, obs=None) -> "MiningExecutor":
        """Build an executor from a :class:`repro.core.config.MiningConfig`.

        Duck-typed (any object with the execution fields works) so this
        module never imports ``config`` — the config layer imports the
        executor for ``AGG_MODES``, not the other way around.
        """
        return cls(
            delta=config.delta, l_max=config.l_max, backend=config.backend,
            zone_chunk=config.zone_chunk, agg=config.agg,
            merge_cap=config.merge_cap,
            memory_budget_mb=config.memory_budget_mb,
            fused=getattr(config, "fused", "auto"),
            fused_backend=getattr(config, "fused_backend", "auto"),
            obs=obs,
        )

    @property
    def backend(self) -> str:
        return self.spec.name

    @property
    def last_run_stats(self) -> dict:
        """REMOVED — stats travel on each run's returned outcome."""
        raise RuntimeError(
            "MiningExecutor.last_run_stats was removed after its "
            "deprecation cycle: it was shared mutable state that "
            "misattributed stats under concurrent runs.  Use the stats "
            "field of the RunOutcome/MultiRunOutcome returned by "
            "run_layout()/run_fused() (or PTMTEngine, whose "
            "DiscoveryResult.layout carries the execution summary).")

    def execution_key(self, z: int, e: int) -> tuple:
        """The compile-cache key a ``[z, e]`` zone batch resolves to.

        Mirrors ``run_arrays``'s resolution order exactly: chunk size from
        the raw shape, zone padding, then agg mode and merge cap from the
        padded shape.  Two batches with equal keys reuse one jitted
        executable (the jit caches are keyed on the same statics plus these
        shapes), so :class:`repro.core.engine.PTMTEngine` counts warm calls
        by tracking keys it has seen.  A merge-cap spill retry recompiles at
        a doubled cap without changing the key — rare, and the retry warns.
        """
        zc = self._zone_chunk_for(z, e)
        if zc and zc < z and z % zc != 0:
            z += zc - z % zc
        mode = self._agg_mode_for(zc, z)
        merge_cap = (self._merge_cap_for(zc, z, e)
                     if mode != "legacy" else 0)
        return (self.backend, self.delta, self.l_max, z, e, zc, mode,
                merge_cap)

    # -- capacity resolution ------------------------------------------------

    def capacity_plan(self, n_zones: int, e_cap: int):
        """Budget-derived :class:`~repro.core.planner.CapacityPlan`, or
        None when no ``memory_budget_mb`` was configured.

        Memoized per ``(n_zones, e_cap)`` — the chunk resolution consults
        it on every run (and the engine's ``execution_key`` again), so
        repeated same-shaped runs must not re-derive the plan.
        """
        if self.memory_budget_mb is None:
            return None
        key = (n_zones, e_cap)
        plan = self._plan_cache.get(key)
        if plan is None:
            plan = planner.plan_capacity(
                n_zones=n_zones, e_cap=e_cap, l_max=self.l_max,
                memory_budget_mb=self.memory_budget_mb,
                mem_model=self.spec.mem_model, merge_cap=self.merge_cap,
            )
            self._plan_cache[key] = plan
        return plan

    def _zone_chunk_for(self, z: int, e: int) -> int:
        if self.zone_chunk:
            return self.zone_chunk
        if self._zone_chunk_explicit:
            return 0           # explicitly unchunked: never consult a budget
        plan = self.capacity_plan(z, e)
        if plan is None:
            return 0
        return plan.zone_chunk if plan.zone_chunk < z else 0

    def _merge_cap_for(self, zc: int, z: int, e: int) -> int:
        if self.merge_cap:
            return self.merge_cap
        if self.spec.default_merge_cap:
            return self.spec.default_merge_cap
        return planner.default_merge_cap(zc or z, e)

    def _agg_mode_for(self, zc: int, z: int) -> str:
        if self.agg != "auto":
            return self.agg
        return "hierarchical" if zc and zc < z else "legacy"

    # -- traceable cores (used inside shard_map by distributed mining) ------

    def _require_jittable(self):
        if not self.spec.jittable:
            raise ValueError(
                f"backend {self.backend!r} is host-only (jittable=False) "
                f"and cannot run inside a traced/sharded computation"
            )

    def scan_aggregate(self, u, v, t, valid, signs) -> CodeCounts:
        """Scan + whole-batch signed-aggregate a [Z, E] batch; traceable.

        Always the legacy (lossless-by-construction) aggregation: inside a
        trace there is no host to run the merge-cap spill/retry policy, so
        callers that want the hierarchical fold must use
        :meth:`scan_aggregate_partial` and surface the spill count
        themselves.  Raises :class:`ZoneChunkError` at trace time when the
        (static) zone count does not divide ``zone_chunk``.
        """
        self._require_jittable()
        codes, lengths = _chunked_scan(
            self.spec.scan, u, v, t, valid,
            delta=self.delta, l_max=self.l_max, zone_chunk=self.zone_chunk,
        )
        return aggregation.aggregate_zones(codes, lengths, signs)

    def scan_aggregate_partial(self, u, v, t, valid, signs):
        """Traceable scan+aggregate honoring the executor's ``agg`` mode.

        Returns ``(CodeCounts, spilled)``.  ``spilled`` is a traced int32:
        0 whenever the result is exact; positive means the hierarchical
        carry overflowed ``merge_cap`` and the caller (e.g. the mesh mining
        step) must surface it — typically via a ``psum`` — so the host can
        re-run with a larger cap instead of silently undercounting.
        """
        self._require_jittable()
        z, e = u.shape
        zc = self._zone_chunk_for(z, e)
        if self._agg_mode_for(zc, z) == "legacy":
            return self.scan_aggregate(u, v, t, valid, signs), jnp.int32(0)
        return _hier_fold(
            self.spec.scan, u, v, t, valid, signs,
            delta=self.delta, l_max=self.l_max, zone_chunk=zc,
            merge_cap=self._merge_cap_for(zc, z, e),
        )

    # -- host-level entry points -------------------------------------------

    @staticmethod
    def check_batch_overflow(batch: ZoneBatch, *,
                             allow_overflow: bool = False) -> None:
        """Enforce the overflow policy on a host-built batch.

        Raises :class:`ZoneOverflowError` when the batch dropped edges
        (``batch.overflow > 0``) — such counts undercount and must not
        masquerade as exact.  ``allow_overflow=True`` downgrades the error
        to a warning for callers that knowingly mine a truncated batch.
        The single copy of the policy: ``run`` and the mesh path
        (``api.discover`` before ``mine_on_mesh``) both call it.
        """
        if not batch.overflow:
            return
        where = f" (bucket {batch.label!r})" if batch.label else ""
        msg = (f"zone batch{where} dropped {batch.overflow} edge(s) that "
               f"exceeded e_cap={batch.e_cap}; counts would silently "
               f"undercount (raise e_cap, or shrink zones by planning "
               f"with e_cap / a memory budget)")
        if not allow_overflow:
            raise ZoneOverflowError(msg)
        warnings.warn(msg + " — continuing because allow_overflow=True",
                      RuntimeWarning, stacklevel=3)

    @staticmethod
    def check_layout_overflow(layout: ZoneBatchLayout, *,
                              allow_overflow: bool = False) -> None:
        """One overflow policy across every bucket of a layout.

        Aggregates the per-bucket tallies into a single
        :class:`ZoneOverflowError` (or warning) that names each offending
        bucket, so a truncated burst is attributable to its capacity class
        instead of an anonymous global count.
        """
        bad = [b for b in layout.buckets if b.overflow]
        if not bad:
            return
        detail = ", ".join(
            f"{b.label or 'dense'}: {b.overflow} edge(s) beyond "
            f"e_cap={b.e_cap}" for b in bad)
        msg = (f"zone layout dropped {layout.overflow} edge(s) across "
               f"{len(bad)} bucket(s) [{detail}]; counts would silently "
               f"undercount (raise e_cap, or shrink zones by planning "
               f"with e_cap / a memory budget)")
        if not allow_overflow:
            raise ZoneOverflowError(msg)
        warnings.warn(msg + " — continuing because allow_overflow=True",
                      RuntimeWarning, stacklevel=3)

    def run(self, batch: ZoneBatch, *, allow_overflow: bool = False
            ) -> CodeCounts:
        """Mine a host-built :class:`ZoneBatch` to signed code counts.

        Applies :meth:`check_batch_overflow` first — overflowed batches
        raise unless ``allow_overflow=True``.
        """
        self.check_batch_overflow(batch, allow_overflow=allow_overflow)
        return self.run_arrays(batch.u, batch.v, batch.t, batch.valid,
                               batch.sign, label=batch.label)

    def _fused_spec(self) -> backends.BackendSpec:
        """The backend whose flat kernel serves this executor's fused runs.

        An explicit ``fused_backend`` pins it (validated at construction).
        ``"auto"`` keeps this executor's own backend, except when that
        backend is an accelerator kernel (Pallas) that would execute in
        *interpret* mode on this host (CPU) — there the compiled ``xla``
        lowering is strictly faster at identical output, so it takes over.
        Pallas stays the lowering on real accelerators and the
        differential oracle everywhere (pin ``fused_backend="pallas"``).
        """
        if self.fused_backend != "auto":
            return backends.get_backend(self.fused_backend)
        spec = self.spec
        if spec.supports_fused and spec.grade == "accelerator":
            from repro.kernels.common import resolve_interpret

            if resolve_interpret(None, quiet=True):
                try:
                    xla = backends.get_backend("xla")
                except ValueError:
                    return spec
                if xla.supports_fused:
                    return xla
        return spec

    def _fused_path(self, suffix: str = "") -> str:
        """Stats ``path`` label: "fused" when the executor's own backend
        ran the kernel, "fused_<name>" when dispatch rerouted it."""
        fspec = self._fused_spec()
        base = "fused" if fspec.name == self.backend else \
            f"fused_{fspec.name}"
        return base + suffix

    def resolve_fused(self, fused: bool | None = None) -> bool:
        """Resolve the fused-dispatch decision for a layout run.

        A per-call boolean beats the constructor policy; ``True`` (or
        policy "on") when no fused kernel resolves raises rather than
        silently falling back — the caller asked for one launch and would
        otherwise benchmark the wrong path.  The decision consults the
        *resolved* fused backend (:meth:`_fused_spec`), so e.g.
        ``backend="ref", fused_backend="xla"`` takes the fused path even
        though the reference backend has no flat kernel of its own.
        """
        if fused is None:
            if self.fused == "off":
                return False
            if self.fused == "auto":
                return self._fused_spec().supports_fused
            fused = True
        if fused and not self._fused_spec().supports_fused:
            raise ValueError(
                f"backend {self.backend!r} has no fused single-launch "
                f"scan; use fused=False (or fused='off') for the "
                f"per-bucket path, or pick a fused_backend that has one")
        return bool(fused)

    def run_layout(self, layout: ZoneBatchLayout, *,
                   allow_overflow: bool = False,
                   fused: bool | None = None) -> RunOutcome:
        """Mine a :class:`ZoneBatchLayout` (dense or bucketed) exactly.

        Dispatch is decided by :meth:`resolve_fused`: the fused path
        (:meth:`run_fused`) mines the whole layout in a single
        bucket-native kernel launch with the Phase-2 fold on-device; the
        per-bucket path runs each bucket through :meth:`run_arrays` with
        its own shape — and hence its own budget-derived
        ``zone_chunk``/``merge_cap`` from :meth:`capacity_plan`, keyed on
        the bucket's geometry rather than the global max — then folds the
        per-bucket partial count tables through the signed bounded-carry
        merge (:func:`merge_partial_counts`).  Lemma 4.2's signed sum is
        associative over zones, so either split is exact; the differential
        tests assert fused == per-bucket == dense code-for-code.

        Returns a :class:`RunOutcome` — the counts plus this run's own
        dispatch stats (never read stats back off the executor; that is
        the shared-state race the outcome type exists to close).
        """
        if self.resolve_fused(fused):
            return self.run_fused(layout, allow_overflow=allow_overflow)
        self.check_layout_overflow(layout, allow_overflow=allow_overflow)
        with self.obs.tracer.span("mine.layout", path="per-bucket",
                                  buckets=layout.n_buckets):
            parts = [
                self.run_arrays(b.u, b.v, b.t, b.valid, b.sign,
                                label=b.label)
                for b in layout.buckets
            ]
            stats = {
                "path": "per-bucket",
                "launches": len(layout.buckets),
                "spill_retries": 0,
            }
            self.obs.metrics.counter(
                "repro_mining_launches_total",
                path="per-bucket").inc(len(layout.buckets))
            counts = merge_partial_counts(parts, merge_cap=self.merge_cap,
                                          warn_label="zone-layout bucket",
                                          obs=self.obs)
            return RunOutcome(counts=counts, stats=stats)

    # -- fused single-launch path -------------------------------------------

    def _fused_geometry(self, layout: ZoneBatchLayout) -> tuple[int, int, int]:
        """``(blk, fold_chunk, n_slots_padded)`` for a layout's fused run.

        Derivable from bucket shapes alone (no arrays built), so
        :meth:`fused_execution_key` can report the compile-cache geometry
        without paying the concatenation.  Must agree with
        :func:`repro.core.tzp.concat_layout`'s padding rule.
        """
        blk = self.fused_blk
        real_slots = sum(b.n_real_zones * b.e_cap for b in layout.buckets)
        if self.memory_budget_mb is not None:
            key = ("fused", real_slots)
            plan = self._plan_cache.get(key)
            if plan is None:
                plan = planner.plan_fused_capacity(
                    n_slots=real_slots, l_max=self.l_max,
                    memory_budget_mb=self.memory_budget_mb, blk=blk,
                    merge_cap=self.merge_cap,
                )
                self._plan_cache[key] = plan
            fold_chunk = plan.fold_chunk
        else:
            fold_chunk = planner.default_fold_chunk(real_slots, blk=blk)
        mult = fold_chunk
        s_pad = max(-(-max(real_slots, 1) // mult) * mult, mult)
        return blk, fold_chunk, s_pad

    def _fused_merge_cap(self, fold_chunk: int) -> int:
        if self.merge_cap:
            return self.merge_cap
        base = self.spec.default_merge_cap or max(1024, fold_chunk)
        return max(base, self._fused_cap_adapt.get(fold_chunk, 0))

    def _note_fused_cap(self, fold_chunk: int, cap: int,
                        retries: int) -> None:
        """Remember a spill-adapted cap so the NEXT run starts there."""
        if retries and not self.merge_cap:
            prev = self._fused_cap_adapt.get(fold_chunk, 0)
            self._fused_cap_adapt[fold_chunk] = max(prev, cap)

    def fused_execution_key(self, layout: ZoneBatchLayout) -> tuple:
        """The compile-cache key a fused layout run resolves to.

        The fused analog of :meth:`execution_key`: the jitted executable
        is keyed on the flat stream geometry (padded slot count + block
        size), the fold shape, the resolved fused backend (Pallas and XLA
        lowerings compile separately — ``scan`` is a jit static), and the
        sweep-bounds mode (full and live plans ship different descriptor
        contents under the same shapes).
        """
        blk, fold_chunk, s_pad = self._fused_geometry(layout)
        merge_cap = min(self._fused_merge_cap(fold_chunk), s_pad + 1)
        return ("fused", self.backend, self._fused_spec().name,
                self.fused_bounds, self.delta, self.l_max, s_pad, blk,
                fold_chunk, merge_cap)

    def run_fused(self, layout: ZoneBatchLayout, *,
                  allow_overflow: bool = False) -> RunOutcome:
        """Mine a layout in ONE bucket-native kernel launch, fold on-device.

        The layout is flattened to a :class:`~repro.core.tzp.
        FusedZoneLayout` slot stream (real zone rows only, padded to the
        fold chunk) and handed to the backend's flat kernel inside
        ``_mine_fused_jit`` — a single ``pallas_call`` whose grid spans
        every bucket, with the ``count_codes``/``merge_bounded`` fold in
        the same executable.  Only the bounded count table and the spill
        counter come back; a spill retries host-side with a doubled cap
        (ceiling ``n_slots + 1``, which provably cannot spill).
        """
        self.check_layout_overflow(layout, allow_overflow=allow_overflow)
        obs = self.obs
        fspec = self._fused_spec()
        path = self._fused_path()
        blk, fold_chunk, _ = self._fused_geometry(layout)
        fl = concat_layout(layout, blk=blk, pad_slots_to=fold_chunk,
                           delta=self.delta, l_max=self.l_max,
                           bounds=self.fused_bounds)
        cap_ceiling = fl.n_slots + 1
        merge_cap = min(self._fused_merge_cap(fold_chunk), cap_ceiling)
        with obs.tracer.span("mine.h2d", n_slots=fl.n_slots) as sp:
            arrays = tuple(jnp.asarray(x) for x in (
                fl.u, fl.v, fl.t, fl.valid, fl.zone_id, fl.sign, fl.lo,
                fl.hi))
            sp.sync(arrays)
        retries = 0
        while True:
            # one span per launch attempt; the compile key changes when a
            # spill retry doubles merge_cap (a genuine recompile), so the
            # tracer's compile-vs-exec attribution stays honest
            ck = ("fused", self.backend, fspec.name, fl.bounds, self.delta,
                  self.l_max, fl.n_slots, blk, fold_chunk, merge_cap) \
                if obs.enabled else None
            with obs.tracer.span("mine.fused", n_slots=fl.n_slots,
                                 merge_cap=merge_cap, retry=retries,
                                 compile_key=ck) as sp:
                counts, spilled = _mine_fused_jit(
                    *arrays, delta=self.delta, l_max=self.l_max,
                    scan=fspec.fused_scan, blk=blk,
                    fold_chunk=fold_chunk, merge_cap=merge_cap,
                )
                sp.sync((counts, spilled))
            with obs.tracer.span("mine.d2h"):
                n_spilled = int(spilled)
            if n_spilled == 0:
                self._note_fused_cap(fold_chunk, merge_cap, retries)
                stats = {
                    "path": path,
                    "backend": fspec.name,
                    "bounds": fl.bounds,
                    "launches": 1,
                    "spill_retries": retries,
                    "merge_cap": merge_cap,
                    "fold_chunk": fold_chunk,
                    "n_slots": fl.n_slots,
                    "sweep_slots": fl.sweep_slots,
                }
                obs.metrics.counter("repro_mining_launches_total",
                                    path=path).inc()
                m = obs.metrics
                m.gauge("repro_mining_fused_merge_cap").set(merge_cap)
                m.gauge("repro_mining_fused_fold_chunk").set(fold_chunk)
                m.gauge("repro_mining_fused_slots").set(fl.n_slots)
                m.gauge("repro_mining_fused_sweep_slots").set(fl.sweep_slots)
                return RunOutcome(counts=counts, stats=stats)
            need = max(2 * merge_cap, merge_cap + n_spilled, 8)
            new_cap = min(1 << (need - 1).bit_length(), cap_ceiling)
            warnings.warn(
                f"fused on-device merge spilled {n_spilled} unique code(s) "
                f"at merge_cap={merge_cap}; retrying with "
                f"merge_cap={new_cap}",
                RuntimeWarning, stacklevel=3,
            )
            obs.metrics.counter("repro_mining_spill_retries_total",
                                path="fused").inc()
            merge_cap = new_cap
            retries += 1

    def layout_execution_keys(self, layout: ZoneBatchLayout,
                              fused: bool | None = None) -> tuple:
        """Execution keys a layout run will resolve to.

        Per-bucket :meth:`execution_key` tuples on the per-bucket path —
        bucket shapes, not whole-layout shapes, key the jit caches, so a
        recurring bucket geometry reuses its compiled executable even when
        the surrounding layout differs.  On the fused path the whole
        layout resolves to one :meth:`fused_execution_key`.
        """
        if self.resolve_fused(fused):
            return (self.fused_execution_key(layout),)
        return tuple(self.execution_key(b.n_zones, b.e_cap)
                     for b in layout.buckets)

    # -- config-lattice co-mining --------------------------------------------

    def _check_comine_params(self, params) -> tuple:
        params = tuple((int(d), int(l)) for d, l in params)
        if not params:
            raise ValueError("co-mine needs at least one (delta, l_max)")
        if not self.spec.supports_comine:
            raise ValueError(
                f"backend {self.backend!r} does not support co-mining "
                f"(its scan has no with_ts timestamp output)")
        for d, l in params:
            if not (1 <= d <= self.delta and 1 <= l <= self.l_max):
                raise ValueError(
                    f"co-mined config (delta={d}, l_max={l}) is not "
                    f"dominated by the sweep config (delta={self.delta}, "
                    f"l_max={self.l_max})")
        return params

    def run_layout_multi(self, layout: ZoneBatchLayout, params, *,
                         allow_overflow: bool = False,
                         fused: bool | None = None) -> MultiRunOutcome:
        """Co-mine N member configs from ONE dominating Phase-1 sweep.

        ``params`` is a sequence of ``(delta_i, l_max_i)`` pairs, each
        dominated by this executor's ``(delta, l_max)`` (the planner's
        :func:`~repro.core.planner.build_config_lattices` guarantees that
        for lattice members).  The layout is swept exactly once per launch
        at the dominating config with per-step absorption timestamps; each
        member's count table is split out during the Phase-2 fold by
        prefix-truncating candidates on those timestamps — byte-identical
        to mining that member independently, at one sweep's cost.

        Returns a :class:`MultiRunOutcome` with one exact
        :class:`CodeCounts` per param (spills retry per member with a
        doubled cap, exactly like the single-config paths).
        """
        params = self._check_comine_params(params)
        if self.resolve_fused(fused):
            return self.run_fused_multi(layout, params,
                                        allow_overflow=allow_overflow)
        self.check_layout_overflow(layout, allow_overflow=allow_overflow)
        with self.obs.tracer.span("mine.layout", path="per-bucket-multi",
                                  buckets=layout.n_buckets,
                                  n_configs=len(params)):
            parts: list[list[CodeCounts]] = [[] for _ in params]
            retries_total = 0
            for b in layout.buckets:
                bucket_counts, retries = self._run_arrays_multi(
                    b.u, b.v, b.t, b.valid, b.sign, params, label=b.label)
                retries_total += retries
                for member_parts, c in zip(parts, bucket_counts):
                    member_parts.append(c)
            self.obs.metrics.counter(
                "repro_mining_launches_total",
                path="per-bucket-multi").inc(len(layout.buckets))
            counts = tuple(
                merge_partial_counts(p, merge_cap=self.merge_cap,
                                     warn_label="zone-layout bucket",
                                     obs=self.obs)
                for p in parts)
            stats = {
                "path": "per-bucket-multi",
                "launches": len(layout.buckets),
                "spill_retries": retries_total,
                "n_configs": len(params),
            }
            return MultiRunOutcome(counts=counts, stats=stats)

    def run_fused_multi(self, layout: ZoneBatchLayout, params, *,
                        allow_overflow: bool = False) -> MultiRunOutcome:
        """Co-mine a layout in ONE kernel launch with N on-device folds."""
        params = self._check_comine_params(params)
        self.check_layout_overflow(layout, allow_overflow=allow_overflow)
        obs = self.obs
        fspec = self._fused_spec()
        path = self._fused_path("-multi")
        blk, fold_chunk, _ = self._fused_geometry(layout)
        fl = concat_layout(layout, blk=blk, pad_slots_to=fold_chunk,
                           delta=self.delta, l_max=self.l_max,
                           bounds=self.fused_bounds)
        cap_ceiling = fl.n_slots + 1
        caps = [min(self._fused_merge_cap(fold_chunk), cap_ceiling)
                for _ in params]
        with obs.tracer.span("mine.h2d", n_slots=fl.n_slots) as sp:
            arrays = tuple(jnp.asarray(x) for x in (
                fl.u, fl.v, fl.t, fl.valid, fl.zone_id, fl.sign, fl.lo,
                fl.hi))
            sp.sync(arrays)
        retries = 0
        while True:
            with obs.tracer.span("mine.fused", n_slots=fl.n_slots,
                                 n_configs=len(params), retry=retries) as sp:
                out = _mine_fused_multi_jit(
                    *arrays, delta=self.delta, l_max=self.l_max,
                    scan=fspec.fused_scan, blk=blk,
                    fold_chunk=fold_chunk, params=params,
                    merge_caps=tuple(caps),
                )
                sp.sync(out)
            with obs.tracer.span("mine.d2h"):
                spills = [int(sp_i) for _, sp_i in out]
            if not any(spills):
                self._note_fused_cap(fold_chunk, max(caps), retries)
                stats = {
                    "path": path,
                    "backend": fspec.name,
                    "bounds": fl.bounds,
                    "launches": 1,
                    "spill_retries": retries,
                    "merge_caps": tuple(caps),
                    "fold_chunk": fold_chunk,
                    "n_slots": fl.n_slots,
                    "sweep_slots": fl.sweep_slots,
                    "n_configs": len(params),
                }
                obs.metrics.counter("repro_mining_launches_total",
                                    path=path).inc()
                return MultiRunOutcome(
                    counts=tuple(c for c, _ in out), stats=stats)
            for i, n_spilled in enumerate(spills):
                if n_spilled:
                    need = max(2 * caps[i], caps[i] + n_spilled, 8)
                    caps[i] = min(1 << (need - 1).bit_length(), cap_ceiling)
            warnings.warn(
                f"fused co-mine spilled {spills} unique code(s) across "
                f"{len(params)} member config(s); retrying with "
                f"merge_caps={caps}",
                RuntimeWarning, stacklevel=3,
            )
            obs.metrics.counter("repro_mining_spill_retries_total",
                                path="fused-multi").inc()
            retries += 1

    def _run_arrays_multi(self, u, v, t, valid, signs, params, *,
                          label: str = ""):
        """Co-mine raw [Z, E] zone arrays; returns (counts tuple, retries).

        Mirrors :meth:`run_arrays`'s pad/chunk resolution, but always takes
        the bounded hierarchical fold — the multi path has no legacy
        whole-batch mode (an unchunked batch is simply one chunk).
        """
        u, v, t, valid, signs = (np.asarray(x)
                                 for x in (u, v, t, valid, signs))
        z, e = u.shape
        with self.obs.tracer.span("mine.launch", z=z, e=e, label=label,
                                  multi=len(params)) as sp:
            zc = self._zone_chunk_for(z, e)
            if zc and zc < z and z % zc != 0:
                if self.pad_policy == "raise":
                    where = f" in bucket {label!r}" if label else ""
                    raise ZoneChunkError(
                        f"zone count {z}{where} is not divisible by "
                        f"zone_chunk {zc} (pad_policy='raise'); the "
                        f"trailing {z % zc} zone(s) would need inert "
                        f"padding rows — pad the batch (pad_policy='pad') "
                        f"or pick a divisor"
                    )
                u, v, t, valid, signs = pad_zone_arrays(
                    u, v, t, valid, signs, n_rows=z + (zc - z % zc))
                z = u.shape[0]
            sp.set(zone_chunk=zc)
            return self._run_bounded_multi(u, v, t, valid, signs, zc, params)

    def _run_bounded_multi(self, u, v, t, valid, signs, zc, params):
        """Multi-config bounded fold with per-member spill/retry."""
        z, e = u.shape
        cap_ceiling = z * e + 1
        base_cap = min(self._merge_cap_for(zc, z, e), cap_ceiling)
        caps = [base_cap for _ in params]
        retries = 0
        while True:
            if not self.spec.jittable:
                out = self._fold_host_scan_multi(u, v, t, valid, signs, zc,
                                                 params, caps)
            else:
                out = _mine_multi_jit(
                    jnp.asarray(u), jnp.asarray(v), jnp.asarray(t),
                    jnp.asarray(valid), jnp.asarray(signs),
                    delta=self.delta, l_max=self.l_max, scan=self.spec.scan,
                    zone_chunk=zc, params=params, merge_caps=tuple(caps),
                )
            spills = [int(sp) for _, sp in out]
            if not any(spills):
                return tuple(c for c, _ in out), retries
            for i, n_spilled in enumerate(spills):
                if n_spilled:
                    need = max(2 * caps[i], caps[i] + n_spilled, 8)
                    caps[i] = min(1 << (need - 1).bit_length(), cap_ceiling)
            warnings.warn(
                f"co-mine hierarchical merge spilled {spills} unique "
                f"code(s) across {len(params)} member config(s); retrying "
                f"with merge_caps={caps}",
                RuntimeWarning, stacklevel=3,
            )
            self.obs.metrics.counter("repro_mining_spill_retries_total",
                                     path="bucket-multi").inc()
            retries += 1

    def _fold_host_scan_multi(self, u, v, t, valid, signs, zc, params, caps):
        """Chunked multi-config fold for host-only backends."""
        z, e = u.shape
        zc = zc if (zc and zc < z) else z
        nchunk = _n_chunks(z, zc)
        limbs = encoding.n_limbs(self.l_max)
        carries = [
            (aggregation.empty_counts(cap, limbs), jnp.int32(0))
            for cap in caps]
        for i in range(nchunk):
            sl = slice(i * zc, (i + 1) * zc)
            res = self.spec.scan(u[sl], v[sl], t[sl], valid[sl],
                                 delta=self.delta, l_max=self.l_max,
                                 with_ts=True)
            codes = jnp.asarray(res.code)
            lengths = jnp.asarray(res.length)
            ts = jnp.asarray(res.ts)
            sg = jnp.asarray(signs[sl])
            for ci, ((d_i, l_i), cap) in enumerate(zip(params, caps)):
                carry, spilled = carries[ci]
                carries[ci] = _derive_merge_chunk_jit(
                    carry, spilled, codes, lengths, ts, sg,
                    d_i=d_i, l_i=l_i, delta=self.delta, l_max=self.l_max,
                    merge_cap=cap,
                )
        return carries

    def run_arrays(self, u, v, t, valid, signs, *,
                   label: str = "") -> CodeCounts:
        """Mine raw [Z, E] zone arrays (+ [Z] signs) to signed code counts."""
        u, v, t, valid, signs = (np.asarray(x)
                                 for x in (u, v, t, valid, signs))
        z, e = u.shape
        # compile key from the raw shape — execution_key replays the same
        # pad/chunk resolution run below, so the tracer's compile-vs-exec
        # attribution lines up with the engine's warm-call accounting
        ck = self.execution_key(z, e) if self.obs.enabled else None
        with self.obs.tracer.span("mine.launch", z=z, e=e, label=label,
                                  compile_key=ck) as sp:
            zc = self._zone_chunk_for(z, e)
            if zc and zc < z and z % zc != 0:
                if self.pad_policy == "raise":
                    where = f" in bucket {label!r}" if label else ""
                    raise ZoneChunkError(
                        f"zone count {z}{where} is not divisible by "
                        f"zone_chunk {zc} (pad_policy='raise'); the "
                        f"trailing {z % zc} zone(s) would need inert "
                        f"padding rows — pad the batch (pad_policy='pad') "
                        f"or pick a divisor"
                    )
                u, v, t, valid, signs = pad_zone_arrays(
                    u, v, t, valid, signs, n_rows=z + (zc - z % zc))
                z = u.shape[0]

            mode = self._agg_mode_for(zc, z)
            sp.set(agg=mode, zone_chunk=zc)
            if mode == "legacy":
                counts = self._run_legacy(u, v, t, valid, signs, zc)
            else:
                counts = self._run_bounded(u, v, t, valid, signs, zc, mode)
            sp.sync(counts)
            return counts

    def _run_legacy(self, u, v, t, valid, signs, zc) -> CodeCounts:
        if not self.spec.jittable:
            res = self.spec.scan(u, v, t, valid,
                                 delta=self.delta, l_max=self.l_max)
            return aggregation.aggregate_zones(
                jnp.asarray(res.code), jnp.asarray(res.length),
                jnp.asarray(signs),
            )
        return _mine_jit(
            jnp.asarray(u), jnp.asarray(v), jnp.asarray(t),
            jnp.asarray(valid), jnp.asarray(signs),
            delta=self.delta, l_max=self.l_max, scan=self.spec.scan,
            zone_chunk=zc,
        )

    def _run_bounded(self, u, v, t, valid, signs, zc, mode) -> CodeCounts:
        """Hierarchical/pipelined fold with the merge-cap spill policy.

        Spills are exact signals, so retrying with a doubled cap is
        lossless; ``merge_cap >= z*e + 1`` can never spill (at most z*e
        distinct live codes, plus one row for the all-zero padding group
        that sorts ahead of them), so the loop terminates.
        """
        z, e = u.shape
        cap_ceiling = z * e + 1
        merge_cap = min(self._merge_cap_for(zc, z, e), cap_ceiling)
        while True:
            if not self.spec.jittable:
                counts, spilled = self._fold_host_scan(
                    u, v, t, valid, signs, zc, merge_cap)
            elif mode == "pipelined":
                counts, spilled = self._fold_pipelined(
                    u, v, t, valid, signs, zc, merge_cap)
            else:
                counts, spilled = _mine_jit_hier(
                    jnp.asarray(u), jnp.asarray(v), jnp.asarray(t),
                    jnp.asarray(valid), jnp.asarray(signs),
                    delta=self.delta, l_max=self.l_max, scan=self.spec.scan,
                    zone_chunk=zc, merge_cap=merge_cap,
                )
            n_spilled = int(spilled)
            if n_spilled == 0:
                return counts
            # cap+spilled approximates the live-code population (a code cut
            # in several steps is counted per step, so it can only
            # overshoot the next guess); exactness is re-checked each
            # round, and the z*e+1 ceiling provably cannot spill
            need = max(2 * merge_cap, merge_cap + n_spilled, 8)
            new_cap = min(1 << (need - 1).bit_length(), cap_ceiling)
            warnings.warn(
                f"hierarchical merge spilled {n_spilled} unique code(s) at "
                f"merge_cap={merge_cap}; retrying with merge_cap={new_cap}",
                RuntimeWarning, stacklevel=3,
            )
            self.obs.metrics.counter("repro_mining_spill_retries_total",
                                     path="bucket").inc()
            merge_cap = new_cap

    def _fold_pipelined(self, u, v, t, valid, signs, zc, merge_cap):
        """Host-driven double-buffered chunk pipeline.

        Each jitted step is dispatched asynchronously; the *next* chunk's
        host->device transfer (``jax.device_put``) is issued immediately
        after, overlapping with the in-flight compute.  Carry buffers are
        donated, so aggregation state never exceeds one ``merge_cap``
        table.
        """
        z, e = u.shape
        zc = zc if (zc and zc < z) else z
        nchunk = _n_chunks(z, zc)
        limbs = encoding.n_limbs(self.l_max)

        def put(i):
            sl = slice(i * zc, (i + 1) * zc)
            return tuple(jax.device_put(x[sl])
                         for x in (u, v, t, valid, signs))

        carry = aggregation.empty_counts(merge_cap, limbs)
        spilled = jnp.zeros((), jnp.int32)
        nxt = put(0)
        for i in range(nchunk):
            cur = nxt
            carry, spilled = _pipeline_step(
                carry, spilled, *cur, delta=self.delta, l_max=self.l_max,
                scan=self.spec.scan, merge_cap=merge_cap,
            )
            if i + 1 < nchunk:
                nxt = put(i + 1)    # async H2D behind the running chunk
        return carry, spilled

    def _fold_host_scan(self, u, v, t, valid, signs, zc, merge_cap):
        """Chunked fold for host-only backends (scan outside jit).

        Even the NumPy oracle gets the hierarchical memory bound: only one
        chunk's [zc, E, L] code block exists at a time, merged through the
        same bounded carry as the device paths.
        """
        z, e = u.shape
        zc = zc if (zc and zc < z) else z
        nchunk = _n_chunks(z, zc)
        limbs = encoding.n_limbs(self.l_max)
        carry = aggregation.empty_counts(merge_cap, limbs)
        spilled = jnp.zeros((), jnp.int32)
        for i in range(nchunk):
            sl = slice(i * zc, (i + 1) * zc)
            res = self.spec.scan(u[sl], v[sl], t[sl], valid[sl],
                                 delta=self.delta, l_max=self.l_max)
            carry, spilled = _merge_chunk_jit(
                carry, spilled, jnp.asarray(res.code),
                jnp.asarray(res.length), jnp.asarray(signs[sl]),
                merge_cap=merge_cap,
            )
        return carry, spilled
