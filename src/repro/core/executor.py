"""Unified mining executor — ONE chunked scan+aggregate engine.

Every discovery entry point (batch ``discover``, the sequential baseline,
``distributed.mining.mine_on_mesh`` and the streaming miner) routes through
:class:`MiningExecutor` instead of carrying its own copy of the zone sweep:

* backend dispatch goes through :mod:`repro.core.backends` (capability-aware,
  pluggable);
* zone chunking (``lax.map`` over zone sub-batches to bound peak memory) is
  implemented once, with an explicit policy for zone counts that do not
  divide ``zone_chunk`` — **pad** (default: append inert zero-sign rows) or
  **raise** — never the silent remainder drop the pre-refactor
  ``_mine_batch`` had;
* jit compilation is cached per ``(backend, delta, l_max, zone_chunk, batch
  shape)`` via a single module-level jitted function, shared by every
  executor instance;
* host-only backends (``jittable=False``, e.g. the NumPy oracle) run their
  scan outside the jit boundary and only the signed aggregation is jitted.

``scan_aggregate`` is the traceable core (usable inside ``shard_map``);
``run`` is the host-level entry that applies batching policy first.
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

from . import aggregation, backends
from .aggregation import CodeCounts
from .tzp import ZoneBatch


class ZoneChunkError(ValueError):
    """Zone count does not divide ``zone_chunk`` under pad_policy='raise'."""


def _chunked_scan(scan, u, v, t, valid, *, delta, l_max, zone_chunk):
    """Sweep a [Z, E] zone batch, optionally in chunks of ``zone_chunk``.

    Traceable; shapes are static here, so divisibility is checked at trace
    time (the executor's host path pads beforehand under pad_policy='pad').
    """

    def chunk_fn(args):
        cu, cv, ct, cvalid = args
        res = scan(cu, cv, ct, cvalid, delta=delta, l_max=l_max)
        return res.code, res.length

    z = u.shape[0]
    if zone_chunk and zone_chunk < z:
        if z % zone_chunk != 0:
            raise ZoneChunkError(
                f"zone count {z} is not divisible by zone_chunk "
                f"{zone_chunk}; pad the batch (pad_policy='pad') or pick a "
                f"divisor — remainder zones would otherwise be dropped"
            )
        nchunk = z // zone_chunk
        reshape = lambda x: x.reshape(nchunk, zone_chunk, *x.shape[1:])
        codes, lengths = jax.lax.map(
            chunk_fn, (reshape(u), reshape(v), reshape(t), reshape(valid))
        )
        codes = codes.reshape(z, *codes.shape[2:])
        lengths = lengths.reshape(z, *lengths.shape[2:])
    else:
        codes, lengths = chunk_fn((u, v, t, valid))
    return codes, lengths


@functools.partial(
    jax.jit, static_argnames=("delta", "l_max", "scan", "zone_chunk")
)
def _mine_jit(u, v, t, valid, signs, *, delta, l_max, scan, zone_chunk):
    """Jitted zone sweep + signed aggregation (shared compile cache).

    jax.jit keys its cache on the static args plus input shapes, so every
    executor instance with the same (scan fn, delta, l_max, zone_chunk,
    batch shape) reuses one executable.  The cache is keyed on the resolved
    scan *callable*, not the backend name, so re-registering a backend
    (``overwrite=True``) cannot serve a stale executable.
    """
    codes, lengths = _chunked_scan(
        scan, u, v, t, valid, delta=delta, l_max=l_max, zone_chunk=zone_chunk
    )
    return aggregation.aggregate_zones(codes, lengths, signs)


class MiningExecutor:
    """Chunked scan+aggregate engine over padded zone batches.

    Args:
      delta, l_max: paper parameters (Definitions 2-5).
      backend: registry name ("ref", "pallas", "numpy", or plugin).
      zone_chunk: process zones in chunks of this many to bound peak memory
        (None/0 = whole batch at once); defaults to the backend's hint.
      pad_policy: "pad" appends inert zero-sign zone rows when the zone
        count does not divide ``zone_chunk``; "raise" errors instead.
    """

    def __init__(
        self,
        *,
        delta: int,
        l_max: int,
        backend: str = "ref",
        zone_chunk: int | None = None,
        pad_policy: str = "pad",
    ):
        if pad_policy not in ("pad", "raise"):
            raise ValueError(f"unknown pad_policy {pad_policy!r}")
        self.delta = int(delta)
        self.l_max = int(l_max)
        self.spec = backends.get_backend(backend)
        if zone_chunk is None:
            zone_chunk = self.spec.default_zone_chunk
        self.zone_chunk = int(zone_chunk or 0)
        self.pad_policy = pad_policy

    @property
    def backend(self) -> str:
        return self.spec.name

    # -- traceable core (used inside shard_map by distributed mining) -------

    def scan_aggregate(self, u, v, t, valid, signs) -> CodeCounts:
        """Scan + signed-aggregate a [Z, E] batch; JAX-traceable.

        Raises :class:`ZoneChunkError` at trace time when the (static) zone
        count does not divide ``zone_chunk`` — inside a trace there is no
        host to pad, so the remainder cannot be silently handled.
        """
        if not self.spec.jittable:
            raise ValueError(
                f"backend {self.backend!r} is host-only (jittable=False) "
                f"and cannot run inside a traced/sharded computation"
            )
        codes, lengths = _chunked_scan(
            self.spec.scan, u, v, t, valid,
            delta=self.delta, l_max=self.l_max, zone_chunk=self.zone_chunk,
        )
        return aggregation.aggregate_zones(codes, lengths, signs)

    # -- host-level entry points -------------------------------------------

    def run(self, batch: ZoneBatch) -> CodeCounts:
        """Mine a host-built :class:`ZoneBatch` to signed code counts."""
        return self.run_arrays(batch.u, batch.v, batch.t, batch.valid,
                               batch.sign)

    def run_arrays(self, u, v, t, valid, signs) -> CodeCounts:
        """Mine raw [Z, E] zone arrays (+ [Z] signs) to signed code counts."""
        u, v, t, valid, signs = (np.asarray(x)
                                 for x in (u, v, t, valid, signs))
        z = u.shape[0]
        zc = self.zone_chunk
        if zc and zc < z and z % zc != 0:
            if self.pad_policy == "raise":
                raise ZoneChunkError(
                    f"zone count {z} is not divisible by zone_chunk {zc} "
                    f"(pad_policy='raise')"
                )
            pad = zc - z % zc
            pad_rows = lambda x: np.concatenate(
                [x, np.zeros((pad, *x.shape[1:]), x.dtype)])
            u, v, t, valid = map(pad_rows, (u, v, t, valid))
            signs = np.concatenate([signs, np.zeros(pad, signs.dtype)])

        if not self.spec.jittable:
            res = self.spec.scan(u, v, t, valid,
                                 delta=self.delta, l_max=self.l_max)
            return aggregation.aggregate_zones(
                jnp.asarray(res.code), jnp.asarray(res.length),
                jnp.asarray(signs),
            )
        return _mine_jit(
            jnp.asarray(u), jnp.asarray(v), jnp.asarray(t),
            jnp.asarray(valid), jnp.asarray(signs),
            delta=self.delta, l_max=self.l_max, scan=self.spec.scan,
            zone_chunk=self.zone_chunk,
        )
