"""Motif-transition statistics and the transition tree (reporting layer).

Final-code counts are sufficient statistics for the whole discovery problem:
a process that stopped at code ``c`` passed through every even-length prefix
of ``c``, so per-level transition counts (Fig. 6 / Table 6 of the paper) are
prefix aggregations.  This module is host-side numpy — it renders results,
the device pipeline never depends on it.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict

import numpy as np

from . import encoding


@dataclasses.dataclass
class TransitionNode:
    """One motif type in the transition tree."""

    code: str                     # paper-style label string, e.g. "0101"
    stopped: int = 0              # processes that ended here
    through: int = 0              # processes that reached here (>= stopped)
    children: dict = dataclasses.field(default_factory=dict)

    @property
    def evolved(self) -> int:
        return self.through - self.stopped

    def transition_rows(self):
        """Rows like Table 6: (child code, count, share of evolved)."""
        total = sum(ch.through for ch in self.children.values())
        rows = []
        for code in sorted(self.children):
            ch = self.children[code]
            share = ch.through / total if total else 0.0
            rows.append((code, ch.through, share))
        return rows


class TransitionTree:
    """Trie over motif codes with stopped/through counts."""

    def __init__(self):
        self.root = TransitionNode(code="")

    def add(self, code: str, count: int):
        node = self.root
        node.through += count
        for level in range(2, len(code) + 1, 2):
            prefix = code[:level]
            if prefix not in node.children:
                node.children[prefix] = TransitionNode(code=prefix)
            node = node.children[prefix]
            node.through += count
        node.stopped += count

    def node(self, code: str) -> TransitionNode:
        node = self.root
        for level in range(2, len(code) + 1, 2):
            node = node.children[code[:level]]
        return node

    def render(self, code: str = "", max_depth: int = 2) -> str:
        """ASCII rendering of the transition tree (Fig. 6 analog)."""
        start = self.node(code) if code else self.root
        lines = []

        def walk(node, depth):
            if depth > max_depth:
                return
            for child_code, count, share in node.transition_rows():
                lines.append(
                    f"{'  ' * depth}{child_code}: {count} ({share:.1%})"
                )
                walk(node.children[child_code], depth + 1)

        walk(start, 0)
        return "\n".join(lines)


def counts_to_dict(codes: np.ndarray, counts: np.ndarray,
                   mask: np.ndarray | None = None) -> dict[str, int]:
    """Device count arrays -> {code string: count}, dropping zeros."""
    out: dict[str, int] = defaultdict(int)
    codes = np.asarray(codes)
    counts = np.asarray(counts)
    if mask is None:
        mask = np.ones(counts.shape, bool)
    for row, cnt in zip(codes[np.asarray(mask)], counts[np.asarray(mask)]):
        if cnt == 0:
            continue
        out[encoding.decode_code_np(row)] += int(cnt)
    return {k: v for k, v in out.items() if v != 0}


def device_counts_to_dict(counts) -> dict[str, int]:
    """:class:`~repro.core.aggregation.CodeCounts` -> {code string: count}."""
    return counts_to_dict(
        np.asarray(counts.codes), np.asarray(counts.counts),
        np.asarray(counts.unique_mask),
    )


def build_tree(final_counts: dict[str, int]) -> TransitionTree:
    tree = TransitionTree()
    for code, count in final_counts.items():
        tree.add(code, count)
    return tree


def level_histogram(final_counts: dict[str, int]) -> dict[int, int]:
    """Processes per final length (1..l_max)."""
    hist: dict[int, int] = defaultdict(int)
    for code, count in final_counts.items():
        hist[len(code) // 2] += count
    return dict(hist)
