"""Temporal Zone Partitioning (TZP) — Algorithm 1, with adaptive zoning.

Growth zone ``G_i = [s_i, e_i)`` with ``e_i - s_i >= 2 * L_b`` where
``L_b = delta * l_max`` (the maximum time span of one motif transition
process, including its trailing time-out window).  Consecutive growth zones
overlap by exactly ``L_b``; the overlap is the boundary zone
``B_i = [s_{i+1}, e_i)``.  Counting every zone independently and summing with
sign +1 (growth) / -1 (boundary) reproduces exact global counts
(inclusion-exclusion, Lemma 4.2).

Beyond-paper: the paper fixes ``omega`` globally; we additionally shrink a
growth zone whose edge population exceeds ``e_cap`` (down to the correctness
floor ``2 * L_b``), which bounds the padded zone batch and load imbalance on
bursty streams.  Zones are host-side metadata (data-pipeline work); the
device-side batch is built once per mining run.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json

import numpy as np

from .temporal_graph import TemporalGraph


@dataclasses.dataclass(frozen=True)
class ZonePlan:
    """Host-side partition table (one row per zone, time-ordered)."""

    lo: np.ndarray        # int64[Z] first edge index of the zone
    count: np.ndarray     # int64[Z] number of edges in the zone
    sign: np.ndarray      # int32[Z] +1 growth / -1 boundary
    t_start: np.ndarray   # int64[Z] zone window start (inclusive)
    t_end: np.ndarray     # int64[Z] zone window end (exclusive)
    l_b: int              # boundary length delta * l_max

    @property
    def n_zones(self) -> int:
        return int(self.lo.shape[0])

    @property
    def n_growth(self) -> int:
        return int((self.sign > 0).sum())

    @property
    def max_count(self) -> int:
        return int(self.count.max()) if self.n_zones else 0

    # -- serialization (the engine-level zone-plan cache persists plans) ----

    def to_json(self) -> str:
        """Exact JSON round-trip (``from_json(to_json(p)) == p``)."""
        return json.dumps({
            "lo": self.lo.tolist(),
            "count": self.count.tolist(),
            "sign": self.sign.tolist(),
            "t_start": self.t_start.tolist(),
            "t_end": self.t_end.tolist(),
            "l_b": self.l_b,
        }, sort_keys=True)

    @classmethod
    def from_json(cls, data: str | bytes | dict) -> "ZonePlan":
        """Inverse of :meth:`to_json`; also accepts an already-parsed dict."""
        if not isinstance(data, dict):
            data = json.loads(data)
        known = {"lo", "count", "sign", "t_start", "t_end", "l_b"}
        unknown = sorted(set(data) - known)
        if unknown:
            raise ValueError(
                f"unknown ZonePlan field(s) {unknown}; known: {sorted(known)}")
        return cls(
            lo=np.asarray(data["lo"], np.int64),
            count=np.asarray(data["count"], np.int64),
            sign=np.asarray(data["sign"], np.int32),
            t_start=np.asarray(data["t_start"], np.int64),
            t_end=np.asarray(data["t_end"], np.int64),
            l_b=int(data["l_b"]),
        )

    def __eq__(self, other) -> bool:
        if not isinstance(other, ZonePlan):
            return NotImplemented
        return self.l_b == other.l_b and all(
            np.array_equal(getattr(self, f), getattr(other, f))
            for f in ("lo", "count", "sign", "t_start", "t_end"))


def graph_fingerprint(graph: TemporalGraph) -> str:
    """Cheap content hash of a temporal graph (zone-plan cache key part).

    Hashes the raw edge arrays, so two graphs with identical streams share
    a fingerprint regardless of object identity.  O(n) but vastly cheaper
    than re-running Algorithm 1's zone scan; the engine memoizes plans
    under ``(fingerprint, delta, l_max, omega, e_cap)``.
    """
    h = hashlib.blake2b(digest_size=16)
    h.update(np.int64(graph.n_edges).tobytes())
    for arr in (graph.u, graph.v, graph.t):
        h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


def adaptive_zone_end(t: np.ndarray, s: int, e: int, *, e_cap: int | None,
                      l_b: int) -> int:
    """Adaptive shrink of a growth zone's end (beyond-paper, see module doc).

    If more than ``e_cap`` edges fall in ``[s, e)``, shrink ``e`` to the
    time of the ``(e_cap+1)``-th edge, floored at the correctness minimum
    ``s + 2*l_b``.  Shared by the batch planner and the streaming frontier
    so the zone geometry rule lives in exactly one place.
    """
    if e_cap is None:
        return e
    lo = int(np.searchsorted(t, s, side="left"))
    hi_target = int(np.searchsorted(t, e, side="left"))
    if hi_target - lo <= e_cap:
        return e
    e_shrunk = int(t[lo + e_cap])
    return int(np.clip(e_shrunk, s + 2 * l_b, e))


def pad_zone_arrays(u, v, t, valid, signs, *, n_rows: int):
    """Append inert zone rows so the batch has exactly ``n_rows`` zones.

    The one copy of the "inert row" definition: all-invalid edges and sign
    0, so a padded row seeds no candidates and its signed contribution is
    identically zero.  Used by the executor's ``pad_policy="pad"`` path
    (zone counts that do not divide ``zone_chunk``) — the same rule
    :func:`build_zone_batch` applies via ``pad_zones_to``, shared instead
    of re-derived inline at the call site.
    """
    z = u.shape[0]
    if n_rows < z:
        raise ValueError(
            f"cannot pad a {z}-zone batch down to {n_rows} rows")
    if n_rows == z:
        return u, v, t, valid, signs
    pad = n_rows - z
    pad_rows = lambda x: np.concatenate(
        [x, np.zeros((pad, *x.shape[1:]), x.dtype)])
    u, v, t, valid = map(pad_rows, (u, v, t, valid))
    signs = np.concatenate([signs, np.zeros(pad, signs.dtype)])
    return u, v, t, valid, signs


def fill_zone_row(u_row, v_row, t_row, valid_row, su, sv, st) -> None:
    """Copy one zone's edges into a padded batch row (in place).

    Padding timestamps repeat the zone max so kernel-level block skipping
    stays conservative (padding edges are masked out by ``valid``).
    """
    cnt = len(su)
    u_row[:cnt] = su
    v_row[:cnt] = sv
    t_row[:cnt] = st
    if cnt:
        t_row[cnt:] = st[-1]
    valid_row[:cnt] = True


def plan_zones(
    graph: TemporalGraph,
    *,
    delta: int,
    l_max: int,
    omega: int = 20,
    e_cap: int | None = None,
) -> ZonePlan:
    """Algorithm 1: linear scan creating interleaved growth/boundary zones."""
    if delta < 1 or l_max < 1:
        raise ValueError("delta and l_max must be >= 1")
    if omega < 2:
        raise ValueError("omega must be >= 2 (growth zone >= 2 boundary zones)")
    t = graph.t.astype(np.int64)
    n = t.shape[0]
    l_b = delta * l_max
    l_g = omega * l_b

    lo_list, cnt_list, sign_list, ts_list, te_list = [], [], [], [], []
    if n == 0:
        return ZonePlan(*[np.zeros(0, np.int64) for _ in range(2)],
                        np.zeros(0, np.int32), np.zeros(0, np.int64),
                        np.zeros(0, np.int64), l_b)

    t_max = int(t[-1])
    s = int(t[0])
    while True:
        e = s + l_g
        lo = int(np.searchsorted(t, s, side="left"))
        if e <= t_max:
            e = adaptive_zone_end(t, s, e, e_cap=e_cap, l_b=l_b)
        hi = int(np.searchsorted(t, e, side="left"))
        lo_list.append(lo)
        cnt_list.append(hi - lo)
        sign_list.append(1)
        ts_list.append(s)
        te_list.append(e)
        if e > t_max:
            break
        # boundary zone = overlap [e - l_b, e)
        b_lo = int(np.searchsorted(t, e - l_b, side="left"))
        lo_list.append(b_lo)
        cnt_list.append(hi - b_lo)
        sign_list.append(-1)
        ts_list.append(e - l_b)
        te_list.append(e)
        s = e - l_b

    return ZonePlan(
        lo=np.asarray(lo_list, np.int64),
        count=np.asarray(cnt_list, np.int64),
        sign=np.asarray(sign_list, np.int32),
        t_start=np.asarray(ts_list, np.int64),
        t_end=np.asarray(te_list, np.int64),
        l_b=l_b,
    )


def single_zone_plan(graph: TemporalGraph, *, l_b: int) -> ZonePlan:
    """One growth zone spanning the whole stream (the TMC-analog baseline).

    The degenerate partition: no boundary zones, sign +1, every edge in one
    row.  Routing the sequential baseline through this plan +
    :func:`build_zone_batch` keeps the padding/fill policy in exactly one
    place instead of a hand-rolled zero-pad block at the call site.
    """
    t = graph.t.astype(np.int64)
    n = int(t.shape[0])
    t0 = int(t[0]) if n else 0
    t_end = int(t[-1]) + 1 if n else 1
    return ZonePlan(
        lo=np.zeros(1, np.int64),
        count=np.asarray([n], np.int64),
        sign=np.ones(1, np.int32),
        t_start=np.asarray([t0], np.int64),
        t_end=np.asarray([t_end], np.int64),
        l_b=l_b,
    )


@dataclasses.dataclass(frozen=True)
class ZoneBatch:
    """Device-ready padded zone batch.

    Arrays are [Z, e_cap]; ``valid`` masks real edges.  ``perm`` records the
    size-balanced zone order (descending population round-robin across
    ``n_shards`` — static load balancing replacing the paper's work stealing).
    """

    u: np.ndarray
    v: np.ndarray
    t: np.ndarray
    valid: np.ndarray
    sign: np.ndarray      # int32[Z]
    perm: np.ndarray      # int64[Z] original zone index per row
    overflow: int         # edges dropped because a zone exceeded e_cap
    label: str = ""       # bucket name in a ZoneBatchLayout ("" = dense)

    @property
    def n_zones(self) -> int:
        return int(self.u.shape[0])

    @property
    def e_cap(self) -> int:
        return int(self.u.shape[1])

    @property
    def n_real_zones(self) -> int:
        """Rows carrying a planned zone (``perm >= 0``; the rest are pad)."""
        return int((self.perm >= 0).sum())

    @property
    def valid_edges(self) -> int:
        return int(self.valid.sum())

    @property
    def padded_slots(self) -> int:
        """Total device edge slots, real or padding (``Z * e_cap``)."""
        return self.n_zones * self.e_cap

    @property
    def occupancy(self) -> float:
        """Fraction of edge slots holding real edges (1 - padding waste)."""
        return self.valid_edges / max(self.padded_slots, 1)


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def dense_cap(plan: ZonePlan, *, e_cap: int | None = None,
              pad_edges_to: int = 8) -> int:
    """The dense layout's per-zone edge capacity for ``plan``.

    The single copy of the rule — :func:`build_zone_batch`,
    :func:`resolve_layout` and :func:`build_zone_layout` must all agree on
    it, or dense and bucketed layouts would clip (and overflow) at
    different capacities.
    """
    cap = e_cap or plan.max_count
    return max(_round_up(max(cap, 1), pad_edges_to), pad_edges_to)


def build_zone_batch(
    graph: TemporalGraph,
    plan: ZonePlan,
    *,
    e_cap: int | None = None,
    pad_zones_to: int = 1,
    pad_edges_to: int = 8,
    n_shards: int = 1,
    label: str = "",
) -> ZoneBatch:
    """Gather zones into a padded [Z, e_cap] batch with validity masks."""
    z = plan.n_zones
    cap = dense_cap(plan, e_cap=e_cap, pad_edges_to=pad_edges_to)
    z_pad = max(_round_up(max(z, 1), pad_zones_to), pad_zones_to)

    # static load balance: biggest zones first, dealt round-robin over shards
    order = np.argsort(-plan.count, kind="stable")
    if n_shards > 1 and z:
        lanes: list[list[int]] = [[] for _ in range(n_shards)]
        for rank, zi in enumerate(order):
            lanes[rank % n_shards].append(int(zi))
        order = np.asarray([zi for lane in lanes for zi in lane], np.int64)

    u = np.zeros((z_pad, cap), np.int32)
    v = np.zeros((z_pad, cap), np.int32)
    t = np.zeros((z_pad, cap), np.int32)
    valid = np.zeros((z_pad, cap), bool)
    sign = np.zeros(z_pad, np.int32)
    perm = np.full(z_pad, -1, np.int64)
    overflow = 0
    for row, zi in enumerate(order):
        lo = int(plan.lo[zi])
        cnt = int(plan.count[zi])
        take = min(cnt, cap)
        overflow += cnt - take
        fill_zone_row(u[row], v[row], t[row], valid[row],
                      graph.u[lo:lo + take], graph.v[lo:lo + take],
                      graph.t[lo:lo + take])
        sign[row] = plan.sign[zi]
        perm[row] = zi
    return ZoneBatch(u=u, v=v, t=t, valid=valid, sign=sign, perm=perm,
                     overflow=overflow, label=label)


# ---------------------------------------------------------------------------
# Ragged zone batching: size-bucketed layouts.
# ---------------------------------------------------------------------------

ZONE_LAYOUTS = ("auto", "dense", "bucketed")


def next_pow2(x: int) -> int:
    """Smallest power of two >= ``x`` (1 for x <= 1).

    The one copy of the bucket-capacity rounding rule — the streaming
    frontier and the bucketed layout must agree on it, or the same zone
    would land on different jit shapes depending on the path.
    """
    return 1 << max(int(x) - 1, 0).bit_length() if x > 1 else 1


def bucket_caps(counts: np.ndarray, *, max_cap: int,
                pad_edges_to: int = 8) -> np.ndarray:
    """Per-zone bucket capacity: power-of-two ceil, aligned to
    ``pad_edges_to``, clipped to ``max_cap``.

    The floor is ``pad_edges_to`` rounded up to a power of two, so the
    quietest zones still land on device-friendly row widths; aligning to
    ``pad_edges_to`` afterwards keeps each bucket's grouping key equal to
    the ``e_cap`` :func:`build_zone_batch` will actually allocate (for a
    non-power-of-two ``pad_edges_to``, a raw pow2 cap would be re-rounded
    there, merging buckets and mislabeling them); the clip keeps the top
    bucket exactly the dense capacity, so a zone that would overflow the
    dense batch overflows the bucketed one by the same edge count
    (identical ``overflow`` semantics across layouts).
    """
    floor = next_pow2(max(int(pad_edges_to), 1))
    caps = np.asarray(
        [next_pow2(max(int(c), 1)) for c in np.asarray(counts)], np.int64)
    caps = np.maximum(caps, floor)
    caps = (caps + pad_edges_to - 1) // pad_edges_to * pad_edges_to
    return np.clip(caps, None, max_cap)


@dataclasses.dataclass(frozen=True)
class ZoneBatchLayout:
    """A zone batch as one or more size-bucketed :class:`ZoneBatch` pieces.

    ``kind`` is ``"dense"`` (one bucket at the global capacity — the seed
    layout, kept as the differential oracle and for tiny plans) or
    ``"bucketed"`` (zones grouped into power-of-two ``e_cap`` buckets so
    quiet zones stop paying a bursty zone's dense O(e_cap²) sweep).
    Buckets are ordered by ascending capacity and each is a self-contained
    padded batch; signed aggregation is associative over zones (Lemma 4.2),
    so mining buckets independently and merging the partial count tables is
    exact.
    """

    kind: str
    buckets: tuple[ZoneBatch, ...]

    @property
    def n_buckets(self) -> int:
        return len(self.buckets)

    @property
    def n_zones(self) -> int:
        """Planned (real) zones across buckets — pad rows excluded."""
        return sum(b.n_real_zones for b in self.buckets)

    @property
    def overflow(self) -> int:
        return sum(b.overflow for b in self.buckets)

    @property
    def e_cap(self) -> int:
        """Largest bucket capacity (== the dense capacity by construction)."""
        return max((b.e_cap for b in self.buckets), default=0)

    @property
    def valid_edges(self) -> int:
        return sum(b.valid_edges for b in self.buckets)

    @property
    def padded_slots(self) -> int:
        return sum(b.padded_slots for b in self.buckets)

    @property
    def padding_ratio(self) -> float:
        """Fraction of device edge slots that are padding (wasted work)."""
        slots = self.padded_slots
        return 1.0 - self.valid_edges / slots if slots else 0.0

    @property
    def sweep_slots(self) -> int:
        """Padded pairwise sweep work — the dense O(e_cap²) cost model the
        bucketing attacks.  One formula, owned by the planner
        (:func:`repro.core.planner.padded_sweep_slots`)."""
        from . import planner

        return planner.padded_sweep_slots(self.bucket_shapes())

    def bucket_shapes(self) -> tuple[tuple[int, int], ...]:
        """Per-bucket ``(n_zones, e_cap)`` — the compile-cache geometry."""
        return tuple((b.n_zones, b.e_cap) for b in self.buckets)

    def summary(self) -> dict:
        """JSON-able layout description (benchmarks, ``engine.stats``)."""
        return {
            "kind": self.kind,
            "n_zones": self.n_zones,
            "padding_ratio": self.padding_ratio,
            "buckets": [
                {
                    "label": b.label,
                    "e_cap": b.e_cap,
                    "n_zones": b.n_zones,
                    "real_zones": b.n_real_zones,
                    "valid_edges": b.valid_edges,
                    "occupancy": b.occupancy,
                }
                for b in self.buckets
            ],
        }


@dataclasses.dataclass(frozen=True)
class FusedZoneLayout:
    """A :class:`ZoneBatchLayout` flattened into one device slot stream.

    Every bucket's padded ``[Z_b, e_cap_b]`` rows are flattened and
    concatenated into flat ``int32[S]`` arrays (``S`` rounded up to a
    multiple of ``blk``), so a *single* kernel launch can sweep the whole
    ragged layout: candidate blocks of ``blk`` lanes tile the stream and
    the per-block ``[lo, hi)`` descriptors bound each block's sweep to the
    flat span of the zones its lanes belong to.  ``zone_id`` (the global
    zone row per slot, -1 for stream padding) gates the kernel's edge
    updates to same-zone pairs, and ``sign`` carries each slot's Lemma-4.2
    sign so the on-device fold can weight candidates without a host gather.

    ``bounds`` records how ``hi`` was planned: ``"full"`` sweeps each
    block to the blk-aligned end of its lanes' zones, ``"live"`` stops at
    the blk-aligned Lemma-4.1 horizon cut (no lane in the block can absorb
    an edge past ``t_seed + l_max * delta``) and skips candidate blocks
    with no valid lane outright (``lo == hi``).
    """

    u: np.ndarray         # int32[S] flat edge endpoints
    v: np.ndarray         # int32[S]
    t: np.ndarray         # int32[S] timestamps (0 on invalid slots)
    valid: np.ndarray     # int32[S] real-edge mask
    zone_id: np.ndarray   # int32[S] owning zone row (-1 = stream pad)
    sign: np.ndarray      # int32[S] zone sign per slot (0 on pad)
    lo: np.ndarray        # int32[S // blk] blk-aligned sweep start per block
    hi: np.ndarray        # int32[S // blk] blk-aligned sweep end per block
    blk: int
    kind: str                                   # source layout kind
    bucket_shapes: tuple[tuple[int, int], ...]  # source (Z_b, e_cap_b)
    n_zones: int                                # real zones in the stream
    overflow: int
    bounds: str = "full"                        # sweep-bound planning mode

    @property
    def n_slots(self) -> int:
        return int(self.u.shape[0])

    @property
    def n_blocks(self) -> int:
        return self.n_slots // self.blk

    @property
    def valid_edges(self) -> int:
        return int((self.valid != 0).sum())

    @property
    def sweep_slots(self) -> int:
        """Padded pairwise sweep work actually dispatched: each candidate
        block sweeps ``hi - lo`` slots (before chunk-level live skipping).
        The fused analog of :attr:`ZoneBatchLayout.sweep_slots`; one
        formula, owned by the planner
        (:func:`repro.core.planner.fused_sweep_slots`)."""
        from . import planner

        return planner.fused_sweep_slots(self.lo, self.hi, self.blk)

    def signature(self) -> tuple:
        """Compile-cache geometry: one jitted executable per signature.

        ``bounds`` is part of the key — full and live plans dispatch the
        same shapes but different descriptor contents, and the engine
        keys compile/stat caches per (backend, layout, bounds).
        """
        return (self.kind, self.bucket_shapes, self.n_slots, self.blk,
                self.bounds)

    def summary(self) -> dict:
        """JSON-able description (benchmarks, ``engine.stats``)."""
        return {
            "kind": f"fused-{self.kind}",
            "bounds": self.bounds,
            "n_zones": self.n_zones,
            "n_slots": self.n_slots,
            "blk": self.blk,
            "n_blocks": self.n_blocks,
            "valid_edges": self.valid_edges,
            "sweep_slots": self.sweep_slots,
            "bucket_shapes": [list(s) for s in self.bucket_shapes],
        }


#: Sweep-bound planning modes for :func:`concat_layout`.
FUSED_BOUNDS = ("full", "live")


def concat_layout(layout: ZoneBatchLayout, *, blk: int = 512,
                  pad_slots_to: int | None = None,
                  delta: int | None = None, l_max: int | None = None,
                  bounds: str = "full") -> FusedZoneLayout:
    """Flatten a (dense or bucketed) layout into a fused slot stream.

    Buckets are visited in layout order (ascending capacity) and only real
    zone rows (``perm >= 0``) are emitted — inert zone-padding rows would
    be pure wasted sweep in a stream that has no rectangular shape to
    satisfy.  The stream is padded to a multiple of ``blk`` (and of
    ``pad_slots_to`` when given — the executor passes its on-device fold
    chunk so the count fold tiles evenly); padding slots carry ``valid=0``,
    ``zone_id=-1``, ``sign=0``.

    ``bounds="full"``: ``hi[i]`` is the blk-aligned end of the last zone
    any of block ``i``'s lanes belongs to — a lane's extensions can only
    come from later slots of its own zone row (earlier same-zone edges are
    not strictly later in time, so they can neither extend nor time out
    the candidate), hence sweeping ``[i*blk, hi[i])`` is exact.

    ``bounds="live"`` (requires ``delta``/``l_max``): tighten ``hi[i]`` to
    the blk-aligned Lemma-4.1 horizon cut.  A candidate seeded at ``t0``
    extends only through edges with ``t <= t0 + l_max * delta`` (after
    ``k`` extensions ``last_t <= t0 + k * delta``, and an extension needs
    ``t <= last_t + delta`` with ``length < l_max``); zone rows are
    time-sorted, so one ``searchsorted`` per valid slot places its cut
    exactly.  Edges past the cut can only set the candidate's ``done``
    flag, which never feeds the ``code``/``length``/``ts`` outputs, so the
    compacted sweep is output-identical to the full one.  Blocks with no
    valid lane get ``hi == lo`` (zero chunks dispatched).  ``lo[i]`` is
    ``i * blk`` in both modes: seeding lane ``q`` requires sweeping slot
    ``q`` itself, and every cut is ``>= q + 1``, so a live block's window
    always covers its own chunk.
    """
    if blk < 1:
        raise ValueError(f"blk must be >= 1, got {blk}")
    if bounds not in FUSED_BOUNDS:
        raise ValueError(
            f"unknown fused sweep bounds {bounds!r}; one of {FUSED_BOUNDS}")
    if bounds == "live" and (delta is None or l_max is None):
        raise ValueError(
            "bounds='live' needs delta and l_max to place the Lemma-4.1 "
            "horizon cut")
    mult = blk
    if pad_slots_to:
        if pad_slots_to % blk:
            raise ValueError(
                f"pad_slots_to {pad_slots_to} must be a multiple of "
                f"blk {blk}")
        mult = pad_slots_to

    horizon = int(delta) * int(l_max) if bounds == "live" else 0
    chunks_u, chunks_v, chunks_t, chunks_valid = [], [], [], []
    chunks_zid, chunks_sign, row_ends, live_ends = [], [], [], []
    zone_row = 0
    pos = 0
    for b in layout.buckets:
        real = np.flatnonzero(b.perm >= 0)
        cap = b.e_cap
        for r in real:
            chunks_u.append(b.u[r])
            chunks_v.append(b.v[r])
            chunks_t.append(b.t[r])
            chunks_valid.append(b.valid[r])
            chunks_zid.append(np.full(cap, zone_row, np.int32))
            chunks_sign.append(np.full(cap, b.sign[r], np.int32))
            row_start = pos
            pos += cap
            row_ends.append(np.full(cap, pos, np.int64))
            if bounds == "live":
                # per-slot horizon cut (int64 guards t + horizon overflow);
                # invalid slots contribute 0 — they seed nothing, so they
                # constrain no block's window
                cnt = int(b.valid[r].sum())
                cuts = np.zeros(cap, np.int64)
                if cnt:
                    st = b.t[r][:cnt].astype(np.int64)
                    cuts[:cnt] = row_start + np.searchsorted(
                        st, st + horizon, side="right")
                live_ends.append(cuts)
            zone_row += 1

    s = pos
    s_pad = max(_round_up(max(s, 1), mult), mult)
    pad = s_pad - s

    def flat(parts, fill, dtype):
        out = np.concatenate(parts).astype(dtype) if parts else \
            np.zeros(0, dtype)
        if pad:
            out = np.concatenate([out, np.full(pad, fill, dtype)])
        return out

    u = flat(chunks_u, 0, np.int32)
    v = flat(chunks_v, 0, np.int32)
    t = flat(chunks_t, 0, np.int32)
    valid = flat(chunks_valid, 0, np.int32)
    zone_id = flat(chunks_zid, -1, np.int32)
    sign = flat(chunks_sign, 0, np.int32)
    # pad slots end at their own position so they never extend a sweep
    slot_end = np.concatenate(row_ends).astype(np.int64) if row_ends else \
        np.zeros(0, np.int64)
    if pad:
        slot_end = np.concatenate(
            [slot_end, np.arange(s, s_pad, dtype=np.int64) + 1])

    n_blocks = s_pad // blk
    bases = np.arange(n_blocks, dtype=np.int64) * blk
    if bounds == "live":
        live = np.concatenate(live_ends).astype(np.int64) if live_ends \
            else np.zeros(0, np.int64)
        if pad:
            live = np.concatenate([live, np.zeros(pad, np.int64)])
        cut = live.reshape(n_blocks, blk).max(axis=1)
        hi = (cut + blk - 1) // blk * blk
        # blocks with no valid lane dispatch zero chunks (their lanes seed
        # nothing and the fold zero-weights length-0 candidates)
        hi = np.where(cut > 0, hi, bases)
    else:
        hi = slot_end.reshape(n_blocks, blk).max(axis=1)
        hi = (hi + blk - 1) // blk * blk

    return FusedZoneLayout(
        u=u, v=v, t=t, valid=valid, zone_id=zone_id, sign=sign,
        lo=bases.astype(np.int32), hi=hi.astype(np.int32), blk=blk,
        kind=layout.kind, bucket_shapes=layout.bucket_shapes(),
        n_zones=zone_row, overflow=layout.overflow, bounds=bounds,
    )


def _select_plan(plan: ZonePlan, idx: np.ndarray) -> ZonePlan:
    return ZonePlan(lo=plan.lo[idx], count=plan.count[idx],
                    sign=plan.sign[idx], t_start=plan.t_start[idx],
                    t_end=plan.t_end[idx], l_b=plan.l_b)


def resolve_layout(plan: ZonePlan, layout: str, *, e_cap: int | None = None,
                   pad_edges_to: int = 8) -> str:
    """Resolve ``"auto"`` to a concrete layout kind for ``plan``.

    ``auto`` picks ``bucketed`` only when the plan's zone sizes actually
    span more than one bucket — a uniform (or tiny) plan gains nothing
    from bucketing and the dense layout keeps one executable shape.
    """
    if layout not in ZONE_LAYOUTS:
        raise ValueError(
            f"unknown zone layout {layout!r}; one of {ZONE_LAYOUTS}")
    if layout != "auto":
        return layout
    if plan.n_zones < 2:
        return "dense"
    counts = np.asarray(plan.count)
    if (counts == 0).any():
        # the bucketed layout drops empty zones outright — always a win
        return "bucketed"
    caps = bucket_caps(counts,
                       max_cap=dense_cap(plan, e_cap=e_cap,
                                         pad_edges_to=pad_edges_to),
                       pad_edges_to=pad_edges_to)
    return "bucketed" if len(np.unique(caps)) > 1 else "dense"


def build_zone_layout(
    graph: TemporalGraph,
    plan: ZonePlan,
    *,
    layout: str = "auto",
    e_cap: int | None = None,
    pad_zones_to: int = 1,
    pad_edges_to: int = 8,
    n_shards: int = 1,
) -> ZoneBatchLayout:
    """Build a device layout for ``plan`` — dense or size-bucketed.

    The bucketed layout groups zones whose edge population rounds up to the
    same power-of-two capacity into one padded batch per bucket (largest
    bucket capped at the dense capacity, so overflow is layout-invariant).
    Empty zones are dropped outright — a zone with no edges seeds no
    candidates, so its signed contribution is identically zero (quiet-gap
    plans routinely carry thousands of them, all padding under the dense
    layout).  Zone ordering inside a bucket keeps
    :func:`build_zone_batch`'s static load balancing (descending size,
    round-robin over ``n_shards``), and ``perm`` is remapped to the
    original plan's zone indices.
    """
    kind = resolve_layout(plan, layout, e_cap=e_cap,
                          pad_edges_to=pad_edges_to)
    if kind == "dense":
        dense = build_zone_batch(
            graph, plan, e_cap=e_cap, pad_zones_to=pad_zones_to,
            pad_edges_to=pad_edges_to, n_shards=n_shards, label="dense")
        return ZoneBatchLayout(kind="dense", buckets=(dense,))

    max_cap = dense_cap(plan, e_cap=e_cap, pad_edges_to=pad_edges_to)
    nonempty = np.flatnonzero(np.asarray(plan.count) > 0)
    if nonempty.size == 0:
        # all-empty plan: one inert bucket so the executor still has a
        # (zero-candidate) batch to run — counts come out empty, exactly.
        # Zone padding/sharding kwargs still apply: a mesh path must be
        # able to partition even an empty batch's zone axis.
        inert = build_zone_batch(
            graph, _select_plan(plan, nonempty), e_cap=pad_edges_to,
            pad_zones_to=pad_zones_to, pad_edges_to=pad_edges_to,
            n_shards=n_shards, label=f"cap{pad_edges_to}")
        return ZoneBatchLayout(kind="bucketed", buckets=(inert,))
    caps = bucket_caps(plan.count[nonempty], max_cap=max_cap,
                       pad_edges_to=pad_edges_to)
    buckets = []
    for cap in sorted(int(c) for c in np.unique(caps)):
        idx = nonempty[np.flatnonzero(caps == cap)]
        sub = _select_plan(plan, idx)
        batch = build_zone_batch(
            graph, sub, e_cap=cap, pad_zones_to=pad_zones_to,
            pad_edges_to=pad_edges_to, n_shards=n_shards,
            label=f"cap{cap}")
        # remap perm from sub-plan rows back to the original zone indices
        perm = np.where(batch.perm >= 0,
                        idx[np.clip(batch.perm, 0, len(idx) - 1)], -1)
        buckets.append(dataclasses.replace(batch, perm=perm))
    return ZoneBatchLayout(kind="bucketed", buckets=tuple(buckets))
