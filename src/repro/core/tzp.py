"""Temporal Zone Partitioning (TZP) — Algorithm 1, with adaptive zoning.

Growth zone ``G_i = [s_i, e_i)`` with ``e_i - s_i >= 2 * L_b`` where
``L_b = delta * l_max`` (the maximum time span of one motif transition
process, including its trailing time-out window).  Consecutive growth zones
overlap by exactly ``L_b``; the overlap is the boundary zone
``B_i = [s_{i+1}, e_i)``.  Counting every zone independently and summing with
sign +1 (growth) / -1 (boundary) reproduces exact global counts
(inclusion-exclusion, Lemma 4.2).

Beyond-paper: the paper fixes ``omega`` globally; we additionally shrink a
growth zone whose edge population exceeds ``e_cap`` (down to the correctness
floor ``2 * L_b``), which bounds the padded zone batch and load imbalance on
bursty streams.  Zones are host-side metadata (data-pipeline work); the
device-side batch is built once per mining run.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .temporal_graph import TemporalGraph


@dataclasses.dataclass(frozen=True)
class ZonePlan:
    """Host-side partition table (one row per zone, time-ordered)."""

    lo: np.ndarray        # int64[Z] first edge index of the zone
    count: np.ndarray     # int64[Z] number of edges in the zone
    sign: np.ndarray      # int32[Z] +1 growth / -1 boundary
    t_start: np.ndarray   # int64[Z] zone window start (inclusive)
    t_end: np.ndarray     # int64[Z] zone window end (exclusive)
    l_b: int              # boundary length delta * l_max

    @property
    def n_zones(self) -> int:
        return int(self.lo.shape[0])

    @property
    def n_growth(self) -> int:
        return int((self.sign > 0).sum())

    @property
    def max_count(self) -> int:
        return int(self.count.max()) if self.n_zones else 0


def adaptive_zone_end(t: np.ndarray, s: int, e: int, *, e_cap: int | None,
                      l_b: int) -> int:
    """Adaptive shrink of a growth zone's end (beyond-paper, see module doc).

    If more than ``e_cap`` edges fall in ``[s, e)``, shrink ``e`` to the
    time of the ``(e_cap+1)``-th edge, floored at the correctness minimum
    ``s + 2*l_b``.  Shared by the batch planner and the streaming frontier
    so the zone geometry rule lives in exactly one place.
    """
    if e_cap is None:
        return e
    lo = int(np.searchsorted(t, s, side="left"))
    hi_target = int(np.searchsorted(t, e, side="left"))
    if hi_target - lo <= e_cap:
        return e
    e_shrunk = int(t[lo + e_cap])
    return int(np.clip(e_shrunk, s + 2 * l_b, e))


def fill_zone_row(u_row, v_row, t_row, valid_row, su, sv, st) -> None:
    """Copy one zone's edges into a padded batch row (in place).

    Padding timestamps repeat the zone max so kernel-level block skipping
    stays conservative (padding edges are masked out by ``valid``).
    """
    cnt = len(su)
    u_row[:cnt] = su
    v_row[:cnt] = sv
    t_row[:cnt] = st
    if cnt:
        t_row[cnt:] = st[-1]
    valid_row[:cnt] = True


def plan_zones(
    graph: TemporalGraph,
    *,
    delta: int,
    l_max: int,
    omega: int = 20,
    e_cap: int | None = None,
) -> ZonePlan:
    """Algorithm 1: linear scan creating interleaved growth/boundary zones."""
    if delta < 1 or l_max < 1:
        raise ValueError("delta and l_max must be >= 1")
    if omega < 2:
        raise ValueError("omega must be >= 2 (growth zone >= 2 boundary zones)")
    t = graph.t.astype(np.int64)
    n = t.shape[0]
    l_b = delta * l_max
    l_g = omega * l_b

    lo_list, cnt_list, sign_list, ts_list, te_list = [], [], [], [], []
    if n == 0:
        return ZonePlan(*[np.zeros(0, np.int64) for _ in range(2)],
                        np.zeros(0, np.int32), np.zeros(0, np.int64),
                        np.zeros(0, np.int64), l_b)

    t_max = int(t[-1])
    s = int(t[0])
    while True:
        e = s + l_g
        lo = int(np.searchsorted(t, s, side="left"))
        if e <= t_max:
            e = adaptive_zone_end(t, s, e, e_cap=e_cap, l_b=l_b)
        hi = int(np.searchsorted(t, e, side="left"))
        lo_list.append(lo)
        cnt_list.append(hi - lo)
        sign_list.append(1)
        ts_list.append(s)
        te_list.append(e)
        if e > t_max:
            break
        # boundary zone = overlap [e - l_b, e)
        b_lo = int(np.searchsorted(t, e - l_b, side="left"))
        lo_list.append(b_lo)
        cnt_list.append(hi - b_lo)
        sign_list.append(-1)
        ts_list.append(e - l_b)
        te_list.append(e)
        s = e - l_b

    return ZonePlan(
        lo=np.asarray(lo_list, np.int64),
        count=np.asarray(cnt_list, np.int64),
        sign=np.asarray(sign_list, np.int32),
        t_start=np.asarray(ts_list, np.int64),
        t_end=np.asarray(te_list, np.int64),
        l_b=l_b,
    )


def single_zone_plan(graph: TemporalGraph, *, l_b: int) -> ZonePlan:
    """One growth zone spanning the whole stream (the TMC-analog baseline).

    The degenerate partition: no boundary zones, sign +1, every edge in one
    row.  Routing the sequential baseline through this plan +
    :func:`build_zone_batch` keeps the padding/fill policy in exactly one
    place instead of a hand-rolled zero-pad block at the call site.
    """
    t = graph.t.astype(np.int64)
    n = int(t.shape[0])
    t0 = int(t[0]) if n else 0
    t_end = int(t[-1]) + 1 if n else 1
    return ZonePlan(
        lo=np.zeros(1, np.int64),
        count=np.asarray([n], np.int64),
        sign=np.ones(1, np.int32),
        t_start=np.asarray([t0], np.int64),
        t_end=np.asarray([t_end], np.int64),
        l_b=l_b,
    )


@dataclasses.dataclass(frozen=True)
class ZoneBatch:
    """Device-ready padded zone batch.

    Arrays are [Z, e_cap]; ``valid`` masks real edges.  ``perm`` records the
    size-balanced zone order (descending population round-robin across
    ``n_shards`` — static load balancing replacing the paper's work stealing).
    """

    u: np.ndarray
    v: np.ndarray
    t: np.ndarray
    valid: np.ndarray
    sign: np.ndarray      # int32[Z]
    perm: np.ndarray      # int64[Z] original zone index per row
    overflow: int         # edges dropped because a zone exceeded e_cap

    @property
    def n_zones(self) -> int:
        return int(self.u.shape[0])

    @property
    def e_cap(self) -> int:
        return int(self.u.shape[1])


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def build_zone_batch(
    graph: TemporalGraph,
    plan: ZonePlan,
    *,
    e_cap: int | None = None,
    pad_zones_to: int = 1,
    pad_edges_to: int = 8,
    n_shards: int = 1,
) -> ZoneBatch:
    """Gather zones into a padded [Z, e_cap] batch with validity masks."""
    z = plan.n_zones
    cap = e_cap or plan.max_count
    cap = max(_round_up(max(cap, 1), pad_edges_to), pad_edges_to)
    z_pad = max(_round_up(max(z, 1), pad_zones_to), pad_zones_to)

    # static load balance: biggest zones first, dealt round-robin over shards
    order = np.argsort(-plan.count, kind="stable")
    if n_shards > 1 and z:
        lanes: list[list[int]] = [[] for _ in range(n_shards)]
        for rank, zi in enumerate(order):
            lanes[rank % n_shards].append(int(zi))
        order = np.asarray([zi for lane in lanes for zi in lane], np.int64)

    u = np.zeros((z_pad, cap), np.int32)
    v = np.zeros((z_pad, cap), np.int32)
    t = np.zeros((z_pad, cap), np.int32)
    valid = np.zeros((z_pad, cap), bool)
    sign = np.zeros(z_pad, np.int32)
    perm = np.full(z_pad, -1, np.int64)
    overflow = 0
    for row, zi in enumerate(order):
        lo = int(plan.lo[zi])
        cnt = int(plan.count[zi])
        take = min(cnt, cap)
        overflow += cnt - take
        fill_zone_row(u[row], v[row], t[row], valid[row],
                      graph.u[lo:lo + take], graph.v[lo:lo + take],
                      graph.t[lo:lo + take])
        sign[row] = plan.sign[zi]
        perm[row] = zi
    return ZoneBatch(u=u, v=v, t=t, valid=valid, sign=sign, perm=perm,
                     overflow=overflow)
