"""Pure-NumPy zone-scan backend (oracle-grade, host-side).

Same semantics as :func:`repro.core.expansion.scan_zones` — candidate *i* is
the process seeded by edge slot *i*, extended by Definition 3's unique-
successor rule — but implemented as the brute-force oracle walk instead of a
dense vector sweep.  It is exact by construction (it *is* the oracle
restricted to one zone), runs anywhere without JAX tracing, and is the
cross-check the registry exposes as ``grade="oracle"``.

Intended for small inputs: O(E^2 l_max) per zone, pure Python inner loop.
The executor keeps it outside the jit boundary (``jittable=False``).
"""

from __future__ import annotations

import numpy as np

from . import encoding
from .expansion import ZoneResult


def scan_zone(u, v, t, valid, *, delta: int, l_max: int,
              with_ts: bool = False) -> ZoneResult:
    """Scan one padded zone; returns numpy (code[E, L], length[E])."""
    u = np.asarray(u)
    v = np.asarray(v)
    t = np.asarray(t)
    valid = np.asarray(valid).astype(bool)
    e = u.shape[0]
    limbs = encoding.n_limbs(l_max)
    code = np.zeros((e, limbs), np.int32)
    length = np.zeros(e, np.int32)
    ts = np.zeros((e, l_max), np.int32) if with_ts else None

    idx = np.flatnonzero(valid)
    for si, seed in enumerate(idx):
        edges = [(int(u[seed]), int(v[seed]))]
        nodes = {int(u[seed]), int(v[seed])}
        last_t = int(t[seed])
        times = [last_t]
        j = si + 1
        while len(edges) < l_max:
            extended = False
            while j < len(idx) and int(t[idx[j]]) <= last_t + delta:
                jj = int(idx[j])
                tj = int(t[jj])
                if tj > last_t and (int(u[jj]) in nodes or int(v[jj]) in nodes):
                    edges.append((int(u[jj]), int(v[jj])))
                    nodes.add(int(u[jj]))
                    nodes.add(int(v[jj]))
                    last_t = tj
                    times.append(tj)
                    extended = True
                    j += 1
                    break
                j += 1
            if not extended:
                break
        code[seed] = encoding.encode_process_np(edges, l_max)
        length[seed] = len(edges)
        if ts is not None:
            ts[seed, :len(times)] = times
    return ZoneResult(code=code, length=length, ts=ts)


def scan_zones(u, v, t, valid, *, delta: int, l_max: int,
               with_ts: bool = False) -> ZoneResult:
    """Reference-signature scan over a [Z, E] zone batch (numpy arrays)."""
    u = np.asarray(u)
    v = np.asarray(v)
    t = np.asarray(t)
    valid = np.asarray(valid)
    z, e = u.shape
    limbs = encoding.n_limbs(l_max)
    code = np.zeros((z, e, limbs), np.int32)
    length = np.zeros((z, e), np.int32)
    ts = np.zeros((z, e, l_max), np.int32) if with_ts else None
    for zi in range(z):
        res = scan_zone(u[zi], v[zi], t[zi], valid[zi],
                        delta=delta, l_max=l_max, with_ts=with_ts)
        code[zi] = res.code
        length[zi] = res.length
        if ts is not None:
            ts[zi] = res.ts
    return ZoneResult(code=code, length=length, ts=ts)
