"""Capacity planner — derive mining capacities from a device-memory budget.

Hardcoded ``zone_chunk`` hints do not transfer between graphs: the right
chunk size depends on the zone batch's edge capacity, on ``l_max`` (node
table + code limbs scale with it), and on how much device memory the
deployment actually has.  This module owns the arithmetic:

* a per-zone **memory model** of the scan (inputs + expansion state +
  outputs).  Backends can override it via ``BackendSpec.mem_model`` — the
  Pallas kernel, for example, pads the edge axis up to block multiples;
* peak-memory estimates for the **legacy** whole-batch aggregation
  (O(Z*C*L): every zone's candidate codes are materialized, flattened and
  sorted at once) and for the **hierarchical** chunked fold
  (O(zone_chunk*C*L + merge_cap*L): one chunk of scan state plus one
  bounded-width merge table, independent of Z);
* :func:`plan_capacity`, which picks the largest power-of-two
  ``zone_chunk`` (and matching ``merge_cap``) whose hierarchical peak fits
  the budget, and :func:`suggest_e_cap` for sizing the zone capacity
  itself.

Estimates are analytic, not measured — they exist to pick sane shapes and
to make the O(Z*C) -> O(zone_chunk*C) ceiling move auditable (see
EXPERIMENTS.md and ``benchmarks/bench_perf_mining.py``), not to account
for every XLA temporary.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

from . import encoding

# host->device inputs: u, v, t int32 + valid bool, per edge slot
_INPUT_BYTES_PER_EDGE = 13
# sort-based counting touches ~2 copies of the (code, count) row stream
# (operand + sorted output) before the segment-sum
_SORT_COPIES = 2


def ref_zone_bytes(e_cap: int, l_max: int) -> int:
    """Per-zone scan footprint of the vectorized reference backend.

    inputs (u, v, t, valid) + ZoneState (length, last_t, n_nodes int32;
    done bool; nodes int32[E, l_max+1]; code int32[E, L]) + ZoneResult
    (code int32[E, L], length int32[E]).
    """
    limbs = encoding.n_limbs(l_max)
    k = l_max + 1
    state = 13 + 4 * k + 4 * limbs
    out = 4 * limbs + 4
    return e_cap * (_INPUT_BYTES_PER_EDGE + state + out)


def pallas_zone_bytes(e_cap: int, l_max: int, *, c_blk: int = 512,
                      e_blk: int = 256) -> int:
    """Pallas kernel model: the edge axis pads up to the larger block."""
    blk = max(c_blk, e_blk)
    e_pad = -(-e_cap // blk) * blk
    return ref_zone_bytes(e_pad, l_max)


def count_table_bytes(rows: int, l_max: int) -> int:
    """Footprint of one sorted count table of ``rows`` (code, count) rows."""
    limbs = encoding.n_limbs(l_max)
    return _SORT_COPIES * rows * 4 * (limbs + 1)


def legacy_peak_bytes(n_zones: int, e_cap: int, l_max: int, *,
                      zone_chunk: int = 0,
                      mem_model: Callable[[int, int], int] | None = None,
                      ) -> int:
    """Peak estimate of whole-batch aggregation: O(Z*C) regardless of chunking.

    Chunking the scan (``lax.map``) bounds the *scan state* to one chunk,
    but the legacy path still materializes every zone's candidate codes
    before the single flatten-and-sort — that [Z*C, L] stream is the term
    the hierarchical fold removes.
    """
    model = mem_model or ref_zone_bytes
    limbs = encoding.n_limbs(l_max)
    chunk = min(zone_chunk, n_zones) if zone_chunk else n_zones
    scan_state = chunk * model(e_cap, l_max)
    all_codes = n_zones * e_cap * (4 * limbs + 4)
    return scan_state + all_codes + count_table_bytes(n_zones * e_cap, l_max)


def hierarchical_peak_bytes(zone_chunk: int, e_cap: int, l_max: int, *,
                            merge_cap: int,
                            mem_model: Callable[[int, int], int] | None = None,
                            ) -> int:
    """Peak estimate of the chunked fold: independent of the zone count."""
    model = mem_model or ref_zone_bytes
    scan_state = zone_chunk * model(e_cap, l_max)
    merge_rows = merge_cap + zone_chunk * e_cap
    limbs = encoding.n_limbs(l_max)
    carry = merge_cap * 4 * (limbs + 1)
    return scan_state + carry + count_table_bytes(merge_rows, l_max)


def fused_peak_bytes(n_slots: int, l_max: int, *, fold_chunk: int,
                     merge_cap: int) -> int:
    """Peak estimate of the fused single-launch path.

    The concatenated stream's resident state: six flat int32 input arrays
    (u, v, t, valid, zone_id, sign), the kernel's [S, L] code + [S] length
    outputs (HBM-resident between the scan and the fold — they never
    round-trip to host), the bounded merge carry, and one fold step's sort
    scratch (``fold_chunk + merge_cap`` rows).  Unlike the per-bucket
    hierarchical model there is no per-zone scan-state term: candidate
    state lives in registers/VMEM per grid step, not in an allocated
    [zone_chunk, E] batch.
    """
    limbs = encoding.n_limbs(l_max)
    inputs = 6 * 4 * n_slots
    outputs = n_slots * (4 * limbs + 4)
    carry = merge_cap * 4 * (limbs + 1)
    return (inputs + outputs + carry
            + count_table_bytes(fold_chunk + merge_cap, l_max))


def default_fold_chunk(n_slots: int, *, blk: int) -> int:
    """Fold-chunk default: ~4096 candidate rows per on-device fold step,
    scaled up (to at most 16384) once the stream is large enough that the
    sequential merge chain would dominate — every fold step pays an
    O(merge_cap) bounded merge regardless of chunk size, so a big stream
    folded in 4096-row steps spends more time merging than scanning.
    Rounded to a ``blk`` multiple and clamped to the (blk-aligned) stream
    so tiny layouts do not pad up to a chunk they cannot fill."""
    scaled = min(16384, n_slots // 8) // blk * blk
    target = max(blk, 4096 // blk * blk, scaled)
    slots = max(-(-max(n_slots, 1) // blk) * blk, blk)
    return min(target, slots)


@dataclasses.dataclass(frozen=True)
class FusedCapacityPlan:
    """Budget-derived capacities for the fused single-launch path."""

    fold_chunk: int
    merge_cap: int
    budget_bytes: int
    est_peak_bytes: int

    @property
    def fits(self) -> bool:
        return self.est_peak_bytes <= self.budget_bytes


def plan_fused_capacity(
    *,
    n_slots: int,
    l_max: int,
    memory_budget_mb: float,
    blk: int,
    merge_cap: int | None = None,
) -> FusedCapacityPlan:
    """Largest ``blk``-multiple ``fold_chunk`` whose fused peak fits.

    Mirrors :func:`plan_capacity` for the flat stream: the fold chunk is
    the only free memory knob (the stream itself is workload-determined),
    doubling from ``blk`` while the estimate stays under budget.
    ``merge_cap`` defaults to one fold chunk's rows, exactly like the
    per-bucket default of one zone chunk's rows.
    """
    if memory_budget_mb <= 0:
        raise ValueError("memory_budget_mb must be > 0")
    budget = int(memory_budget_mb * 2**20)
    ceiling = default_fold_chunk(n_slots, blk=blk)

    def peak(fc: int) -> int:
        cap = merge_cap if merge_cap is not None else max(1024, fc)
        return fused_peak_bytes(n_slots, l_max, fold_chunk=fc, merge_cap=cap)

    fc = blk
    while fc * 2 <= ceiling and peak(fc * 2) <= budget:
        fc *= 2
    cap = merge_cap if merge_cap is not None else max(1024, fc)
    return FusedCapacityPlan(
        fold_chunk=fc, merge_cap=cap, budget_bytes=budget,
        est_peak_bytes=peak(fc),
    )


def default_merge_cap(zone_chunk: int, e_cap: int) -> int:
    """One chunk's candidate rows: the first chunk can never spill, and the
    carry is no bigger than the partial table it merges with.  The 1024-row
    floor (~a few tens of KB) absorbs small chunks whose live-unique
    population exceeds one chunk's rows, avoiding spill-retry recompiles."""
    return max(1024, zone_chunk * e_cap)


@dataclasses.dataclass(frozen=True)
class CapacityPlan:
    """Budget-derived mining capacities (all sizes in bytes)."""

    zone_chunk: int
    merge_cap: int
    budget_bytes: int
    per_zone_bytes: int
    est_peak_bytes: int

    @property
    def fits(self) -> bool:
        return self.est_peak_bytes <= self.budget_bytes


def plan_capacity(
    *,
    n_zones: int,
    e_cap: int,
    l_max: int,
    memory_budget_mb: float,
    mem_model: Callable[[int, int], int] | None = None,
    merge_cap: int | None = None,
) -> CapacityPlan:
    """Largest power-of-two ``zone_chunk`` whose hierarchical peak fits.

    ``merge_cap`` defaults to one chunk's candidate rows and scales with
    the chosen chunk.  The floor is ``zone_chunk=1``; a plan whose
    ``fits`` is False means even one zone exceeds the budget (the caller
    should shrink ``e_cap`` — see :func:`suggest_e_cap`).
    """
    if memory_budget_mb <= 0:
        raise ValueError("memory_budget_mb must be > 0")
    n_zones = max(int(n_zones), 1)
    budget = int(memory_budget_mb * 2**20)

    def peak(zc: int) -> int:
        cap = merge_cap if merge_cap is not None else default_merge_cap(zc,
                                                                        e_cap)
        return hierarchical_peak_bytes(zc, e_cap, l_max, merge_cap=cap,
                                       mem_model=mem_model)

    zc = 1
    while zc * 2 <= n_zones and peak(zc * 2) <= budget:
        zc *= 2
    cap = merge_cap if merge_cap is not None else default_merge_cap(zc, e_cap)
    model = mem_model or ref_zone_bytes
    return CapacityPlan(
        zone_chunk=zc,
        merge_cap=cap,
        budget_bytes=budget,
        per_zone_bytes=model(e_cap, l_max),
        est_peak_bytes=peak(zc),
    )


def plan_layout_capacity(
    bucket_shapes,
    *,
    l_max: int,
    memory_budget_mb: float,
    mem_model: Callable[[int, int], int] | None = None,
    merge_cap: int | None = None,
) -> dict[tuple[int, int], CapacityPlan]:
    """Per-bucket capacity plans for a size-bucketed zone layout.

    ``bucket_shapes`` is a sequence of ``(n_zones, e_cap)`` pairs (see
    ``ZoneBatchLayout.bucket_shapes``).  Each bucket's ``zone_chunk`` and
    ``merge_cap`` are derived from its **own** edge capacity — the whole
    point of the ragged layout: a quiet bucket with e_cap=64 fits far more
    zones per chunk than the dense plan sized by the global max would
    allow, so the device stays occupied instead of sweeping padding.
    Duplicate shapes collapse to one plan.

    Introspection/benchmark helper: at runtime the same per-bucket
    derivation happens inside ``MiningExecutor.run_arrays`` via
    ``capacity_plan`` (which memoizes :func:`plan_capacity` per bucket
    geometry); this function mirrors it for offline what-if analysis
    without building batches.
    """
    return {
        shape: plan_capacity(
            n_zones=shape[0], e_cap=shape[1], l_max=l_max,
            memory_budget_mb=memory_budget_mb, mem_model=mem_model,
            merge_cap=merge_cap,
        )
        for shape in dict.fromkeys(tuple(s) for s in bucket_shapes)
    }


def layout_peak_bytes(plans: dict[tuple[int, int], CapacityPlan]) -> int:
    """Peak estimate of a bucketed run: buckets execute sequentially, so
    the layout's peak is the worst single bucket, not the sum."""
    return max((p.est_peak_bytes for p in plans.values()), default=0)


def padded_sweep_slots(bucket_shapes) -> int:
    """Padded pairwise sweep work ``sum(Z_b * e_cap_b**2)`` of a layout.

    The dense layout's cost is ``Z * e_cap_max**2``; the ratio of the two
    is the padding-waste model the zone-layout benchmark reports
    (EXPERIMENTS.md §Zone batch layout).
    """
    return sum(int(z) * int(e) ** 2 for z, e in bucket_shapes)


def fused_sweep_slots(lo, hi, blk: int) -> int:
    """Dispatched sweep work of a fused flat stream: each candidate block
    of ``blk`` lanes streams its ``[lo, hi)`` window once, so the slot-cell
    cost is ``blk * sum(hi - lo)``.

    The fused analog of :func:`padded_sweep_slots`, and the quantity
    host-planned compaction attacks: tightening ``hi`` to the Lemma-4.1
    horizon cut (``tzp.concat_layout(bounds="live")``) shrinks this model
    directly, and with it the compiled kernel's chunk traffic below.
    """
    return int(blk) * int(sum(int(h) - int(l) for l, h in zip(lo, hi)))


def fused_traffic_bytes(fl, l_max: int) -> int:
    """Traffic model of one fused launch (int32 everywhere).

    * chunk loads — each candidate block streams its ``hi - lo`` window
      once (shared across the block's lanes): 5 arrays (u/v/t/valid/zid)
      x 4 B x ``sweep_slots / blk`` slot-loads;
    * lane loads — every slot is read once as a candidate lane
      (t/valid/zid): 3 x 4 B x ``n_slots``;
    * outputs — per-lane code limbs + length: ``(limbs + 1) x 4 B x
      n_slots`` written by the kernel, read back by the on-device fold.

    ``fl`` is a :class:`repro.core.tzp.FusedZoneLayout`; the roofline
    benchmark divides this by measured wall time for achieved bytes/s.
    """
    limbs = encoding.n_limbs(l_max)
    chunk = (fl.sweep_slots // fl.blk) * 5 * 4
    lanes = fl.n_slots * 3 * 4
    out = fl.n_slots * (limbs + 1) * 4 * 2
    return chunk + lanes + out


# ---------------------------------------------------------------------------
# Config lattice — grouping N tenant configs into shared dominating sweeps.
# ---------------------------------------------------------------------------

# Fields a lattice member may vary while still sharing one Phase-1 sweep.
# ``delta``/``l_max`` shrink losslessly from the dominating sweep by prefix-
# truncating candidates on absorption timestamps; ``omega`` only shapes zone
# geometry (never counts), so planning at the max omega is exact.
_LATTICE_FREE_FIELDS = ("delta", "l_max", "omega")


@dataclasses.dataclass(frozen=True)
class ConfigLattice:
    """One co-minable group of configs plus its dominating sweep config.

    ``members`` preserve the caller's order; ``indices`` are their
    positions in the original request, so ``discover_many`` can return
    results aligned with its input.  ``dominating`` is the member-wise
    maximum over the free fields — every member's process table is a
    prefix-truncation of the dominating sweep's (see
    :func:`repro.core.expansion.derive_lengths`).
    """

    dominating: object                  # MiningConfig (duck-typed)
    members: tuple                      # tuple[MiningConfig, ...]
    indices: tuple[int, ...]

    @property
    def n_configs(self) -> int:
        return len(self.members)

    @property
    def params(self) -> tuple[tuple[int, int], ...]:
        """Per-member ``(delta, l_max)`` — the executor fold's static key."""
        return tuple((m.delta, m.l_max) for m in self.members)


def lattice_key(config) -> tuple:
    """Compatibility key: everything about a config *except* the free
    fields.  Configs with equal keys can share one dominating sweep."""
    d = config.to_dict()
    for f in _LATTICE_FREE_FIELDS:
        d.pop(f, None)
    return tuple(sorted(d.items()))


def dominating_config(configs):
    """The member-wise max config a lattice plans its shared sweep at."""
    if not configs:
        raise ValueError("dominating_config needs at least one config")
    return configs[0].with_updates(
        delta=max(c.delta for c in configs),
        l_max=max(c.l_max for c in configs),
        omega=max(c.omega for c in configs),
    )


def build_config_lattices(configs) -> list[ConfigLattice]:
    """Group configs into co-minable lattices (input order preserved).

    Configs differing only in ``delta``/``l_max``/``omega`` land in one
    lattice; anything else (backend, e_cap, zone layout, merge caps, ...)
    splits them, because those change the sweep itself rather than how its
    candidate table is folded.
    """
    groups: dict[tuple, list[int]] = {}
    for i, cfg in enumerate(configs):
        groups.setdefault(lattice_key(cfg), []).append(i)
    return [
        ConfigLattice(
            dominating=dominating_config([configs[i] for i in idxs]),
            members=tuple(configs[i] for i in idxs),
            indices=tuple(idxs),
        )
        for idxs in groups.values()
    ]


def comine_peak_bytes(zone_chunk: int, e_cap: int, l_max_dom: int, *,
                      merge_caps, mem_model=None) -> int:
    """Peak estimate of the multi-config hierarchical fold.

    One dominating-config scan chunk (plus its ``ts`` int32[E, l_max]
    timestamp table) is resident at a time, but every member keeps its own
    bounded merge carry and the fold sorts one member's table at a time —
    so the count-table term scales with the *largest* member cap while the
    carry term sums over members.
    """
    model = mem_model or ref_zone_bytes
    scan_state = zone_chunk * (model(e_cap, l_max_dom) + 4 * l_max_dom * e_cap)
    limbs = encoding.n_limbs(l_max_dom)
    carry = sum(cap * 4 * (limbs + 1) for cap in merge_caps)
    worst = max(merge_caps, default=0)
    return scan_state + carry + count_table_bytes(
        worst + zone_chunk * e_cap, l_max_dom)


def suggest_e_cap(
    *,
    l_max: int,
    memory_budget_mb: float,
    zone_chunk: int = 1,
    mem_model: Callable[[int, int], int] | None = None,
    pad_edges_to: int = 8,
) -> int:
    """Largest power-of-two zone edge capacity that fits the budget with
    ``zone_chunk`` zones in flight (the planner's answer to "how dense a
    zone can this device even hold?")."""
    if memory_budget_mb <= 0:
        raise ValueError("memory_budget_mb must be > 0")
    budget = int(memory_budget_mb * 2**20)
    e = pad_edges_to
    while hierarchical_peak_bytes(
            zone_chunk, e * 2, l_max,
            merge_cap=default_merge_cap(zone_chunk, e * 2),
            mem_model=mem_model) <= budget:
        e *= 2
        if e >= 1 << 24:        # 16M edges per zone: beyond any real batch
            break
    return e
