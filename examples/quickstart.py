"""Quickstart: discover motif transition processes in a temporal graph.

    PYTHONPATH=src python examples/quickstart.py

Builds a small synthetic interaction stream, runs PTMT (zone-partitioned
parallel discovery) through the session engine, validates against the
sequential TMC-analog baseline, and prints the motif transition tree
(paper Fig. 6).
"""

from repro.core import MiningConfig, PTMTEngine
from repro.data.synthetic_graphs import triadic_stream

# a triadic-closure-heavy interaction stream (paper's WikiTalk case study)
graph = triadic_stream(5_000, 150, window=240, p_close=0.5, seed=7)
print(f"graph: {graph.n_edges} edges / {graph.n_nodes} nodes / "
      f"{graph.time_span}s span")

# --- PTMT: one validated config, one engine owning warm compile state ------
config = MiningConfig(delta=120, l_max=4, omega=8)
engine = PTMTEngine(config)
result = engine.discover(graph)
print(f"\nPTMT: {result.n_zones} zones, {len(result.counts)} motif types, "
      f"{result.total_processes()} processes (overflow={result.overflow})")

# a second same-shaped run dispatches straight to the cached executables
# (one per bucket shape) and skips host-side planning via the plan cache
engine.discover(graph)
print(f"engine reuse: {engine.stats.compile_cache_hits} warm bucket "
      f"dispatch(es), {engine.stats.compile_cache_misses} compile(s), "
      f"{engine.stats.plan_cache_hits} zone-plan cache hit(s)")

# --- zone-batch layout: how the device batch was actually shaped -----------
lay = result.layout
print(f"zone layout: {lay['kind']}, {len(lay['buckets'])} bucket(s), "
      f"padding_ratio={lay['padding_ratio']:.1%}")
for b in lay["buckets"]:
    print(f"  {b['label']}: {b['real_zones']} zones x cap {b['e_cap']} "
          f"({b['occupancy']:.1%} occupied)")

# --- exactness: matches the unpartitioned sequential baseline --------------
seq = engine.sequential(graph)
assert seq.counts == result.counts, "partitioned counts must be exact!"
print("exactness check vs sequential baseline: PASS")

# --- the motif transition tree (paper Fig. 6 / Table 6) --------------------
tree = result.tree()
print("\nmotif transition tree:")
for code, count, share in sorted(tree.root.transition_rows(),
                                 key=lambda r: -r[1])[:4]:
    print(f"  {code}: {count} processes ({share:.1%})")
    for c2, n2, s2 in sorted(tree.node(code).transition_rows(),
                             key=lambda r: -r[1])[:3]:
        label = {"010121": "triangle", "010102": "chain",
                 "010101": "reciprocal"}.get(c2, "")
        print(f"    -> {c2}: {n2} ({s2:.1%}) {label}")

hist = result.level_histogram()
print("\nprocesses by final length:", dict(sorted(hist.items())))
