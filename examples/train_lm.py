"""End-to-end driver: train a ~100M-param granite-style LM for a few hundred
steps on synthetic data with the full production substrate (AdamW + cosine
schedule, grad clipping, fault-tolerant checkpointing, crash resume).

    PYTHONPATH=src python examples/train_lm.py --steps 300

This is the small-scale twin of the dry-run's granite-8b/train_4k cell: the
identical step function lowers onto the 256/512-chip meshes.
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.data import lm_pipeline
from repro.models import params as prm, transformer
from repro.training import optimizer, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M params: granite family scaled to laptop size
    cfg = dataclasses.replace(
        get_arch("granite-8b").config,
        name="granite-100m", n_layers=6, d_model=512, n_heads=8,
        n_kv_heads=4, d_head=64, d_ff=1536, vocab=8192,
        dtype=jnp.float32, remat="none", q_chunk=128,
    )
    print(f"{cfg.name}: "
          f"{prm.count_params(transformer.param_specs(cfg))/1e6:.1f}M params")

    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = optimizer.init_state(params)
    opt_cfg = optimizer.AdamWConfig(
        lr=1e-3, warmup_steps=20, total_steps=args.steps)

    @jax.jit
    def step_fn(p, o, batch):
        loss, grads = jax.value_and_grad(transformer.loss_fn)(
            p, batch, cfg, None)
        p2, o2, m = optimizer.apply_updates(opt_cfg, p, grads, o)
        m["loss"] = loss
        return p2, o2, m

    def batches():
        for tokens, targets in lm_pipeline.batches(
                0, batch=args.batch, seq_len=args.seq_len, vocab=cfg.vocab):
            yield {"tokens": jnp.asarray(tokens),
                   "targets": jnp.asarray(targets)}

    loop_cfg = train_loop.TrainLoopConfig(
        total_steps=args.steps, ckpt_dir=args.ckpt_dir, ckpt_every=100)
    _, _, history = train_loop.run(
        step_fn=step_fn, params=params, opt_state=opt_state,
        batches=batches(), loop_cfg=loop_cfg)

    losses = [h["loss"] for h in history]
    print(f"steps {history[0]['step']}..{history[-1]['step']}: "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "training must make progress"


if __name__ == "__main__":
    main()
