"""PTMT x GNN integration: motif-transition features for node classification.

    PYTHONPATH=src python examples/motif_features.py

Mines motif-transition processes from a temporal interaction stream, builds
per-node participation histograms over the top transition types, and trains
the assigned `gin-tu` GNN with and without the motif features — the paper's
"motif statistics as structural signal" use case, end to end on CPU.
"""

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import MiningConfig, PTMTEngine, oracle
from repro.core.encoding import decode_code_np
from repro.data.synthetic_graphs import triadic_stream
from repro.models import gnn
from repro.models.params import tree_init
from repro.training import optimizer

# --- 1. mine motif transition processes ------------------------------------
graph = triadic_stream(4_000, 120, window=200, p_close=0.55, seed=3)
engine = PTMTEngine(MiningConfig(delta=100, l_max=3, omega=6))
res = engine.discover(graph)
top_codes = [c for c, _ in sorted(res.counts.items(),
                                  key=lambda kv: -kv[1])[:8]]
print(f"mined {len(res.counts)} motif types; top: {top_codes[:4]}")

# --- 2. per-node participation histogram over top transition types ---------
procs = oracle.enumerate_processes(graph.u, graph.v, graph.t, 100, 3)
feat = np.zeros((graph.n_nodes, len(top_codes) + 1), np.float32)
code_idx = {c: i for i, c in enumerate(top_codes)}
for edges in procs:
    from repro.core.encoding import encode_process_np

    code = decode_code_np(encode_process_np(
        [(int(graph.u[e]), int(graph.v[e])) for e in edges], 3))
    idx = code_idx.get(code)
    nodes = {int(graph.u[e]) for e in edges} | {
        int(graph.v[e]) for e in edges}
    for n in nodes:
        if idx is not None:
            feat[n, idx] += 1
        feat[n, -1] += 1
feat = np.log1p(feat)

# --- 3. node-classification task: predict high-triadic-activity nodes ------
deg = np.zeros(graph.n_nodes)
np.add.at(deg, graph.u, 1)
np.add.at(deg, graph.v, 1)
labels = (feat[:, 0] > np.median(feat[:, 0])).astype(np.int32)

src = np.asarray(graph.u)
dst = np.asarray(graph.v)


def batch(with_motifs: bool):
    base = deg[:, None].astype(np.float32)
    x = np.concatenate([base, feat], 1) if with_motifs else base
    return {
        "node_feat": jnp.asarray(x),
        "edge_src": jnp.asarray(src, jnp.int32),
        "edge_dst": jnp.asarray(dst, jnp.int32),
        "node_mask": jnp.ones(graph.n_nodes, bool),
        "edge_mask": jnp.ones(len(src), bool),
        "labels": jnp.asarray(labels),
    }


def train(with_motifs: bool, steps: int = 60) -> float:
    g = batch(with_motifs)
    cfg = dataclasses.replace(
        get_arch("gin-tu").smoke_config,
        d_in=g["node_feat"].shape[1], n_classes=2)
    params = tree_init(jax.random.PRNGKey(0), gnn.gnn_param_specs(cfg))
    state = optimizer.init_state(params)
    opt_cfg = optimizer.AdamWConfig(lr=5e-3, warmup_steps=1,
                                    weight_decay=0.0)

    @jax.jit
    def step(p, o):
        l, grads = jax.value_and_grad(gnn.loss_fn)(p, g, cfg, None)
        p2, o2, _ = optimizer.apply_updates(opt_cfg, p, grads, o)
        return p2, o2, l

    for _ in range(steps):
        params, state, loss = step(params, state)
    logits = gnn.forward(params, g, cfg)
    acc = float((jnp.argmax(logits, -1) == g["labels"]).mean())
    print(f"  {'with' if with_motifs else 'without'} motif features: "
          f"loss={float(loss):.3f} acc={acc:.3f}")
    return acc


print("\ntraining gin-tu node classifier:")
acc_plain = train(False)
acc_motif = train(True)
print(f"\nmotif features improve accuracy: {acc_plain:.3f} -> "
      f"{acc_motif:.3f}")
