"""Serve a small LM with batched requests through the serving engine.

    PYTHONPATH=src python examples/serve_lm.py

Trains a tiny model briefly (so generations aren't pure noise), then runs a
mixed batch of prompts through the slot-pooled engine (the decode step is
the same ``serve_step`` the decode_32k/long_500k dry-run cells lower at
512-chip scale).
"""

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import transformer
from repro.serving.engine import Request, ServingEngine
from repro.training import optimizer

cfg = dataclasses.replace(get_arch("gemma3-1b").smoke_config,
                          name="gemma3-tiny")
params = transformer.init_params(jax.random.PRNGKey(0), cfg)

# teach it a repeating pattern so greedy decode is predictable-ish
tokens = jnp.tile(jnp.arange(8, dtype=jnp.int32), (4, 8))
batch = {"tokens": tokens, "targets": jnp.roll(tokens, -1, 1)}
opt_cfg = optimizer.AdamWConfig(lr=5e-3, warmup_steps=1)
state = optimizer.init_state(params)


@jax.jit
def step(p, o):
    loss, g = jax.value_and_grad(transformer.loss_fn)(p, batch, cfg, None)
    p2, o2, _ = optimizer.apply_updates(opt_cfg, p, g, o)
    return p2, o2, loss


for i in range(60):
    params, state, loss = step(params, state)
print(f"warmup train loss: {float(loss):.3f}")

engine = ServingEngine(cfg, params, slots=2, max_len=96)
requests = [
    Request(prompt=[0, 1, 2, 3], max_new_tokens=8),
    Request(prompt=[4, 5, 6], max_new_tokens=8),
    Request(prompt=[2, 3, 4, 5, 6], max_new_tokens=6),
]
done = engine.run(requests)
for i, r in enumerate(done):
    print(f"request {i}: prompt={r.prompt} -> generated={r.out}")
    assert r.done and len(r.out) == r.max_new_tokens

# the learned pattern is k -> k+1 (mod 8); check at least the first request
expected_next = (requests[0].prompt[-1] + 1) % 8
print(f"expected continuation of {requests[0].prompt}: {expected_next}, "
      f"got {done[0].out[0]}")
